"""End-to-end behaviour tests for the paper's system.

The headline claims, at CPU scale:
  1. adding experts at fixed ops/timestep improves the synthetic-LM loss
     (Figure 2-left / §5.1);
  2. the §4 balancing losses keep expert utilization flat (Table 6);
  3. the full train -> checkpoint -> serve loop works end to end.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import param as pm
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, DataIterator
from repro.models import lm
from repro.models.paper_lm import PaperLMConfig, paper_lm_defs, paper_lm_loss
from repro.optim import optimizers as opt_lib
from repro.serve.engine import ServeConfig, ServeEngine
from repro.train.trainer import Trainer, TrainLoopConfig


def _train_paper(variant_kwargs, steps, dc, workdir, seed=0, d_model=32,
                 expert_hidden=64):
    cfg = PaperLMConfig(vocab_size=dc.vocab_size, d_model=d_model,
                        expert_hidden=expert_hidden, dropout=0.0,
                        capacity_factor=2.0, **variant_kwargs)
    params = pm.materialize(paper_lm_defs(cfg), jax.random.PRNGKey(seed))
    t = Trainer(loss_fn=lambda p, b, r: paper_lm_loss(p, b, cfg, rng=r),
                params=params,
                oc=opt_lib.OptConfig(learning_rate=3e-2, warmup_steps=30),
                loop=TrainLoopConfig(total_steps=steps, checkpoint_every=50,
                                     log_every=steps),
                data_iter=DataIterator(dc), workdir=workdir)
    return t.run()


@pytest.mark.slow
def test_capacity_scaling_moe_beats_matched_dense(tmp_path):
    """Figure 2-left analog: MoE-8 (k=2, same active compute as MoE-2)
    reaches lower xent on a task with more sub-languages than the small
    model can memorize — capacity, not compute, is the limiter."""
    dc = DataConfig(vocab_size=32, seq_len=16, batch_size=64,
                    n_clusters=64, noise_prob=0.01, seed=5)
    # 1500 steps: the capacity separation only emerges once both models
    # pass the shared-structure learning phase (at 500 steps the bigger
    # gate is still paying its balance-loss tax and loses).
    dense = _train_paper(dict(variant="moe", n_experts=2, k=2), 1500, dc,
                         str(tmp_path / "dense"), d_model=16,
                         expert_hidden=16)
    moe = _train_paper(dict(variant="moe", n_experts=8, k=2), 1500, dc,
                       str(tmp_path / "moe8"), d_model=16,
                       expert_hidden=16)
    assert moe["xent"] < dense["xent"], (moe["xent"], dense["xent"])


@pytest.mark.slow
def test_balance_metrics_stay_flat_during_training(tmp_path):
    dc = DataConfig(vocab_size=64, seq_len=16, batch_size=16, n_clusters=8)
    m = _train_paper(dict(variant="moe", n_experts=8, k=2,
                          w_importance=0.1, w_load=0.1), 100, dc,
                     str(tmp_path / "bal"))
    assert m["max_over_mean_load"] < 2.5
    assert m["cv_load"] < 0.6


def test_transformer_moe_lm_trains(tmp_path):
    """The modern-arch path: a tiny kimi-style MoE transformer learns."""
    cfg = get_config("kimi-k2-1t-a32b").replace(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        vocab_size=64, n_experts=4, moe_k=2, moe_d_ff=32,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        q_block=16, kv_block=16, capacity_factor=2.0)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=64, seq_len=32, batch_size=8, n_clusters=4)
    t = Trainer(
        loss_fn=lambda p, b, r: lm.lm_loss(p, b, cfg, rng=r),
        params=params,
        oc=opt_lib.OptConfig(learning_rate=1e-2, warmup_steps=20),
        loop=TrainLoopConfig(total_steps=60, checkpoint_every=30,
                             log_every=60),
        data_iter=DataIterator(dc), workdir=str(tmp_path / "tmoe"))
    m = t.run()
    assert m["xent"] < np.log(64) * 0.9, m   # learned something


def test_serve_engine_generates():
    cfg = get_config("smollm-135m").replace(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
        vocab_size=64, d_ff=64, param_dtype=jnp.float32,
        compute_dtype=jnp.float32, q_block=16, kv_block=16)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, ServeConfig(max_len=64, temperature=0.0))
    prompts = np.random.RandomState(0).randint(1, 64, (4, 16))
    out = eng.generate(prompts, max_new_tokens=8)
    assert out.shape == (4, 8)
    assert ((out >= 0) & (out < 64)).all()
    # greedy decoding is deterministic
    out2 = eng.generate(prompts, max_new_tokens=8)
    np.testing.assert_array_equal(out, out2)
