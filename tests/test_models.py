"""Model-component tests: flash attention, sliding windows, mamba scan
vs sequential recurrence, LSTM, paper LM variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import param as pm
from repro.models import attention, layers, lstm as lstm_lib, ssm
from repro.models.attention import blockwise_attention, flash_attention
from repro.models.paper_lm import (PaperLMConfig, paper_lm_defs,
                                   paper_lm_loss)


def _naive_attn(q, k, v, causal=True, window=0):
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qr = jnp.moveaxis(q.reshape(b, sq, kv, g, hd), 1, 3)
    s = jnp.einsum("bkgqh,bskh->bkgqs", qr, k) / (hd ** 0.5)
    pos = jnp.arange(sq)
    m = jnp.ones((sq, sq), bool)
    if causal:
        m &= pos[None, :] <= pos[:, None]
    if window:
        m &= pos[None, :] > pos[:, None] - window
    p = jax.nn.softmax(jnp.where(m, s, -1e30), axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", p, v)
    return jnp.moveaxis(o, 3, 1).reshape(b, sq, h, hd)


@pytest.mark.parametrize("window", [0, 32])
def test_blockwise_attention_matches_naive(window):
    b, s, h, kv, hd = 2, 128, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd))
    got = blockwise_attention(q, k, v, causal=True, window=window,
                              q_block=32, kv_block=32)
    want = _naive_attn(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=2e-5)


def test_flash_gradients_match_naive():
    b, s, kv, g, hd = 1, 64, 2, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (b, kv, g, s, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, kv, hd, s))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, kv, s, hd))

    def naive(qr, kr, vr):
        sc = jnp.einsum("bkgqh,bkhs->bkgqs", qr, kr) / (hd ** 0.5)
        pos = jnp.arange(s)
        sc = jnp.where(pos[None, :] <= pos[:, None], sc, -1e30)
        p = jax.nn.softmax(sc, -1)
        return jnp.einsum("bkgqs,bksh->bkgqh", p, vr)

    f = lambda *a: jnp.sum(jnp.tanh(flash_attention(*a, True, 0, 16, 16)))
    gref = lambda *a: jnp.sum(jnp.tanh(naive(*a)))
    g1 = jax.grad(f, (0, 1, 2))(q, k, v)
    g2 = jax.grad(gref, (0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=3e-3, atol=3e-5)


def test_decode_matches_prefill_attention():
    """Ring-buffer sliding-window decode == full recompute."""
    d, h, kv, hd, w = 32, 4, 2, 8, 16
    defs = attention.attention_defs(d, h, kv, hd, qk_norm=False,
                                    dtype=jnp.float32)
    params = pm.materialize(defs, jax.random.PRNGKey(0))
    b, s = 2, 48
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full = attention.attention(params, x, positions, rope_theta=1e4,
                               qk_norm=False, window=w, q_block=16,
                               kv_block=16)
    cache = pm.materialize(
        attention.init_cache_defs(b, s, kv, hd, window=w,
                                  dtype=jnp.float32),
        jax.random.PRNGKey(2))
    outs = []
    for i in range(s):
        y, cache = attention.decode_attention(
            params, x[:, i:i + 1], cache, jnp.int32(i), rope_theta=1e4,
            qk_norm=False, window=w)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


def test_mamba_scan_matches_sequential():
    """Chunked associative scan == step-by-step recurrence (train/decode
    equivalence is THE correctness property of the SSM)."""
    d, n = 16, 4
    defs = ssm.mamba_defs(d, d_state=n, d_conv=4, expand=2,
                          dtype=jnp.float32)
    params = pm.materialize(defs, jax.random.PRNGKey(0))
    b, s = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    y_scan = ssm.mamba(params, x, d_state=n, chunk=8)
    state = pm.materialize(ssm.init_state_defs(b, d, d_state=n, d_conv=4,
                                               expand=2, dtype=jnp.float32),
                           jax.random.PRNGKey(2))
    ys = []
    for i in range(s):
        y, state = ssm.mamba_decode(params, x[:, i:i + 1], state, d_state=n)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-4)


def test_mamba_prefill_state_handoff():
    d, n = 16, 4
    defs = ssm.mamba_defs(d, d_state=n, d_conv=4, expand=2,
                          dtype=jnp.float32)
    params = pm.materialize(defs, jax.random.PRNGKey(0))
    b, s = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s + 1, d)) * 0.5
    _, st = ssm.mamba(params, x[:, :s], d_state=n, chunk=8,
                      return_state=True)
    y_next, _ = ssm.mamba_decode(params, x[:, s:s + 1], st, d_state=n)
    y_all = ssm.mamba(params, x, d_state=n, chunk=5 * 5)
    np.testing.assert_allclose(np.asarray(y_next), np.asarray(
        y_all[:, s:s + 1]), rtol=2e-3, atol=2e-4)


def test_lstm_shapes_and_state():
    defs = lstm_lib.lstm_defs(8, 16, d_proj=8, dtype=jnp.float32)
    params = pm.materialize(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 10, 8))
    y, (h, c) = lstm_lib.lstm(params, x)
    assert y.shape == (3, 10, 8) and h.shape == (3, 8) and c.shape == (3, 16)
    # feeding in two halves equals one pass
    y1, st = lstm_lib.lstm(params, x[:, :5])
    y2, _ = lstm_lib.lstm(params, x[:, 5:], st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("variant", ["moe", "moe_1_wide", "moe_1_deep",
                                     "lstm_4x", "lstm_2048_512"])
def test_paper_lm_variants(variant):
    cfg = PaperLMConfig(vocab_size=64, variant=variant, d_model=16,
                        n_experts=4, k=2, expert_hidden=32, dropout=0.1)
    params = pm.materialize(paper_lm_defs(cfg), jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 8), jnp.int32),
             "labels": jnp.ones((2, 8), jnp.int32)}
    loss, m = paper_lm_loss(params, batch, cfg, rng=jax.random.PRNGKey(1))
    assert np.isfinite(float(loss))


def test_paper_lm_hierarchical():
    cfg = PaperLMConfig(vocab_size=64, variant="moe", d_model=16,
                        n_experts=16, hierarchical=(4, 4), expert_hidden=32,
                        dropout=0.0)
    params = pm.materialize(paper_lm_defs(cfg), jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 8), jnp.int32),
             "labels": jnp.ones((2, 8), jnp.int32)}
    loss, _ = paper_lm_loss(params, batch, cfg, rng=jax.random.PRNGKey(1))
    assert np.isfinite(float(loss))


def test_pad_attn_heads_numerically_identical():
    """§Perf iteration 3: padded-group attention (56->64-style) must be
    numerically identical to the unpadded computation."""
    defs = attention.attention_defs(32, 7, 7, 8, qk_norm=False,
                                    dtype=jnp.float32)
    params = pm.materialize(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    y0 = attention.attention(params, x, pos, rope_theta=1e4, qk_norm=False,
                             q_block=32, kv_block=32)
    y1 = attention.attention(params, x, pos, rope_theta=1e4, qk_norm=False,
                             q_block=32, kv_block=32, pad_heads=16)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=2e-5,
                               atol=2e-6)
    # grads flow only through real heads
    f = lambda p: jnp.sum(attention.attention(
        p, x, pos, rope_theta=1e4, qk_norm=False, q_block=32, kv_block=32,
        pad_heads=16) ** 2)
    g = jax.grad(f)(params)
    assert np.isfinite(np.asarray(g["wq"])).all()
