"""The kernel backend subsystem: registry resolution, MeshContext-aware
per-shard block specs, and ref-vs-pallas backend equivalence — forward,
one full training step of the small MoE LM, and the 8-device fake-mesh
variants (subprocess, test_distributed-style)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import param as pm
from repro.core.moe import MoEArgs, moe_apply, moe_defs
from repro.data.pipeline import DataConfig, batch_at
from repro.kernels import backend as bk_lib
from repro.models.paper_lm import PaperLMConfig, paper_lm_defs, paper_lm_loss
from repro.optim import optimizers as opt_lib
from repro.sharding import context as ctx_lib
from repro.train.trainer import make_train_step

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# registry + explicit resolution (the silent-fallback fix)
# ---------------------------------------------------------------------------

def test_registry_has_both_backends():
    assert {"ref", "pallas"} <= set(bk_lib.available())
    assert bk_lib.get("ref").topk_impl is None
    assert bk_lib.get("pallas").topk_impl is not None


def test_unknown_backend_raises_listing_available():
    with pytest.raises(bk_lib.KernelBackendError, match="nope"):
        bk_lib.get("nope")
    with pytest.raises(bk_lib.KernelBackendError, match="pallas"):
        # error message names what IS registered
        bk_lib.get("nope")


def test_broken_backend_reraises_import_error():
    err = ImportError("no pallas on this host")
    bk_lib.register_broken("broken_for_test", err)
    try:
        with pytest.raises(bk_lib.KernelBackendError,
                           match="failed to import"):
            bk_lib.get("broken_for_test")
    finally:
        del bk_lib._REGISTRY["broken_for_test"]


def test_resolve_explicit_and_legacy():
    a = MoEArgs(n_experts=4, k=2, d_model=8, d_ff=16,
                kernel_backend="pallas")
    assert bk_lib.resolve(a).name == "pallas"
    # legacy expert_impl spelling still routes
    a = MoEArgs(n_experts=4, k=2, d_model=8, d_ff=16, expert_impl="pallas")
    assert bk_lib.resolve(a).name == "pallas"
    a = MoEArgs(n_experts=4, k=2, d_model=8, d_ff=16)
    assert bk_lib.resolve(a).name == "ref"


def test_moe_apply_raises_not_degrades_on_bad_backend():
    """The old lazy `from repro.kernels import ops` degraded to the slow
    path with no signal; backend resolution must raise instead."""
    a = MoEArgs(n_experts=4, k=2, d_model=8, d_ff=16, dtype=jnp.float32,
                kernel_backend="does_not_exist")
    params = pm.materialize(moe_defs(a), jax.random.PRNGKey(0))
    x = jnp.ones((16, 8))
    with pytest.raises(bk_lib.KernelBackendError):
        moe_apply(params, x, a, train=False)


def test_trainer_validates_backend_at_construction(tmp_path):
    from repro.data.pipeline import DataIterator
    from repro.train.trainer import Trainer, TrainLoopConfig
    cfg = PaperLMConfig(vocab_size=64, variant="moe", n_experts=4, k=2,
                        d_model=16, expert_hidden=32, dropout=0.0)
    params = pm.materialize(paper_lm_defs(cfg), jax.random.PRNGKey(0))
    kw = dict(
        loss_fn=lambda p, b, r: paper_lm_loss(p, b, cfg, rng=r),
        params=params, oc=opt_lib.OptConfig(),
        loop=TrainLoopConfig(total_steps=1),
        data_iter=DataIterator(DataConfig(vocab_size=64, seq_len=8,
                                          batch_size=4, n_clusters=2)),
        workdir=str(tmp_path))
    with pytest.raises(bk_lib.KernelBackendError):
        Trainer(**kw, kernel_backend="not_a_backend")
    t = Trainer(**kw, kernel_backend="pallas")      # fail-fast path passes
    assert t.kernel_backend == "pallas"


# ---------------------------------------------------------------------------
# MeshContext consumption: per-shard shapes and block specs
# ---------------------------------------------------------------------------

class _FakeMesh:
    """Mesh stand-in: shard_shape/block_plan only read axis names+sizes,
    so an 8-device topology can be faked in the 1-device test process."""
    axis_names = ("data", "model")
    shape = {"data": 2, "model": 4}


def _fake_ctx(manual=True):
    from repro.sharding import partition
    ctx = ctx_lib.MeshContext(mesh=_FakeMesh(),
                              rules=partition.PLANS["dp_tp_ep"])
    return ctx.manual("data", "model") if manual else ctx


def test_shard_shape_divides_by_manual_axes_only():
    ctx = _fake_ctx(manual=True)
    # experts -> model (size 4) is manual: E=8 -> 2 local
    assert bk_lib.shard_shape(ctx, (8, 64, 16),
                              ("experts", "expert_capacity", "embed")) \
        == (2, 64, 16)
    # Auto-mode context (no manual axes): kernels see global shapes
    assert bk_lib.shard_shape(_fake_ctx(manual=False), (8, 64, 16),
                              ("experts", "expert_capacity", "embed")) \
        == (8, 64, 16)
    # non-divisible dims replicate (partition.py fallback semantics)
    assert bk_lib.shard_shape(ctx, (6,), ("experts",)) == (6,)
    # off-mesh: identity
    assert bk_lib.shard_shape(None, (8, 64), ("experts", "embed")) \
        == (8, 64)


def test_block_plan_is_per_shard():
    a = MoEArgs(n_experts=8, k=2, d_model=16, d_ff=100, dtype=jnp.float32)
    ctx = _fake_ctx(manual=True)
    bp = bk_lib.block_plan(a, capacity=72, ctx=ctx)
    assert bp.e == 2                      # 8 experts / model=4
    assert bp.c % bp.bm == 0 and bp.c >= 72      # ragged capacity padded
    assert bp.n % bp.bn == 0 and bp.n >= 100     # ragged d_ff padded
    # off-mesh plan covers the global shape
    assert bk_lib.block_plan(a, capacity=72, ctx=None).e == 8


def test_pallas_expert_ffn_rejects_mismatched_shard():
    a = MoEArgs(n_experts=8, k=2, d_model=16, d_ff=32, dtype=jnp.float32)
    ctx = _fake_ctx(manual=True)          # expects E_local == 2
    x = jnp.ones((3, 8, 16))              # 3 % 2 != 0: not a shard view
    params = {"w1": jnp.ones((3, 16, 32)), "w2": jnp.ones((3, 32, 16))}
    with pytest.raises(bk_lib.KernelBackendError, match="per-shard"):
        bk_lib.get("pallas").expert_ffn(params, x, a, ctx=ctx)


# ---------------------------------------------------------------------------
# backend equivalence: forward + one full training step (1 device)
# ---------------------------------------------------------------------------

MOE_KW = dict(n_experts=8, k=2, d_model=16, d_ff=36, dtype=jnp.float32,
              capacity_factor=2.0)


@pytest.mark.parametrize("train", [False, True])
def test_moe_forward_equivalence(train):
    params = pm.materialize(moe_defs(MoEArgs(**MOE_KW)),
                            jax.random.PRNGKey(0))
    params["gate"]["wg"] = 0.5 * jax.random.normal(jax.random.PRNGKey(7),
                                                   (16, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (100, 16))
    rng = jax.random.PRNGKey(2)
    y_ref, aux_ref = moe_apply(params, x, MoEArgs(**MOE_KW,
                                                  kernel_backend="ref"),
                               train=train, rng=rng)
    y_pal, aux_pal = moe_apply(params, x, MoEArgs(**MOE_KW,
                                                  kernel_backend="pallas"),
                               train=train, rng=rng)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_pal["aux_loss"]),
                               float(aux_ref["aux_loss"]), rtol=1e-4)


def _one_train_step(backend: str, ctx=None, steps: int = 1):
    cfg = PaperLMConfig(vocab_size=64, variant="moe", n_experts=4, k=2,
                        d_model=16, expert_hidden=24,     # ragged d_ff
                        dropout=0.0, kernel_backend=backend)
    params = pm.materialize(paper_lm_defs(cfg), jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=64, seq_len=16, batch_size=8, n_clusters=4)
    oc = opt_lib.OptConfig(learning_rate=1e-2, warmup_steps=1)
    step = make_train_step(
        lambda p, b, r: paper_lm_loss(p, b, cfg, rng=r, ctx=ctx), oc)
    state = {"params": params, "opt": opt_lib.init(params, oc)}
    rng = jax.random.PRNGKey(3)
    metrics = None
    for i in range(steps):
        state, metrics = jax.jit(step)(state, batch_at(dc, i),
                                       jax.random.fold_in(rng, i))
    return state, metrics


def test_train_step_equivalence_1device():
    """One full training step of the small MoE LM: pallas and ref backends
    produce allclose losses and parameter updates."""
    st_ref, m_ref = _one_train_step("ref")
    st_pal, m_pal = _one_train_step("pallas")
    np.testing.assert_allclose(float(m_pal["loss"]), float(m_ref["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_flatten(st_pal["params"])[0],
                    jax.tree_util.tree_flatten(st_ref["params"])[0]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_train_step_equivalence_scan_remat_stack():
    """One training step through the *transformer* stack (lax.scan + remat
    — a different AD path than the paper LM) on both backends.

    Regression: the topk kernel's custom_vjp must not expose integer
    outputs; under scan+remat jax linearizes through it and instantiates
    float0 cotangents for int dtypes, which crashed the dispatch plan's
    integer argsort ("Called mul with a float0")."""
    from repro.configs.base import get_config
    from repro.models import lm

    base = get_config("kimi-k2-1t-a32b").replace(
        n_layers=2, d_model=32, vocab_size=64, n_heads=4, n_kv_heads=2,
        head_dim=8, d_ff=48, n_experts=4, moe_k=2, moe_d_ff=24,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        q_block=16, kv_block=16)
    dc = DataConfig(vocab_size=64, seq_len=16, batch_size=4, n_clusters=4)
    oc = opt_lib.OptConfig(learning_rate=1e-2, warmup_steps=1)

    def one_step(backend):
        cfg = base.replace(kernel_backend=backend)
        params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
        step = make_train_step(
            lambda p, b, r: lm.lm_loss(p, b, cfg, rng=r), oc)
        state = {"params": params, "opt": opt_lib.init(params, oc)}
        return jax.jit(step)(state, batch_at(dc, 0), jax.random.PRNGKey(3))

    st_ref, m_ref = one_step("ref")
    st_pal, m_pal = one_step("pallas")
    np.testing.assert_allclose(float(m_pal["loss"]), float(m_ref["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_flatten(st_pal["params"])[0],
                    jax.tree_util.tree_flatten(st_ref["params"])[0]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# hierarchical MoE through the registry (ROADMAP open item: no more direct
# jnp path) — ref vs pallas parity, forward and gradients
# ---------------------------------------------------------------------------

HMOE_KW = dict(n_groups=4, n_experts_per_group=4, k_primary=2,
               k_secondary=2, d_model=16, d_ff=32, dtype=jnp.float32,
               capacity_factor=4.0)


def _hmoe_setup():
    from repro.core.hierarchical import HMoEArgs, hmoe_defs
    params = pm.materialize(hmoe_defs(HMoEArgs(**HMOE_KW)),
                            jax.random.PRNGKey(0))
    params["gate_primary"]["wg"] = 0.5 * jax.random.normal(
        jax.random.PRNGKey(7), (16, 4))
    params["gate_secondary"]["wg"] = 0.5 * jax.random.normal(
        jax.random.PRNGKey(8), (4, 16, 4))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    return params, x


@pytest.mark.parametrize("train", [False, True])
def test_hmoe_backend_parity(train):
    from repro.core.hierarchical import HMoEArgs, hmoe_apply
    params, x = _hmoe_setup()
    rng = jax.random.PRNGKey(2)
    y_ref, aux_ref = hmoe_apply(params, x,
                                HMoEArgs(**HMOE_KW, kernel_backend="ref"),
                                train=train, rng=rng)
    y_pal, aux_pal = hmoe_apply(params, x,
                                HMoEArgs(**HMOE_KW,
                                         kernel_backend="pallas"),
                                train=train, rng=rng)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_pal["aux_loss"]),
                               float(aux_ref["aux_loss"]), rtol=1e-4)
    # serving telemetry over the flattened (group, expert) grid
    assert aux_ref["telemetry"]["expert_load"].shape == (16,)
    np.testing.assert_allclose(
        np.asarray(aux_pal["telemetry"]["expert_load"]),
        np.asarray(aux_ref["telemetry"]["expert_load"]))


def test_hmoe_backend_grad_parity():
    from repro.core.hierarchical import HMoEArgs, hmoe_apply
    params, x = _hmoe_setup()
    rng = jax.random.PRNGKey(2)

    def loss(p, backend):
        y, aux = hmoe_apply(p, x, HMoEArgs(**HMOE_KW,
                                           kernel_backend=backend),
                            train=True, rng=rng)
        return jnp.sum(y ** 2) + aux["aux_loss"]

    g_ref = jax.grad(lambda p: loss(p, "ref"))(params)
    g_pal = jax.grad(lambda p: loss(p, "pallas"))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_pal)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)


def test_hmoe_unknown_backend_raises():
    from repro.core.hierarchical import HMoEArgs, hmoe_apply
    params, x = _hmoe_setup()
    with pytest.raises(bk_lib.KernelBackendError):
        hmoe_apply(params, x,
                   HMoEArgs(**HMOE_KW, kernel_backend="does_not_exist"),
                   train=False)


# ---------------------------------------------------------------------------
# VMEM-footprint guard on the fused dispatch/combine kernel: shapes whose
# single-expert slab exceeds even the E-blocked budget still raise (kernel
# level) / fall back to ref with a warning (backend level); everything
# else now runs fused — see tests/test_kernel_eblock.py
# ---------------------------------------------------------------------------

def test_dispatch_vmem_guard_raises_directly():
    from repro.kernels import dispatch as dl
    # estimate helper: [E, C, d] buffer + token block, in bytes
    assert dl.vmem_bytes(8, 64, 32, jnp.float32) == 8 * 64 * 32 * 4
    with pytest.raises(dl.DispatchVMEMError, match="VMEM"):
        dl.check_vmem(1024, 4096, 4096, jnp.float32, limit=1 << 20)
    x = jnp.ones((16, 8), jnp.float32)
    eidx = jnp.zeros((16, 2), jnp.int32)
    pos = jnp.tile(jnp.arange(2, dtype=jnp.int32)[None], (16, 1))
    with pytest.raises(dl.DispatchVMEMError):
        dl.dispatch(x, eidx, pos, n_experts=4, capacity=8, vmem_limit=16)
    buf = jnp.ones((4, 8, 8), jnp.float32)
    with pytest.raises(dl.DispatchVMEMError):
        dl.combine(buf, jnp.ones((16, 2)), eidx, pos, vmem_limit=16)
    # default limit admits the small shape
    assert dl.dispatch(x, eidx, pos, n_experts=4, capacity=8).shape \
        == (4, 8, 8)


def test_backend_vmem_guard_falls_back_to_ref():
    """Past the configured budget the pallas backend must route
    dispatch/combine to the ref scatter (same numerics) instead of
    OOMing — MoEArgs.dispatch_vmem_limit is the knob."""
    params = pm.materialize(moe_defs(MoEArgs(**MOE_KW)),
                            jax.random.PRNGKey(0))
    params["gate"]["wg"] = 0.5 * jax.random.normal(jax.random.PRNGKey(7),
                                                   (16, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (100, 16))
    y_pal, _ = moe_apply(params, x,
                         MoEArgs(**MOE_KW, kernel_backend="pallas"),
                         train=False)
    y_fb, _ = moe_apply(params, x,
                        MoEArgs(**MOE_KW, kernel_backend="pallas",
                                dispatch_vmem_limit=64),
                        train=False)
    np.testing.assert_allclose(np.asarray(y_fb), np.asarray(y_pal),
                               rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# 8-device fake mesh (subprocess, like test_distributed.py)
# ---------------------------------------------------------------------------

def _run(body: str, n_devices: int = 8) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_devices}")
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_train_step_equivalence_8device_mesh():
    """One training step under a (2,4) MeshContext on 8 fake devices:
    pallas vs ref backends agree on loss and updated params."""
    out = _run("""
        from repro.common import param as pm
        from repro.data.pipeline import DataConfig, batch_at
        from repro.models.paper_lm import (PaperLMConfig, paper_lm_defs,
                                           paper_lm_loss)
        from repro.optim import optimizers as opt_lib
        from repro.sharding import context
        from repro.train.trainer import make_train_step
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = context.make_mesh((2, 4), ("data", "model"))
        ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")

        def run(backend):
            cfg = PaperLMConfig(vocab_size=64, variant="moe", n_experts=4,
                                k=2, d_model=16, expert_hidden=24,
                                dropout=0.0, kernel_backend=backend)
            params = pm.materialize(paper_lm_defs(cfg),
                                    jax.random.PRNGKey(0))
            params = jax.device_put(params, NamedSharding(mesh, P()))
            dc = DataConfig(vocab_size=64, seq_len=16, batch_size=8,
                            n_clusters=4)
            oc = opt_lib.OptConfig(learning_rate=1e-2, warmup_steps=1)
            step = make_train_step(
                lambda p, b, r: paper_lm_loss(p, b, cfg, rng=r, ctx=ctx),
                oc)
            state = {"params": params, "opt": opt_lib.init(params, oc)}
            batch = jax.device_put(batch_at(dc, 0),
                                   NamedSharding(mesh, P(("data",))))
            return jax.jit(step)(state, batch, jax.random.PRNGKey(3))

        st_ref, m_ref = run("ref")
        st_pal, m_pal = run("pallas")
        np.testing.assert_allclose(float(m_pal["loss"]),
                                   float(m_ref["loss"]), rtol=1e-5)
        for a, b in zip(jax.tree_util.tree_flatten(st_pal["params"])[0],
                        jax.tree_util.tree_flatten(st_ref["params"])[0]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)
        print("STEP8_OK")
    """)
    assert "STEP8_OK" in out


def test_expert_parallel_pallas_matches_ref_8device():
    """The explicit all-to-all EP schedule with the pallas backend (ops
    consuming the Manual-mode ctx: [E/ep, ep*C, d] local blocks) matches
    the ref backend and the single-device oracle."""
    out = _run("""
        from repro.common import param as pm
        from repro.core.moe import MoEArgs, moe_defs, moe_apply
        from repro.core.expert_parallel import moe_apply_ep
        from repro.sharding import context
        mesh = context.make_mesh((2, 4), ("data", "model"))
        ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")
        kw = dict(n_experts=8, k=2, d_model=16, d_ff=36,
                  dtype=jnp.float32, capacity_factor=8.0,
                  eval_capacity_factor=8.0)
        params = pm.materialize(moe_defs(MoEArgs(**kw)),
                                jax.random.PRNGKey(0))
        params["gate"]["wg"] = 0.5 * jax.random.normal(
            jax.random.PRNGKey(7), params["gate"]["wg"].shape)
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 16))
        y_ref, _ = jax.jit(lambda p, x: moe_apply_ep(
            p, x, MoEArgs(**kw, kernel_backend="ref"), train=False,
            ctx=ctx))(params, x)
        y_pal, _ = jax.jit(lambda p, x: moe_apply_ep(
            p, x, MoEArgs(**kw, kernel_backend="pallas"), train=False,
            ctx=ctx))(params, x)
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-5)
        y1, _ = moe_apply(params, x, MoEArgs(**kw), train=False)
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y1),
                                   rtol=2e-4, atol=2e-5)
        print("EP_PALLAS_OK")
    """)
    assert "EP_PALLAS_OK" in out
