"""MeshContext subsystem: plan resolution on 1- and 8-device meshes,
Manual-axis stripping, the contextvar plumbing, and the jax-0.4.x
no-abstract-mesh fallback (identity constraints off-mesh)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.param import ParamDef
from repro.sharding import context, partition

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, n_devices: int = 8) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_devices}")
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# A representative ParamDef tree touching the interesting logical axes.
def _defs():
    return {
        "w1": ParamDef((8, 16, 32), ("experts", "expert_embed",
                                     "expert_mlp"), dtype=jnp.float32),
        "unembed": ParamDef((16, 128), ("embed_fsdp", "vocab"),
                            dtype=jnp.float32),
        "scale": ParamDef((16,), ("embed",), init="ones",
                          dtype=jnp.float32),
    }


# ---------------------------------------------------------------------------
# 1-device mesh: every plan must resolve every ParamDef without error and
# produce valid NamedShardings (everything collapses to replication).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plan", sorted(partition.PLANS))
def test_every_plan_resolves_on_one_device(plan):
    mesh = context.make_mesh((1, 1), ("data", "model"))
    ctx = context.MeshContext.for_mesh(mesh, plan)
    shd = ctx.tree_shardings(_defs())
    for leaf in jax.tree_util.tree_leaves(shd):
        assert isinstance(leaf, jax.sharding.NamedSharding)
    # Constraint inside jit must be a functional no-op on one device.
    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    with ctx:
        y = jax.jit(lambda v: context.with_constraint(
            v, ("batch", "embed")))(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_divisibility_fallback_recorded():
    """A dim not divisible by its mesh axes falls back (and is recorded),
    never errors."""
    mesh = context.make_mesh((1, 1), ("data", "model"))
    ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")
    # 7 is not divisible by anything > 1; on a 1-device mesh axes of size 1
    # always divide, so force the interesting case via an 8-dev subprocess
    # below.  Here just check the fallback list plumbing.
    fallbacks = []
    spec = ctx.resolve((7, 16), ("experts", "expert_embed"), fallbacks)
    assert isinstance(spec, jax.sharding.PartitionSpec)


# ---------------------------------------------------------------------------
# Manual-axis stripping (the pipeline stage-axis path)
# ---------------------------------------------------------------------------

def test_manual_axis_stripped_from_specs():
    mesh = context.make_mesh((1, 1), ("data", "model"))
    ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")
    stage_ctx = ctx.manual("data")
    assert stage_ctx.manual_axes == frozenset({"data"})
    assert "data" not in stage_ctx.auto_axes
    # batch resolves to ("pod","data") under dp_tp_ep -> data must be gone.
    spec = stage_ctx.resolve((8, 16), ("batch", "embed"))
    flat = []
    for e in spec:
        if e is None:
            continue
        flat += list(e) if isinstance(e, tuple) else [e]
    assert "data" not in flat
    # the parent context is untouched (frozen dataclass derivation)
    assert ctx.manual_axes == frozenset()


def test_manual_constraint_degrades_on_04x():
    """Under a Manual-mode context on jax 0.4.x, with_constraint must be
    the identity (the partitioner cannot mix NamedSharding constraints
    with manual axes there)."""
    if context.CAN_CONSTRAIN_UNDER_MANUAL:
        pytest.skip("new jax: constraints allowed under manual mode")
    mesh = context.make_mesh((1, 1), ("data", "model"))
    stage_ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep").manual(
        "data")
    x = jnp.ones((4, 4))
    assert stage_ctx.with_constraint(x, ("batch", "embed")) is x


# ---------------------------------------------------------------------------
# contextvar plumbing + the no-abstract-mesh fallback
# ---------------------------------------------------------------------------

def test_null_context_constraint_is_identity():
    x = jnp.ones((4, 4))
    assert context.MeshContext.null().with_constraint(
        x, ("batch", "embed")) is x


def test_no_ctx_no_abstract_mesh_is_identity():
    """jax 0.4.x has no ambient abstract mesh: with no active context the
    free-function constraint must return its input unchanged (this is the
    exact seed failure mode — an AttributeError — turned into graceful
    degradation)."""
    assert context.current_ctx() is None
    x = jnp.ones((4, 4))
    y = context.with_constraint(x, ("batch", "embed"))
    if context.abstract_mesh_or_none() is None:
        assert y is x
    else:
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_contextvar_nesting():
    mesh = context.make_mesh((1, 1), ("data", "model"))
    outer = context.MeshContext.for_mesh(mesh, "dp_tp_ep")
    inner = context.MeshContext.for_mesh(mesh, "decode_std")
    assert context.current_ctx() is None
    with outer:
        assert context.current_ctx() is outer
        with inner:
            assert context.current_ctx() is inner
            with inner:       # re-entrant on the same object
                assert context.current_ctx() is inner
            assert context.current_ctx() is inner
        assert context.current_ctx() is outer
    assert context.current_ctx() is None


def test_with_plan_derivation():
    mesh = context.make_mesh((1, 1), ("data", "model"))
    ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")
    d = ctx.with_plan("decode_std")
    assert d.rules.name == "decode_std" and ctx.rules.name == "dp_tp_ep"
    assert d.mesh is ctx.mesh


# ---------------------------------------------------------------------------
# 8-device meshes (subprocess): every plan, real shardings, and sharded
# execution equivalence through the MoE layer.
# ---------------------------------------------------------------------------

def test_every_plan_resolves_on_eight_devices():
    out = _run("""
        from repro.common.param import ParamDef
        from repro.sharding import context, partition
        mesh = context.make_mesh((2, 4), ("data", "model"))
        defs = {
            "w1": ParamDef((8, 16, 32), ("experts", "expert_embed",
                                         "expert_mlp"),
                           dtype=jnp.float32),
            "unembed": ParamDef((16, 128), ("embed_fsdp", "vocab"),
                                dtype=jnp.float32),
            "odd": ParamDef((7, 16), ("experts", "expert_embed"),
                            dtype=jnp.float32),
        }
        for plan in sorted(partition.PLANS):
            ctx = context.MeshContext.for_mesh(mesh, plan)
            fallbacks = []
            shd = ctx.tree_shardings(defs, fallbacks)
            for leaf in jax.tree_util.tree_leaves(shd):
                assert isinstance(leaf, jax.sharding.NamedSharding)
            # the 7-dim 'odd' leaf must have fallen back, not failed
        # dp_tp_ep: experts=8 shards over model=4
        ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")
        spec = ctx.resolve((8, 16, 32), ("experts", "expert_embed",
                                         "expert_mlp"))
        assert spec[0] == "model", spec
        print("PLANS_OK")
    """)
    assert "PLANS_OK" in out


def test_sharded_constraint_matches_unsharded_execution():
    out = _run("""
        from repro.sharding import context
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = context.make_mesh((2, 4), ("data", "model"))
        ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")

        def f(x):
            h = context.with_constraint(x, ("tokens", "embed"))
            return jnp.tanh(h) * 2.0

        x = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
        y_ref = f(x)                      # eager, off-mesh: identity path
        with ctx:
            xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
            y = jax.jit(f)(xs)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-6, atol=1e-6)
        print("CONSTRAIN_OK")
    """)
    assert "CONSTRAIN_OK" in out


def test_manual_stripping_on_eight_devices():
    """shard_map manual over 'data' with an in-body constraint: on 0.4.x
    the constraint degrades to identity; either way numerics match the
    unsharded reference."""
    out = _run("""
        from jax.sharding import PartitionSpec as P
        from repro.sharding import context
        mesh = context.make_mesh((4, 2), ("data", "model"))
        ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")
        stage_ctx = ctx.manual("data")

        def body(x):
            h = stage_ctx.with_constraint(x, ("batch", "embed"))
            return h * 3.0

        fn = context.shard_map(body, mesh, (P("data"),), P("data"),
                               manual_axes=("data",))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
        y = jax.jit(fn)(x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x) * 3.0,
                                   rtol=1e-6)
        print("MANUAL_OK")
    """)
    assert "MANUAL_OK" in out
