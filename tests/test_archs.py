"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (assignment
requirement), plus prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import small_config
from repro.common import param as pm
from repro.configs.base import count_params, get_config, layer_kinds
from repro.models import lm, transformer

ARCHS = [
    "pixtral-12b", "jamba-v0.1-52b", "kimi-k2-1t-a32b", "arctic-480b",
    "qwen3-1.7b", "gemma3-27b", "smollm-135m", "llama3-8b",
    "musicgen-large", "falcon-mamba-7b",
]


def _batch(cfg, b=2, s=64):
    batch = {"tokens": jnp.asarray(
        np.random.RandomState(0).randint(1, cfg.vocab_size, (b, s)),
        jnp.int32)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.n_prefix:
        batch["prefix_embeds"] = 0.1 * jnp.ones(
            (b, cfg.n_prefix, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = small_config(arch)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        return lm.lm_loss(p, batch, cfg, rng=jax.random.PRNGKey(1))[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.abs(g)))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    """Greedy next-token from prefill must equal running decode over the
    same tokens step by step (cache correctness across all mixer types)."""
    # generous capacity: prefill routes 32 tokens at once while decode
    # routes 2 — different capacity truncation would differ by design.
    cfg = small_config(arch, capacity_factor=8.0)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    cache0 = pm.materialize(transformer.cache_defs(cfg, b, 64),
                            jax.random.PRNGKey(9))
    logits_p, _ = jax.jit(lambda p, bt, c: lm.lm_prefill(p, bt, c, cfg))(
        params, batch, cache0)

    cache = pm.materialize(transformer.cache_defs(cfg, b, 64),
                           jax.random.PRNGKey(9))
    dec = jax.jit(lambda p, t, c, i: lm.lm_decode(p, t, c, i, cfg))
    logits_d = None
    for i in range(s):
        logits_d, cache = dec(params, batch["tokens"][:, i], cache,
                              jnp.int32(i))
    if cfg.n_prefix:
        return  # prefix embeds only exist on the prefill path
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_p),
                               rtol=5e-2, atol=5e-2)
    assert (np.argmax(np.asarray(logits_d), -1)
            == np.argmax(np.asarray(logits_p), -1)).mean() >= 0.95


def test_param_count_sanity():
    """Analytic counts match the published scale of each model."""
    expect = {
        "kimi-k2-1t-a32b": (1.04e12, 3.19e10),
        "llama3-8b": (8.0e9, 8.0e9),
        # we do not tie embeddings; untied unembed adds ~28M to smollm
        "smollm-135m": (1.63e8, 1.63e8),
        "falcon-mamba-7b": (7.3e9, 7.3e9),
        "jamba-v0.1-52b": (5.2e10, 1.2e10),
        "arctic-480b": (4.8e11, 1.7e10),
    }
    for name, (tot, act) in expect.items():
        got = count_params(get_config(name))
        assert abs(got["total"] - tot) / tot < 0.12, (name, got["total"])
        assert abs(got["active"] - act) / act < 0.35, (name, got["active"])


def test_layer_patterns():
    jamba = get_config("jamba-v0.1-52b")
    kinds = layer_kinds(jamba)
    assert sum(k.mixer == "attn" for k in kinds) == 1          # 1:7
    assert sum(k.ffn == "moe" for k in kinds) == 4             # every 2nd
    gemma = get_config("gemma3-27b")
    kinds = layer_kinds(gemma)
    assert sum(k.mixer == "attn_local" for k in kinds) == 5    # 5:1
    assert sum(k.mixer == "attn" for k in kinds) == 1
    falcon = get_config("falcon-mamba-7b")
    assert all(k.mixer == "mamba" and k.ffn == "none"
               for k in layer_kinds(falcon))


def test_materialize_matches_abstract():
    cfg = small_config("qwen3-1.7b")
    defs = lm.lm_defs(cfg)
    abst = pm.abstract(defs)
    real = pm.materialize(defs, jax.random.PRNGKey(0))
    ja, jr = jax.tree_util.tree_leaves(abst), jax.tree_util.tree_leaves(real)
    assert len(ja) == len(jr)
    for a, r in zip(ja, jr):
        assert a.shape == r.shape and a.dtype == r.dtype
