"""Gradient parity: the Pallas custom-VJP path vs the jnp oracle.

The acceptance test for the kernels' ``jax.custom_vjp`` rules —
``jax.grad`` of a scalar loss through ``ops.expert_ffn`` /
``ops.topk_gating`` / the fused dispatch+combine must match differentiating
the pure-jnp reference, and ``check_grads`` validates against numerical
differences on small shapes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.test_util import check_grads

from repro.core import dispatch as dsp
from repro.kernels import ops, ref


def _allclose_tree(got, want, rtol=1e-3, atol=1e-4):
    for g, w in zip(jax.tree_util.tree_flatten(got)[0],
                    jax.tree_util.tree_flatten(want)[0]):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# gmm / expert_ffn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(2, 64, 32, 48), (3, 56, 72, 40)])
@pytest.mark.parametrize("act", ["none", "relu", "silu"])
def test_gmm_grads_match_oracle(shape, act):
    e, c, k, n = shape
    x = jax.random.normal(jax.random.PRNGKey(0), (e, c, k))
    w = 0.2 * jax.random.normal(jax.random.PRNGKey(1), (e, k, n))
    tgt = jax.random.normal(jax.random.PRNGKey(2), (e, c, n))

    def loss(fn):
        return lambda x, w: jnp.mean((fn(x, w) - tgt) ** 2)

    gk = jax.grad(loss(lambda x, w: ops.gmm(x, w, activation=act)),
                  (0, 1))(x, w)
    gr = jax.grad(loss(lambda x, w: ref.gmm_ref(x, w, activation=act)),
                  (0, 1))(x, w)
    _allclose_tree(gk, gr)


@pytest.mark.parametrize("activation", ["relu", "swiglu"])
def test_expert_ffn_grads_match_oracle(activation):
    e, c, d, f = 2, 40, 24, 36
    x = jax.random.normal(jax.random.PRNGKey(0), (e, c, d))
    params = {
        "w1": 0.2 * jax.random.normal(jax.random.PRNGKey(1), (e, d, f)),
        "w2": 0.2 * jax.random.normal(jax.random.PRNGKey(2), (e, f, d)),
    }
    if activation == "swiglu":
        params["w3"] = 0.2 * jax.random.normal(jax.random.PRNGKey(3),
                                               (e, d, f))

    def loss_k(params, x):
        return jnp.mean(ops.expert_ffn(params, x, activation=activation)**2)

    def loss_r(params, x):
        return jnp.mean(ref.expert_ffn_ref(
            x, params["w1"], params["w2"], params.get("w3"))**2)

    gk = jax.grad(loss_k, (0, 1))(params, x)
    gr = jax.grad(loss_r, (0, 1))(params, x)
    _allclose_tree(gk, gr)


def test_gmm_check_grads_small():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8))
    w = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8))
    for act in ("none", "silu"):
        check_grads(lambda x, w: ops.gmm(x, w, activation=act), (x, w),
                    order=1, modes=["rev"], rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# topk_gating
# ---------------------------------------------------------------------------

def test_topk_gating_grads_match_oracle():
    t, e, k = 48, 16, 4
    logits = jax.random.normal(jax.random.PRNGKey(0), (t, e))
    coef = jax.random.normal(jax.random.PRNGKey(1), (t, k))

    def loss_k(l):
        w, idx, vals = ops.topk_gating_full(l, k, extra=1)
        # touch both outputs: the combine weights and the raw values the
        # Appendix-A load estimator consumes
        return jnp.sum(w * coef) + jnp.sum(jnp.tanh(vals))

    def loss_r(l):
        tv, ti = jax.lax.top_k(l, k + 1)
        w = jax.nn.softmax(tv[:, :k], axis=-1)
        return jnp.sum(w * coef) + jnp.sum(jnp.tanh(tv))

    gk = jax.grad(loss_k)(logits)
    gr = jax.grad(loss_r)(logits)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               rtol=1e-4, atol=1e-5)


def test_topk_gating_check_grads_small():
    # Well-separated logits keep the argmax selection away from the
    # (legitimately non-differentiable) tie boundaries.
    logits = jnp.array([[3.0, -1.0, 1.5, 0.2, -2.0, 0.9],
                        [0.1, 2.4, -0.7, 1.1, 3.3, -1.9]])
    check_grads(lambda l: ops.topk_gating(l, 2)[0], (logits,),
                order=1, modes=["rev"], rtol=1e-2, atol=1e-2)


def test_topk_gating_idx_has_no_grad():
    """Integer outputs contribute zero cotangent (and don't crash grad)."""
    logits = jax.random.normal(jax.random.PRNGKey(0), (16, 8))

    def loss(l):
        w, idx = ops.topk_gating(l, 2)
        return jnp.sum(w ** 2)

    g = jax.grad(loss)(logits)
    assert g.shape == logits.shape and np.isfinite(np.asarray(g)).all()


# ---------------------------------------------------------------------------
# fused dispatch/combine
# ---------------------------------------------------------------------------

def test_dispatch_combine_grads_match_oracle():
    t, d, e, k, cap = 40, 12, 6, 2, 16
    x = jax.random.normal(jax.random.PRNGKey(4), (t, d))
    eidx = jax.random.randint(jax.random.PRNGKey(5), (t, k), 0, e)
    wt = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(6), (t, k)), -1)
    p = dsp.plan(eidx, wt, e, cap)
    kept = np.asarray(p.position < cap)

    def loss_k(x, w):
        buf = ops.dispatch(x, p.expert_index, p.position, n_experts=e,
                           capacity=cap)
        y = ops.combine(buf * buf, w, p.expert_index, p.position)
        return jnp.sum(y ** 2)

    def loss_r(x, w):
        buf = dsp.dispatch(x, p)
        return jnp.sum(dsp.combine(buf * buf, p._replace(weight=w)) ** 2)

    gk = jax.grad(loss_k, (0, 1))(x, p.weight)
    gr = jax.grad(loss_r, (0, 1))(x, p.weight)
    np.testing.assert_allclose(np.asarray(gk[0]), np.asarray(gr[0]),
                               rtol=1e-4, atol=1e-5)
    # Weight grads agree on kept slots; the kernel zeroes dropped slots
    # where the jnp clipped-gather leaks a spurious (plan-masked) value.
    np.testing.assert_allclose(np.asarray(gk[1])[kept],
                               np.asarray(gr[1])[kept],
                               rtol=1e-4, atol=1e-5)
    assert (np.asarray(gk[1])[~kept] == 0).all()


def test_dispatch_check_grads_small():
    t, d, e, k, cap = 8, 4, 3, 2, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d))
    eidx = jax.random.randint(jax.random.PRNGKey(1), (t, k), 0, e)
    wt = jnp.ones((t, k)) / k
    p = dsp.plan(eidx, wt, e, cap)
    check_grads(
        lambda x: ops.dispatch(x, p.expert_index, p.position, n_experts=e,
                               capacity=cap),
        (x,), order=1, modes=["rev"], rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# the whole MoE layer: backend-resolved grads, ref vs pallas
# ---------------------------------------------------------------------------

def test_moe_layer_grads_ref_vs_pallas():
    from repro.common import param as pm
    from repro.core.moe import MoEArgs, moe_apply, moe_defs
    kw = dict(n_experts=8, k=2, d_model=16, d_ff=36, dtype=jnp.float32,
              capacity_factor=2.0)
    params = pm.materialize(moe_defs(MoEArgs(**kw)), jax.random.PRNGKey(0))
    params["gate"]["wg"] = 0.5 * jax.random.normal(jax.random.PRNGKey(7),
                                                   (16, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (100, 16))
    rng = jax.random.PRNGKey(2)

    def loss(params, backend):
        a = MoEArgs(**kw, kernel_backend=backend)
        y, aux = moe_apply(params, x, a, train=True, rng=rng)
        return jnp.sum(y ** 2) + aux["aux_loss"]

    g_ref = jax.grad(loss)(params, "ref")
    g_pal = jax.grad(loss)(params, "pallas")
    _allclose_tree(g_pal, g_ref, rtol=5e-4, atol=5e-5)
