"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode
on CPU; the identical kernel bodies compile for TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    (4, 128, 128, 128),
    (2, 256, 384, 256),
    (3, 128, 256, 512),
    (1, 512, 128, 128),
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("act", ["none", "relu", "silu"])
def test_gmm_allclose(shape, dtype, act):
    e, c, k, n = shape
    x = jax.random.normal(jax.random.PRNGKey(0), (e, c, k), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (e, k, n), dtype)
    got = ops.gmm(x, w, activation=act)
    want = ref.gmm_ref(x, w, activation=act)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("block", [(64, 128, 128), (128, 64, 128),
                                   (128, 128, 64)])
def test_gmm_block_shape_independence(block):
    bm, bn, bk = block
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 128, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 128))
    got = ops.gmm(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.gmm_ref(x, w)),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("gated", [False, True])
def test_expert_ffn_fused(gated):
    e, c, d, f = 4, 128, 128, 256
    x = jax.random.normal(jax.random.PRNGKey(0), (e, c, d))
    w1 = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (e, d, f))
    w2 = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (e, f, d))
    if gated:
        w3 = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (e, d, f))
        got = ops.expert_ffn({"w1": w1, "w2": w2, "w3": w3}, x,
                             activation="swiglu")
        want = ref.expert_ffn_ref(x, w1, w2, w3)
    else:
        got = ops.expert_ffn({"w1": w1, "w2": w2}, x, activation="relu")
        want = ref.expert_ffn_ref(x, w1, w2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("t,e,k", [(256, 64, 4), (512, 384, 8), (256, 8, 2)])
def test_topk_gating_kernel(t, e, k):
    logits = jax.random.normal(jax.random.PRNGKey(0), (t, e))
    w, idx = ops.topk_gating(logits, k)
    rw, ridx, _ = ref.topk_gating_ref(logits, k)
    np.testing.assert_allclose(np.asarray(w), np.asarray(rw), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))


def test_topk_gating_ties_stable():
    logits = jnp.zeros((8, 16))
    w, idx = ops.topk_gating(logits, 2)
    # all-equal logits: uniform weights, first indices win (argmax order)
    np.testing.assert_allclose(np.asarray(w), 0.5, rtol=1e-6)
    assert (np.asarray(idx) == np.array([0, 1])).all()
