"""Pallas kernels vs pure-jnp oracles: the parity suite.

Sweeps (E, C, d, d_ff, k, dtype, activation) — including non-tile-aligned
C/d_ff shapes, which exercise the block-plan padding — in interpret mode on
CPU; the identical kernel bodies compile for TPU.  Gradient parity lives in
test_kernel_grads.py, backend wiring in test_kernel_backend.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch as dsp
from repro.kernels import ops, ref
from repro.kernels.gmm import plan_blocks

# (E, C, K, N): MXU-aligned shapes plus deliberately ragged ones that only
# work through the padding path (100, 96, 56, 72, 40, 33 ...).
SHAPES = [
    (4, 128, 128, 128),
    (2, 256, 384, 256),
    (3, 128, 256, 512),
    (1, 512, 128, 128),
    (2, 100, 96, 160),          # ragged C / K / N
    (3, 56, 72, 40),
    (1, 8, 16, 24),             # tiny: blocks clamp to the problem
    (5, 136, 48, 264),          # just past one tile
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return 3e-2 if dtype == jnp.bfloat16 else 2e-3


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("act", ["none", "relu", "silu"])
def test_gmm_allclose(shape, dtype, act):
    e, c, k, n = shape
    x = jax.random.normal(jax.random.PRNGKey(0), (e, c, k), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (e, k, n), dtype)
    got = ops.gmm(x, w, activation=act)
    assert got.shape == (e, c, n) and got.dtype == dtype
    want = ref.gmm_ref(x, w, activation=act)
    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("block", [(64, 128, 128), (128, 64, 128),
                                   (128, 128, 64), (32, 32, 32)])
def test_gmm_block_shape_independence(block):
    bm, bn, bk = block
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 128, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 128))
    got = ops.gmm(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.gmm_ref(x, w)),
                               rtol=2e-3, atol=2e-3)


def test_gmm_padding_is_invisible():
    """A ragged problem equals the same problem manually zero-padded."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 100, 72))
    w = jax.random.normal(jax.random.PRNGKey(1), (2, 72, 90))
    got = ops.gmm(x, w, activation="relu")
    xp = jnp.pad(x, ((0, 0), (0, 28), (0, 56)))
    wp = jnp.pad(w, ((0, 0), (0, 56), (0, 38)))
    padded = ops.gmm(xp, wp, activation="relu")[:, :100, :90]
    np.testing.assert_allclose(np.asarray(got), np.asarray(padded),
                               rtol=1e-5, atol=1e-5)


def test_plan_blocks_pads_to_tiles():
    bp = plan_blocks(3, 100, 72, 90, jnp.float32)
    assert bp.c % bp.bm == 0 and bp.k % bp.bk == 0 and bp.n % bp.bn == 0
    assert bp.c >= 100 and bp.k >= 72 and bp.n >= 90
    assert bp.c % 8 == 0 and bp.grid[0] == 3
    # bf16 sublane tile is 16
    assert plan_blocks(1, 20, 128, 128, jnp.bfloat16).c % 16 == 0
    # aligned shapes don't pad
    bp = plan_blocks(4, 256, 128, 512, jnp.float32)
    assert (bp.c, bp.k, bp.n) == (256, 128, 512)


@pytest.mark.parametrize("e,c,d,f", [(4, 128, 128, 256),   # aligned
                                     (3, 72, 48, 100)])    # ragged
@pytest.mark.parametrize("gated", [False, True])
def test_expert_ffn_fused(e, c, d, f, gated):
    x = jax.random.normal(jax.random.PRNGKey(0), (e, c, d))
    w1 = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (e, d, f))
    w2 = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (e, f, d))
    if gated:
        w3 = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (e, d, f))
        got = ops.expert_ffn({"w1": w1, "w2": w2, "w3": w3}, x,
                             activation="swiglu")
        want = ref.expert_ffn_ref(x, w1, w2, w3)
    else:
        got = ops.expert_ffn({"w1": w1, "w2": w2}, x, activation="relu")
        want = ref.expert_ffn_ref(x, w1, w2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", DTYPES)
def test_expert_ffn_dtypes(dtype):
    e, c, d, f = 2, 64, 32, 48
    x = jax.random.normal(jax.random.PRNGKey(0), (e, c, d), dtype)
    w1 = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (e, d, f), dtype)
    w2 = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (e, f, d), dtype)
    got = ops.expert_ffn({"w1": w1, "w2": w2}, x, activation="relu")
    assert got.dtype == dtype
    want = ref.expert_ffn_ref(x, w1, w2)
    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# top-k gating
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,e,k", [(256, 64, 4), (512, 384, 8), (256, 8, 2),
                                   (100, 16, 4), (37, 12, 3)])  # ragged T
def test_topk_gating_kernel(t, e, k):
    logits = jax.random.normal(jax.random.PRNGKey(0), (t, e))
    w, idx = ops.topk_gating(logits, k)
    rw, ridx, _ = ref.topk_gating_ref(logits, k)
    np.testing.assert_allclose(np.asarray(w), np.asarray(rw), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))


@pytest.mark.parametrize("extra", [1, 2])
def test_topk_gating_full_raw_values(extra):
    """The k+extra raw values/indices match lax.top_k (load-estimator
    inputs: the (k+1)-th noisy logit is the Appendix-A threshold)."""
    t, e, k = 64, 32, 4
    logits = jax.random.normal(jax.random.PRNGKey(1), (t, e))
    w, idx, vals = ops.topk_gating_full(logits, k, extra=extra)
    tv, ti = jax.lax.top_k(logits, k + extra)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(tv), rtol=1e-6,
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ti))
    np.testing.assert_allclose(
        np.asarray(w), np.asarray(jax.nn.softmax(tv[:, :k], axis=-1)),
        rtol=1e-5, atol=1e-6)


def test_topk_gating_ties_stable():
    logits = jnp.zeros((8, 16))
    w, idx = ops.topk_gating(logits, 2)
    # all-equal logits: uniform weights, first indices win (argmax order)
    np.testing.assert_allclose(np.asarray(w), 0.5, rtol=1e-6)
    assert (np.asarray(idx) == np.array([0, 1])).all()


# ---------------------------------------------------------------------------
# fused dispatch/combine scatter
# ---------------------------------------------------------------------------

def _mk_plan(t, e, k, cap, seed=0):
    eidx = jax.random.randint(jax.random.PRNGKey(seed), (t, k), 0, e)
    wt = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(seed + 1), (t, k)), axis=-1)
    return dsp.plan(eidx, wt, e, cap)


@pytest.mark.parametrize("t,e,k,cap", [(64, 8, 2, 32), (33, 6, 2, 8),
                                       (128, 16, 4, 8),   # heavy dropping
                                       (100, 4, 1, 64)])
def test_fused_dispatch_matches_scatter(t, e, k, cap):
    x = jax.random.normal(jax.random.PRNGKey(2), (t, 16))
    p = _mk_plan(t, e, k, cap)
    got = ops.dispatch(x, p.expert_index, p.position, n_experts=e,
                       capacity=cap)
    want = dsp.dispatch(x, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("t,e,k,cap", [(64, 8, 2, 32), (33, 6, 2, 8),
                                       (128, 16, 4, 8)])
def test_fused_combine_matches_gather(t, e, k, cap):
    p = _mk_plan(t, e, k, cap, seed=3)
    buf = jax.random.normal(jax.random.PRNGKey(4), (e, cap, 16))
    got = ops.combine(buf, p.weight, p.expert_index, p.position)
    want = dsp.combine(buf, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_fused_roundtrip_matches_einsum_path():
    """dispatch ∘ expert-identity ∘ combine equals the GShard one-hot
    einsum oracle end-to-end."""
    t, e, k, cap = 48, 4, 2, 32
    x = jax.random.normal(jax.random.PRNGKey(5), (t, 8))
    p = _mk_plan(t, e, k, cap, seed=6)
    buf = ops.dispatch(x, p.expert_index, p.position, n_experts=e,
                       capacity=cap)
    y = ops.combine(buf, p.weight, p.expert_index, p.position)
    want = dsp.combine_einsum(dsp.dispatch_einsum(x, p), p)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
