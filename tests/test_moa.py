"""Mixture-of-Attention-Heads (core/moa.py, docs/moa.md).

The correctness bar (ISSUE 9): the routed dispatch→gmm→combine pipeline
is *exactly* the per-expert dense attention oracle weighted by the gates;
ref and pallas backends agree (values and grads, 1- and 8-device meshes);
decode is consistent with the full-sequence forward; chunked prefill
matches whole-prompt; an MoA-layered LM serves under continuous batching
bit-identical to the sequential oracle; unsupported combos (MoA on an ssm
or sliding-window position) fail loudly at config time.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.test_util import check_grads

from repro.common import param as pm
from repro.configs.base import get_config, layer_kinds
from repro.core.moa import (MoAArgs, assignment_plan, init_cache_defs,
                            moa_apply, moa_decode, moa_defs, moa_prefill)
from repro.core.router import RouterSpec
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

B, S, D, E, K, HG, HD = 2, 16, 32, 4, 2, 2, 8


def _args(**kw):
    base = dict(n_experts=E, k=K, d_model=D, n_heads_per_expert=HG,
                head_dim=HD, n_kv_heads=1, dtype=jnp.float32,
                q_block=8, kv_block=8, kernel_backend="ref")
    base.update(kw)
    return MoAArgs(**base)


def _setup(a, seed=0):
    params = pm.materialize(moa_defs(a), jax.random.PRNGKey(seed))
    params["gate"]["wg"] = 0.5 * jax.random.normal(
        jax.random.PRNGKey(seed + 1), (D, E))
    x = jax.random.normal(jax.random.PRNGKey(seed + 2), (B, S, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    return params, x, pos


# ---------------------------------------------------------------------------
# layer math: routed pipeline == dense per-expert oracle
# ---------------------------------------------------------------------------

def _dense_oracle(params, x, a, positions):
    """Every expert densely, combined with the router's gate weights —
    the literal layer equation y = sum_e w_e Attn(x W_q^e, K, V) W_o^e."""
    from repro.core import router as router_lib
    from repro.models import attention as attn_lib
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    router = router_lib.build(a)
    dec = router.route(params, flat, train=False, rng=None)
    # token-major dense gate weights [T, E] from the (possibly capacity-
    # truncated) plan
    w = jnp.zeros((b * s, a.n_experts))
    w = w.at[jnp.arange(b * s)[:, None],
             dec.plan.expert_index].add(dec.plan.weight)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    from repro.models import layers
    k = layers.rope(k, positions, a.rope_theta)
    y = jnp.zeros_like(x)
    for e in range(a.n_experts):
        q = (flat @ params["wq"][e].astype(x.dtype)).reshape(
            b, s, a.n_heads_per_expert, a.head_dim)
        q = layers.rope(q, positions, a.rope_theta)
        o = attn_lib.blockwise_attention(q, k, v, causal=True, window=0,
                                         q_block=8, kv_block=8)
        oe = o.reshape(b * s, a.d_head_group) @ params["wo"][e].astype(
            x.dtype)
        y = y + (w[:, e:e + 1] * oe).reshape(b, s, d)
    return y


def test_matches_dense_per_expert_oracle():
    a = _args()
    params, x, pos = _setup(a)
    y, aux = moa_apply(params, x, a, positions=pos, train=False)
    ref = _dense_oracle(params, x, a, pos)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    assert np.isfinite(float(aux["aux_loss"]))
    # telemetry accounts for every assignment: T tokens x k groups
    assert float(aux["telemetry"]["expert_load"].sum()) == B * S * K


@pytest.mark.parametrize("policy", ["noisy_topk", "expert_choice"])
def test_ref_vs_pallas_parity(policy):
    spec = RouterSpec(policy=policy, capacity_factor=2.0)
    a = _args(router=spec)
    params, x, pos = _setup(a)
    y_ref, _ = moa_apply(params, x, a, positions=pos, train=False)
    ap = dataclasses.replace(a, kernel_backend="pallas")
    y_pal, _ = moa_apply(params, x, ap, positions=pos, train=False)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_check_grads(backend):
    a = _args(kernel_backend=backend)
    params, x, pos = _setup(a)

    def f(p, xx):
        y, aux = moa_apply(p, xx, a, positions=pos, train=False)
        return jnp.sum(y ** 2) + aux["aux_loss"]

    check_grads(f, (params, x), order=1, modes=["rev"],
                atol=2e-2, rtol=2e-2)


def test_assignment_plan_view():
    """[T, k] plan -> [T·k, 1]: positions/experts preserved row-per-
    assignment, weights collapsed to {0, 1} (dropped stays 0)."""
    from repro.core import dispatch as dsp
    p = dsp.DispatchPlan(
        expert_index=jnp.array([[0, 1], [1, 2]]),
        position=jnp.array([[0, 0], [1, 5]]),     # 5 >= capacity: dropped
        weight=jnp.array([[0.7, 0.3], [0.6, 0.0]]),
        n_experts=4, capacity=4, fraction_dropped=jnp.array(0.25))
    ap = assignment_plan(p)
    assert ap.expert_index.shape == (4, 1)
    assert ap.position.reshape(-1).tolist() == [0, 0, 1, 5]
    assert ap.weight.reshape(-1).tolist() == [1.0, 1.0, 1.0, 0.0]


# ---------------------------------------------------------------------------
# serving invariants: decode == apply, chunked == whole, masked slots
# ---------------------------------------------------------------------------

def test_decode_matches_apply_last_position():
    a = _args()
    params, x, pos = _setup(a)
    cache = pm.materialize(init_cache_defs(B, S + 4, a),
                           jax.random.PRNGKey(9))
    y, cache = moa_prefill(params, x, pos, a, cache=cache)
    xt = jax.random.normal(jax.random.PRNGKey(10), (B, 1, D))
    yd, _, _ = moa_decode(params, xt, cache, jnp.full((B,), S, jnp.int32), a)
    xc = jnp.concatenate([x, xt], axis=1)
    posc = jnp.broadcast_to(jnp.arange(S + 1)[None, :], (B, S + 1))
    yc, _ = moa_apply(params, xc, a, positions=posc, train=False)
    np.testing.assert_allclose(np.asarray(yd[:, 0]), np.asarray(yc[:, -1]),
                               atol=1e-4, rtol=1e-4)


def test_chunked_prefill_matches_whole_prompt():
    a = _args()
    params, x, pos = _setup(a)
    cache = pm.materialize(init_cache_defs(B, S, a), jax.random.PRNGKey(9))
    y, cache = moa_prefill(params, x, pos, a, cache=cache)
    cacheA = pm.materialize(init_cache_defs(B, S, a), jax.random.PRNGKey(9))
    h = S // 2
    y1, cacheA = moa_prefill(params, x[:, :h], pos[:, :h], a, cache=cacheA,
                             start_pos=0)
    y2, cacheA = moa_prefill(params, x[:, h:], pos[:, h:], a, cache=cacheA,
                             start_pos=h)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(y),
        atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(cacheA["k"]),
                               np.asarray(cache["k"]), atol=1e-5)


def test_dead_slot_mask_zeroes_output_and_load():
    a = _args()
    params, x, _ = _setup(a)
    cache = pm.materialize(init_cache_defs(B, S, a), jax.random.PRNGKey(9))
    xt = jax.random.normal(jax.random.PRNGKey(11), (B, 1, D))
    cur = jnp.full((B,), 4, jnp.int32)
    y, _, aux = moa_decode(params, xt, cache, cur, a,
                           mask=jnp.array([1.0, 0.0]))
    assert float(jnp.abs(y[1]).max()) == 0.0      # dead slot: no output
    assert float(jnp.abs(y[0]).max()) > 0.0
    # only the live slot's k assignments count
    assert float(aux["telemetry"]["expert_load"].sum()) == K


# ---------------------------------------------------------------------------
# config-level loud fallbacks for unsupported combos
# ---------------------------------------------------------------------------

def test_moa_on_ssm_position_raises():
    cfg = get_config("falcon-mamba-7b").replace(
        moa_positions=(0,), moa_experts=4, moa_k=2, moa_heads_per_expert=2)
    with pytest.raises(ValueError, match="state-?space|ssm"):
        layer_kinds(cfg)


def test_moa_on_hybrid_mamba_position_raises():
    cfg = get_config("jamba-v0.1-52b")
    mamba_pos = next(p for p in range(cfg.period)
                     if p not in cfg.attn_positions)
    cfg = cfg.replace(moa_positions=(mamba_pos,), moa_experts=4, moa_k=2,
                      moa_heads_per_expert=2)
    with pytest.raises(ValueError, match="state-?space|ssm"):
        layer_kinds(cfg)


def test_moa_on_sliding_window_position_raises():
    cfg = get_config("gemma3-27b")
    local_pos = next(p for p in range(cfg.period)
                     if p not in cfg.global_attn_positions)
    cfg = cfg.replace(moa_positions=(local_pos,), moa_experts=4, moa_k=2,
                      moa_heads_per_expert=2)
    with pytest.raises(ValueError, match="sliding-window"):
        layer_kinds(cfg)


def test_moa_unconfigured_knobs_raise():
    cfg = get_config("moa-demo").replace(moa_experts=0)
    with pytest.raises(ValueError, match="not configured"):
        layer_kinds(cfg)


def test_moa_args_validation():
    with pytest.raises(ValueError, match="head group"):
        _args(n_heads_per_expert=3, n_kv_heads=2)    # 3 % 2 != 0
    with pytest.raises(ValueError, match="out of range"):
        _args(k=5)
    with pytest.raises(ValueError, match=">= 2"):
        _args(n_experts=1, k=1)


# ---------------------------------------------------------------------------
# model integration: one train step ref-vs-pallas, param accounting
# ---------------------------------------------------------------------------

def _lm_cfg(**kw):
    from conftest import small_config
    return small_config("moa-demo", q_block=16, kv_block=16, **kw)


def test_lm_train_step_ref_vs_pallas_allclose():
    cfg = _lm_cfg()
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 1,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def loss_of(backend):
        c = cfg.replace(kernel_backend=backend)
        loss, grads = jax.value_and_grad(
            lambda p: lm.lm_loss(p, batch, c, rng=jax.random.PRNGKey(2))[0]
        )(params)
        return float(loss), grads

    l_ref, g_ref = loss_of("ref")
    l_pal, g_pal = loss_of("pallas")
    assert np.allclose(l_ref, l_pal, atol=1e-4), (l_ref, l_pal)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_pal)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)


def test_count_params_matches_materialized():
    from repro.configs.base import count_params
    cfg = _lm_cfg()
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    n = int(sum(np.prod(x.shape)
                for x in jax.tree_util.tree_leaves(params)))
    analytic = count_params(cfg)["total"]
    # analytic excludes the tiny norm vectors (same convention as the
    # other archs) — agree within 1.5%
    assert abs(n - analytic) / n < 0.015, (n, analytic)


def test_moa_decode_telemetry_accounts_for_active_tokens():
    """Per-step moa_load sums to active·k·(MoA layers) with dead-slot
    masking on — the MoA twin of the MoE telemetry accounting test."""
    cfg = _lm_cfg()
    n_moa_layers = sum(1 for kind in layer_kinds(cfg)
                       if kind.mixer == "moa") * (cfg.n_layers // cfg.period)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=3))
    rs = np.random.RandomState(1)
    for plen, m, a in [(8, 6, 0), (12, 4, 0), (16, 8, 1), (8, 5, 2)]:
        eng.submit(rs.randint(1, cfg.vocab_size, (plen,)), m, arrival=a)
    eng.run()
    assert len(eng.telemetry) == eng.stats["decode_steps"]
    for entry in eng.telemetry:
        assert entry["moa_load"].shape == (cfg.moa_experts,)
        assert entry["moa_load"].sum() \
            == entry["active"] * cfg.moa_k * n_moa_layers
        assert (entry["moa_overflow"] >= 0).all()
    assert np.isfinite(eng.stats["moa_overflow_total"])


# ---------------------------------------------------------------------------
# serving parity: continuous batching == sequential, bit for bit (greedy)
# ---------------------------------------------------------------------------

MOA_TRACE = [(40, 4, 0), (8, 3, 0), (33, 5, 1), (12, 4, 2)]


@pytest.mark.parametrize("chunked", [False, True], ids=["whole", "chunked"])
@pytest.mark.parametrize("policy", ["noisy_topk", "expert_choice"])
def test_moa_serve_parity(policy, chunked):
    """tests/test_serve.py's parity matrix with an MoA layer in the stack:
    greedy outputs under continuous batching (staggered long-prompt mix,
    chunked or whole-prompt prefill against the shared-K/V cache) are
    bit-identical to one-at-a-time sequential generation."""
    cfg = _lm_cfg(vocab_size=64,
                  router=RouterSpec(policy=policy, capacity_factor=2.0))
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    rs = np.random.RandomState(2)
    specs = [(rs.randint(1, cfg.vocab_size, (l,)).astype(np.int32), m, a)
             for l, m, a in MOA_TRACE]
    kw = (dict(prefill_chunk=16, prefill_budget=32, admission="aware")
          if chunked else {})
    eng = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=3, **kw))
    reqs = [eng.submit(p, m, arrival=a) for p, m, a in specs]
    eng.run()
    assert all(r.done for r in reqs)
    if chunked:
        assert eng.stats["prefill_chunks"] >= 5
    oracle = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=1))
    for req, (p, m, _) in zip(reqs, specs):
        oracle.reset()
        ref = oracle.submit(p, m)
        oracle.run()
        assert ref.tokens == req.tokens, \
            (policy, chunked, req.rid, ref.tokens, req.tokens)


# ---------------------------------------------------------------------------
# 8-device mesh (subprocess): parity + grads + serve on the mesh
# ---------------------------------------------------------------------------

def _run(body: str, n_devices: int = 8) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_devices}")
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src") + ":"
               + os.path.join(REPO, "tests"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moa_8device_parity_grads_and_serve():
    """On a (data=2, model=4) fake mesh: the MoA layer's ref and pallas
    backends agree under the mesh context, check_grads passes, and an
    MoA-layered LM under continuous batching stays bit-identical to the
    sequential oracle on the mesh."""
    out = _run("""
        import dataclasses
        from jax.test_util import check_grads
        from repro.common import param as pm
        from repro.core.moa import MoAArgs, moa_apply, moa_defs
        from repro.models import lm
        from repro.serve.engine import ServeConfig, ServeEngine
        from repro.sharding import context
        from conftest import small_config

        mesh = context.make_mesh((2, 4), ("data", "model"))
        ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")

        B, S, D, E, K = 2, 16, 32, 4, 2
        a = MoAArgs(n_experts=E, k=K, d_model=D, n_heads_per_expert=2,
                    head_dim=8, n_kv_heads=1, dtype=jnp.float32,
                    q_block=8, kv_block=8, kernel_backend="ref")
        params = pm.materialize(moa_defs(a), jax.random.PRNGKey(0))
        params["gate"]["wg"] = 0.5 * jax.random.normal(
            jax.random.PRNGKey(1), (D, E))
        x = jax.random.normal(jax.random.PRNGKey(2), (B, S, D))
        pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

        y_ref, _ = moa_apply(params, x, a, positions=pos, train=False,
                             ctx=ctx)
        ap = dataclasses.replace(a, kernel_backend="pallas")
        y_pal, _ = moa_apply(params, x, ap, positions=pos, train=False,
                             ctx=ctx)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                                   atol=1e-5, rtol=1e-5)

        def f(p, xx):
            y, aux = moa_apply(p, xx, a, positions=pos, train=False,
                               ctx=ctx)
            return jnp.sum(y ** 2) + aux["aux_loss"]
        check_grads(f, (params, x), order=1, modes=["rev"],
                    atol=2e-2, rtol=2e-2)

        cfg = small_config("moa-demo", vocab_size=64)
        lparams = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
        sctx = context.MeshContext.for_mesh(mesh, "decode_std")
        eng = ServeEngine(lparams, cfg, ServeConfig(max_len=64, n_slots=3),
                          ctx=sctx)
        rs = np.random.RandomState(1)
        specs = [(rs.randint(1, 64, (l,)), m, a)
                 for l, m, a in [(8, 4, 0), (16, 5, 1), (12, 3, 2)]]
        reqs = [eng.submit(p, m, arrival=a) for p, m, a in specs]
        eng.run()
        assert all(r.done for r in reqs)
        oracle = ServeEngine(lparams, cfg, ServeConfig(max_len=64,
                                                       n_slots=1), ctx=sctx)
        for req, (p, m, _) in zip(reqs, specs):
            oracle.reset()
            ref = oracle.submit(p, m)
            oracle.run()
            assert ref.tokens == req.tokens, req.rid
        print("MOA8_OK")
    """)
    assert "MOA8_OK" in out
