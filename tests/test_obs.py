"""Observability: chrome-trace capture, typed metrics, cost-model replay.

Four layers of coverage:

* ``repro.obs.trace`` unit semantics — span/counter/instant event schema
  (the Perfetto-required keys per phase), nesting containment, numpy
  attr coercion on save, save/load round-trip, the NULL tracer and the
  ambient ``use``/``current`` stack.
* ``repro.obs.metrics`` — counter monotonicity, gauge, fixed-bucket
  histogram percentiles (bounded memory, min/max clamping), registry
  type-collision errors, labelled families, the flat ``stats()`` view.
* Engine integration — a traced ``ServeEngine`` run produces a loadable
  chrome trace with the expected span names while greedy outputs stay
  bit-identical to the untraced run; the telemetry ring stays bounded
  while aggregate instruments keep counting.
* Replay fidelity — the simulator drives the *same* ``Scheduler`` /
  ``RequestQueue`` / ``PrefixCache`` code as the engine, so its
  ``StepDecision`` log and counters must equal a real
  ``log_decisions=True`` run exactly, and a trace-fitted ``CostModel``
  must predict the recorded per-op wall within tolerance; plus
  determinism, policy-comparison, and scale smokes.

Timer-hygiene helpers (``benchmarks/common.py``) are covered at the
bottom: ``pctl`` against ``np.percentile``, ``best_of`` min-selection.
"""
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import param as pm
from repro.configs.base import get_config
from repro.models import lm
from repro.obs import metrics as metrics_lib
from repro.obs import replay as rp
from repro.obs import trace as trace_lib
from repro.serve.engine import ServeConfig, ServeEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# trace: event schema, coercion, save/load, NULL, ambient stack
# ---------------------------------------------------------------------------

def test_span_schema_and_nesting():
    tr = trace_lib.Tracer()
    with tr.span("outer", kind="test"):
        with tr.span("inner", n=3):
            pass
    inner, outer = tr.events          # inner exits (and records) first
    for ev in (inner, outer):
        assert ev["ph"] == "X"
        assert set(ev) >= {"name", "ph", "ts", "dur", "pid", "tid", "args"}
        assert ev["dur"] >= 0 and ev["ts"] >= 0
        assert ev["pid"] == os.getpid()
    assert inner["name"] == "inner" and inner["args"] == {"n": 3}
    assert outer["args"] == {"kind": "test"}
    # containment: inner span lies inside outer's [ts, ts+dur]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-9


def test_counter_and_instant_events():
    tr = trace_lib.Tracer()
    tr.counter("serve.queue", depth=4)
    tr.instant("evicted", page=7)
    cnt, inst = tr.events
    assert cnt["ph"] == "C" and cnt["args"] == {"depth": 4}
    assert inst["ph"] == "i" and inst["s"] == "t"
    assert inst["args"] == {"page": 7}


def test_save_load_roundtrip_and_numpy_coercion(tmp_path):
    path = str(tmp_path / "t.json")
    tr = trace_lib.Tracer(path, process_name="unit")
    with tr.span("op", n=np.int64(5), f=np.float32(0.5),
                 shape=(np.int32(2), 3), arr=np.arange(2)):
        pass
    assert tr.save() == path
    events = trace_lib.load(path)
    # metadata first: Perfetto reads the process_name M event
    assert events[0]["ph"] == "M"
    assert events[0]["args"] == {"name": "unit"}
    (ev,) = [e for e in events if e["ph"] == "X"]
    assert ev["args"] == {"n": 5, "f": 0.5, "shape": [2, 3], "arr": [0, 1]}
    with open(path) as f:
        payload = json.load(f)
    assert payload["displayTimeUnit"] == "ms"
    assert isinstance(payload["traceEvents"], list)
    # bare-array form loads too
    bare = str(tmp_path / "bare.json")
    with open(bare, "w") as f:
        json.dump(events, f)
    assert trace_lib.load(bare) == events


def test_save_requires_path(tmp_path):
    tr = trace_lib.Tracer()
    with pytest.raises(ValueError, match="path"):
        tr.save()
    assert tr.save(str(tmp_path / "explicit.json"))


def test_null_tracer_is_free_and_unsaveable():
    assert trace_lib.NULL.enabled is False
    s1 = trace_lib.NULL.span("a", n=1)
    s2 = trace_lib.NULL.span("b")
    assert s1 is s2                    # shared singleton, no allocation
    with s1:
        pass
    trace_lib.NULL.counter("c", v=1)
    trace_lib.NULL.instant("i")
    assert trace_lib.NULL.events == []
    with pytest.raises(ValueError):
        trace_lib.NULL.save()


def test_clear_keeps_inflight_spans_recording():
    """A span opened before ``clear()`` still lands: spans append to the
    tracer's live event list, which clear() empties in place."""
    tr = trace_lib.Tracer()
    span = tr.span("survivor")
    with span:
        tr.clear()
    assert [e["name"] for e in tr.events] == ["survivor"]


def test_ambient_use_stack_restores_on_exception():
    assert trace_lib.current() is trace_lib.NULL
    tr = trace_lib.Tracer()
    with trace_lib.use(tr):
        assert trace_lib.current() is tr
        inner = trace_lib.Tracer()
        with trace_lib.use(inner):
            assert trace_lib.current() is inner
        assert trace_lib.current() is tr
    assert trace_lib.current() is trace_lib.NULL
    with pytest.raises(RuntimeError):
        with trace_lib.use(tr):
            raise RuntimeError("boom")
    assert trace_lib.current() is trace_lib.NULL


# ---------------------------------------------------------------------------
# metrics: instruments and registry
# ---------------------------------------------------------------------------

def test_counter_monotonic():
    c = metrics_lib.Counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(metrics_lib.MetricError, match="negative"):
        c.inc(-1)
    assert c.value == 3.5


def test_gauge_moves_both_ways():
    g = metrics_lib.Gauge("g")
    g.set(4)
    g.dec()
    g.inc(0.5)
    assert g.value == 3.5


def test_histogram_percentiles_bounded_memory():
    h = metrics_lib.Histogram("h")
    h.observe(10.0)
    # a single sample reports itself at every percentile (min/max clamp)
    assert h.percentile(0) == h.p50 == h.p99 == 10.0
    rs = np.random.RandomState(0)
    samples = rs.uniform(0.1, 1.0, size=2000)
    for v in samples:
        h.observe(v)
    assert h.count == 2001
    assert np.isclose(h.sum, samples.sum() + 10.0)
    assert h.p50 <= h.p95 <= h.p99 <= samples.max() + 10.0
    # geometric buckets: interpolated percentile within bucket resolution
    assert abs(h.p50 - np.percentile(samples, 50)) / np.percentile(
        samples, 50) < 0.3
    # bounded memory: the sample list is never kept
    assert len(h._counts) == len(metrics_lib.DEFAULT_BUCKETS) + 1
    snap = h.snapshot()
    assert snap["kind"] == "histogram" and snap["count"] == 2001
    assert snap["max"] == 10.0


def test_histogram_validation():
    with pytest.raises(metrics_lib.MetricError, match="ascending"):
        metrics_lib.Histogram("bad", buckets=(2.0, 1.0))
    h = metrics_lib.Histogram("h", buckets=(1.0, 2.0, 4.0))
    with pytest.raises(metrics_lib.MetricError):
        h.percentile(101)
    assert h.percentile(50) == 0.0      # empty histogram
    h.observe(3.0)
    assert h.percentile(100) == 3.0     # overflow-side clamp to max


def test_registry_declares_and_rejects_collisions():
    reg = metrics_lib.MetricsRegistry()
    c = reg.counter("requests")
    assert reg.counter("requests") is c          # get-or-create
    with pytest.raises(metrics_lib.MetricError, match="already declared"):
        reg.gauge("requests")
    with pytest.raises(metrics_lib.MetricError, match="already declared"):
        reg.counter("requests", labels=("expert",))
    with pytest.raises(metrics_lib.MetricError, match="unknown"):
        reg.get("nope")
    assert "requests" in reg and "nope" not in reg


def test_registry_labelled_family():
    reg = metrics_lib.MetricsRegistry()
    fam = reg.counter("expert_load", labels=("expert",))
    fam.child(expert=0).inc(3)
    fam.child(expert=1).inc()
    assert fam.child(expert=0).value == 3
    with pytest.raises(metrics_lib.MetricError, match="labels"):
        fam.child(layer=0)
    snap = reg.snapshot()["expert_load"]
    assert snap["kind"] == "family"
    assert snap["children"]["expert_load{expert=0}"]["value"] == 3
    # labelled families are not flattened into the back-compat view
    assert "expert_load" not in reg.stats()


def test_stats_flat_view_keeps_int_types():
    reg = metrics_lib.MetricsRegistry()
    reg.counter("n").inc(6)
    reg.gauge("frac").set(0.25)
    reg.histogram("lat").observe(1.0)
    stats = reg.stats()
    assert stats == {"n": 6, "frac": 0.25}
    assert isinstance(stats["n"], int)           # old `== 6` asserts hold


# ---------------------------------------------------------------------------
# engine integration: trace capture, bit-identity, bounded telemetry
# ---------------------------------------------------------------------------

def _moe_cfg():
    return get_config("kimi-k2-1t-a32b").replace(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        vocab_size=64, n_experts=4, moe_k=2, moe_d_ff=32,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        q_block=16, kv_block=16, capacity_factor=2.0)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = _moe_cfg()
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _staggered_trace(vocab: int, n: int = 6):
    """Shared 32-token prefix, staggered arrivals: request 0 retires and
    seeds the trie before the rest arrive."""
    rs = np.random.RandomState(3)
    shared = rs.randint(1, vocab, (32,)).astype(np.int32)
    return [(np.concatenate([shared,
                             rs.randint(1, vocab, (8,)).astype(np.int32)]),
             4, 0 if i == 0 else 12 + i) for i in range(n)]


_SERVE_KW = dict(max_len=64, n_slots=4, prefill_chunk=16,
                 prefill_budget=32, admission="aware", prefix_cache=True)


def _run_engine(params, cfg, trace, **kw):
    eng = ServeEngine(params, cfg, ServeConfig(**_SERVE_KW, **kw))
    reqs = [eng.submit(p, m, arrival=a) for p, m, a in trace]
    eng.run()
    assert all(r.done for r in reqs)
    return [r.tokens for r in reqs], eng


def test_traced_run_bit_identical_with_loadable_trace(moe_setup, tmp_path):
    cfg, params = moe_setup
    trace = _staggered_trace(cfg.vocab_size)
    path = str(tmp_path / "serve.json")
    toks_off, _ = _run_engine(params, cfg, trace)
    toks_on, eng = _run_engine(params, cfg, trace, trace_path=path)
    assert toks_on == toks_off                   # tracing is observation
    assert os.path.exists(path)                  # run() saved at trace end
    events = trace_lib.load(path)
    assert events[0]["ph"] == "M"
    names = {e["name"] for e in events}
    assert {"serve.step", "serve.schedule", "serve.prefill_chunk",
            "serve.decode", "serve.sample", "serve.kv_insert",
            "serve.retire", "serve.prefix_probe",
            "serve.queue"} <= names
    for ev in events:
        if ev["ph"] == "X":
            assert ev["dur"] >= 0
            assert set(ev) >= {"name", "ts", "dur", "pid", "tid"}
    # span attrs carry the cost-model regressors
    chunk = next(e for e in events if e["name"] == "serve.prefill_chunk")
    assert chunk["args"]["tokens"] == \
        chunk["args"]["Gp"] * chunk["args"]["C"]
    assert len(events) == len(eng.tracer.events) + 1   # + process_name


def test_telemetry_ring_bounded_while_aggregates_count(moe_setup):
    cfg, params = moe_setup
    rs = np.random.RandomState(9)
    eng = ServeEngine(params, cfg, ServeConfig(
        max_len=64, n_slots=2, telemetry_keep_last_n=3))
    for _ in range(2):
        eng.submit(rs.randint(1, cfg.vocab_size, (8,)).astype(np.int32), 8)
    eng.run()
    assert eng.stats["decode_steps"] >= 7    # first token comes from prefill
    assert len(eng.telemetry) == 3               # ring kept only the tail
    assert eng.metrics.get("decode_overflow_per_step").count == \
        eng.stats["decode_steps"]                # aggregates saw every step


# ---------------------------------------------------------------------------
# replay: cost model, fidelity, determinism, scale
# ---------------------------------------------------------------------------

def _synth_events(name, xs, durs_us, xattr):
    return [{"name": name, "ph": "X", "ts": 0.0, "dur": d,
             "args": {xattr: x}} for x, d in zip(xs, durs_us)]


def test_cost_model_fit_recovers_linear_and_constant():
    xs = [1, 2, 4, 8, 16]
    events = _synth_events("serve.decode", xs, [2.0 * x + 5.0 for x in xs],
                           "active")
    events += _synth_events("serve.retire", [1] * 4, [3.0] * 4, "unused")
    model = rp.CostModel.fit(events)
    dec = model.ops["serve.decode"]
    assert np.isclose(dec.a, 2.0e-6) and np.isclose(dec.b, 5.0e-6)
    assert dec.n == 5
    ret = model.ops["serve.retire"]
    assert ret.a == 0.0 and np.isclose(ret.b, 3.0e-6)   # constant fit
    assert model.cost("serve.decode", 10) == pytest.approx(25e-6)
    assert model.cost("never.seen") == 0.0
    rt = rp.CostModel.from_dict(model.to_dict())
    assert rt.ops == model.ops


def test_replay_reproduces_engine_decisions_and_wall(moe_setup, tmp_path):
    """The fidelity contract: same Scheduler/RequestQueue/PrefixCache
    code ⇒ the sim's StepDecision log and counters equal a real
    ``log_decisions=True`` engine run exactly, and the trace-fitted cost
    model predicts the recorded per-op wall within tolerance."""
    cfg, params = moe_setup
    trace = _staggered_trace(cfg.vocab_size, n=8)
    path = str(tmp_path / "fit.json")
    # trace_sync: calibration mode, so span durations are real op walls
    # (what the cost model fits on) rather than async dispatch times.
    eng = ServeEngine(params, cfg, ServeConfig(
        **_SERVE_KW, trace_path=path, log_decisions=True,
        trace_sync=True))
    # warmup pass absorbs jit compiles, then measure a clean run
    for p, m, a in trace:
        eng.submit(p, m, arrival=a)
    eng.run()
    eng.reset()
    eng.tracer.clear()
    reqs = [eng.submit(p, m, arrival=a) for p, m, a in trace]
    eng.run()
    assert all(r.done for r in reqs)
    real_decisions = tuple(eng.sched.decision_log)
    assert real_decisions, "engine logged no decisions"

    model = rp.CostModel.fit_trace(path)
    sim_cfg = rp.ReplayConfig(n_slots=4, admission="aware",
                              prefill_chunk=16, prefill_budget=32,
                              prefix_cache=True, max_len=64)
    res = rp.replay(trace, sim_cfg, model)

    assert tuple(res.decisions) == real_decisions
    for key in ("prefills", "decode_steps", "generated_tokens",
                "slot_steps_active", "slot_steps_total", "prefill_chunks",
                "prefill_tokens", "prefill_calls", "prefix_hits",
                "prefix_hit_tokens"):
        assert res.stats[key] == eng.stats[key], key
    assert [len(r.tokens) for r in res.requests] == \
        [m for _, m, _ in trace]
    assert res.metrics.get("request_latency_steps").count == len(trace)

    # predicted wall vs the recorded time of exactly the ops the sim
    # charges (serve.step would double-count its children; kernel.* spans
    # are compile-time and excluded by the warmup clear above)
    charged = {"serve.schedule", "serve.prefix_probe", "serve.prefix_hit",
               "serve.retire", "serve.prefill", "serve.prefill_chunk",
               "serve.kv_insert", "serve.sample", "serve.decode"}
    recorded = sum(e["dur"] for e in trace_lib.load(path)
                   if e.get("ph") == "X" and e["name"] in charged) / 1e6
    assert recorded > 0
    assert abs(res.predicted_wall_s - recorded) / recorded < 0.10

    # decisions are cost-independent: a zero-cost replay schedules the same
    res0 = rp.replay(trace, sim_cfg, None)
    assert tuple(res0.decisions) == real_decisions
    assert res0.predicted_wall_s == 0.0


def test_replay_deterministic():
    reqs = rp.synthetic_requests(500, prompt_lens=(8, 48), new_tokens=(2, 6),
                                 arrival_every=0.5, shared_prefix=16, seed=4)
    cfg = rp.ReplayConfig(n_slots=4, admission="aware", prefill_chunk=16,
                          prefill_budget=32, prefix_cache=True, max_len=64)
    a = rp.replay(reqs, cfg)
    b = rp.replay(rp.synthetic_requests(500, prompt_lens=(8, 48),
                                        new_tokens=(2, 6), arrival_every=0.5,
                                        shared_prefix=16, seed=4), cfg)
    assert tuple(a.decisions) == tuple(b.decisions)
    assert a.stats == b.stats
    assert a.steps == b.steps


def test_replay_policy_comparison_under_budget_pressure():
    """The simulator's reason to exist: under a tight prefill budget with
    mixed prompt lengths, prompt-length-aware admission beats fcfs on
    tail latency — thousands of requests compared in well under a second
    of host time."""
    reqs = rp.synthetic_requests(2000, prompt_lens=(16, 96),
                                 new_tokens=(4, 8), arrival_every=1.0,
                                 shared_prefix=16, seed=2)
    lat = {}
    for adm in ("fcfs", "aware"):
        cfg = rp.ReplayConfig(n_slots=4, admission=adm, prefill_chunk=16,
                              prefill_budget=16, prefix_cache=True,
                              max_len=128)
        res = rp.replay(reqs, cfg)
        assert res.stats["prefix_hits"] > 0
        lat[adm] = res.metrics.get("request_latency_steps")
    assert lat["aware"].p95 <= lat["fcfs"].p95
    assert lat["aware"].p50 < lat["fcfs"].p50


def test_replay_scale_smoke():
    reqs = rp.synthetic_requests(10_000, prompt_lens=(16, 64),
                                 new_tokens=(2, 8), arrival_every=1.0,
                                 shared_prefix=16, seed=3)
    cfg = rp.ReplayConfig(n_slots=8, admission="aware", prefill_chunk=16,
                          prefill_budget=48, prefix_cache=True, max_len=128)
    t0 = time.perf_counter_ns()
    res = rp.replay(reqs, cfg)
    wall = (time.perf_counter_ns() - t0) / 1e9
    assert res.metrics.get("request_latency_steps").count == 10_000
    assert wall < 30.0, f"10k-request replay took {wall:.1f}s"


@pytest.mark.slow
def test_replay_100k_under_60s():
    """The acceptance bound: 100k requests, two admission policies,
    each under 60s of host wall."""
    reqs = rp.synthetic_requests(100_000, prompt_lens=(16, 192),
                                 new_tokens=(4, 16), arrival_every=1.8,
                                 shared_prefix=64, seed=1)
    for adm in ("fcfs", "aware"):
        cfg = rp.ReplayConfig(n_slots=8, admission=adm, prefill_chunk=32,
                              prefill_budget=32, prefix_cache=True,
                              max_len=256)
        t0 = time.perf_counter_ns()
        res = rp.replay(reqs, cfg)
        wall = (time.perf_counter_ns() - t0) / 1e9
        assert res.metrics.get("request_latency_steps").count == 100_000
        assert wall < 60.0, f"{adm}: {wall:.1f}s"


def test_synthetic_requests_deterministic_shared_prefix():
    a = rp.synthetic_requests(20, shared_prefix=8, seed=7)
    b = rp.synthetic_requests(20, shared_prefix=8, seed=7)
    assert all((pa == pb).all() and ma == mb and aa == ab
               for (pa, ma, aa), (pb, mb, ab) in zip(a, b))
    first = a[0][0][:8]
    assert all((p[:8] == first[:len(p[:8])]).all() for p, _, _ in a)


# ---------------------------------------------------------------------------
# benchmark timer helpers (satellite: shared best-of/percentile discipline)
# ---------------------------------------------------------------------------

def _bench_common():
    sys.path.insert(0, REPO)
    from benchmarks import common
    return common


def test_pctl_matches_numpy():
    common = _bench_common()
    rs = np.random.RandomState(1)
    samples = rs.uniform(0, 100, size=73).tolist()
    for p in (0, 25, 50, 95, 99, 100):
        assert common.pctl(samples, p) == pytest.approx(
            float(np.percentile(samples, p)))
    assert common.pctl([42.0], 95) == 42.0


def test_best_of_picks_min_after_warmup():
    common = _bench_common()
    walls = iter([0.05, 0.3, 0.1, 0.2])          # first is warmup
    runs = []

    def run():
        r = {"wall_s": next(walls), "i": len(runs)}
        runs.append(r)
        return r

    best = common.best_of(run, n=3)
    assert len(runs) == 4                        # warmup + n timed
    assert best["wall_s"] == 0.1                 # warmup's 0.05 excluded
