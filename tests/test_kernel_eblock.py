"""E-blocked fused dispatch/combine + the GMM tiling autotune table.

Pins the PR-7 seams: buffer-regime selection (`select_e_block`), E-blocked
vs resident-buffer kernel parity (forward + grad, 1- and 8-device), the
over-budget acceptance config running on the pallas backend *without* a
ref fallback, tuned-vs-default GMM tilings, the guard-estimate dedup
(`COMBINE_BLOCK_T`), and the `python -O` survival of the promoted
ValueError guards."""
import json
import logging
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch as dsp
from repro.core.moe import MoEArgs, moe_apply, moe_defs
from repro.common import param as pm
from repro.kernels import backend as bk_lib
from repro.kernels import dispatch as dl
from repro.kernels import gmm as gmm_lib
from repro.kernels import ops

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MIB = 1024 * 1024


def _mk_plan(t, e, k, cap, seed=0, d=None):
    """Random routed plan + token batch (mirrors test_kernels helper)."""
    rng = np.random.default_rng(seed)
    d = d or 16
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    logits = jnp.asarray(rng.normal(size=(t, e)), jnp.float32)
    w, eidx = jax.lax.top_k(jax.nn.softmax(logits), k)
    p = dsp.plan(eidx, w, e, cap)
    return x, p


# ---------------------------------------------------------------------------
# regime selection
# ---------------------------------------------------------------------------

def test_select_e_block_resident_when_fits():
    assert dl.select_e_block(8, 16, 16, jnp.float32) is None


def test_select_e_block_picks_power_of_two_slab():
    # 128*128*288 f32 = 18 MiB > DEFAULT_VMEM_LIMIT -> E-blocked, and the
    # chosen slab's double-buffered estimate must fit where 2x doesn't.
    eb = dl.select_e_block(128, 128, 288, jnp.float32, n_tokens=64)
    assert isinstance(eb, int) and eb & (eb - 1) == 0
    assert dl.eblock_vmem_bytes(eb, 128, 288, jnp.float32,
                                64) <= dl.DEFAULT_VMEM_LIMIT
    assert dl.eblock_vmem_bytes(2 * eb, 128, 288, jnp.float32,
                                64) > dl.DEFAULT_VMEM_LIMIT


def test_select_e_block_raises_when_one_expert_slab_too_big():
    with pytest.raises(dl.DispatchVMEMError, match="even E-blocked"):
        dl.select_e_block(4, 1024, 1024, jnp.float32, limit=64)


def test_combine_guard_shares_backend_estimate():
    """ops.combine's guard and the backend's pre-call estimate both derive
    their token-block term from COMBINE_BLOCK_T: a limit that exactly fits
    the backend estimate also passes the kernel-level guard (no regime
    mismatch on borderline shapes)."""
    e, cap, d, t, k = 4, 8, 32, 256, 2
    x, p = _mk_plan(t, e, k, cap, seed=3, d=d)
    buf = dsp.dispatch(x, p)
    limit = dl.vmem_bytes(e, cap, d, jnp.float32,
                          min(dl.COMBINE_BLOCK_T, t))
    out = ops.combine(buf, p.weight, p.expert_index, p.position,
                      vmem_limit=limit)     # must not raise at the boundary
    assert out.shape == (t, d)


# ---------------------------------------------------------------------------
# E-blocked vs resident parity (forward + grad)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,e,k,cap,e_block", [
    (64, 8, 2, 16, 2),
    (64, 8, 2, 16, 8),       # one slab == whole buffer
    (33, 6, 2, 8, 4),        # ragged: E not a multiple of e_block
    (128, 16, 4, 8, 1),      # heavy dropping, slab of one
])
def test_eblock_dispatch_combine_match_resident(t, e, k, cap, e_block):
    x, p = _mk_plan(t, e, k, cap, seed=t + e_block)
    kw = dict(n_experts=e, capacity=cap)
    buf0 = ops.dispatch(x, p.expert_index, p.position, **kw)
    bufE = ops.dispatch(x, p.expert_index, p.position, e_block=e_block,
                        **kw)
    np.testing.assert_array_equal(np.asarray(bufE), np.asarray(buf0))
    y0 = ops.combine(buf0, p.weight, p.expert_index, p.position)
    yE = ops.combine(buf0, p.weight, p.expert_index, p.position,
                     e_block=e_block)
    np.testing.assert_allclose(np.asarray(yE), np.asarray(y0), rtol=1e-6,
                               atol=1e-6)


@pytest.mark.parametrize("e_block", [1, 2, 4])
def test_eblock_grads_match_resident(e_block):
    t, e, k, cap = 48, 6, 2, 12
    x, p = _mk_plan(t, e, k, cap, seed=11)
    w = p.weight

    def loss(x_, w_, eb):
        buf = ops.dispatch(x_, p.expert_index, p.position, n_experts=e,
                           capacity=cap, e_block=eb)
        y = ops.combine(buf, w_, p.expert_index, p.position, e_block=eb)
        return jnp.sum(y * (1.0 + 0.1 * y))

    g0x, g0w = jax.grad(loss, argnums=(0, 1))(x, w, None)
    gEx, gEw = jax.grad(loss, argnums=(0, 1))(x, w, e_block)
    np.testing.assert_allclose(np.asarray(gEx), np.asarray(g0x),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gEw), np.asarray(g0w),
                               rtol=1e-5, atol=1e-6)


def test_full_moe_layer_forced_eblock_matches_ref():
    """Whole-layer parity with the E-blocked kernels forced at a small
    shape: moe_apply(pallas, dispatch_e_block=2) == moe_apply(ref), fwd
    and parameter/input grads."""
    kw = dict(n_experts=6, k=2, d_model=24, d_ff=40, dtype=jnp.float32,
              capacity_factor=2.0, eval_capacity_factor=2.0)
    params = pm.materialize(moe_defs(MoEArgs(**kw)), jax.random.PRNGKey(0))
    params["gate"]["wg"] = 0.5 * jax.random.normal(
        jax.random.PRNGKey(7), params["gate"]["wg"].shape)
    x = jax.random.normal(jax.random.PRNGKey(1), (96, 24))
    aR = MoEArgs(**kw, kernel_backend="ref")
    aP = MoEArgs(**kw, kernel_backend="pallas", dispatch_e_block=2)

    def loss(pr, x_, a):
        return jnp.sum(moe_apply(pr, x_, a, train=False)[0] ** 2)

    y_ref = moe_apply(params, x, aR, train=False)[0]
    y_pal = moe_apply(params, x, aP, train=False)[0]
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    gR = jax.grad(loss, argnums=(0, 1))(params, x, aR)
    gP = jax.grad(loss, argnums=(0, 1))(params, x, aP)
    for lR, lP in zip(jax.tree_util.tree_leaves(gR),
                      jax.tree_util.tree_leaves(gP)):
        np.testing.assert_allclose(np.asarray(lP), np.asarray(lR),
                                   rtol=2e-3, atol=2e-4)


# ---------------------------------------------------------------------------
# the acceptance config: buffer > DEFAULT_VMEM_LIMIT on the pallas path
# ---------------------------------------------------------------------------

# E=64, cap=144 (cf 2.25 @ T=2048, k=2), d=512 f32: 18 MiB buffer.
BIG = dict(t=2048, e=64, k=2, cap=144, d=512)


def test_over_budget_dispatch_runs_eblocked_no_fallback(caplog):
    """An [E, C, d] buffer past DEFAULT_VMEM_LIMIT runs on the pallas
    backend via the E-blocked kernels — no ref-fallback warning — and the
    dispatch output bit-matches the ref scatter; grads match the resident
    oracle."""
    t, e, k, cap, d = (BIG[z] for z in ("t", "e", "k", "cap", "d"))
    assert dl.vmem_bytes(e, cap, d, jnp.float32) > dl.DEFAULT_VMEM_LIMIT
    x, p = _mk_plan(t, e, k, cap, seed=5, d=d)
    a = MoEArgs(n_experts=e, k=k, d_model=d, d_ff=8, dtype=jnp.float32,
                kernel_backend="pallas")
    bk = bk_lib.get("pallas")
    with caplog.at_level(logging.WARNING, logger="repro.kernels.backend"):
        buf = bk.dispatch(x, p, a)
        y = bk.combine(buf, p, a)
    assert not [r for r in caplog.records if "falling back" in r.message]
    np.testing.assert_array_equal(np.asarray(buf),
                                  np.asarray(dsp.dispatch(x, p)))
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(dsp.combine(buf, p)),
                               rtol=1e-5, atol=1e-5)

    # grad parity vs the jnp oracle at the same (over-budget) shape
    def loss_pal(x_):
        b = bk.dispatch(x_, p, a)
        return jnp.sum(bk.combine(b, p, a) ** 2)

    def loss_ref(x_):
        b = dsp.dispatch(x_, p)
        return jnp.sum(dsp.combine(b, p) ** 2)

    gP = jax.grad(loss_pal)(x)
    gR = jax.grad(loss_ref)(x)
    np.testing.assert_allclose(np.asarray(gP), np.asarray(gR),
                               rtol=2e-4, atol=2e-5)


def test_over_budget_full_layer_pallas_matches_ref(caplog):
    """The full MoE layer at the over-budget shape: pallas (E-blocked
    dispatch/combine + tuned-tile GMMs) vs ref, forward + grads, with no
    ref-fallback warning.  The committed tuning table carries this
    config's GMM shapes, so the interpret-mode cost stays test-sized."""
    t, e, k, cap, d = (BIG[z] for z in ("t", "e", "k", "cap", "d"))
    kw = dict(n_experts=e, k=k, d_model=d, d_ff=8, dtype=jnp.float32,
              capacity_factor=2.25, eval_capacity_factor=2.25)
    params = pm.materialize(moe_defs(MoEArgs(**kw)), jax.random.PRNGKey(0))
    params["gate"]["wg"] = 0.3 * jax.random.normal(
        jax.random.PRNGKey(3), params["gate"]["wg"].shape)
    x = jax.random.normal(jax.random.PRNGKey(2), (t, d)) * 0.1
    aR = MoEArgs(**kw, kernel_backend="ref")
    aP = MoEArgs(**kw, kernel_backend="pallas")
    # the router must actually produce the over-budget buffer shape
    assert dsp.capacity_for(t, e, k, 2.25) == cap

    with caplog.at_level(logging.WARNING, logger="repro.kernels.backend"):
        y_pal = moe_apply(params, x, aP, train=False)[0]
    assert not [r for r in caplog.records if "falling back" in r.message]
    y_ref = moe_apply(params, x, aR, train=False)[0]
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)

    def loss(pr, a):
        return jnp.mean(moe_apply(pr, x, a, train=False)[0] ** 2)

    gR = jax.grad(loss)(params, aR)
    gP = jax.grad(loss)(params, aP)
    for lR, lP in zip(jax.tree_util.tree_leaves(gR),
                      jax.tree_util.tree_leaves(gP)):
        np.testing.assert_allclose(np.asarray(lP), np.asarray(lR),
                                   rtol=2e-3, atol=2e-4)


# ---------------------------------------------------------------------------
# GMM tiling autotune
# ---------------------------------------------------------------------------

def test_tuning_table_lookup_and_precedence(tmp_path, monkeypatch):
    path = tmp_path / "tunings.json"
    key = gmm_lib.tuning_key(4, 256, 64, 96, jnp.float32)
    path.write_text(json.dumps({"_meta": {"note": "test"},
                                key: [256, 128, 128]}))
    monkeypatch.setenv(gmm_lib.TUNINGS_ENV, str(path))
    # tuned entry wins when tiles are unset
    bp = gmm_lib.plan_blocks(4, 256, 64, 96, jnp.float32)
    assert (bp.bm, bp.bn, bp.bk) == (256, 128, 128)
    # explicit arguments beat the table
    bp = gmm_lib.plan_blocks(4, 256, 64, 96, jnp.float32, bm=128, bn=128,
                             bk=128)
    assert bp.bm == 128
    # unknown shape -> static defaults
    bp = gmm_lib.plan_blocks(4, 256, 128, 96, jnp.float32)
    assert (bp.bm, bp.bn, bp.bk) == (128, 128, 128)
    # metadata keys are not tilings
    assert "_meta" not in gmm_lib.load_tunings(str(path))


def test_gmm_tuned_tiles_match_default(tmp_path, monkeypatch):
    """A tuned entry changes the tile walk, never the numbers: fwd + grad
    parity between table-resolved and static-default tiles.  (Unique
    operand dims so the None-tile jit cache can't have been primed with a
    different table.)"""
    e, c, k, n = 5, 136, 72, 80
    path = tmp_path / "tunings.json"
    path.write_text(json.dumps(
        {gmm_lib.tuning_key(e, c, k, n, jnp.float32): [136, 128, 128]}))
    monkeypatch.setenv(gmm_lib.TUNINGS_ENV, str(path))
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(e, c, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(e, k, n)), jnp.float32)

    def loss(x_, w_, **tiles):
        return jnp.sum(ops.gmm(x_, w_, activation="relu", **tiles) ** 2)

    y_tuned = ops.gmm(x, w, activation="relu")            # table-resolved
    y_def = ops.gmm(x, w, activation="relu", bm=128, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(y_tuned), np.asarray(y_def),
                               rtol=1e-5, atol=1e-5)
    gt = jax.grad(loss, argnums=(0, 1))(x, w)
    gd = jax.grad(loss, argnums=(0, 1))(x, w, bm=128, bn=128, bk=128)
    for a_, b_ in zip(gt, gd):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)


def test_committed_tuning_table_is_valid():
    """The repo ships a measured table (make tune-kernels); it must parse
    and hold (bm, bn, bk) int triples keyed by ExCxKxNxdtype."""
    table = gmm_lib.load_tunings(
        os.path.join(REPO, "src", "repro", "kernels", "gmm_tunings.json"))
    assert table, "committed gmm_tunings.json is missing or empty"
    for key, tiles in table.items():
        dims = key.split("x")
        assert len(dims) == 5, key
        assert len(tiles) == 3
        assert all(isinstance(v, int) and v > 0 for v in tiles)


# ---------------------------------------------------------------------------
# 8-device mesh: EP schedule with E-blocking + tuned tilings (subprocess)
# ---------------------------------------------------------------------------

def _run(body: str, n_devices: int = 8, env_extra: dict | None = None
         ) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_devices}")
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               **(env_extra or {}))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_ep_eblock_and_tuned_gmm_8device(tmp_path):
    """The explicit all-to-all EP schedule on 8 fake devices with (a) the
    E-blocked dispatch/combine forced and (b) a tuning table blanketing
    the local GMM shapes with large tiles — both match the ref backend."""
    # Blanket table: big tiles for every plausible local (e, c, k, n) so
    # whatever per-shard shape the EP body hands the GMM resolves tuned.
    table = {}
    for e_ in (1, 2, 4, 8):
        for c_ in (8, 16, 32, 64, 128, 256, 512, 1024):
            for k_ in (16, 36):
                for n_ in (16, 36):
                    table[gmm_lib.tuning_key(e_, c_, k_, n_,
                                             jnp.float32)] = [1024, 512,
                                                              512]
    path = tmp_path / "blanket_tunings.json"
    path.write_text(json.dumps(table))
    out = _run("""
        from repro.common import param as pm
        from repro.core.moe import MoEArgs, moe_defs
        from repro.core.expert_parallel import moe_apply_ep
        from repro.sharding import context
        mesh = context.make_mesh((2, 4), ("data", "model"))
        ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")
        kw = dict(n_experts=8, k=2, d_model=16, d_ff=36,
                  dtype=jnp.float32, capacity_factor=8.0,
                  eval_capacity_factor=8.0)
        params = pm.materialize(moe_defs(MoEArgs(**kw)),
                                jax.random.PRNGKey(0))
        params["gate"]["wg"] = 0.5 * jax.random.normal(
            jax.random.PRNGKey(7), params["gate"]["wg"].shape)
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 16))
        def run(a):
            return jax.jit(lambda p, x: moe_apply_ep(
                p, x, a, train=False, ctx=ctx))(params, x)[0]
        y_ref = run(MoEArgs(**kw, kernel_backend="ref"))
        y_eb = run(MoEArgs(**kw, kernel_backend="pallas",
                           dispatch_e_block=2))
        np.testing.assert_allclose(np.asarray(y_eb), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-5)
        print("EP_EBLOCK_OK")
        y_tuned = run(MoEArgs(**kw, kernel_backend="pallas"))
        y_static = run(MoEArgs(**kw, kernel_backend="pallas",
                               gmm_autotune=False))
        np.testing.assert_allclose(np.asarray(y_tuned),
                                   np.asarray(y_static),
                                   rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(np.asarray(y_tuned),
                                   np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-5)
        print("EP_TUNED_OK")
    """, env_extra={gmm_lib.TUNINGS_ENV: str(path)})
    assert "EP_EBLOCK_OK" in out and "EP_TUNED_OK" in out


# ---------------------------------------------------------------------------
# python -O: the promoted guards must be real exceptions
# ---------------------------------------------------------------------------

def test_promoted_guards_survive_python_O():
    """Under `python -O` asserts vanish; the PR-7 promotions (gmm
    activation guards, top-k k<=E, Scheduler.admit chunking guard) must
    still raise ValueError."""
    script = textwrap.dedent("""
        if __debug__:
            raise SystemExit("must run under -O")
        import jax.numpy as jnp
        hits = []
        from repro.kernels import gmm
        for fn in (gmm._act, gmm._act_grad):
            try:
                fn(jnp.ones((2,)), "tanh")
            except ValueError:
                hits.append("act")
        from repro.kernels import topk_gating as tk
        try:
            tk._topk_raw(jnp.ones((4, 3)), 3, 1, 256, True)
        except ValueError:
            hits.append("topk")
        from repro.serve.scheduler import Scheduler, RequestQueue
        try:
            Scheduler(2, prefill_chunk=8).admit(RequestQueue(), 0)
        except ValueError:
            hits.append("admit")
        print("HITS=" + ",".join(hits))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-O", "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "HITS=act,act,topk,admit" in out.stdout
