"""Roofline machinery: collective parsing, analytic-vs-XLA FLOPs validation
on unrolled single-trip configs (where XLA's while-body-once counting is
exact), and term arithmetic."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch import roofline as rl

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_parse_collectives_shapes_and_factors():
    hlo = """
  %ar = f32[128,512]{1,0} all-reduce(%x), replica_groups=[8,8]<=[64]
  %ag.1 = bf16[64,1024]{1,0} all-gather(%y), replica_groups=[4,16]<=[64]
  %rs = f32[16]{0} reduce-scatter(%z), replica_groups=[16,4]<=[64]
  %a2a = bf16[2,8]{1,0} all-to-all(%w), replica_groups=[8,8]<=[64]
  %cp = f32[10]{0} collective-permute(%v)
  %ard = f32[128]{0} all-reduce-done(%h)
"""
    got = rl.parse_collectives(hlo, 64)
    by = got["wire_bytes_by_kind"]
    assert by["all-reduce"] == 128 * 512 * 4 * 2 * 7 / 8
    assert by["all-gather"] == 64 * 1024 * 2 * 15 / 16
    assert by["reduce-scatter"] == 16 * 4 * 3
    assert by["all-to-all"] == 2 * 8 * 2 * 7 / 8
    assert by["collective-permute"] == 10 * 4
    assert got["op_counts"]["all-reduce"] == 1     # -done line skipped


def test_roofline_terms_and_dominance():
    rec = {
        "n_devices": 256, "kind": "train", "global_batch": 256,
        "seq_len": 4096,
        "analytic": {"flops_per_dev": 197e12,       # exactly 1s compute
                     "hbm_bytes_per_dev": 819e9 / 2,  # 0.5s memory
                     "wire_bytes_per_dev": 50e9 * 2},  # 2s collective
    }
    from repro.configs.base import get_config
    r = rl.analyze(rec, get_config("llama3-8b"))
    assert r.dominant == "collective"
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(2.0)
    assert 0 < rl.roofline_fraction(r, 256) < 1


@pytest.mark.slow
def test_analytic_flops_match_xla_on_unrolled_model():
    """On a config where every loop has trip count 1 (scan_layers=False,
    S == q_block == kv_block == xent chunk), XLA's cost_analysis counts the
    whole program exactly once — analytic FLOPs must agree within ~25%
    (XLA fuses/elides some elementwise work; matmuls dominate)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=16"
        import jax, jax.numpy as jnp
        import dataclasses
        from repro.configs.base import get_config
        from repro.configs.shapes import ShapeSpec
        from repro.launch.analytic import analyze_cell
        from repro.launch.steps import lower_cell
        from repro.sharding import context
        mesh = context.make_mesh((4, 4), ("data", "model"))
        cfg = get_config("smollm-135m").replace(
            n_layers=2, scan_layers=False, remat=False,
            q_block=512, kv_block=512)
        shape = ShapeSpec("train_tiny", "train", 512, 8)
        lowered, spec = lower_cell(cfg, shape, mesh)
        compiled = lowered.compile()
        xla = context.compiled_cost_analysis(compiled)["flops"]
        ana = analyze_cell(cfg, shape, mesh, "dp_tp_ep").flops_per_dev
        ratio = ana / xla
        print("RATIO", ratio)
        assert 0.7 < ratio < 1.45, (ana, xla)
        print("VALID_OK")
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "VALID_OK" in out.stdout, out.stdout
