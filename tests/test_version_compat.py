"""jax-version drift guard.

The seed broke because src/ modules reached for jax symbols that do not
exist in the pinned jax (abstract-mesh queries, ``jax.set_mesh``,
top-level ``shard_map``, ``axis_types=``, ``lax.axis_size``).  All
version probing now lives in repro/sharding/context.py behind getattr
guards; this module fails the build if drift creeps back in:

1. every module under src/repro imports cleanly (catches module-level
   AttributeErrors on the pinned version), and
2. no source file outside the compat shim references a known-drifting
   symbol directly.
"""
import importlib
import os
import pkgutil
import re

import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")

# The single file allowed to probe jax's API surface (with getattr guards).
COMPAT_SHIM = os.path.join("repro", "sharding", "context.py")

# Symbols that differ across the jax versions this repo has met.  Each
# pattern matches a *direct* use; the compat shim wraps them all.
BANNED = [
    (r"get_abstract_mesh", "context.abstract_mesh_or_none()"),
    (r"jax\.set_mesh", "context.use_mesh(mesh)"),
    (r"jax\.shard_map", "context.shard_map(...)"),
    (r"experimental\.shard_map", "context.shard_map(...)"),
    (r"AxisType", "context.make_mesh(...)"),
    (r"axis_types\s*=", "context.make_mesh(...)"),
    (r"check_vma", "context.shard_map(...)"),
    (r"check_rep", "context.shard_map(...)"),
    (r"lax\.axis_size", "context.axis_size(name)"),
    (r"jax\.sharding\.use_mesh", "context.use_mesh(mesh)"),
    (r"jax\.typeof", "(no wrapper yet — add one to context.py)"),
    (r"\.cost_analysis\(\)", "context.compiled_cost_analysis(compiled)"),
]


def _src_py_files():
    for root, _dirs, files in os.walk(os.path.join(SRC, "repro")):
        for f in files:
            if f.endswith(".py"):
                yield os.path.join(root, f)


def test_every_src_module_imports():
    import repro
    failures = []
    for mod in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        try:
            importlib.import_module(mod.name)
        except Exception as e:          # noqa: BLE001 - report them all
            failures.append((mod.name, f"{type(e).__name__}: {e}"))
    assert not failures, failures


@pytest.mark.parametrize("pattern,replacement",
                         BANNED, ids=[b[0] for b in BANNED])
def test_no_drifting_jax_symbols_outside_compat_shim(pattern, replacement):
    rx = re.compile(pattern)
    hits = []
    for path in _src_py_files():
        if path.endswith(COMPAT_SHIM):
            continue
        with open(path) as f:
            for lineno, line in enumerate(f, 1):
                if rx.search(line):
                    hits.append(f"{os.path.relpath(path, SRC)}:{lineno}: "
                                f"{line.strip()}")
    assert not hits, (
        f"direct use of a version-drifting jax symbol; use {replacement} "
        f"from repro.sharding.context instead:\n" + "\n".join(hits))


def test_compat_shim_works_on_pinned_version():
    """The shim's guarded queries must all be callable on the installed
    jax — this is what 'graceful degradation' means."""
    from repro.sharding import context
    context.abstract_mesh_or_none()          # None on 0.4.x, mesh later
    mesh = context.make_mesh((1, 1), ("data", "model"))
    with context.use_mesh(mesh):
        pass
    assert isinstance(context.CAN_CONSTRAIN_UNDER_MANUAL, bool)
