"""Pipeline parallelism: the SPMD GPipe stack must match the sequential
stack bit-for-bit (fwd) and in gradients, including ragged (padded) depths.
Runs in a subprocess with 8 placeholder devices."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, n_devices: int = 8) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_devices}")
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_pipeline_matches_sequential():
    out = _run("""
        from repro.common import param as pm
        from repro.configs.base import get_config
        from repro.models import lm
        from repro.train import pipeline as pp
        from repro.sharding import context
        import repro.models.transformer as tr

        mesh = context.make_mesh((4, 2), ("data", "model"))
        # 7 layers over 4 stages => padded to 8 with one identity layer.
        cfg = get_config("kimi-k2-1t-a32b").replace(
            n_layers=7, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
            vocab_size=128, n_experts=4, moe_k=2, moe_d_ff=32,
            param_dtype=jnp.float32, compute_dtype=jnp.float32,
            q_block=16, kv_block=16, capacity_factor=8.0, remat=False,
            # balance CVs are batch statistics: per-microbatch vs full-batch
            # differ by construction, so zero them for exact equivalence
            # (aux normalization itself is covered by the loss-shape check).
            w_importance=0.0, w_load=0.0)
        n_stages, n_micro = 4, 4

        # sequential reference params
        seq_defs = lm.lm_defs(cfg)
        seq_params = pm.materialize(seq_defs, jax.random.PRNGKey(0))

        # pipeline params: copy the same per-layer weights into stages
        pp_defs = pp.pipeline_param_defs(cfg, n_stages)
        pp_params = pm.materialize(pp_defs, jax.random.PRNGKey(0))
        per, total = pp.stages_for(cfg, n_stages)
        def restack(seq_leaf, pp_leaf):
            # seq stacked [7, ...] -> padded [8, ...] -> [4, 2, ...]
            pad = jnp.zeros((total - cfg.n_layers,) + seq_leaf.shape[1:],
                            seq_leaf.dtype)
            return jnp.concatenate([seq_leaf, pad], 0).reshape(
                (n_stages, per) + seq_leaf.shape[1:])
        pp_params["blocks"] = jax.tree_util.tree_map(
            restack, seq_params["blocks"]["periods"]["pos0"],
            pp_params["blocks"])
        pp_params["blocks"] = pp.zero_identity_padding(
            pp_params["blocks"], cfg, n_stages)
        pp_params["embed"] = seq_params["embed"]
        pp_params["ln_f"] = seq_params["ln_f"]
        pp_params["unembed"] = seq_params["unembed"]

        batch = {"tokens": jnp.ones((8, 16), jnp.int32),
                 "labels": jnp.ones((8, 16), jnp.int32)}
        batch["tokens"] = jax.random.randint(jax.random.PRNGKey(5),
                                             (8, 16), 1, 128)
        batch["labels"] = jnp.roll(batch["tokens"], -1, 1)

        loss_seq, _ = lm.lm_loss(seq_params, batch, cfg, rng=None,
                                 train=False)

        loss_pp, m = jax.jit(lambda p, b: pp.pipeline_lm_loss(
            p, b, cfg, mesh=mesh, n_stages=n_stages,
            n_micro=n_micro, train=False))(pp_params, batch)
        print("SEQ", float(loss_seq), "PP", float(loss_pp))
        np.testing.assert_allclose(float(loss_pp), float(loss_seq),
                                   rtol=2e-4)

        # gradients agree for a layer deep inside the stack
        def f_pp(p):
            return pp.pipeline_lm_loss(p, batch, cfg, mesh=mesh,
                                       n_stages=n_stages,
                                       n_micro=n_micro,
                                       train=False)[0]
        def f_seq(p):
            return lm.lm_loss(p, batch, cfg, rng=None, train=False)[0]
        g_pp = jax.jit(jax.grad(f_pp))(pp_params)
        g_seq = jax.grad(f_seq)(seq_params)
        a = np.asarray(g_pp["blocks"]["attn"]["wq"]).reshape(
            total, *g_seq["blocks"]["periods"]["pos0"]["attn"]["wq"]
            .shape[1:])[:cfg.n_layers]
        b_ = np.asarray(g_seq["blocks"]["periods"]["pos0"]["attn"]["wq"])
        np.testing.assert_allclose(a, b_, rtol=2e-3, atol=2e-5)
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out
