"""The fused single-launch decode step (kernels/fused_decode.py).

Correctness bar: the fused kernel is *bit identical* to the unfused pallas
pipeline it replaces (same dots, same cast points, same ascending-k f32
combine), matches the independently-formulated oracle to float tolerance,
reports the exact route telemetry, and collapses the per-MoE-layer decode
hot path from >=4 pallas launches to exactly 1.  Serving-level on/off
parity lives in test_serve.py (test_serve_parity_matrix_fused*).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import param as pm
from repro.core import dispatch as dsp
from repro.core.moe import MoEArgs, moe_apply, moe_defs
from repro.core.router import RouterSpec
from repro.kernels import fused_decode as fd
from repro.kernels import ops, ref


def _problem(t=8, d=16, e=4, f=32, k=2, gated=False, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (t, d), jnp.float32)
    wg = jax.random.normal(ks[1], (d, e), jnp.float32) * 0.5
    w1 = jax.random.normal(ks[2], (e, d, f), jnp.float32) * 0.1
    w2 = jax.random.normal(ks[3], (e, f, d), jnp.float32) * 0.1
    w3 = (jax.random.normal(ks[4], (e, d, f), jnp.float32) * 0.1
          if gated else None)
    return x, wg, w1, w2, w3


VALID = np.array([1, 1, 1, 0, 1, 1, 0, 1], np.float32)


# ---------------------------------------------------------------------------
# kernel vs oracle (independent formulation: lax.top_k + argsort plan)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("activation", ["relu", "swiglu"])
def test_decode_step_matches_oracle(activation):
    gated = activation == "swiglu"
    x, wg, w1, w2, w3 = _problem(gated=gated)
    valid = jnp.asarray(VALID)
    y, load, over = fd.decode_step(x, valid, wg, w1, w2, w3, k=2,
                                   capacity=8, activation=activation)
    yr, lr, ovr = ref.fused_decode_ref(x, wg, w1, w2, w3, valid, k=2,
                                       capacity=8)
    assert y.shape == x.shape and y.dtype == x.dtype
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(load), np.asarray(lr))
    np.testing.assert_array_equal(np.asarray(over), np.asarray(ovr))
    # masked-out tokens produce exactly zero output and route nowhere
    np.testing.assert_array_equal(np.asarray(y)[VALID == 0], 0.0)
    assert int(load.sum()) == int(VALID.sum()) * 2


def test_decode_step_overflow_telemetry_tight_capacity():
    """capacity=1 forces drops; load counts every kept-or-dropped positive
    assignment, overflow exactly the dropped ones (route_telemetry math)."""
    x, wg, w1, w2, _ = _problem()
    valid = jnp.ones((8,), jnp.float32)
    y, load, over = fd.decode_step(x, valid, wg, w1, w2, k=2, capacity=1)
    yr, lr, ovr = ref.fused_decode_ref(x, wg, w1, w2, valid=valid, k=2,
                                       capacity=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(load), np.asarray(lr))
    np.testing.assert_array_equal(np.asarray(over), np.asarray(ovr))
    assert int(over.sum()) > 0
    assert int((load - over).max()) <= 1      # kept <= capacity per expert


def test_decode_step_validates_arguments():
    x, wg, w1, w2, _ = _problem()
    valid = jnp.ones((8,), jnp.float32)
    with pytest.raises(ValueError, match="w3"):
        fd.decode_step(x, valid, wg, w1, w2, k=2, capacity=8,
                       activation="swiglu")
    with pytest.raises(ValueError, match="k"):
        fd.decode_step(x, valid, wg, w1, w2, k=5, capacity=8)


# ---------------------------------------------------------------------------
# bit-exactness vs the unfused pallas pipeline (the launches it replaces)
# ---------------------------------------------------------------------------

def _unfused_decode(x, wg, w1, w2, w3, valid, *, k, capacity,
                    activation="relu"):
    """The exact op sequence the fused kernel collapses: pallas top-k
    gating on the clean logits, stable-argsort plan, pallas dispatch /
    expert FFN / combine."""
    logits = jnp.dot(x.astype(jnp.float32), wg.astype(jnp.float32))
    w, idx, _ = ops.topk_gating_full(logits, k)
    w = w * valid.astype(jnp.float32)[:, None]
    p = dsp.plan(idx, w, wg.shape[-1], capacity)
    buf = ops.dispatch(x, p.expert_index, p.position,
                       n_experts=p.n_experts, capacity=capacity)
    params = {"w1": w1, "w2": w2}
    if w3 is not None:
        params["w3"] = w3
    out = ops.expert_ffn(params, buf, activation=activation)
    return ops.combine(out, p.weight, p.expert_index, p.position,
                       out_dtype=x.dtype)


@pytest.mark.parametrize("activation", ["relu", "swiglu"])
def test_decode_step_bit_exact_vs_unfused(activation):
    gated = activation == "swiglu"
    x, wg, w1, w2, w3 = _problem(gated=gated, seed=3)
    valid = jnp.asarray(VALID)
    y, _, _ = fd.decode_step(x, valid, wg, w1, w2, w3, k=2, capacity=8,
                             activation=activation)
    want = _unfused_decode(x, wg, w1, w2, w3, valid, k=2, capacity=8,
                           activation=activation)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))


@pytest.mark.parametrize("mode", ["ffn", "proj"])
def test_routed_apply_bit_exact_vs_unfused(mode):
    """Plan-mode kernel (routing done outside — expert_choice, MoA): same
    scatter/FFN/combine as the separate pallas launches, bit for bit."""
    t, e, k, cap, d = 16, 4, 2, 8, 16
    x = jax.random.normal(jax.random.PRNGKey(5), (t, d), jnp.float32)
    eidx = jax.random.randint(jax.random.PRNGKey(6), (t, k), 0, e)
    wt = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(7), (t, k)),
                        axis=-1)
    p = dsp.plan(eidx, wt, e, cap)
    if mode == "ffn":
        f = 32
        w1 = jax.random.normal(jax.random.PRNGKey(8), (e, d, f)) * 0.1
        w2 = jax.random.normal(jax.random.PRNGKey(9), (e, f, d)) * 0.1
        got = ops.fused_routed_apply(x, p, p, w1, w2, mode="ffn",
                                     activation="relu")
        buf = ops.dispatch(x, p.expert_index, p.position, n_experts=e,
                           capacity=cap)
        out = ops.expert_ffn({"w1": w1, "w2": w2}, buf, activation="relu")
    else:
        d_out = 24
        w = jax.random.normal(jax.random.PRNGKey(8), (e, d, d_out)) * 0.1
        got = ops.fused_routed_apply(x, p, p, w, mode="proj",
                                     out_dtype=x.dtype)
        buf = ops.dispatch(x, p.expert_index, p.position, n_experts=e,
                           capacity=cap)
        out = ops.gmm(buf, w)
    want = ops.combine(out, p.weight, p.expert_index, p.position,
                       out_dtype=x.dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# backend wiring: moe_apply on/off parity, launch count, VMEM fallback
# ---------------------------------------------------------------------------

MOE_KW = dict(n_experts=4, k=2, d_model=16, d_ff=32, dtype=jnp.float32,
              capacity_factor=2.0)


def _moe_problem(policy="noisy_topk", **over):
    kw = dict(MOE_KW, router=RouterSpec(policy=policy, capacity_factor=2.0),
              **over)
    params = pm.materialize(moe_defs(MoEArgs(**kw)), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, kw["d_model"]),
                          jnp.float32)
    mask = jnp.asarray(VALID)
    return kw, params, x, mask


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("policy", ["noisy_topk", "expert_choice"])
def test_moe_apply_fused_decode_parity(policy, backend):
    """moe_apply(train=False) with fused_decode on is bit-identical to the
    unfused path and reports the same telemetry, for both router policies
    (full-fusion vs plan-mode kernels) on both backends."""
    kw, params, x, mask = _moe_problem(policy, kernel_backend=backend)
    y0, aux0 = moe_apply(params, x, MoEArgs(**kw), train=False, mask=mask)
    y1, aux1 = moe_apply(params, x, MoEArgs(**kw, fused_decode=True),
                         train=False, mask=mask)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    for key in ("expert_load", "overflow"):
        np.testing.assert_array_equal(np.asarray(aux0["telemetry"][key]),
                                      np.asarray(aux1["telemetry"][key]))
    # decode consumers read telemetry only; the fused branch's aux_loss
    # and balance metrics are inert zeros
    assert float(aux1["aux_loss"]) == 0.0


def test_fused_decode_ignored_under_train():
    kw, params, x, mask = _moe_problem(kernel_backend="pallas")
    y0, aux0 = moe_apply(params, x, MoEArgs(**kw), train=True,
                         rng=jax.random.PRNGKey(2), mask=mask)
    y1, aux1 = moe_apply(params, x, MoEArgs(**kw, fused_decode=True),
                         train=True, rng=jax.random.PRNGKey(2), mask=mask)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(aux0["aux_loss"]),
                                  np.asarray(aux1["aux_loss"]))


def _count_launches(fn, monkeypatch):
    import jax.experimental.pallas as pl
    count = [0]
    real = pl.pallas_call

    def counting(*args, **kwargs):
        count[0] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(pl, "pallas_call", counting)
    jax.clear_caches()
    try:
        jax.block_until_ready(fn())
    finally:
        jax.clear_caches()
    return count[0]


def test_fused_decode_single_launch(monkeypatch):
    """The acceptance criterion: >=4 pallas launches per MoE decode layer
    (top-k, dispatch, 2x GMM, combine) collapse to exactly 1."""
    kw, params, x, mask = _moe_problem(kernel_backend="pallas")
    unfused = _count_launches(
        lambda: moe_apply(params, x, MoEArgs(**kw), train=False,
                          mask=mask)[0], monkeypatch)
    fused = _count_launches(
        lambda: moe_apply(params, x, MoEArgs(**kw, fused_decode=True),
                          train=False, mask=mask)[0], monkeypatch)
    assert unfused >= 4, unfused
    assert fused == 1, fused


def test_fused_decode_vmem_fallback_warns_and_matches(monkeypatch):
    """Past the slab budget the pallas backend falls back *loudly* to the
    unfused pipeline (the dispatch VMEM fallback pattern) — same output."""
    kw, params, x, mask = _moe_problem(kernel_backend="pallas")
    tiny = MoEArgs(**kw, fused_decode=True, dispatch_vmem_limit=1024)
    with pytest.warns(RuntimeWarning, match="fused slab"):
        y1, aux1 = moe_apply(params, x, tiny, train=False, mask=mask)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        y0, _ = moe_apply(params, x, MoEArgs(**kw), train=False, mask=mask)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    for key in ("expert_load", "overflow"):
        assert key in aux1["telemetry"]


def test_vmem_estimates_scale():
    relu = fd.decode_vmem_bytes(8, 16, 32, 4, 8, jnp.float32, jnp.float32)
    gated = fd.decode_vmem_bytes(8, 16, 32, 4, 8, jnp.float32, jnp.float32,
                                 gated=True)
    assert 0 < relu < gated
    proj = fd.routed_vmem_bytes(8, 16, 24, 0, 4, 8, jnp.float32,
                                jnp.float32, mode="proj")
    ffn = fd.routed_vmem_bytes(8, 16, 16, 32, 4, 8, jnp.float32,
                               jnp.float32, mode="ffn")
    assert 0 < proj and 0 < ffn
