"""Shared fixtures + tier-1 test selection.

NOTE: no XLA_FLAGS here — tests see 1 CPU device; only launch/dryrun.py
forces 512 placeholder devices.  Multi-device tests spawn subprocesses
with their own XLA_FLAGS (see test_distributed.py).

Tier-1 selection: a bare ``pytest`` run deselects ``slow`` (and ``tpu``)
tests — the default is effectively ``-m "not slow and not tpu"``.  Passing
a non-empty ``-m`` expression disables the default and runs exactly what
you asked for: ``-m slow`` for the slow tier (``make test-slow``),
``-m "not tpu"`` for everything runnable off-TPU (``make test-all``).
Markers themselves are registered in pyproject.toml."""
import jax
import jax.numpy as jnp
import pytest


def pytest_collection_modifyitems(config, items):
    # Guard: the serving parity matrix's slowest cells — interpret-mode
    # pallas backends and the 8-device subprocess — are auto-marked slow
    # so tier-1 keeps its wall-clock; `make test-slow` runs the full
    # matrix (policies x backends x chunked/unchunked x mesh sizes).
    for item in items:
        if item.name.startswith("test_serve_parity_matrix") and (
                "pallas" in item.name or "8device" in item.name):
            item.add_marker(pytest.mark.slow)
    if config.option.markexpr:
        return          # explicit -m wins
    deselected = [i for i in items
                  if "slow" in i.keywords or "tpu" in i.keywords]
    if not deselected:
        return
    dropped = set(map(id, deselected))
    config.hook.pytest_deselected(items=deselected)
    items[:] = [i for i in items if id(i) not in dropped]


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def small_config(name: str, **overrides):
    """Reduced config of the same family (the assigned smoke-test shape)."""
    from repro.configs.base import get_config
    cfg = get_config(name)
    kw = dict(
        n_layers=(2 * cfg.period + 1) if cfg.period > 1 else 2,
        d_model=64, vocab_size=256,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        q_block=32, kv_block=32)
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=2, head_dim=16)
    if cfg.d_ff:
        kw.update(d_ff=96)
    if cfg.n_experts:
        kw.update(n_experts=4, moe_k=2, moe_d_ff=32)
    if getattr(cfg, "moa_experts", 0):
        kw.update(moa_experts=4, moa_k=2, moa_heads_per_expert=2)
    if cfg.ssm_d_state:
        kw.update(ssm_d_state=4)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    if cfg.n_prefix:
        kw.update(n_prefix=4)
    kw.update(overrides)
    return cfg.replace(**kw)
