"""The continuous-batching serving subsystem.

The correctness bar (ISSUE 3): per-request greedy outputs are *bit
identical* between the continuous-batching engine (slots of mixed age,
staggered arrivals, recycled the step a sequence finishes) and one-at-a-
time sequential generation; slot recycling works under oversubscription;
EOS/length retirement is uniform (including the final budget token — the
old static engine's off-by-one); the prefill_tp → decode_std boundary
reshards explicitly on an 8-device fake mesh; and per-step MoE telemetry
accounts for every routed token.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import param as pm
from repro.configs.base import get_config
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.kv_cache import SlotKVCache
from repro.serve.scheduler import Request, RequestQueue, Scheduler

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _moe_cfg():
    return get_config("kimi-k2-1t-a32b").replace(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        vocab_size=64, n_experts=4, moe_k=2, moe_d_ff=32,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        q_block=16, kv_block=16, capacity_factor=2.0)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = _moe_cfg()
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


# Mixed prompt lengths, mixed budgets, staggered arrivals.
TRACE = [(8, 6, 0), (12, 4, 0), (16, 8, 1), (8, 5, 2), (12, 7, 3),
         (16, 3, 5)]


def _trace_prompts(vocab: int):
    rs = np.random.RandomState(1)
    return [(rs.randint(1, vocab, (plen,)).astype(np.int32), mnt, arr)
            for plen, mnt, arr in TRACE]


# ---------------------------------------------------------------------------
# scheduler + queue (host-side policy, no device work)
# ---------------------------------------------------------------------------

def test_queue_respects_arrivals_fifo():
    q = RequestQueue()
    for rid, arr in enumerate((0, 2, 0)):
        q.push(Request(rid=rid, prompt=np.zeros(4, np.int32),
                       max_new_tokens=1, arrival=arr))
    assert q.pop_ready(0).rid == 0
    assert q.pop_ready(0).rid == 2      # rid 1 hasn't arrived yet
    assert q.pop_ready(1) is None
    assert q.pop_ready(2).rid == 1
    assert not q


def test_scheduler_continuous_vs_static_admission():
    def fill(policy):
        q = RequestQueue()
        for rid in range(3):
            q.push(Request(rid=rid, prompt=np.zeros(4, np.int32),
                           max_new_tokens=1))
        s = Scheduler(2, policy=policy)
        first = s.admit(q, 0)
        assert [slot for slot, _ in first] == [0, 1]
        s.retire(0)                      # one slot frees, one stays busy
        return s, [r.rid for _, r in s.admit(q, 1)]

    _, cont = fill("continuous")
    assert cont == [2]                   # continuous refills immediately
    s, stat = fill("static")
    assert stat == []                    # static waits for the full drain
    s.retire(1)
    assert [r.rid for _, r in s.admit(None or RequestQueue(), 2)] == []
    with pytest.raises(ValueError):
        Scheduler(2, policy="banana")


# ---------------------------------------------------------------------------
# SlotKVCache: insert / evict / compact page semantics
# ---------------------------------------------------------------------------

def test_kv_cache_slot_ops(moe_setup):
    cfg, _ = moe_setup
    kv = SlotKVCache(cfg, n_slots=3, max_len=32)

    def page(value):
        return jax.tree_util.tree_map(
            lambda a: jnp.full(a.shape, value, a.dtype),
            pm.materialize(kv.seq_defs, jax.random.PRNGKey(0)))

    def slot_vals(slot):
        out = []
        for ax, leaf in zip(jax.tree_util.tree_leaves(kv._batch_axes),
                            jax.tree_util.tree_leaves(kv.cache)):
            out.append(np.unique(np.take(np.asarray(leaf), slot, axis=ax)))
        return out

    for slot in range(3):
        kv.insert(slot, page(float(slot + 1)), length=8 + slot)
    for slot in range(3):
        assert all(v.tolist() == [slot + 1] for v in slot_vals(slot))
    assert kv.lengths.tolist() == [8, 9, 10]

    kv.evict(1)
    assert all(v.tolist() == [0] for v in slot_vals(1))
    assert kv.lengths[1] == 0
    # other slots untouched
    assert all(v.tolist() == [1] for v in slot_vals(0))

    kv.compact([2, 0, 1])
    assert all(v.tolist() == [3] for v in slot_vals(0))
    assert all(v.tolist() == [1] for v in slot_vals(1))
    assert kv.lengths.tolist() == [10, 8, 0]


def test_kv_cache_append_stages_partial_pages(moe_setup):
    """Chunked prefill's partial pages: intermediate appends stage (the
    pool is untouched — a mid-prefill slot never decodes), the last
    append folds into the pool, and lengths grow monotonically."""
    cfg, _ = moe_setup
    kv = SlotKVCache(cfg, n_slots=2, max_len=32)

    def page(value):
        return jax.tree_util.tree_map(
            lambda a: jnp.full(a.shape, value, a.dtype),
            pm.materialize(kv.seq_defs, jax.random.PRNGKey(0)))

    pool_before = jax.tree_util.tree_leaves(kv.cache)
    kv.append(0, page(1.0), length=8, last=False)
    assert kv.staged(0) is not None and kv.lengths[0] == 8
    for a, b in zip(pool_before, jax.tree_util.tree_leaves(kv.cache)):
        assert a is b                        # pool untouched while staged
    with pytest.raises(ValueError, match="shrank"):   # monotonic growth —
        # a real exception (not an assert): must survive `python -O`
        kv.append(0, page(2.0), length=4, last=False)
    kv.append(0, page(2.0), length=16, last=True)
    assert kv.staged(0) is None and kv.lengths[0] == 16
    leaf = jax.tree_util.tree_leaves(kv.cache)[0]
    ax = jax.tree_util.tree_leaves(kv._batch_axes)[0]
    assert np.unique(np.take(np.asarray(leaf), 0, axis=ax)).tolist() == [2]
    kv.release(0)
    assert kv.lengths[0] == 0 and kv.staged(0) is None


# ---------------------------------------------------------------------------
# continuous batching == sequential generation, bit for bit (greedy)
# ---------------------------------------------------------------------------

def test_continuous_matches_sequential_and_recycles_slots(moe_setup):
    cfg, params = moe_setup
    specs = _trace_prompts(cfg.vocab_size)

    eng = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=3))
    reqs = [eng.submit(p, m, arrival=a) for p, m, a in specs]
    eng.run()

    assert all(r.done for r in reqs)
    # Oversubscription: 6 requests through 3 slots, recycled continuously.
    assert eng.sched.admitted == len(specs)
    assert eng.sched.max_concurrent <= 3
    assert eng.stats["prefills"] == len(specs)
    assert all(length == 0 for length in eng.kv.lengths)  # pool drained

    oracle = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=1))
    for req, (p, m, _) in zip(reqs, specs):
        oracle.reset()
        ref = oracle.submit(p, m)
        oracle.run()
        assert ref.tokens == req.tokens, \
            f"req {req.rid}: {ref.tokens} != {req.tokens}"


def test_continuous_beats_static_scheduling(moe_setup):
    """Same staggered mixed-length trace: continuous batching finishes in
    strictly fewer fused decode steps (each step is the same jitted call,
    so fewer steps at equal per-step cost == higher tokens/sec — the
    wall-clock version of this claim is benchmarks/serve_bench.py)."""
    cfg, params = moe_setup
    specs = _trace_prompts(cfg.vocab_size)
    steps, utils = {}, {}
    for policy in ("static", "continuous"):
        eng = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=3,
                                                   policy=policy))
        reqs = [eng.submit(p, m, arrival=a) for p, m, a in specs]
        eng.run()
        assert all(r.done for r in reqs)
        steps[policy] = eng.stats["decode_steps"]
        utils[policy] = eng.slot_utilization
    assert steps["continuous"] < steps["static"], steps
    assert utils["continuous"] > utils["static"], utils


# ---------------------------------------------------------------------------
# EOS / max-len retirement (uniform, including the final budget token)
# ---------------------------------------------------------------------------

def test_eos_checked_on_final_token(moe_setup):
    """Regression for the static engine's off-by-one drain: the
    ``max_new_tokens``-th sampled token was appended but never checked for
    EOS, so a terminal EOS was misreported as a length stop."""
    cfg, params = moe_setup
    prompt = np.random.RandomState(3).randint(1, cfg.vocab_size, (8,))
    probe = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=1))
    ref = probe.submit(prompt, 3)
    probe.run()
    assert ref.done_reason == "length" and len(ref.tokens) == 3

    final = ref.tokens[-1]
    budget = 3 if final not in ref.tokens[:-1] else ref.tokens.index(final) + 1
    eng = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=1,
                                               eos_id=final))
    req = eng.submit(prompt, budget)
    eng.run()
    # Same budget, same greedy stream: the EOS landing exactly on the last
    # budget token must be reported as an EOS stop, not a length stop.
    assert req.tokens == ref.tokens[:budget]
    assert req.done_reason == "eos"


def test_midstream_eos_frees_slot_for_queued_request(moe_setup):
    cfg, params = moe_setup
    rs = np.random.RandomState(4)
    probe = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=1))
    p0 = rs.randint(1, cfg.vocab_size, (8,))
    ref = probe.submit(p0, 8)
    probe.run()
    eos = ref.tokens[2]                   # stop p0 after <= 3 tokens

    eng = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=1,
                                               eos_id=eos))
    r0 = eng.submit(p0, 8)
    r1 = eng.submit(rs.randint(1, cfg.vocab_size, (12,)), 4)
    eng.run()
    assert r0.done_reason == "eos" and len(r0.tokens) <= 3
    assert r1.done                        # recycled into the freed slot
    assert eng.sched.max_concurrent == 1
    assert r1.admitted_step >= r0.finished_step


def test_generate_compat_pads_after_eos(moe_setup):
    cfg, params = moe_setup
    prompts = np.random.RandomState(5).randint(1, cfg.vocab_size, (3, 8))
    probe = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=3))
    ref = probe.generate(prompts, max_new_tokens=6)
    assert ref.shape == (3, 6)
    eos = int(ref[0, 2])                  # row 0 stops early
    eng = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=3,
                                               eos_id=eos))
    out = eng.generate(prompts, max_new_tokens=6)
    assert out.shape[0] == 3
    row0 = out[0].tolist()
    stop = row0.index(eos)
    assert all(t == eos for t in row0[stop:])   # padded with eos after stop


# ---------------------------------------------------------------------------
# telemetry: every routed token is accounted per step
# ---------------------------------------------------------------------------

def test_decode_telemetry_accounts_for_active_tokens(moe_setup):
    """With dead-slot masking (the default) only *active* slots route —
    the per-step expert_load total is active·k·layers; with masking off
    (the pre-router baseline) every pool slot routes and the total is
    n_slots·k·layers."""
    cfg, params = moe_setup
    n_moe_layers = cfg.n_layers          # kimi family: MoE in every layer
    eng = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=3))
    for p, m, a in _trace_prompts(cfg.vocab_size):
        eng.submit(p, m, arrival=a)
    eng.run()
    assert len(eng.telemetry) == eng.stats["decode_steps"]
    for entry in eng.telemetry:
        assert entry["expert_load"].shape == (cfg.n_experts,)
        # every active token routes to k experts in every MoE layer; dead
        # slots are masked out of routing entirely (zero load)
        total = entry["expert_load"].sum()
        assert total == entry["active"] * cfg.moe_k * n_moe_layers
        assert (entry["overflow"] >= 0).all()
    assert np.isfinite(eng.stats["overflow_total"])

    unmasked = ServeEngine(params, cfg, ServeConfig(
        max_len=64, n_slots=3, mask_dead_slots=False))
    for p, m, a in _trace_prompts(cfg.vocab_size):
        unmasked.submit(p, m, arrival=a)
    unmasked.run()
    for entry in unmasked.telemetry:
        assert entry["expert_load"].sum() \
            == unmasked.sc.n_slots * cfg.moe_k * n_moe_layers


def test_dead_slot_masking_bit_identical_and_reduces_overflow(moe_setup):
    """ROADMAP serving item: dead slots are masked out of routing.  Under
    partial occupancy with tight expert capacity the masked engine (a)
    stays bit-identical to sequential greedy generation — active tokens
    are never displaced by dead-slot traffic — and (b) records strictly
    less capacity overflow than the unmasked baseline, where the dead
    slots' identical pad-token embeddings pile onto the same experts."""
    from repro.core.router import RouterSpec
    cfg, params = moe_setup
    tight = cfg.replace(router=RouterSpec(capacity_factor=0.5,
                                          capacity_multiple=1))
    # Sparse arrivals: at most 2 of the 8 slots are ever active, so the
    # decode capacity (ceil(k·8·0.5/E) = 2 slots/expert) always fits the
    # *active* tokens — but not the 6 dead slots, whose identical pad
    # embeddings all route to the same k experts when unmasked.
    rs = np.random.RandomState(11)
    specs = [(rs.randint(1, cfg.vocab_size, (8,)).astype(np.int32), 4,
              i * 3) for i in range(4)]

    masked = ServeEngine(params, tight, ServeConfig(max_len=64, n_slots=8))
    reqs = [masked.submit(p, m, arrival=a) for p, m, a in specs]
    masked.run()
    assert all(r.done for r in reqs)

    oracle = ServeEngine(params, tight, ServeConfig(max_len=64, n_slots=1))
    for req, (p, m, _) in zip(reqs, specs):
        oracle.reset()
        ref = oracle.submit(p, m)
        oracle.run()
        assert ref.tokens == req.tokens, \
            f"req {req.rid}: {ref.tokens} != {req.tokens}"

    unmasked = ServeEngine(params, tight, ServeConfig(
        max_len=64, n_slots=8, mask_dead_slots=False))
    for p, m, a in specs:
        unmasked.submit(p, m, arrival=a)
    unmasked.run()
    assert unmasked.stats["overflow_total"] > masked.stats["overflow_total"]


# ---------------------------------------------------------------------------
# bucketed prefill: power-of-two buckets, bit-identical to exact-length
# ---------------------------------------------------------------------------

def test_bucketed_prefill_bit_identical_fewer_compiles(moe_setup):
    """Prompts pad to power-of-two buckets (one jit per bucket, not per
    distinct length); the padded tail is masked out of MoE routing and
    causally invisible, so greedy outputs are bit-identical to the
    exact-length engine."""
    cfg, params = moe_setup
    rs = np.random.RandomState(7)
    # 6 distinct prompt lengths -> 2 buckets (8 and 16)
    specs = [(rs.randint(1, cfg.vocab_size, (l,)).astype(np.int32), m, a)
             for l, m, a in [(5, 4, 0), (7, 5, 0), (9, 4, 1), (11, 6, 2),
                             (13, 3, 3), (16, 4, 4)]]

    bucketed = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=3))
    exact = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=3,
                                                 prefill_buckets=False))
    rb = [bucketed.submit(p, m, arrival=a) for p, m, a in specs]
    re_ = [exact.submit(p, m, arrival=a) for p, m, a in specs]
    bucketed.run()
    exact.run()
    for b, e in zip(rb, re_):
        assert b.tokens == e.tokens, (b.rid, b.tokens, e.tokens)
    assert bucketed.prefill_lengths == {8, 16}
    assert exact.prefill_lengths == {5, 7, 9, 11, 13, 16}
    assert len(bucketed.prefill_lengths) < len(exact.prefill_lengths)


def test_bucketing_disabled_for_stateful_mixers():
    """ssm/hybrid scans and sliding-window ring buffers would absorb the
    padded tail — the engine must fall back to exact-length prefill."""
    from repro.configs.base import get_config
    cfg = get_config("falcon-mamba-7b").replace(
        n_layers=2, d_model=32, vocab_size=64, ssm_d_state=4,
        param_dtype=jnp.float32, compute_dtype=jnp.float32)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, ServeConfig(max_len=32, n_slots=2))
    assert not eng._can_bucket
    eng.submit(np.arange(1, 6, dtype=np.int32), 3)   # length-5 prompt
    eng.run()
    assert eng.prefill_lengths == {5}                # exact, not bucketed


def test_chunking_refused_for_stateful_mixers():
    """Chunked prefill shares bucketing's restriction (resuming
    mid-prompt needs the whole prefix recoverable from the KV cache):
    configuring it on an ssm model must fall back *loudly* to
    whole-prompt prefill, not silently chunk through recurrent state."""
    from repro.configs.base import get_config
    cfg = get_config("falcon-mamba-7b").replace(
        n_layers=2, d_model=32, vocab_size=64, ssm_d_state=4,
        param_dtype=jnp.float32, compute_dtype=jnp.float32)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    with pytest.warns(RuntimeWarning, match="chunked prefill"):
        eng = ServeEngine(params, cfg, ServeConfig(
            max_len=32, n_slots=2, prefill_chunk=8))
    assert eng._chunk == 0                  # fell back to whole-prompt
    eng.submit(np.arange(1, 14, dtype=np.int32), 3)  # longer than chunk
    eng.run()
    assert eng.stats["prefill_chunks"] == 0
    assert eng.prefill_lengths == {13}      # exact whole-prompt prefill


def test_bucketing_and_chunking_refused_for_sliding_window():
    """Sliding-window ring-buffer caches retain padded positions and make
    the chunk prefix ambiguous: buckets must stay auto-disabled and
    chunked prefill must refuse (loud fallback) on such architectures."""
    from conftest import small_config
    cfg = small_config("gemma3-27b")       # 5:1 local:global, window=32
    assert cfg.sliding_window
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    with pytest.warns(RuntimeWarning, match="chunked prefill"):
        eng = ServeEngine(params, cfg, ServeConfig(
            max_len=64, n_slots=2, prefill_chunk=16, prefill_budget=32))
    assert not eng._can_bucket and eng._chunk == 0
    # the scheduler was built without chunking: work-items are
    # whole-prompt and the budget guards at submit time instead
    assert eng.sched.prefill_chunk == 0
    with pytest.raises(ValueError, match="prefill budget"):
        eng.submit(np.arange(1, 40, dtype=np.int32), 2)  # 39 > budget 32


def test_chunk_window_must_fit_page(moe_setup):
    """The final chunk ships a full chunk-padded buffer; a prompt whose
    chunk-rounded length exceeds max_len would make that write clamp at
    the page boundary and silently overwrite cached prefix positions —
    submit must reject it loudly instead.  Triggerable only when
    max_len is not a chunk multiple (e.g. the bench's 96-chunk / 512
    page): prompt + budget fit the page but the padded window does not."""
    cfg, params = moe_setup
    eng = ServeEngine(params, cfg, ServeConfig(max_len=56, n_slots=2,
                                               prefill_chunk=16))
    eng.submit(np.full((48,), 1, np.int32), 4)     # 48 -> 48 padded: fits
    with pytest.raises(ValueError, match="chunk-padded"):
        eng.submit(np.full((50,), 1, np.int32), 4)  # 50 -> 64 padded > 56
    # a short prompt (no chunking) near the page end stays accepted
    req = eng.submit(np.full((12,), 1, np.int32), 40)
    assert req.prompt_len == 12


# ---------------------------------------------------------------------------
# serving parity matrix: router policy x kernel backend x chunked prefill
# (the conftest guard marks the interpret-mode pallas cells and the
# 8-device subprocess as `slow`; `make test-slow` runs the full matrix)
# ---------------------------------------------------------------------------

# Long-prompt staggered mix: 40/33 force multi-chunk prefill at chunk=16.
MATRIX_TRACE = [(40, 4, 0), (8, 3, 0), (33, 5, 1), (12, 4, 2)]
CHUNK_KW = dict(prefill_chunk=16, prefill_budget=32, admission="aware")


def _matrix_cfg(policy: str, backend: str):
    from repro.core.router import RouterSpec
    return _moe_cfg().replace(
        kernel_backend=backend,
        router=RouterSpec(policy=policy, capacity_factor=2.0))


@pytest.mark.parametrize("chunked", [False, True], ids=["whole", "chunked"])
@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("policy", ["noisy_topk", "expert_choice"])
def test_serve_parity_matrix(policy, backend, chunked):
    """The correctness bar across the whole configuration surface: greedy
    outputs from the continuous-batching engine (staggered long-prompt
    mix, chunked or whole-prompt prefill) are bit-identical to sequential
    generation for every router policy x kernel backend combination."""
    cfg = _matrix_cfg(policy, backend)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    rs = np.random.RandomState(2)
    specs = [(rs.randint(1, cfg.vocab_size, (l,)).astype(np.int32), m, a)
             for l, m, a in MATRIX_TRACE]
    kw = CHUNK_KW if chunked else {}
    eng = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=3, **kw))
    reqs = [eng.submit(p, m, arrival=a) for p, m, a in specs]
    eng.run()
    assert all(r.done for r in reqs)
    if chunked:
        # the long prompts really went through the chunked path
        assert eng.stats["prefill_chunks"] >= 5
        assert eng.chunk_offsets >= {0, 16, 32}
    oracle = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=1))
    for req, (p, m, _) in zip(reqs, specs):
        oracle.reset()
        ref = oracle.submit(p, m)
        oracle.run()
        assert ref.tokens == req.tokens, \
            (policy, backend, chunked, req.rid, ref.tokens, req.tokens)


def test_serve_parity_matrix_8device():
    """The chunked cells of the matrix on a (data=2, model=4) fake mesh:
    chunk pages reshard onto the decode plan after every chunk and greedy
    outputs stay bit-identical to sequential generation on the mesh."""
    out = _run("""
        from repro.common import param as pm
        from repro.configs.base import get_config
        from repro.core.router import RouterSpec
        from repro.models import lm
        from repro.serve.engine import ServeConfig, ServeEngine
        from repro.sharding import context

        mesh = context.make_mesh((2, 4), ("data", "model"))
        for policy in ("noisy_topk", "expert_choice"):
            cfg = get_config("kimi-k2-1t-a32b").replace(
                n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                head_dim=16, vocab_size=64, n_experts=4, moe_k=2,
                moe_d_ff=32, param_dtype=jnp.float32,
                compute_dtype=jnp.float32, q_block=16, kv_block=16,
                router=RouterSpec(policy=policy, capacity_factor=2.0))
            params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
            ctx = context.MeshContext.for_mesh(mesh, "decode_std")
            eng = ServeEngine(params, cfg, ServeConfig(
                max_len=64, n_slots=4, prefill_chunk=16,
                prefill_budget=32, admission="aware"), ctx=ctx)
            rs = np.random.RandomState(1)
            specs = [(rs.randint(1, 64, (l,)), m, a)
                     for l, m, a in [(40, 4, 0), (8, 3, 1), (33, 4, 2)]]
            reqs = [eng.submit(p, m, arrival=a) for p, m, a in specs]
            eng.run()
            assert all(r.done for r in reqs)
            # 40 and 33 chunk as 3 work-items each, 8 prefills whole;
            # intermediate partial pages stay staged on the prefill
            # plan, so exactly one reshard per completed prompt lands
            # a page in the decode-plan pool.
            assert eng.stats["prefill_chunks"] == 6
            assert eng.stats["prefills"] == 3
            assert eng.stats["reshards"] == 3
            oracle = ServeEngine(params, cfg, ServeConfig(
                max_len=64, n_slots=1), ctx=ctx)
            for req, (p, m, _) in zip(reqs, specs):
                oracle.reset()
                ref = oracle.submit(p, m)
                oracle.run()
                assert ref.tokens == req.tokens, (policy, req.rid)
        print("MATRIX8_OK")
    """)
    assert "MATRIX8_OK" in out


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("policy", ["noisy_topk", "expert_choice"])
def test_serve_parity_matrix_fused(policy, backend):
    """Fused-decode on/off parity across the serving matrix: one kernel
    launch per MoE layer must not change a single greedy token relative
    to both the unfused engine and sequential generation.  (conftest
    auto-marks the pallas cells slow, like the base matrix.)"""
    cfg = _matrix_cfg(policy, backend)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    rs = np.random.RandomState(2)
    specs = [(rs.randint(1, cfg.vocab_size, (l,)).astype(np.int32), m, a)
             for l, m, a in MATRIX_TRACE]
    eng = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=3,
                                               fused_decode=True))
    reqs = [eng.submit(p, m, arrival=a) for p, m, a in specs]
    eng.run()
    assert all(r.done for r in reqs)

    base = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=3))
    rb = [base.submit(p, m, arrival=a) for p, m, a in specs]
    base.run()
    for req, b in zip(reqs, rb):
        assert req.tokens == b.tokens, \
            (policy, backend, req.rid, b.tokens, req.tokens)
    # telemetry families unchanged: same per-step keys and totals
    assert len(eng.telemetry) == len(base.telemetry)
    for fe, be in zip(eng.telemetry, base.telemetry):
        assert set(fe) == set(be)
        assert fe["expert_load"].sum() == be["expert_load"].sum()

    oracle = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=1,
                                                  fused_decode=True))
    for req, (p, m, _) in zip(reqs, specs):
        oracle.reset()
        ref = oracle.submit(p, m)
        oracle.run()
        assert ref.tokens == req.tokens, \
            (policy, backend, req.rid, ref.tokens, req.tokens)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_serve_parity_matrix_fused_moa(backend):
    """MoA engines route the assignment-major [T*k, 1] plan views through
    the same fused decode_proj op: greedy parity with the unfused engine,
    MoA telemetry intact."""
    cfg = get_config("moa-demo").replace(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
        vocab_size=64, moa_experts=4, moa_k=2, moa_heads_per_expert=2,
        n_experts=4, moe_k=2, moe_d_ff=32, param_dtype=jnp.float32,
        compute_dtype=jnp.float32, q_block=16, kv_block=16,
        capacity_factor=2.0, kernel_backend=backend)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    rs = np.random.RandomState(2)
    specs = [(rs.randint(1, cfg.vocab_size, (l,)).astype(np.int32), m, a)
             for l, m, a in [(8, 4, 0), (12, 3, 0), (8, 5, 1)]]
    outs = {}
    for fused in (False, True):
        eng = ServeEngine(params, cfg, ServeConfig(max_len=32, n_slots=2,
                                                   fused_decode=fused))
        reqs = [eng.submit(p, m, arrival=a) for p, m, a in specs]
        eng.run()
        assert all(r.done for r in reqs)
        assert any("moa_load" in entry for entry in eng.telemetry)
        outs[fused] = [r.tokens for r in reqs]
    assert outs[True] == outs[False]


def test_serve_parity_matrix_fused_8device():
    """Fused on/off parity on a (data=2, model=4) fake mesh: the fused
    op runs under the decode plan's sharding constraints and greedy
    outputs stay bit-identical to the unfused engine on the mesh."""
    out = _run("""
        from repro.common import param as pm
        from repro.configs.base import get_config
        from repro.core.router import RouterSpec
        from repro.models import lm
        from repro.serve.engine import ServeConfig, ServeEngine
        from repro.sharding import context

        mesh = context.make_mesh((2, 4), ("data", "model"))
        for policy in ("noisy_topk", "expert_choice"):
            cfg = get_config("kimi-k2-1t-a32b").replace(
                n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                head_dim=16, vocab_size=64, n_experts=4, moe_k=2,
                moe_d_ff=32, param_dtype=jnp.float32,
                compute_dtype=jnp.float32, q_block=16, kv_block=16,
                router=RouterSpec(policy=policy, capacity_factor=2.0))
            params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
            ctx = context.MeshContext.for_mesh(mesh, "decode_std")
            rs = np.random.RandomState(1)
            specs = [(rs.randint(1, 64, (l,)), m, a)
                     for l, m, a in [(8, 4, 0), (16, 3, 1), (8, 4, 2)]]
            outs = {}
            for fused in (False, True):
                eng = ServeEngine(params, cfg, ServeConfig(
                    max_len=64, n_slots=4, fused_decode=fused), ctx=ctx)
                reqs = [eng.submit(p, m, arrival=a) for p, m, a in specs]
                eng.run()
                assert all(r.done for r in reqs)
                outs[fused] = [r.tokens for r in reqs]
            assert outs[True] == outs[False], policy
        print("FUSED8_OK")
    """)
    assert "FUSED8_OK" in out


# ---------------------------------------------------------------------------
# slot reuse: per-slot kv.lengths / position pinning across retire->readmit
# ---------------------------------------------------------------------------

def test_slot_reuse_repins_kv_lengths(moe_setup):
    """_step_body pins ``kv.lengths[slot]`` to the fed token's write
    position every decode step; a slot recycled from a retired request
    must restart from the *new* request's prompt length, never inherit
    the old occupant's cache length."""
    cfg, params = moe_setup
    eng = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=1))
    rs = np.random.RandomState(9)
    prompts = [rs.randint(1, cfg.vocab_size, (8,)).astype(np.int32),
               rs.randint(1, cfg.vocab_size, (12,)).astype(np.int32)]
    r0 = eng.submit(prompts[0], 4)
    r1 = eng.submit(prompts[1], 5)
    served = []
    while eng.queue or eng.sched.active():
        eng.step()
        for slot, req in eng.sched.decoding():
            assert slot == 0
            # the next decode feeds req.tokens[-1] at position
            # prompt_len + len(tokens) - 1; the cache is valid exactly
            # that far (prefill wrote [0, prompt_len), each decode step
            # appended one)
            assert eng.kv.lengths[slot] \
                == req.prompt_len + len(req.tokens) - 1, \
                (req.rid, len(req.tokens), int(eng.kv.lengths[slot]))
            served.append(req.rid)
    assert r0.done and r1.done
    assert {r0.rid, r1.rid} <= set(served)      # slot 0 served both
    assert r1.admitted_step >= r0.finished_step  # genuine reuse
    assert eng.kv.lengths[0] == 0                # released at the end
    # the readmitted request's stream is bit-identical to a fresh engine
    fresh = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=1))
    ref = fresh.submit(prompts[1], 5)
    fresh.run()
    assert ref.tokens == r1.tokens


def test_dense_model_has_no_telemetry():
    cfg = get_config("smollm-135m").replace(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
        vocab_size=64, d_ff=64, param_dtype=jnp.float32,
        compute_dtype=jnp.float32, q_block=16, kv_block=16)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, ServeConfig(max_len=32, n_slots=2))
    eng.submit(np.arange(1, 9), 3)
    eng.run()
    assert eng.telemetry == []


# ---------------------------------------------------------------------------
# prefill_tp -> decode_std reshard on an 8-device fake mesh (subprocess)
# ---------------------------------------------------------------------------

def _run(body: str, n_devices: int = 8) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_devices}")
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_prefill_decode_reshard_8device_mesh():
    """The serving handoff on a (data=2, model=4) mesh: the prefilled page
    is explicitly device_put onto the decode_std plan (KV sequence sharded
    over model — a *different* layout than prefill produces), and the
    engine completes a staggered mixed-length trace on the mesh."""
    out = _run("""
        from repro.common import param as pm
        from repro.configs.base import get_config
        from repro.models import lm
        from repro.serve.engine import ServeConfig, ServeEngine
        from repro.sharding import context

        cfg = get_config("kimi-k2-1t-a32b").replace(
            n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=16,
            vocab_size=64, n_experts=4, moe_k=2, moe_d_ff=32,
            param_dtype=jnp.float32, compute_dtype=jnp.float32,
            q_block=16, kv_block=16, capacity_factor=2.0)
        params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
        mesh = context.make_mesh((2, 4), ("data", "model"))
        ctx = context.MeshContext.for_mesh(mesh, "decode_std")
        eng = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=4),
                          ctx=ctx)

        # 1. the reshard itself: a prefilled page lands exactly on the
        # decode plan's shardings (kv_seq over model for attention KV).
        prompt = jnp.asarray(
            np.random.RandomState(0).randint(1, 64, (1, 16)), jnp.int32)
        page = pm.materialize(eng.kv.seq_defs, jax.random.PRNGKey(0))
        _, page = eng._prefill(params, {"tokens": prompt}, page,
                               jnp.asarray(15, jnp.int32),
                               jnp.ones((1, 16), jnp.float32))
        page = eng.decode_ctx.reshard(page, eng.kv.seq_defs)
        expected = eng.decode_ctx.tree_shardings(eng.kv.seq_defs)
        n_model_sharded = 0
        for leaf, shd in zip(jax.tree_util.tree_leaves(page),
                             jax.tree_util.tree_leaves(expected)):
            assert leaf.sharding == shd, (leaf.sharding, shd)
            if any(e == "model" or (isinstance(e, tuple) and "model" in e)
                   for e in shd.spec):
                n_model_sharded += 1
        assert n_model_sharded > 0, "the handoff must be a real relayout"

        # 2. end to end on the mesh: staggered mixed-length trace.
        rs = np.random.RandomState(1)
        reqs = [eng.submit(rs.randint(1, 64, (l,)), m, arrival=a)
                for l, m, a in [(8, 4, 0), (16, 6, 0), (8, 3, 1),
                                (16, 4, 2), (8, 5, 3)]]
        eng.run()
        assert all(r.done for r in reqs)
        assert eng.stats["reshards"] == eng.stats["prefills"] == 5
        assert all(0 <= t < 64 for r in reqs for t in r.tokens)
        print("RESHARD8_OK")
    """)
    assert "RESHARD8_OK" in out
