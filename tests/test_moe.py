"""MoE layer behaviour: §4 balancing (Table 6 qualitative), hierarchy
(Appendix B), and the layer's functional invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import param as pm
from repro.core import moe as moe_lib
from repro.core.hierarchical import HMoEArgs, hmoe_apply, hmoe_defs
from repro.core.moe import MoEArgs, moe_apply, moe_defs


def _setup(**kw):
    a = MoEArgs(n_experts=kw.pop("n_experts", 8), k=kw.pop("k", 2),
                d_model=16, d_ff=32, dtype=jnp.float32, **kw)
    params = pm.materialize(moe_defs(a), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 16))
    return a, params, x


def test_output_shape_and_finite():
    a, params, x = _setup()
    y, aux = moe_apply(params, x, a, train=True, rng=jax.random.PRNGKey(2))
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(float(aux["aux_loss"]))


def test_balancing_losses_reduce_imbalance():
    """Table 6: training WITH the losses yields CV(Importance) and CV(Load)
    near zero and max/mean load near 1; without them the gate collapses."""
    def train(w_importance, w_load, steps=150):
        a, params, _ = _setup(w_importance=w_importance, w_load=w_load,
                              capacity_factor=4.0)
        # break symmetry: biased init favours expert 0
        params["gate"]["wg"] = params["gate"]["wg"].at[:, 0].set(1.0)
        data = jax.random.normal(jax.random.PRNGKey(3), (512, 16))

        def loss_fn(p, x, rng):
            y, aux = moe_apply(p, x, a, train=True, rng=rng)
            # toy regression task
            return jnp.mean((y - x) ** 2) + aux["aux_loss"], aux

        @jax.jit
        def step(p, rng):
            (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                p, data, rng)
            p = jax.tree_util.tree_map(lambda a_, b: a_ - 0.1 * b, p, g)
            return p, aux
        aux = None
        for s in range(steps):
            params, aux = step(params, jax.random.PRNGKey(10 + s))
        return {k: float(v) for k, v in aux["metrics"].items()}

    balanced = train(0.1, 0.1)
    unbalanced = train(0.0, 0.0)
    assert balanced["cv_importance"] < 1.0
    assert balanced["max_over_mean_load"] < 2.5
    # no-loss run stays collapsed on the favoured expert
    assert unbalanced["max_over_mean_load"] > balanced["max_over_mean_load"]


def test_eval_deterministic():
    a, params, x = _setup()
    params["gate"]["wg"] = jax.random.normal(jax.random.PRNGKey(9), (16, 8))
    y1, _ = moe_apply(params, x, a, train=False)
    y2, _ = moe_apply(params, x, a, train=False)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_expert_permutation_equivariance():
    """Permuting experts (weights + gate columns) leaves the output
    unchanged — the layer has no positional dependence on expert ids."""
    a, params, x = _setup(capacity_factor=8.0, eval_capacity_factor=8.0)
    params["gate"]["wg"] = jax.random.normal(jax.random.PRNGKey(4), (16, 8))
    y1, _ = moe_apply(params, x, a, train=False)
    perm = np.random.RandomState(0).permutation(8)
    p2 = {
        "gate": {"wg": params["gate"]["wg"][:, perm],
                 "wnoise": params["gate"]["wnoise"][:, perm]},
        "w1": params["w1"][perm], "w2": params["w2"][perm],
    }
    y2, _ = moe_apply(p2, x, a, train=False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-5)


def test_pallas_expert_impl_matches_einsum():
    a, params, x = _setup(capacity_factor=8.0, eval_capacity_factor=8.0)
    params["gate"]["wg"] = jax.random.normal(jax.random.PRNGKey(5), (16, 8))
    y1, _ = moe_apply(params, x, a, train=False)
    a2 = moe_lib.MoEArgs(**{**a.__dict__, "expert_impl": "pallas"})
    y2, _ = moe_apply(params, x, a2, train=False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3,
                               atol=2e-4)


def test_hierarchical_moe_runs_and_balances():
    a = HMoEArgs(n_groups=4, n_experts_per_group=4, k_primary=2,
                 k_secondary=2, d_model=16, d_ff=32, dtype=jnp.float32,
                 capacity_factor=4.0)
    params = pm.materialize(hmoe_defs(a), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 16))
    y, aux = hmoe_apply(params, x, a, train=True, rng=jax.random.PRNGKey(2))
    assert y.shape == x.shape and np.isfinite(np.asarray(y)).all()
    # zero-init gates: hierarchy starts balanced too
    assert float(aux["metrics"]["cv_importance"]) < 0.6


def test_hierarchical_equivalent_flat_capacity():
    """A (1 group x E experts) hierarchy behaves like the flat MoE with the
    same experts when the primary gate routes everything to that group."""
    e = 4
    flat = MoEArgs(n_experts=e, k=2, d_model=16, d_ff=32,
                   dtype=jnp.float32, capacity_factor=8.0,
                   eval_capacity_factor=8.0)
    fp = pm.materialize(moe_defs(flat), jax.random.PRNGKey(0))
    fp["gate"]["wg"] = jax.random.normal(jax.random.PRNGKey(4), (16, e))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    y_flat, _ = moe_apply(fp, x, flat, train=False)

    h = HMoEArgs(n_groups=1, n_experts_per_group=e, k_primary=1,
                 k_secondary=2, d_model=16, d_ff=32, dtype=jnp.float32,
                 capacity_factor=64.0)
    hp = pm.materialize(hmoe_defs(h), jax.random.PRNGKey(0))
    hp["w1"] = fp["w1"][None]
    hp["w2"] = fp["w2"][None]
    hp["gate_secondary"]["wg"] = fp["gate"]["wg"][None]
    hp["gate_secondary"]["wnoise"] = fp["gate"]["wnoise"][None]
    y_h, _ = hmoe_apply(hp, x, h, train=False)
    np.testing.assert_allclose(np.asarray(y_h), np.asarray(y_flat),
                               rtol=2e-4, atol=2e-5)
