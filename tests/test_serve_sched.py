"""Scheduler / RequestQueue invariants (host-side, no device work).

Property tests (hypothesis, when installed — same pattern as
test_dispatch/test_gating) drive the scheduler through whole synthetic
traffic traces and check the structural invariants the engine relies on:

* no slot double-assignment: a request occupies at most one slot, a slot
  at most one request, and every work-item targets the slot that owns its
  request;
* chunk continuity: work-items ingest contiguous prompt ranges, each
  resuming exactly where the previous chunk ended;
* the per-step prefill-token budget is never exceeded;
* liveness: every submitted request is eventually admitted, fully
  prefilled, decoded to its budget, and retired (admitted == retired).

Without hypothesis the parametrized grid below covers the same invariants
at fixed points (mixed chunked/unchunked, budgeted/unbudgeted, fcfs/aware,
over/undersubscribed pools).
"""
import importlib.util

import numpy as np
import pytest

from repro.serve.scheduler import Request, RequestQueue, Scheduler

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None

# (n_slots, chunk, budget, admission, specs) — specs are
# (prompt_len, max_new_tokens, arrival) triples.
GRID = [
    (2, 0, 0, "fcfs",
     [(8, 3, 0), (12, 2, 0), (5, 4, 1), (20, 1, 3)]),
    (3, 4, 8, "aware",
     [(17, 2, 0), (3, 3, 0), (9, 1, 0), (30, 2, 2), (4, 2, 2)]),
    (1, 8, 8, "fcfs",
     [(33, 2, 0), (7, 1, 5), (8, 3, 5)]),
    (4, 4, 16, "aware",
     [(40, 1, 0), (4, 1, 0), (4, 1, 0), (4, 1, 0), (18, 2, 1),
      (2, 5, 9)]),
    (2, 0, 16, "aware",                       # unchunked + budget
     [(16, 2, 0), (10, 1, 0), (16, 3, 1), (3, 2, 1)]),
]


def _simulate(n_slots, chunk, budget, admission, specs, max_steps=5000):
    """Drive a whole trace through the scheduler with a fake engine loop
    (prefill work-items mark progress; decoding slots emit one token per
    step) and assert every invariant along the way."""
    queue = RequestQueue()
    reqs = [Request(rid=i, prompt=np.zeros(plen, np.int32),
                    max_new_tokens=mnt, arrival=arr)
            for i, (plen, mnt, arr) in enumerate(specs)]
    for r in reqs:
        queue.push(r)
    sched = Scheduler(n_slots, admission=admission, prefill_chunk=chunk,
                      prefill_budget=budget)
    step = 0
    while (queue or sched.active()) and step < max_steps:
        work = sched.schedule_prefill(queue, step)
        # budget invariant: one step never plans more prompt tokens than
        # the configured per-step budget
        if budget > 0:
            assert sum(w.length for w in work) <= budget, \
                (step, [(w.req.rid, w.start, w.length) for w in work])
        for w in work:
            # the work-item's slot owns its request (no cross-wiring)
            assert sched.slots[w.slot] is w.req, (step, w)
            assert w.req.admitted_step is not None
            assert w.req.arrival <= step        # never admitted early
            # chunk continuity: resumes exactly where the last one ended
            assert w.start == w.req.prefill_pos, (step, w)
            assert 0 < w.length <= (chunk if chunk > 0
                                    else w.req.prompt_len)
            w.req.prefill_pos = w.start + w.length
            assert w.req.prefill_pos <= w.req.prompt_len
        # no slot double-assignment / request never in two slots
        occupied = [r for r in sched.slots if r is not None]
        assert len({id(r) for r in occupied}) == len(occupied)
        assert len(occupied) <= n_slots
        # fake decode: every fully-prefilled slot emits one token
        for slot, r in sched.decoding():
            r.tokens.append(0)
            if len(r.tokens) >= r.max_new_tokens:
                r.done_reason = "length"
                sched.retire(slot)
        step += 1
    # liveness: the trace drains and every request retired complete
    assert not queue and not sched.active(), \
        f"stalled at step {step}: queue={len(queue)}"
    assert sched.admitted == sched.retired == len(specs)
    for r in reqs:
        assert r.prefill_pos == r.prompt_len
        assert len(r.tokens) == r.max_new_tokens


def _legalize(n_slots, chunk, budget, admission, specs):
    """Clamp generated parameters to the combinations the engine can
    configure (chunk <= budget; unchunked prompts <= budget) — the same
    guards ServeEngine enforces at init/submit time."""
    if budget > 0 and chunk > budget:
        chunk = budget
    if budget > 0 and chunk == 0:
        specs = [(min(p, budget), m, a) for p, m, a in specs]
    return n_slots, chunk, budget, admission, specs


@pytest.mark.parametrize("n_slots,chunk,budget,admission,specs", GRID)
def test_scheduler_invariants(n_slots, chunk, budget, admission, specs):
    _simulate(n_slots, chunk, budget, admission, specs)


def test_scheduler_invariants_property():
    pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis (dev req)")
    from hypothesis import given, settings, strategies as st

    spec_st = st.tuples(st.integers(1, 40),      # prompt_len
                        st.integers(1, 5),       # max_new_tokens
                        st.integers(0, 12))      # arrival

    @settings(deadline=None, max_examples=60)
    @given(n_slots=st.integers(1, 5),
           chunk=st.sampled_from([0, 4, 8, 16]),
           budget=st.sampled_from([0, 8, 16, 32]),
           admission=st.sampled_from(["fcfs", "aware"]),
           specs=st.lists(spec_st, min_size=1, max_size=12))
    def prop(n_slots, chunk, budget, admission, specs):
        _simulate(*_legalize(n_slots, chunk, budget, admission, specs))

    prop()


def test_queue_pop_ready_fits_predicate():
    """pop_ready(fits=...) pops the earliest *arrived* request passing the
    predicate and skips (without reordering) the ones that fail it — the
    hook prompt-length-aware admission uses to let short prompts pass a
    long head-of-line prompt."""
    q = RequestQueue()
    for rid, (plen, arr) in enumerate([(30, 0), (4, 0), (8, 1), (2, 0)]):
        q.push(Request(rid=rid, prompt=np.zeros(plen, np.int32),
                       max_new_tokens=1, arrival=arr))
    short = lambda r: r.prompt_len <= 8  # noqa: E731
    assert q.pop_ready(0, short).rid == 1      # skipped the length-30 head
    assert q.pop_ready(0, short).rid == 3      # rid 2 hasn't arrived yet
    assert q.pop_ready(0, short) is None       # only the long head remains
    assert q.pop_ready(0).rid == 0             # no predicate: FIFO head
    assert q.pop_ready(1).rid == 2
    assert not q


def test_scheduler_config_validation():
    with pytest.raises(ValueError, match="admission"):
        Scheduler(2, admission="shortest")
    with pytest.raises(ValueError, match="prefill_chunk"):
        Scheduler(2, prefill_chunk=16, prefill_budget=8)


def test_budget_spreads_admission_over_steps():
    """Two 8-token prompts under a 8-token/step budget: the second
    admission waits for the next step's budget; with chunking a long
    prompt advances one chunk per step while decode continues."""
    q = RequestQueue()
    for rid in range(2):
        q.push(Request(rid=rid, prompt=np.zeros(8, np.int32),
                       max_new_tokens=2))
    s = Scheduler(2, prefill_budget=8)
    w0 = s.schedule_prefill(q, 0)
    assert [(w.req.rid, w.length) for w in w0] == [(0, 8)]
    for w in w0:
        w.req.prefill_pos = w.start + w.length
    w1 = s.schedule_prefill(q, 1)
    assert [(w.req.rid, w.length) for w in w1] == [(1, 8)]

    # chunked: a 24-token prompt takes 8 tokens of budget per step
    q2 = RequestQueue()
    long = Request(rid=9, prompt=np.zeros(24, np.int32), max_new_tokens=1)
    q2.push(long)
    s2 = Scheduler(1, prefill_chunk=8, prefill_budget=8)
    starts = []
    for step in range(3):
        work = s2.schedule_prefill(q2, step)
        starts += [(w.start, w.length) for w in work]
        for w in work:
            w.req.prefill_pos = w.start + w.length
    assert starts == [(0, 8), (8, 8), (16, 8)]
    assert not long.prefilling
