"""The first-class Router API (ISSUE 4): RouterSpec + policy registry.

Covers the registry semantics (unknown policy raises, extension via
``register_policy``), the unified capacity-factor default (one RouterSpec
default instead of ModelConfig's 1.25 vs MoEArgs' 2.0, with the paper
config's resolved value pinned), the deprecation shim for the legacy
``gating_mode``/``dispatch_impl``/``expert_impl`` strings (old spellings
warn AND produce identical routing decisions), eval-capacity resolution
at ``train=False``, token-validity masking (zero gate weight, zero load,
zero telemetry, zero capacity consumption), and the new ``expert_choice``
policy — capacity-bound by construction, ref-vs-pallas parity forward and
through one full training step on 1- and 8-device meshes.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import param as pm
from repro.core import dispatch as dsp
from repro.core import gating
from repro.core import router as rl
from repro.core.moe import MoEArgs, moe_apply, moe_defs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _moe(policy=None, **kw):
    spec = rl.RouterSpec(policy=policy) if policy else None
    a = MoEArgs(n_experts=kw.pop("n_experts", 8), k=kw.pop("k", 2),
                d_model=16, d_ff=32, dtype=jnp.float32, router=spec, **kw)
    params = pm.materialize(moe_defs(a), jax.random.PRNGKey(0))
    params["gate"]["wg"] = 0.5 * jax.random.normal(
        jax.random.PRNGKey(7), (16, a.n_experts))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    return a, params, x


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_builtin_policies_registered():
    assert {"noisy_topk", "batchwise", "threshold", "expert_choice"} \
        <= set(rl.available_policies())


def test_unknown_policy_raises_listing_registered():
    with pytest.raises(rl.RouterError, match="nope"):
        rl.get_policy("nope")
    with pytest.raises(rl.RouterError, match="expert_choice"):
        rl.get_policy("nope")          # error names what IS registered
    # ... and through the full resolution path / the MoE layer:
    a = MoEArgs(n_experts=4, k=2, d_model=8, d_ff=16, dtype=jnp.float32,
                router=rl.RouterSpec(policy="does_not_exist"))
    with pytest.raises(rl.RouterError):
        rl.resolve_spec(a)
    with pytest.raises(rl.RouterError):
        moe_apply({"gate": {}}, jnp.ones((8, 8)), a, train=False)


def test_registry_extension_new_policy_needs_no_core_edits():
    """The extensibility claim: a new policy lands as one registered
    function and immediately works through moe_apply."""
    def route(params, x, spec, n_experts, *, train, rng, mask, capacity,
              topk_impl):
        # degenerate round-robin: token t -> expert t % E, weight 1
        t = x.shape[0]
        idx = (jnp.arange(t, dtype=jnp.int32) % n_experts)[:, None]
        w = jnp.ones((t, 1), jnp.float32)
        if mask is not None:
            w = w * mask[:, None]
        gates = jnp.zeros((t, n_experts), jnp.float32).at[
            jnp.arange(t)[:, None], idx].set(w)
        info = gating.GatingInfo(combine_weights=w, expert_index=idx,
                                 gates=gates, load=jnp.sum(gates, 0),
                                 raw_logits=gates)
        return rl.PolicyOutput(info=info)

    rl.register_policy(rl.RouterPolicy(
        name="round_robin_for_test", route=route,
        defs=lambda spec, d, e: {"gate": gating.gating_defs(d, e,
                                                            noisy=False)}))
    try:
        a, params, x = _moe("round_robin_for_test")
        y, aux = moe_apply(params, x, a, train=False)
        assert y.shape == x.shape
        # perfectly balanced by construction
        load = np.asarray(aux["telemetry"]["expert_load"])
        assert (load == load[0]).all() and load.sum() == x.shape[0]
    finally:
        del rl._POLICIES["round_robin_for_test"]


# ---------------------------------------------------------------------------
# capacity-factor default unification (satellite 1)
# ---------------------------------------------------------------------------

def test_capacity_factor_single_default():
    """One default, defined once: RouterSpec.  ModelConfig used to say
    1.25 while MoEArgs said 2.0."""
    from repro.configs.base import ModelConfig
    assert rl.RouterSpec().capacity_factor == rl.DEFAULT_CAPACITY_FACTOR
    # MoEArgs default resolves to the spec default
    a = MoEArgs(n_experts=8, k=2, d_model=16, d_ff=32)
    assert rl.resolve_spec(a).capacity_factor == rl.DEFAULT_CAPACITY_FACTOR
    # ModelConfig default is literally the same constant now
    cfg = ModelConfig(name="x", family="moe", n_layers=2, d_model=8,
                      vocab_size=16)
    assert cfg.capacity_factor == rl.DEFAULT_CAPACITY_FACTOR
    assert rl.resolve_spec(cfg).capacity_factor \
        == rl.DEFAULT_CAPACITY_FACTOR


def test_paper_config_resolved_capacity_pinned():
    """Regression pin: the paper LM config (§C.1) resolves to capacity
    factor 2.0 at both train and eval, k=4 (flat MoE-32 row)."""
    from repro.configs.moe_paper import paper_config
    from repro.models.paper_lm import _moe_args
    spec = rl.resolve_spec(_moe_args(paper_config("moe-32")))
    assert spec.capacity_factor == 2.0
    assert spec.eval_cf == 2.0
    assert spec.k == 4
    assert spec.policy == "noisy_topk"


# ---------------------------------------------------------------------------
# deprecation shim (satellite 2)
# ---------------------------------------------------------------------------

def test_legacy_strings_warn_and_resolve():
    a = MoEArgs(n_experts=8, k=2, d_model=16, d_ff=32,
                gating_mode="batchwise", dispatch_impl="einsum")
    with pytest.warns(DeprecationWarning, match="gating_mode"):
        spec = rl.resolve_spec(a)
    assert spec.policy == "batchwise"
    assert spec.dispatch == "einsum"
    assert spec.k == 2
    # the new spelling resolves silently
    a2 = MoEArgs(n_experts=8, k=2, d_model=16, d_ff=32,
                 router=rl.RouterSpec(policy="batchwise",
                                      dispatch="einsum"))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        spec2 = rl.resolve_spec(a2)
    assert spec2.policy == spec.policy and spec2.dispatch == spec.dispatch


def test_legacy_expert_impl_warns_through_backend():
    from repro.kernels import backend as bk_lib
    a = MoEArgs(n_experts=4, k=2, d_model=8, d_ff=16,
                expert_impl="pallas")
    with pytest.warns(DeprecationWarning, match="expert_impl"):
        assert bk_lib.resolve(a).name == "pallas"


@pytest.mark.parametrize("mode", ["noisy_topk", "batchwise", "threshold"])
def test_old_spellings_route_identically(mode):
    """The shim must be a pure re-spelling: gating_mode=X and
    RouterSpec(policy=X) produce bit-identical routing decisions and
    layer outputs."""
    kw = dict(n_experts=8, k=2, d_model=16, d_ff=32, dtype=jnp.float32,
              capacity_factor=4.0)
    old = MoEArgs(**kw, gating_mode=mode)
    new = MoEArgs(**kw, router=rl.RouterSpec(policy=mode,
                                             capacity_factor=4.0))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        params = pm.materialize(moe_defs(old), jax.random.PRNGKey(0))
        params["gate"]["wg"] = 0.5 * jax.random.normal(
            jax.random.PRNGKey(7), (16, 8))
        assert jax.tree_util.tree_structure(moe_defs(old)) \
            == jax.tree_util.tree_structure(moe_defs(new))
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
        rng = jax.random.PRNGKey(2)
        for train in (False, True):
            dec_old = rl.build(old).route(params, x, train=train, rng=rng)
            dec_new = rl.build(new).route(params, x, train=train, rng=rng)
            np.testing.assert_array_equal(np.asarray(dec_old.expert_index),
                                          np.asarray(dec_new.expert_index))
            np.testing.assert_array_equal(
                np.asarray(dec_old.combine_weights),
                np.asarray(dec_new.combine_weights))
            assert dec_old.plan.capacity == dec_new.plan.capacity
            y_old, _ = moe_apply(params, x, old, train=train, rng=rng)
            y_new, _ = moe_apply(params, x, new, train=train, rng=rng)
            np.testing.assert_array_equal(np.asarray(y_old),
                                          np.asarray(y_new))


def test_run_gating_wrapper_is_deprecated():
    from repro.core import moe as moe_lib
    a, params, x = _moe()
    with pytest.warns(DeprecationWarning, match="run_gating"):
        info = moe_lib.run_gating(params, x, a, train=False, rng=None)
    assert info.combine_weights.shape == (64, 2)


# ---------------------------------------------------------------------------
# eval capacity factor takes effect at train=False (satellite 3)
# ---------------------------------------------------------------------------

def test_eval_capacity_factor_applies_at_eval():
    spec = rl.RouterSpec(k=2, capacity_factor=4.0,
                         eval_capacity_factor=1.0)
    r = rl.Router(spec, n_experts=8)
    assert r.capacity(256, train=True) \
        == dsp.capacity_for(256, 8, 2, 4.0)
    assert r.capacity(256, train=False) \
        == dsp.capacity_for(256, 8, 2, 1.0)
    # ... and through the layer: a skewed gate overflows the tight eval
    # capacity but not the roomy train capacity.
    a = MoEArgs(n_experts=8, k=2, d_model=16, d_ff=32, dtype=jnp.float32,
                router=spec)
    params = pm.materialize(moe_defs(a), jax.random.PRNGKey(0))
    params["gate"]["wg"] = params["gate"]["wg"].at[:, 0].set(3.0)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (256, 16)))
    _, aux_train = moe_apply(params, x, a, train=True,
                             rng=jax.random.PRNGKey(2))
    _, aux_eval = moe_apply(params, x, a, train=False)
    assert float(aux_eval["metrics"]["fraction_dropped"]) > 0.0
    assert float(aux_eval["metrics"]["fraction_dropped"]) \
        > float(aux_train["metrics"]["fraction_dropped"])
    assert float(aux_eval["telemetry"]["overflow"].sum()) > 0.0


# ---------------------------------------------------------------------------
# token-validity masking (satellite 3: dead slots)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["noisy_topk", "expert_choice"])
def test_masked_tokens_zero_gate_zero_load_zero_capacity(policy):
    a, params, x = _moe(policy)
    t = x.shape[0]
    mask = jnp.concatenate([jnp.ones((t // 2,)), jnp.zeros((t // 2,))])
    router = rl.build(a)
    dec = router.route(params, x, train=False, mask=mask)
    gates = np.asarray(dec.gates)
    # masked tokens: zero gate weight everywhere
    assert (gates[t // 2:] == 0.0).all()
    assert (np.asarray(dec.plan.weight)[t // 2:] == 0.0).all()
    # zero load: the load vector equals the valid-only load
    dec_valid = router.route(params, x[:t // 2], train=False,
                             capacity=dec.plan.capacity)
    np.testing.assert_allclose(np.asarray(dec.load),
                               np.asarray(dec_valid.load), atol=1e-5)
    # zero telemetry: only valid tokens are counted
    telem = dec.telemetry
    assert float(telem["expert_load"].sum()) \
        == np.count_nonzero(gates[:t // 2])
    # zero capacity consumption: every *valid* assignment keeps a slot
    # even at a capacity sized for the valid half only
    tight_cap = dsp.capacity_for(t // 2, a.n_experts, 2, 1.0)
    dec_tight = router.route(params, x, train=False, mask=mask,
                             capacity=tight_cap)
    kept = np.asarray(dec_tight.plan.position) < tight_cap
    valid_assigned = np.asarray(dec_tight.combine_weights)[:t // 2] > 0
    unmasked = router.route(params, x, train=False, capacity=tight_cap)
    # with dead tokens routing, some valid assignments would be displaced;
    # with the mask none are (masked rows sort behind every real token)
    assert kept[:t // 2][valid_assigned].sum() \
        >= (np.asarray(unmasked.plan.position)[:t // 2][valid_assigned]
            < tight_cap).sum()
    assert float(dec_tight.telemetry["overflow"].sum()) \
        <= float(unmasked.telemetry["overflow"][
            np.arange(a.n_experts)].sum())


def test_masked_output_matches_compact_batch():
    """moe_apply on [valid; dead] with a mask reproduces moe_apply on the
    compact valid batch (ample capacity), and dead rows come out zero."""
    a, params, x = _moe(capacity_factor=8.0)
    t = x.shape[0]
    mask = jnp.concatenate([jnp.ones((t // 2,)), jnp.zeros((t // 2,))])
    y_masked, _ = moe_apply(params, x, a, train=False, mask=mask)
    y_compact, _ = moe_apply(params, x[:t // 2], a, train=False)
    np.testing.assert_allclose(np.asarray(y_masked)[:t // 2],
                               np.asarray(y_compact), rtol=2e-4,
                               atol=2e-5)
    assert (np.asarray(y_masked)[t // 2:] == 0.0).all()


def test_hierarchical_mask_threading():
    from repro.core.hierarchical import HMoEArgs, hmoe_apply, hmoe_defs
    a = HMoEArgs(n_groups=4, n_experts_per_group=4, k_primary=2,
                 k_secondary=2, d_model=16, d_ff=32, dtype=jnp.float32,
                 capacity_factor=8.0)
    params = pm.materialize(hmoe_defs(a), jax.random.PRNGKey(0))
    params["gate_primary"]["wg"] = 0.5 * jax.random.normal(
        jax.random.PRNGKey(7), (16, 4))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    mask = jnp.concatenate([jnp.ones((32,)), jnp.zeros((32,))])
    y, aux = hmoe_apply(params, x, a, train=False, mask=mask)
    np.testing.assert_allclose(np.asarray(y)[32:], 0.0, atol=1e-6)
    y_c, _ = hmoe_apply(params, x[:32], a, train=False)
    np.testing.assert_allclose(np.asarray(y)[:32], np.asarray(y_c),
                               rtol=2e-3, atol=2e-4)


# ---------------------------------------------------------------------------
# expert_choice: capacity-bound by construction + backend parity
# ---------------------------------------------------------------------------

def test_expert_choice_never_overflows():
    """Experts pick tokens, so the dispatch buffers are full by
    construction and the overflow counter is structurally zero — even at
    a capacity factor that makes noisy_topk drop heavily."""
    spec = rl.RouterSpec(policy="expert_choice", capacity_factor=0.5)
    a = MoEArgs(n_experts=8, k=2, d_model=16, d_ff=32, dtype=jnp.float32,
                router=spec)
    params = pm.materialize(moe_defs(a), jax.random.PRNGKey(0))
    # heavily skewed gate: noisy_topk would overflow expert 0
    params["gate"]["wg"] = params["gate"]["wg"].at[:, 0].set(3.0)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (256, 16)))
    dec = rl.build(a).route(params, x, train=False)
    assert float(dec.telemetry["overflow"].sum()) == 0.0
    assert (np.asarray(dec.plan.position)[
        np.asarray(dec.plan.weight) > 0] < dec.plan.capacity).all()
    # every expert's buffer is exactly full (load == capacity per expert)
    assert (np.asarray(dec.load) == dec.plan.capacity).all()
    # the skew-matched noisy_topk DOES overflow at this capacity
    a_nt = MoEArgs(n_experts=8, k=2, d_model=16, d_ff=32,
                   dtype=jnp.float32,
                   router=rl.RouterSpec(policy="noisy_topk",
                                        capacity_factor=0.5))
    dec_nt = rl.build(a_nt).route(params, x, train=False)
    assert float(dec_nt.telemetry["overflow"].sum()) > 0.0


@pytest.mark.parametrize("train", [False, True])
def test_expert_choice_backend_parity(train):
    spec = rl.RouterSpec(policy="expert_choice", capacity_factor=2.0)
    kw = dict(n_experts=8, k=2, d_model=16, d_ff=36, dtype=jnp.float32,
              router=spec)
    params = pm.materialize(moe_defs(MoEArgs(**kw)), jax.random.PRNGKey(0))
    params["gate"]["wg"] = 0.5 * jax.random.normal(jax.random.PRNGKey(7),
                                                   (16, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (100, 16))
    rng = jax.random.PRNGKey(2)
    y_ref, aux_ref = moe_apply(params, x,
                               MoEArgs(**kw, kernel_backend="ref"),
                               train=train, rng=rng)
    y_pal, aux_pal = moe_apply(params, x,
                               MoEArgs(**kw, kernel_backend="pallas"),
                               train=train, rng=rng)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_pal["aux_loss"]),
                               float(aux_ref["aux_loss"]), rtol=1e-4)


@pytest.mark.parametrize("policy", ["noisy_topk", "expert_choice"])
def test_train_step_policy_parity_1device(policy):
    """One full training step of the small MoE LM through the RouterSpec
    path: ref and pallas backends produce allclose losses and updated
    parameters for both the paper's noisy_topk and the new expert_choice
    policy."""
    from repro.data.pipeline import DataConfig, batch_at
    from repro.models.paper_lm import (PaperLMConfig, paper_lm_defs,
                                       paper_lm_loss)
    from repro.optim import optimizers as opt_lib
    from repro.train.trainer import make_train_step

    def one_step(backend):
        cfg = PaperLMConfig(vocab_size=64, variant="moe", n_experts=4,
                            k=2, d_model=16, expert_hidden=24,
                            dropout=0.0, kernel_backend=backend,
                            router=rl.RouterSpec(policy=policy))
        params = pm.materialize(paper_lm_defs(cfg), jax.random.PRNGKey(0))
        dc = DataConfig(vocab_size=64, seq_len=16, batch_size=8,
                        n_clusters=4)
        oc = opt_lib.OptConfig(learning_rate=1e-2, warmup_steps=1)
        step = make_train_step(
            lambda p, b, r: paper_lm_loss(p, b, cfg, rng=r), oc)
        state = {"params": params, "opt": opt_lib.init(params, oc)}
        return jax.jit(step)(state, batch_at(dc, 0), jax.random.PRNGKey(3))

    st_ref, m_ref = one_step("ref")
    st_pal, m_pal = one_step("pallas")
    np.testing.assert_allclose(float(m_pal["loss"]), float(m_ref["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_flatten(st_pal["params"])[0],
                    jax.tree_util.tree_flatten(st_ref["params"])[0]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# ModelConfig threading + trainer fail-fast
# ---------------------------------------------------------------------------

def test_router_spec_threads_through_transformer_stack():
    from repro.configs.base import get_config
    from repro.models import lm
    from repro.data.pipeline import DataConfig, batch_at

    cfg = get_config("kimi-k2-1t-a32b").replace(
        n_layers=2, d_model=32, vocab_size=64, n_heads=4, n_kv_heads=2,
        head_dim=8, d_ff=48, n_experts=4, moe_k=2, moe_d_ff=24,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        q_block=16, kv_block=16,
        router=rl.RouterSpec(policy="expert_choice", capacity_factor=1.0))
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    batch = batch_at(DataConfig(vocab_size=64, seq_len=16, batch_size=4,
                                n_clusters=4), 0)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.lm_loss(p, batch, cfg, rng=jax.random.PRNGKey(1)),
        has_aux=True)(params)
    assert np.isfinite(float(loss))
    # expert_choice buffers are always full: nothing can overflow, and the
    # gate gradient is live (routing is trainable)
    assert float(metrics["fraction_dropped"]) >= 0.0
    gate_grads = [g for path, g in
                  jax.tree_util.tree_flatten_with_path(grads)[0]
                  if any(getattr(k, "key", None) == "moe" for k in path)]
    assert any(float(jnp.abs(g).sum()) > 0 for g in gate_grads)


def test_trainer_validates_router_at_construction(tmp_path):
    from repro.data.pipeline import DataConfig, DataIterator
    from repro.models.paper_lm import (PaperLMConfig, paper_lm_defs,
                                       paper_lm_loss)
    from repro.optim import optimizers as opt_lib
    from repro.train.trainer import Trainer, TrainLoopConfig
    cfg = PaperLMConfig(vocab_size=64, variant="moe", n_experts=4, k=2,
                        d_model=16, expert_hidden=32, dropout=0.0)
    params = pm.materialize(paper_lm_defs(cfg), jax.random.PRNGKey(0))
    kw = dict(
        loss_fn=lambda p, b, r: paper_lm_loss(p, b, cfg, rng=r),
        params=params, oc=opt_lib.OptConfig(),
        loop=TrainLoopConfig(total_steps=1),
        data_iter=DataIterator(DataConfig(vocab_size=64, seq_len=8,
                                          batch_size=4, n_clusters=2)),
        workdir=str(tmp_path))
    with pytest.raises(rl.RouterError):
        Trainer(**kw, router=rl.RouterSpec(policy="not_a_policy"))
    t = Trainer(**kw, router=rl.RouterSpec(policy="expert_choice"))
    assert t.router.policy == "expert_choice"


# ---------------------------------------------------------------------------
# 8-device fake mesh: both policies train ref-vs-pallas allclose
# ---------------------------------------------------------------------------

def _run(body: str, n_devices: int = 8) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_devices}")
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_train_step_expert_choice_8device_mesh():
    """One training step under a (2,4) MeshContext on 8 fake devices with
    the expert_choice RouterSpec: pallas vs ref backends agree on loss
    and updated params (the noisy_topk twin lives in
    test_kernel_backend.test_train_step_equivalence_8device_mesh)."""
    out = _run("""
        from repro.common import param as pm
        from repro.core import router as rl
        from repro.data.pipeline import DataConfig, batch_at
        from repro.models.paper_lm import (PaperLMConfig, paper_lm_defs,
                                           paper_lm_loss)
        from repro.optim import optimizers as opt_lib
        from repro.sharding import context
        from repro.train.trainer import make_train_step
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = context.make_mesh((2, 4), ("data", "model"))
        ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")

        def run(backend):
            cfg = PaperLMConfig(vocab_size=64, variant="moe", n_experts=4,
                                k=2, d_model=16, expert_hidden=24,
                                dropout=0.0, kernel_backend=backend,
                                router=rl.RouterSpec(
                                    policy="expert_choice"))
            params = pm.materialize(paper_lm_defs(cfg),
                                    jax.random.PRNGKey(0))
            params = jax.device_put(params, NamedSharding(mesh, P()))
            dc = DataConfig(vocab_size=64, seq_len=16, batch_size=8,
                            n_clusters=4)
            oc = opt_lib.OptConfig(learning_rate=1e-2, warmup_steps=1)
            step = make_train_step(
                lambda p, b, r: paper_lm_loss(p, b, cfg, rng=r, ctx=ctx),
                oc)
            state = {"params": params, "opt": opt_lib.init(params, oc)}
            batch = jax.device_put(batch_at(dc, 0),
                                   NamedSharding(mesh, P(("data",))))
            return jax.jit(step)(state, batch, jax.random.PRNGKey(3))

        st_ref, m_ref = run("ref")
        st_pal, m_pal = run("pallas")
        np.testing.assert_allclose(float(m_pal["loss"]),
                                   float(m_ref["loss"]), rtol=1e-5)
        for a, b in zip(jax.tree_util.tree_flatten(st_pal["params"])[0],
                        jax.tree_util.tree_flatten(st_ref["params"])[0]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)
        print("EC_STEP8_OK")
    """)
    assert "EC_STEP8_OK" in out
