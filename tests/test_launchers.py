"""Launcher entrypoints run end to end on a dev host (reduced configs)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(mod, *args):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-m", mod, *args], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_train_launcher(tmp_path):
    out = _run("repro.launch.train", "--arch", "qwen3-1.7b", "--reduce",
               "--steps", "12", "--batch", "4", "--seq", "32",
               "--checkpoint-every", "6",
               "--workdir", str(tmp_path / "w"))
    assert "[train] done" in out
    # relaunch resumes from the checkpoint
    out2 = _run("repro.launch.train", "--arch", "qwen3-1.7b", "--reduce",
                "--steps", "12", "--batch", "4", "--seq", "32",
                "--checkpoint-every", "6",
                "--workdir", str(tmp_path / "w"))
    assert "restored checkpoint" in out2


def test_serve_launcher():
    out = _run("repro.launch.serve", "--arch", "smollm-135m", "--reduce",
               "--requests", "2", "--prompt-len", "8", "--new-tokens", "4")
    assert "tok/s" in out
