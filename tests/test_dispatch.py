"""Dispatch/combine invariants (capacity semantics, sort == einsum).

Property tests run under hypothesis when it is installed (dev
requirement); without it they skip and the plain parametrized grid below
still covers the same invariants at fixed points.
"""
import importlib.util

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch as dsp

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None

# (t, e, k, cf, seed) grid for the non-hypothesis fallback: edge capacity
# factors, k=1 and k=e, tiny and largish token counts.
GRID = [
    (4, 2, 1, 0.5, 0),
    (16, 4, 2, 1.0, 1),
    (33, 7, 3, 1.5, 2),
    (64, 16, 4, 4.0, 3),
    (8, 2, 2, 0.75, 4),
]


def _random_assignment(t, e, k, seed):
    key = jax.random.PRNGKey(seed)
    idx = jax.random.randint(key, (t, k), 0, e)
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(seed + 1),
                                         (t, k)), axis=-1)
    return idx.astype(jnp.int32), w


def _check_sort_equals_einsum(t, e, k, cf, seed):
    idx, w = _random_assignment(t, e, k, seed)
    cap = dsp.capacity_for(t, e, k, cf)
    p = dsp.plan(idx, w, e, cap)
    x = jax.random.normal(jax.random.PRNGKey(seed + 2), (t, 8))
    np.testing.assert_allclose(np.asarray(dsp.dispatch(x, p)),
                               np.asarray(dsp.dispatch_einsum(x, p)),
                               rtol=1e-5, atol=1e-6)
    out = jax.random.normal(jax.random.PRNGKey(seed + 3), p.expert_index
                            .shape[:0] + (e, cap, 8))
    np.testing.assert_allclose(np.asarray(dsp.combine(out, p)),
                               np.asarray(dsp.combine_einsum(out, p)),
                               rtol=1e-4, atol=1e-5)


def _check_identity_roundtrip(t, e, k, seed):
    idx, w = _random_assignment(t, e, k, seed)
    p = dsp.plan(idx, w, e, capacity=t * k)
    assert float(p.fraction_dropped) == 0.0
    x = jax.random.normal(jax.random.PRNGKey(seed), (t, 8))
    buf = dsp.dispatch(x, p)
    y = dsp.combine(buf, p)
    wsum = np.asarray(jnp.sum(w, axis=1, keepdims=True))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) * wsum,
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("t,e,k,cf,seed", GRID)
def test_sort_equals_einsum(t, e, k, cf, seed):
    _check_sort_equals_einsum(t, e, k, cf, seed)


@pytest.mark.parametrize("t,e,k,cf,seed", GRID)
def test_identity_roundtrip_when_capacity_sufficient(t, e, k, cf, seed):
    """With capacity >= T nothing drops: combine(dispatch(x)) == x scaled by
    the sum of weights (each token contributes w_k * x through expert slots
    when the 'expert' is the identity)."""
    _check_identity_roundtrip(t, e, k, seed)


def test_sort_equals_einsum_property():
    hyp = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis (dev req)")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=25)
    @given(t=st.integers(4, 64), e=st.integers(2, 16), k=st.integers(1, 4),
           cf=st.floats(0.5, 4.0), seed=st.integers(0, 100))
    def prop(t, e, k, cf, seed):
        _check_sort_equals_einsum(t, e, k, cf, seed)

    prop()


def test_identity_roundtrip_property():
    hyp = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis (dev req)")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=25)
    @given(t=st.integers(4, 64), e=st.integers(2, 16), k=st.integers(1, 4),
           seed=st.integers(0, 100))
    def prop(t, e, k, seed):
        _check_identity_roundtrip(t, e, k, seed)

    prop()


def test_capacity_drop_order():
    """Batch-order truncation: earliest tokens keep their slots."""
    t, e, k = 8, 1, 1
    idx = jnp.zeros((t, k), jnp.int32)
    w = jnp.ones((t, k)) * 0.5
    p = dsp.plan(idx, w, e, capacity=4)
    pos = np.asarray(p.position)[:, 0]
    assert (pos[:4] < 4).all() and (pos[4:] >= 4).all()
    assert abs(float(p.fraction_dropped) - 0.5) < 1e-6


def test_priority_dispatch_keeps_heaviest():
    t, e, k = 8, 1, 1
    idx = jnp.zeros((t, k), jnp.int32)
    w = jnp.arange(1, t + 1, dtype=jnp.float32)[:, None] / t
    p = dsp.plan(idx, w, e, capacity=4, priority=True)
    kept = np.asarray(p.position)[:, 0] < 4
    assert kept[-4:].all() and not kept[:4].any()


def test_zero_weight_assignments_never_displace():
    """Batchwise-gating padding (w=0) must not consume capacity."""
    idx = jnp.array([[0], [0], [0], [0]], jnp.int32)
    w = jnp.array([[0.0], [1.0], [0.0], [1.0]])
    p = dsp.plan(idx, w, 1, capacity=2)
    pos = np.asarray(p.position)[:, 0]
    assert pos[1] < 2 and pos[3] < 2          # real tokens kept
    assert (np.asarray(p.weight)[[0, 2], 0] == 0).all()
    assert float(p.fraction_dropped) == 0.0
