"""Training substrate: optimizers, checkpoint/restart fault tolerance,
exact-resume data pipeline, gradient compression, straggler detection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import param as pm
from repro.data.pipeline import DataConfig, DataIterator, batch_at
from repro.models.paper_lm import PaperLMConfig, paper_lm_defs, paper_lm_loss
from repro.optim import optimizers as opt_lib
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import (dequantize_int8, init_ef_state,
                                     quantize_int8)
from repro.train.trainer import Trainer, TrainLoopConfig, make_train_step


# --------------------------------------------------------------------------
# optimizers
# --------------------------------------------------------------------------

def _quadratic(dim=6):
    a = jnp.diag(jnp.linspace(1.0, 4.0, dim))
    params = {"w": jnp.ones((dim, dim)), "b": jnp.ones((dim,))}

    def loss(p):
        return (jnp.sum((p["w"] @ a) ** 2) + jnp.sum(p["b"] ** 2))
    return params, loss


@pytest.mark.parametrize("kind", ["adam", "factored"])
def test_optimizer_converges(kind):
    params, loss = _quadratic()
    oc = opt_lib.OptConfig(kind=kind, learning_rate=0.3, warmup_steps=10,
                           clip_norm=0.0)
    state = opt_lib.init(params, oc)
    l0 = float(loss(params))
    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, state, _ = opt_lib.apply_updates(params, grads, state, oc)
    assert float(loss(params)) < 0.01 * l0


def test_factored_state_is_small():
    """Appendix D: factored second moments keep optimizer memory ~row+col
    vectors instead of a full matrix."""
    params = {"w": jnp.ones((512, 512))}
    oc_f = opt_lib.OptConfig(kind="factored")
    oc_a = opt_lib.OptConfig(kind="adam")
    sf = opt_lib.state_bytes(opt_lib.init(params, oc_f)["mu"])
    sa = opt_lib.state_bytes(opt_lib.init(params, oc_a)["mu"])
    assert sf < sa / 100


def test_state_defs_match_init():
    cfg = PaperLMConfig(vocab_size=64, variant="moe", d_model=16,
                        n_experts=4, k=2, expert_hidden=32)
    defs = paper_lm_defs(cfg)
    params = pm.materialize(defs, jax.random.PRNGKey(0))
    oc = opt_lib.OptConfig(kind="factored")
    real = opt_lib.init(params, oc)
    abst = pm.abstract(opt_lib.state_defs(defs, oc))
    ra = jax.tree_util.tree_leaves(real)
    aa = jax.tree_util.tree_leaves(abst)
    assert len(ra) == len(aa)
    for r, a in zip(ra, aa):
        assert r.shape == a.shape, (r.shape, a.shape)


def test_schedule_warmup_then_inverse_sqrt():
    oc = opt_lib.OptConfig(learning_rate=1.0, warmup_steps=100)
    assert float(opt_lib.schedule(oc, jnp.int32(50))) == pytest.approx(0.5)
    assert float(opt_lib.schedule(oc, jnp.int32(100))) == pytest.approx(1.0)
    assert float(opt_lib.schedule(oc, jnp.int32(400))) == pytest.approx(0.5)


# --------------------------------------------------------------------------
# data pipeline: exact resume
# --------------------------------------------------------------------------

def test_data_deterministic_and_seekable():
    dc = DataConfig(vocab_size=97, seq_len=16, batch_size=4, n_clusters=3)
    it = DataIterator(dc)
    seq = [next(it) for _ in range(5)]
    it2 = DataIterator(dc)
    it2.restore({"step": 3})
    np.testing.assert_array_equal(np.asarray(next(it2)["tokens"]),
                                  np.asarray(seq[3]["tokens"]))
    np.testing.assert_array_equal(np.asarray(batch_at(dc, 4)["labels"]),
                                  np.asarray(seq[4]["labels"]))


# --------------------------------------------------------------------------
# checkpointing + crash/restart
# --------------------------------------------------------------------------

def _mk_trainer(workdir, total_steps=40, crash_at=None, seed=0):
    dc = DataConfig(vocab_size=64, seq_len=16, batch_size=8, n_clusters=4)
    cfg = PaperLMConfig(vocab_size=64, variant="moe", n_experts=4, k=2,
                        d_model=16, expert_hidden=32, dropout=0.0)
    params = pm.materialize(paper_lm_defs(cfg), jax.random.PRNGKey(seed))
    return Trainer(
        loss_fn=lambda p, b, r: paper_lm_loss(p, b, cfg, rng=r),
        params=params,
        oc=opt_lib.OptConfig(learning_rate=1e-2, warmup_steps=10),
        loop=TrainLoopConfig(total_steps=total_steps, checkpoint_every=10,
                             log_every=100),
        data_iter=DataIterator(dc), workdir=workdir,
        crash_at_step=crash_at)


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    mgr.save(7, tree, {"data": {"step": 7}})
    got, extra, step = mgr.restore(7, tree)
    assert step == 7 and extra["data"]["step"] == 7
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_prunes_and_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    # a stray .tmp dir must not be listed as a checkpoint
    os.makedirs(tmp_path / "step_0000000099.tmp")
    assert 99 not in mgr.all_steps()


def test_crash_and_resume_bitexact(tmp_path):
    """Kill training mid-run; a fresh Trainer must resume from the last
    checkpoint and reach the same final state as an uninterrupted run."""
    w1 = tmp_path / "crash"
    t = _mk_trainer(str(w1), total_steps=40, crash_at=25)
    with pytest.raises(RuntimeError, match="injected crash"):
        t.run()
    t2 = _mk_trainer(str(w1), total_steps=40)     # auto-restores step 20
    assert t2.start_step == 20
    assert t2.data_iter.step == 20                # data stream seeks too
    m_resumed = t2.run()

    w2 = tmp_path / "clean"
    m_clean = _mk_trainer(str(w2), total_steps=40).run()
    assert m_resumed["loss"] == pytest.approx(m_clean["loss"], rel=1e-5)


def test_straggler_detection(tmp_path):
    t = _mk_trainer(str(tmp_path / "s"), total_steps=12)
    import time as _time
    orig = t.step_fn

    def slow(state, batch, rng, _n=[0]):
        _n[0] += 1
        if _n[0] == 11:
            _time.sleep(0.5)
        return orig(state, batch, rng)
    t.step_fn = slow
    t.run()
    assert any(ev["step"] == 10 for ev in t.straggler_events), \
        t.straggler_events


# --------------------------------------------------------------------------
# microbatched step == full-batch step
# --------------------------------------------------------------------------

def test_grad_accumulation_equivalence():
    cfg = PaperLMConfig(vocab_size=64, variant="moe_1_wide", d_model=16,
                        dropout=0.0)
    params = pm.materialize(paper_lm_defs(cfg), jax.random.PRNGKey(0))
    dc = DataConfig(vocab_size=64, seq_len=16, batch_size=8, n_clusters=4)
    batch = batch_at(dc, 0)
    oc = opt_lib.OptConfig(learning_rate=1e-2, warmup_steps=1)
    loss_fn = lambda p, b, r: paper_lm_loss(p, b, cfg, rng=r, train=False)
    s1 = make_train_step(loss_fn, oc, microbatches=1)
    s4 = make_train_step(loss_fn, oc, microbatches=4)
    st = {"params": params, "opt": opt_lib.init(params, oc)}
    rng = jax.random.PRNGKey(1)
    out1, m1 = s1(st, batch, rng)
    out4, m4 = s4({"params": params, "opt": opt_lib.init(params, oc)},
                  batch, rng)
    for a, b in zip(jax.tree_util.tree_leaves(out1["params"]),
                    jax.tree_util.tree_leaves(out4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2e-6)


# --------------------------------------------------------------------------
# int8 error-feedback compression
# --------------------------------------------------------------------------

def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """The accumulated compressed sum converges to the true sum: EF replays
    quantization error so the bias does not accumulate."""
    rng = np.random.RandomState(0)
    true_acc = np.zeros(64)
    comp_acc = np.zeros(64)
    ef = np.zeros(64)
    for step in range(200):
        g = rng.randn(64)
        true_acc += g
        e = g + ef
        q, s = quantize_int8(jnp.asarray(e))
        deq = np.asarray(dequantize_int8(q, s))
        ef = e - deq
        comp_acc += deq
    # residual error is bounded by one step's quantization error
    assert np.abs(true_acc - comp_acc).max() < 0.2
