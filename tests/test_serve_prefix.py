"""Shared-prefix radix KV cache + cross-slot batched prefill.

Three layers of coverage:

* ``PrefixCache`` host-only semantics: trie hits/caps/alignment, pinning,
  LRU eviction under a byte budget, node pruning — plus a property suite
  (hypothesis when installed, the parametrized grid otherwise) driving
  random insert/lookup/unpin traces and checking the structural
  invariants (refcounts never negative, eviction never frees a pinned
  page, ``hit + tail == prompt_len`` with block-aligned hits, byte
  accounting exact).
* Engine integration: greedy outputs bit-identical {prefix cache on, off}
  × {chunked, whole-prompt} against the sequential oracle, on 1 device
  and (slow) an 8-device fake mesh; cross-slot chunk batching reduces
  ``prefill_calls`` below ``prefill_chunks``; prefix-aware admission
  charges only the uncached tail.
* The serve-path bugfix sweep: ``max_new_tokens < 1`` rejected at
  submit, staged-page resume uses an explicit ``is None`` (pytree
  truthiness hazard), and the KV/scheduler invariants survive
  ``python -O`` (real exceptions, not asserts).
"""
import importlib.util
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import param as pm
from repro.configs.base import get_config
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.kv_cache import PrefixCache, SlotKVCache
from repro.serve.scheduler import Request, RequestQueue, Scheduler

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


def _moe_cfg():
    return get_config("kimi-k2-1t-a32b").replace(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        vocab_size=64, n_experts=4, moe_k=2, moe_d_ff=32,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        q_block=16, kv_block=16, capacity_factor=2.0)


@pytest.fixture(scope="module")
def moe_setup():
    cfg = _moe_cfg()
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


# ---------------------------------------------------------------------------
# PrefixCache: host-only trie semantics (pages are opaque sentinels)
# ---------------------------------------------------------------------------

def test_prefix_trie_hit_cap_and_alignment():
    pc = PrefixCache(block=4, page_bytes=10)
    prompt = np.arange(14, dtype=np.int32)        # 3 full blocks + tail 2
    assert pc.probe(prompt) == 0
    assert pc.insert(prompt, "page0") == 3        # blocks [0,4), [4,8), [8,12)
    assert pc.n_pages == 1 and pc.bytes == 10

    # identical prompt: the hit is capped one block short of the aligned
    # prefix when the prompt length is block-aligned — the tail must keep
    # >= 1 token to produce the first-token logits.
    aligned = np.arange(12, dtype=np.int32)
    hit, page, entry = pc.lookup(aligned)
    assert (hit, page) == (8, "page0") and hit % 4 == 0 and hit < 12
    pc.unpin(entry)

    # longer prompt sharing the prefix: full 3-block hit
    longer = np.concatenate([np.arange(12), [99, 98]]).astype(np.int32)
    hit, page, entry = pc.lookup(longer)
    assert (hit, page) == (12, "page0")
    assert hit + (longer.shape[0] - hit) == longer.shape[0]
    pc.unpin(entry)

    # diverging in block 2: only the shared blocks hit
    div = np.concatenate([np.arange(8), [77, 77, 77, 77, 1]]).astype(np.int32)
    assert pc.probe(div) == 8

    # diverging immediately: miss
    assert pc.probe(np.full(9, 55, np.int32)) == 0
    assert pc.lookup(np.full(9, 55, np.int32)) == (0, None, None)

    # sub-block prompts can never hit or be stored
    assert pc.probe(np.arange(3, dtype=np.int32)) == 0
    assert pc.insert(np.arange(3, dtype=np.int32), "tiny") == 0
    assert pc.n_pages == 1


def test_prefix_trie_insert_idempotent_and_covered():
    pc = PrefixCache(block=4, page_bytes=10)
    long = np.arange(16, dtype=np.int32)
    short = np.arange(8, dtype=np.int32)
    assert not pc.covered(long)
    pc.insert(short, "p_short")                   # blocks 0,1
    assert pc.covered(short) and not pc.covered(long)
    assert pc.insert(long, "p_long") == 2         # only blocks 2,3 are new
    assert pc.covered(long)
    # fully covered: stores nothing (duplicate retirements are free)
    assert pc.insert(long, "p_dup") == 0
    assert pc.n_pages == 2
    # deepest entry on the path wins the lookup
    hit, page, e = pc.lookup(np.concatenate([long, [9]]).astype(np.int32))
    assert (hit, page) == (16, "p_long")
    pc.unpin(e)
    # zero-length prompts are trivially covered
    assert pc.covered(np.zeros(0, np.int32))


def test_prefix_pins_block_eviction_lru_order():
    pc = PrefixCache(block=4, page_bytes=10, max_bytes=20)   # 2 pages max
    pa = np.arange(0, 8, dtype=np.int32)
    pb = np.arange(8, 16, dtype=np.int32)
    pc_prompt = np.arange(16, 24, dtype=np.int32)
    pc.insert(pa, "A")
    pc.insert(pb, "B")
    assert pc.bytes == 20
    hit, _, ea = pc.lookup(np.concatenate([pa, [1]]).astype(np.int32))
    assert hit == 8 and ea.pins == 1

    # over budget: LRU victim would be A (oldest tick) but it is pinned —
    # B must be evicted instead, never the referenced page.
    pc.insert(pc_prompt, "C")
    assert pc.stats["evictions"] == 1
    assert pc.probe(np.concatenate([pb, [1]]).astype(np.int32)) == 0
    assert pc.probe(np.concatenate([pa, [1]]).astype(np.int32)) == 8
    assert ea.page == "A"                        # pinned page survives

    # unpinned: A becomes evictable; a third insert now evicts it (LRU)
    pc.unpin(ea)
    assert ea.pins == 0
    with pytest.raises(ValueError, match="refcount"):
        pc.unpin(ea)                             # double unpin
    pc.insert(np.arange(24, 32, dtype=np.int32), "D")
    assert pc.n_pages == 2 and pc.bytes == 20
    assert pc.probe(np.concatenate([pa, [1]]).astype(np.int32)) == 0
    # evicted paths prune their trie nodes (no leak)
    assert len(pc.root.children) == 2            # C and D remain


def test_prefix_eviction_overshoot_when_all_pinned():
    pc = PrefixCache(block=2, page_bytes=10, max_bytes=30)
    entries = []
    for i in range(3):
        p = np.arange(4 * i, 4 * i + 4, dtype=np.int32)
        pc.insert(p, f"P{i}")
        hit, page, e = pc.lookup(np.concatenate([p, [1]]).astype(np.int32))
        assert (hit, page) == (4, f"P{i}")
        entries.append(e)
    pc.max_bytes = 10     # budget shrinks below the pinned working set
    pc.insert(np.arange(100, 104, dtype=np.int32), "Q")
    # the unpinned newcomer is the only victim; the three pinned pages
    # overshoot the budget rather than corrupting an in-flight prefill
    assert pc.n_pages == 3 and pc.bytes == 30
    assert all(e.page == f"P{i}" for i, e in enumerate(entries))
    for e in entries:
        pc.unpin(e)
    pc.insert(np.arange(200, 204, dtype=np.int32), "R")
    assert pc.bytes <= 10


# -- property suite (hypothesis-optional, same pattern as the scheduler) --

def _simulate_prefix_ops(block, page_bytes, max_bytes, seed, n_ops=120):
    """Random insert/lookup/unpin trace over a small block alphabet (to
    force path sharing) with every structural invariant checked after
    each op."""
    rng = np.random.RandomState(seed)
    pc = PrefixCache(block=block, page_bytes=page_bytes,
                     max_bytes=max_bytes)
    alphabet = [rng.randint(0, 5, (block,)).astype(np.int32)
                for _ in range(4)]
    pinned = []      # (entry, hit, prompt) held by "in-flight prefills"

    def rand_prompt():
        n_blocks = rng.randint(0, 6)
        tail = rng.randint(1, block + 1)
        parts = [alphabet[rng.randint(len(alphabet))]
                 for _ in range(n_blocks)]
        parts.append(rng.randint(0, 5, (tail,)).astype(np.int32))
        return np.concatenate(parts)

    lookups = 0
    for _ in range(n_ops):
        op = rng.randint(3)
        if op == 0:                                   # retirement insert
            pc.insert(rand_prompt(), object())
        elif op == 1:                                 # admission lookup
            prompt = rand_prompt()
            probed = pc.probe(prompt)
            hit, page, entry = pc.lookup(prompt)
            lookups += 1
            assert hit == probed                      # probe == lookup
            if entry is None:
                assert hit == 0 and page is None
            else:
                assert page is entry.page and page is not None
                assert hit % block == 0               # block-aligned
                assert 0 < hit < prompt.shape[0]      # tail >= 1 token
                # hit + uncached tail reconstructs the whole prompt
                assert hit + (prompt.shape[0] - hit) == prompt.shape[0]
                pinned.append((entry, hit, prompt))
        elif pinned:                                  # prefill completes
            entry, _, _ = pinned.pop(rng.randint(len(pinned)))
            pc.unpin(entry)
        # -- invariants ------------------------------------------------
        for entry, hit, prompt in pinned:
            assert entry.pins >= 1                    # never negative
            assert entry.page is not None             # never freed pinned
            # the pinned page still serves at least the hit prefix
            assert pc.probe(prompt) >= hit
        assert pc.bytes == pc.n_pages * page_bytes    # exact accounting
        if max_bytes > 0 and not pinned:
            assert pc.bytes <= max_bytes              # budget honored
        assert pc.stats["hits"] + pc.stats["misses"] == lookups
    for entry, _, _ in pinned:
        pc.unpin(entry)
    pc.insert(rand_prompt(), object())                # trigger final evict
    if max_bytes > 0:
        assert pc.bytes <= max_bytes


PREFIX_GRID = [
    (4, 10, 0, 0),        # unlimited budget
    (4, 10, 20, 1),       # tight: 2 pages
    (2, 7, 7, 2),         # tighter: 1 page, small blocks
    (8, 100, 300, 3),     # 3 pages, large blocks
    (4, 10, 10, 4),       # 1 page, heavy eviction churn
]


@pytest.mark.parametrize("block,page_bytes,max_bytes,seed", PREFIX_GRID)
def test_prefix_cache_invariants(block, page_bytes, max_bytes, seed):
    _simulate_prefix_ops(block, page_bytes, max_bytes, seed)


def test_prefix_cache_invariants_property():
    pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis (dev req)")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=60)
    @given(block=st.sampled_from([2, 4, 8]),
           page_bytes=st.integers(1, 100),
           max_bytes=st.sampled_from([0, 10, 50, 200]),
           seed=st.integers(0, 2 ** 16))
    def prop(block, page_bytes, max_bytes, seed):
        _simulate_prefix_ops(block, page_bytes, max_bytes, seed, n_ops=60)

    prop()


# ---------------------------------------------------------------------------
# prefix-aware admission: the budget charges only the uncached tail
# ---------------------------------------------------------------------------

def test_aware_admission_charges_uncached_tail():
    """With 8 tokens of leftover budget, an 80-token prompt whose first
    72 tokens are cached admits (its next chunk is the 8-token tail);
    without the cached prefix the same prompt is skipped."""
    def build(probe_hit):
        admitted = []
        sched = Scheduler(
            2, admission="aware", prefill_chunk=16, prefill_budget=24,
            prefix_probe=lambda r: probe_hit,
            on_admit=lambda slot, r: (
                admitted.append((slot, r.rid)),
                setattr(r, "prefill_pos", probe_hit)))
        # slot 0 mid-prefill: its next chunk eats 16 of the 24 budget
        inflight = Request(rid=0, prompt=np.zeros(48, np.int32),
                           max_new_tokens=1)
        inflight.prefill_pos = 16
        inflight.admitted_step = 0
        sched.slots[0] = inflight
        q = RequestQueue()
        q.push(Request(rid=1, prompt=np.zeros(80, np.int32),
                       max_new_tokens=1))
        return sched, q, admitted

    sched, q, admitted = build(probe_hit=72)
    work = sched.schedule_prefill(q, 1)
    assert admitted == [(1, 1)]
    # in-flight chunk + exactly the 8-token uncached tail
    assert [(w.req.rid, w.start, w.length) for w in work] == \
        [(0, 16, 16), (1, 72, 8)]
    assert sum(w.length for w in work) <= 24

    sched, q, admitted = build(probe_hit=0)
    work = sched.schedule_prefill(q, 1)
    assert admitted == [] and len(q) == 1      # full chunk doesn't fit
    assert [(w.req.rid, w.start, w.length) for w in work] == [(0, 16, 16)]


# ---------------------------------------------------------------------------
# engine integration: bit-identical greedy parity, batching, eviction
# ---------------------------------------------------------------------------

def _shared_prefix_trace(vocab: int, n: int = 5):
    """Staggered arrivals: request 0 retires before the rest arrive, so
    its page seeds the trie for every later request."""
    rs = np.random.RandomState(3)
    shared = rs.randint(1, vocab, (32,)).astype(np.int32)
    return [(np.concatenate([shared,
                             rs.randint(1, vocab, (8,)).astype(np.int32)]),
             4, 0 if i == 0 else 12 + i) for i in range(n)]


def test_engine_prefix_parity_and_hits(moe_setup):
    """Greedy outputs bit-identical across {prefix on, off, tiny-budget
    on} × {chunked} and the whole-prompt sequential oracle, with real
    trie hits and a measurable prefill-token drop."""
    cfg, params = moe_setup
    trace = _shared_prefix_trace(cfg.vocab_size)
    base = dict(max_len=64, n_slots=4, prefill_chunk=16,
                prefill_budget=32, admission="aware")

    def run(**kw):
        eng = ServeEngine(params, cfg, ServeConfig(**base, **kw))
        reqs = [eng.submit(p, m, arrival=a) for p, m, a in trace]
        eng.run()
        assert all(r.done for r in reqs)
        return [r.tokens for r in reqs], eng

    toks_off, eng_off = run()
    toks_on, eng_on = run(prefix_cache=True)
    assert toks_on == toks_off
    assert eng_on.stats["prefix_hits"] == len(trace) - 1
    assert eng_on.stats["prefix_hit_tokens"] == 32 * (len(trace) - 1)
    assert eng_on.stats["prefill_tokens"] < eng_off.stats["prefill_tokens"]
    assert eng_on.prefix.n_pages >= 1
    # every pin released once its prefill completed
    assert eng_on._pins == {}
    assert all(e.pins == 0 for e in eng_on.prefix._entries)

    # a one-page byte budget: the shared-prefix page just fits, hits
    # still land, and the LRU accounting never exceeds the budget
    page_bytes = eng_on.prefix.page_bytes
    toks_tiny, eng_tiny = run(prefix_cache=True,
                              prefix_cache_bytes=page_bytes)
    assert toks_tiny == toks_off
    assert eng_tiny.stats["prefix_hits"] > 0
    assert eng_tiny.prefix.bytes <= page_bytes

    # sequential whole-prompt oracle (no chunking, no prefix)
    oracle = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=1))
    for (p, m, _), toks in zip(trace, toks_on):
        oracle.reset()
        ref = oracle.submit(p, m)
        oracle.run()
        assert ref.tokens == toks


def test_cross_slot_batched_prefill_reduces_calls(moe_setup):
    """Four same-length prompts admitted the same step march through the
    chunk offsets in lockstep: each round's same-offset chunks fuse into
    one multi-row call, so prefill_calls << prefill_chunks — with greedy
    outputs bit-identical to the sequential oracle."""
    cfg, params = moe_setup
    rs = np.random.RandomState(5)
    prompts = [rs.randint(1, cfg.vocab_size, (32,)).astype(np.int32)
               for _ in range(4)]
    eng = ServeEngine(params, cfg, ServeConfig(
        max_len=64, n_slots=4, prefill_chunk=16))
    reqs = [eng.submit(p, 3) for p in prompts]
    eng.run()
    # 4 slots x 2 chunks each, grouped by offset into 2 calls
    assert eng.stats["prefill_chunks"] == 8
    assert eng.stats["prefill_calls"] == 2
    assert eng.chunk_offsets == {0, 16}
    oracle = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=1))
    for p, req in zip(prompts, reqs):
        oracle.reset()
        ref = oracle.submit(p, 3)
        oracle.run()
        assert ref.tokens == req.tokens


@pytest.mark.slow
def test_engine_prefix_parity_8device():
    """{prefix on, off} parity on a (data=2, model=4) fake mesh: batched
    multi-row chunk calls and trie-aliased base pages keep greedy outputs
    bit-identical, with still exactly one reshard per completed prompt."""
    out = _run_subprocess("""
        from repro.common import param as pm
        from repro.configs.base import get_config
        from repro.models import lm
        from repro.serve.engine import ServeConfig, ServeEngine
        from repro.sharding import context

        cfg = get_config("kimi-k2-1t-a32b").replace(
            n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=16,
            vocab_size=64, n_experts=4, moe_k=2, moe_d_ff=32,
            param_dtype=jnp.float32, compute_dtype=jnp.float32,
            q_block=16, kv_block=16, capacity_factor=2.0)
        params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
        mesh = context.make_mesh((2, 4), ("data", "model"))
        ctx = context.MeshContext.for_mesh(mesh, "decode_std")
        rs = np.random.RandomState(3)
        shared = rs.randint(1, 64, (32,)).astype(np.int32)
        trace = [(np.concatenate([shared,
                                  rs.randint(1, 64, (8,)).astype(np.int32)]),
                  4, 0 if i == 0 else 12 + i) for i in range(4)]

        def run(**kw):
            eng = ServeEngine(params, cfg, ServeConfig(
                max_len=64, n_slots=4, prefill_chunk=16,
                prefill_budget=32, admission="aware", **kw), ctx=ctx)
            reqs = [eng.submit(p, m, arrival=a) for p, m, a in trace]
            eng.run()
            assert all(r.done for r in reqs)
            return [r.tokens for r in reqs], eng

        toks_off, eng_off = run()
        toks_on, eng_on = run(prefix_cache=True)
        assert toks_on == toks_off, (toks_off, toks_on)
        assert eng_on.stats["prefix_hits"] == 3
        # one reshard per completed prompt, cache on or off
        assert eng_off.stats["reshards"] == eng_off.stats["prefills"] == 4
        assert eng_on.stats["reshards"] == eng_on.stats["prefills"] == 4
        assert eng_on.stats["prefill_tokens"] < eng_off.stats["prefill_tokens"]
        print("PREFIX8_OK")
    """)
    assert "PREFIX8_OK" in out


def _run_subprocess(body: str, n_devices: int = 8, optimize: bool = False
                    ) -> str:
    import textwrap
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_devices}")
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    cmd = [sys.executable] + (["-O"] if optimize else []) + ["-c", script]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# bugfix sweep regressions
# ---------------------------------------------------------------------------

def test_max_new_tokens_below_one_rejected(moe_setup):
    """The engine unconditionally samples a first token when a prefill
    completes, so max_new_tokens=0 used to return 1 token (off-by-one);
    submit must reject it before the request enters the queue."""
    cfg, params = moe_setup
    eng = ServeEngine(params, cfg, ServeConfig(max_len=32, n_slots=2))
    for bad in (0, -3):
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(np.arange(1, 9, dtype=np.int32), bad)
    assert not eng.queue
    req = eng.submit(np.arange(1, 9, dtype=np.int32), 1)
    eng.run()
    assert len(req.tokens) == 1


def test_resume_page_uses_explicit_is_none(moe_setup):
    """`staged(slot) or blank` asks the staged pytree for truthiness —
    raising on bare multi-element jax-array leaves and silently
    restarting the prefill for falsy containers.  The resume helper must
    use an explicit ``is None`` check."""
    cfg, params = moe_setup
    eng = ServeEngine(params, cfg, ServeConfig(
        max_len=32, n_slots=2, prefill_chunk=16))
    # falsy-but-staged container page: must be returned, not replaced
    eng.kv._staged[0] = {}
    assert eng._resume_page(0) == {}
    # bare multi-element array page: `or` would raise TypeError
    arr = jnp.zeros((4,))
    eng.kv._staged[0] = arr
    assert eng._resume_page(0) is arr
    del eng.kv._staged[0]
    assert eng._resume_page(0) is eng._blank_page


def test_serve_invariants_survive_python_O():
    """append monotonicity, compact permutation and retire-empty-slot are
    real exceptions: they must still raise under ``python -O`` (asserts
    would be stripped, turning KV corruption into silent wrong output)."""
    out = _run_subprocess("""
        assert not __debug__, "must run under -O"
        from repro.common import param as pm
        from repro.configs.base import get_config
        from repro.serve.kv_cache import SlotKVCache
        from repro.serve.scheduler import Scheduler

        sched = Scheduler(2)
        try:
            sched.retire(0)
        except ValueError:
            print("RETIRE_RAISES")

        cfg = get_config("kimi-k2-1t-a32b").replace(
            n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
            vocab_size=64, n_experts=4, moe_k=2, moe_d_ff=32,
            param_dtype=jnp.float32, compute_dtype=jnp.float32,
            q_block=16, kv_block=16)
        kv = SlotKVCache(cfg, n_slots=2, max_len=32)
        page = pm.materialize(kv.seq_defs, jax.random.PRNGKey(0))
        kv.append(0, page, length=8, last=False)
        try:
            kv.append(0, page, length=4, last=False)
        except ValueError:
            print("APPEND_RAISES")
        try:
            kv.compact([0, 0])
        except ValueError:
            print("COMPACT_RAISES")
    """, n_devices=1, optimize=True)
    assert "RETIRE_RAISES" in out
    assert "APPEND_RAISES" in out
    assert "COMPACT_RAISES" in out


def test_prefix_cache_requires_chunked_prefill(moe_setup):
    cfg, params = moe_setup
    with pytest.raises(ValueError, match="prefix_cache"):
        ServeEngine(params, cfg, ServeConfig(
            max_len=32, n_slots=2, prefix_cache=True))


def test_prefix_cache_disabled_with_chunk_fallback():
    """ssm architectures refuse chunked prefill; the prefix cache rides
    on the chunk grid, so it must disable loudly alongside it."""
    cfg = get_config("falcon-mamba-7b").replace(
        n_layers=2, d_model=32, vocab_size=64, ssm_d_state=4,
        param_dtype=jnp.float32, compute_dtype=jnp.float32)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    with pytest.warns(RuntimeWarning, match="prefix cache disabled"):
        eng = ServeEngine(params, cfg, ServeConfig(
            max_len=32, n_slots=2, prefill_chunk=8, prefix_cache=True))
    assert eng._chunk == 0 and eng.prefix is None
    eng.submit(np.arange(1, 10, dtype=np.int32), 2)
    eng.run()
    assert eng.stats["prefix_hits"] == 0


def test_chunk_must_fit_page(moe_setup):
    cfg, params = moe_setup
    with pytest.raises(ValueError, match="max_len"):
        ServeEngine(params, cfg, ServeConfig(
            max_len=16, n_slots=2, prefill_chunk=32))
