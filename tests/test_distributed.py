"""Multi-device tests.  The pytest process owns 1 CPU device, so these
spawn subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the same trick dryrun.py uses at 512)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, n_devices: int = 8) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_devices}")
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_shard_map_ep_matches_reference():
    """The paper's §3.1 explicit all-to-all EP schedule must agree with the
    single-device MoE (combined-batch semantics)."""
    out = _run("""
        from repro.common import param as pm
        from repro.core.moe import MoEArgs, moe_defs, moe_apply
        from repro.core.expert_parallel import moe_apply_ep
        from repro.sharding import context
        mesh = context.make_mesh((2, 4), ("data", "model"))
        ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")
        a = MoEArgs(n_experts=8, k=2, d_model=16, d_ff=32,
                    dtype=jnp.float32, capacity_factor=8.0,
                    eval_capacity_factor=8.0)
        params = pm.materialize(moe_defs(a), jax.random.PRNGKey(0))
        params["gate"]["wg"] = 0.5 * jax.random.normal(
            jax.random.PRNGKey(7), params["gate"]["wg"].shape)
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 16))
        y_ep, aux = jax.jit(lambda p, x: moe_apply_ep(
            p, x, a, train=False, ctx=ctx))(params, x)
        y_ref, _ = moe_apply(params, x, a, train=False)
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-5)
        print("EP_OK")
    """)
    assert "EP_OK" in out


def test_gspmd_moe_sharded_matches_single_device():
    out = _run("""
        from repro.common import param as pm
        from repro.core.moe import MoEArgs, moe_defs, moe_apply
        from repro.sharding import context
        from jax.sharding import PartitionSpec as P, NamedSharding
        mesh = context.make_mesh((2, 4), ("data", "model"))
        ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")
        a = MoEArgs(n_experts=8, k=2, d_model=16, d_ff=32,
                    dtype=jnp.float32, capacity_factor=8.0,
                    eval_capacity_factor=8.0)
        params = pm.materialize(moe_defs(a), jax.random.PRNGKey(0))
        params["gate"]["wg"] = 0.5 * jax.random.normal(
            jax.random.PRNGKey(7), params["gate"]["wg"].shape)
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 16))
        y1, _ = moe_apply(params, x, a, train=False)
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        ps = jax.device_put(
            params, NamedSharding(mesh, P()))
        y2, _ = jax.jit(lambda p, x: moe_apply(p, x, a, train=False,
                                               ctx=ctx))(ps, xs)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-4, atol=2e-5)
        print("GSPMD_OK")
    """)
    assert "GSPMD_OK" in out


def test_elastic_remesh_restore(tmp_path):
    """Checkpoint written under one topology restores under another
    (node-loss scenario: 8 -> 4 devices) with identical values."""
    ckpt = str(tmp_path / "ck")
    out = _run(f"""
        from repro.common import param as pm
        from repro.train.checkpoint import CheckpointManager
        from repro.sharding import context
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = context.make_mesh((4, 2), ("data", "model"))
        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
        tree = jax.device_put(tree, sh)
        mgr = CheckpointManager({ckpt!r})
        mgr.save(1, tree)
        print("SAVED")
    """, n_devices=8)
    assert "SAVED" in out
    out = _run(f"""
        from repro.train.checkpoint import CheckpointManager
        from repro.sharding import context
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = context.make_mesh((2, 2), ("data", "model"))
        like = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
        sh = {{"w": NamedSharding(mesh, P("model", "data"))}}
        mgr = CheckpointManager({ckpt!r})
        got, extra, step = mgr.restore(1, like, shardings=sh)
        np.testing.assert_array_equal(
            np.asarray(got["w"]),
            np.arange(64, dtype=np.float32).reshape(8, 8))
        assert got["w"].sharding.spec == P("model", "data")
        print("REMESH_OK")
    """, n_devices=4)
    assert "REMESH_OK" in out


def test_ef_compression_sync_multidevice():
    """int8 EF gradient sync over a 2-pod axis: mean within quantization
    error on step one, unbiased accumulated over steps."""
    out = _run("""
        from jax.sharding import PartitionSpec as P
        from repro.sharding import context
        from repro.train.compression import ef_compress_sync, init_ef_state
        mesh = context.make_mesh((2,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (2, 64))
        true_mean = jnp.mean(g, axis=0)
        def sync(g, ef):
            return ef_compress_sync({"g": g}, {"g": ef}, "pod")
        fn = context.shard_map(sync, mesh,
                               (P("pod"), P("pod")),
                               ({"g": P("pod")}, {"g": P("pod")}))
        synced, ef = fn(g.reshape(2, 64)[:, :],
                        jnp.zeros((2, 64)))
        got = np.asarray(synced["g"])[0]
        err = np.abs(got - np.asarray(true_mean)).max()
        scale = np.abs(np.asarray(g)).max() / 127
        assert err <= scale + 1e-5, (err, scale)
        print("EF_OK")
    """, n_devices=2)
    assert "EF_OK" in out


@pytest.mark.slow
def test_dryrun_cell_smoke():
    """One real dry-run cell on a 16-device placeholder mesh scaled down."""
    out = _run("""
        from repro.configs import shapes as shp
        from repro.configs.base import get_config
        from repro.launch.steps import lower_cell
        from repro.sharding import context
        mesh = context.make_mesh((4, 4), ("data", "model"))
        cfg = get_config("smollm-135m")
        lowered, spec = lower_cell(cfg, shp.SHAPES["decode_32k"], mesh)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        assert ma.temp_size_in_bytes >= 0
        print("CELL_OK")
    """, n_devices=16)
    assert "CELL_OK" in out
