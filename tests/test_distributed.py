"""Multi-device tests.  The pytest process owns 1 CPU device, so these
spawn subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the same trick dryrun.py uses at 512)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, n_devices: int = 8) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_devices}")
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_shard_map_ep_matches_reference():
    """The paper's §3.1 explicit all-to-all EP schedule must agree with the
    single-device MoE (combined-batch semantics)."""
    out = _run("""
        from repro.common import param as pm
        from repro.core.moe import MoEArgs, moe_defs, moe_apply
        from repro.core.expert_parallel import moe_apply_ep
        from repro.sharding import context
        mesh = context.make_mesh((2, 4), ("data", "model"))
        ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")
        a = MoEArgs(n_experts=8, k=2, d_model=16, d_ff=32,
                    dtype=jnp.float32, capacity_factor=8.0,
                    eval_capacity_factor=8.0)
        params = pm.materialize(moe_defs(a), jax.random.PRNGKey(0))
        params["gate"]["wg"] = 0.5 * jax.random.normal(
            jax.random.PRNGKey(7), params["gate"]["wg"].shape)
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 16))
        y_ep, aux = jax.jit(lambda p, x: moe_apply_ep(
            p, x, a, train=False, ctx=ctx))(params, x)
        y_ref, _ = moe_apply(params, x, a, train=False)
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-5)
        print("EP_OK")
    """)
    assert "EP_OK" in out


def test_gspmd_moe_sharded_matches_single_device():
    out = _run("""
        from repro.common import param as pm
        from repro.core.moe import MoEArgs, moe_defs, moe_apply
        from repro.sharding import context
        from jax.sharding import PartitionSpec as P, NamedSharding
        mesh = context.make_mesh((2, 4), ("data", "model"))
        ctx = context.MeshContext.for_mesh(mesh, "dp_tp_ep")
        a = MoEArgs(n_experts=8, k=2, d_model=16, d_ff=32,
                    dtype=jnp.float32, capacity_factor=8.0,
                    eval_capacity_factor=8.0)
        params = pm.materialize(moe_defs(a), jax.random.PRNGKey(0))
        params["gate"]["wg"] = 0.5 * jax.random.normal(
            jax.random.PRNGKey(7), params["gate"]["wg"].shape)
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 16))
        y1, _ = moe_apply(params, x, a, train=False)
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        ps = jax.device_put(
            params, NamedSharding(mesh, P()))
        y2, _ = jax.jit(lambda p, x: moe_apply(p, x, a, train=False,
                                               ctx=ctx))(ps, xs)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-4, atol=2e-5)
        print("GSPMD_OK")
    """)
    assert "GSPMD_OK" in out


def test_ep_load_psum_global_batch_semantics():
    """ROADMAP fix: the EP schedule's balancing losses must be computed
    from the *combined-batch* (psum'd) importance/load vectors, not the
    pmean of shard-local CVs — the paper's Eqs. (6)/(11) sum over all
    data-parallel shards.  Construction: every shard routes all of its
    tokens to a different expert pair, so each shard is maximally skewed
    locally while the global load is perfectly balanced; the EP aux loss
    must see the balanced global batch.  Also covers expert_choice, whose
    shard-local load is capacity-uniform by construction (only the psum'd
    global view can ever show imbalance)."""
    out = _run("""
        from repro.common import param as pm
        from repro.core import router as rl
        from repro.core.moe import MoEArgs, moe_defs
        from repro.core.expert_parallel import moe_apply_ep
        from repro.sharding import context
        mesh = context.make_mesh((2, 4), ("data", "model"))
        e, d, t = 8, 16, 128               # 8 shards x 16 tokens
        a = MoEArgs(n_experts=e, k=2, d_model=d, d_ff=32,
                    dtype=jnp.float32, capacity_factor=8.0,
                    eval_capacity_factor=8.0)
        params = pm.materialize(moe_defs(a), jax.random.PRNGKey(0))
        # Gate: feature direction i -> logits peaked at experts (i, i+1).
        wg = np.zeros((d, e), np.float32)
        for i in range(e):
            wg[i, i] = 10.0
            wg[i, (i + 1) % e] = 5.0
        params["gate"]["wg"] = jnp.asarray(wg)
        # Token block s (= shard s under the (data, model) token sharding)
        # points along feature s: the whole shard routes to (s, s+1).
        x = np.zeros((t, d), np.float32)
        for s in range(8):
            x[s * 16:(s + 1) * 16, s] = 4.0
        x += 0.01 * np.random.RandomState(0).randn(t, d)
        x = jnp.asarray(x)
        _, aux = jax.jit(lambda p, x: moe_apply_ep(
            p, x, a, train=False, ctx=context.MeshContext.for_mesh(
                mesh, "dp_tp_ep")))(params, x)
        # Reference: what the old pmean-of-shard-local losses would say.
        router = rl.build(a)
        local = []
        for s in range(8):
            dec = router.route(params, x[s * 16:(s + 1) * 16],
                               train=False)
            local.append(float(dec.aux_loss))
        local_mean = float(np.mean(local))
        global_aux = float(aux["aux_loss"])
        # Each shard is one-expert-pair skewed -> big local CVs; the
        # combined batch is balanced -> the EP loss must be tiny.
        assert local_mean > 0.5, local_mean
        assert global_aux < 0.05, global_aux
        assert global_aux < local_mean / 10.0, (global_aux, local_mean)
        assert float(aux["metrics"]["cv_load"]) < 0.2
        assert abs(float(aux["metrics"]["max_over_mean_load"]) - 1.0) < 0.3
        # expert_choice: shard-local load is capacity-uniform by
        # construction; the psum'd vector is what the metrics report.
        a_ec = MoEArgs(n_experts=e, k=2, d_model=d, d_ff=32,
                       dtype=jnp.float32,
                       router=rl.RouterSpec(policy="expert_choice",
                                            capacity_factor=8.0))
        p_ec = pm.materialize(moe_defs(a_ec), jax.random.PRNGKey(0))
        p_ec["gate"]["wg"] = jnp.asarray(wg)
        _, aux_ec = jax.jit(lambda p, x: moe_apply_ep(
            p, x, a_ec, train=False, ctx=context.MeshContext.for_mesh(
                mesh, "dp_tp_ep")))(p_ec, x)
        assert np.isfinite(float(aux_ec["aux_loss"]))
        assert float(aux_ec["metrics"]["cv_load"]) < 1e-3
        print("EP_GLOBAL_LOAD_OK")
    """)
    assert "EP_GLOBAL_LOAD_OK" in out


def test_elastic_remesh_restore(tmp_path):
    """Checkpoint written under one topology restores under another
    (node-loss scenario: 8 -> 4 devices) with identical values."""
    ckpt = str(tmp_path / "ck")
    out = _run(f"""
        from repro.common import param as pm
        from repro.train.checkpoint import CheckpointManager
        from repro.sharding import context
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = context.make_mesh((4, 2), ("data", "model"))
        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
        tree = jax.device_put(tree, sh)
        mgr = CheckpointManager({ckpt!r})
        mgr.save(1, tree)
        print("SAVED")
    """, n_devices=8)
    assert "SAVED" in out
    out = _run(f"""
        from repro.train.checkpoint import CheckpointManager
        from repro.sharding import context
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = context.make_mesh((2, 2), ("data", "model"))
        like = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
        sh = {{"w": NamedSharding(mesh, P("model", "data"))}}
        mgr = CheckpointManager({ckpt!r})
        got, extra, step = mgr.restore(1, like, shardings=sh)
        np.testing.assert_array_equal(
            np.asarray(got["w"]),
            np.arange(64, dtype=np.float32).reshape(8, 8))
        assert got["w"].sharding.spec == P("model", "data")
        print("REMESH_OK")
    """, n_devices=4)
    assert "REMESH_OK" in out


def test_ef_compression_sync_multidevice():
    """int8 EF gradient sync over a 2-pod axis: mean within quantization
    error on step one, unbiased accumulated over steps."""
    out = _run("""
        from jax.sharding import PartitionSpec as P
        from repro.sharding import context
        from repro.train.compression import ef_compress_sync, init_ef_state
        mesh = context.make_mesh((2,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (2, 64))
        true_mean = jnp.mean(g, axis=0)
        def sync(g, ef):
            return ef_compress_sync({"g": g}, {"g": ef}, "pod")
        fn = context.shard_map(sync, mesh,
                               (P("pod"), P("pod")),
                               ({"g": P("pod")}, {"g": P("pod")}))
        synced, ef = fn(g.reshape(2, 64)[:, :],
                        jnp.zeros((2, 64)))
        got = np.asarray(synced["g"])[0]
        err = np.abs(got - np.asarray(true_mean)).max()
        scale = np.abs(np.asarray(g)).max() / 127
        assert err <= scale + 1e-5, (err, scale)
        print("EF_OK")
    """, n_devices=2)
    assert "EF_OK" in out


@pytest.mark.slow
def test_dryrun_cell_smoke():
    """One real dry-run cell on a 16-device placeholder mesh scaled down."""
    out = _run("""
        from repro.configs import shapes as shp
        from repro.configs.base import get_config
        from repro.launch.steps import lower_cell
        from repro.sharding import context
        mesh = context.make_mesh((4, 4), ("data", "model"))
        cfg = get_config("smollm-135m")
        lowered, spec = lower_cell(cfg, shp.SHAPES["decode_32k"], mesh)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        assert ma.temp_size_in_bytes >= 0
        print("CELL_OK")
    """, n_devices=16)
    assert "CELL_OK" in out
