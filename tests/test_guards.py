"""Guard hardening: shape/config checks that used to be ``assert``
statements are now typed exceptions, so they survive ``python -O``
(which strips asserts — the old guards silently vanished in optimized
deployments).  The whole battery runs in one ``python -O`` subprocess.

Also here: the REPRO_GMM_TUNINGS override validation (a typo'd path must
raise, not silently fall back to the static tile defaults) and the
dryrun launchers' jax-already-imported guard (their XLA_FLAGS mutation
is a silent no-op once jax has initialized a backend).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_optimized(body: str) -> str:
    """Run ``body`` under ``python -O`` with the repo on PYTHONPATH."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-O", "-c",
                          textwrap.dedent(body)], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


def test_promoted_guards_survive_python_O():
    """Every promoted guard still fires with asserts stripped.  The
    script may not use ``assert`` itself — failures are collected and
    re-raised explicitly."""
    out = _run_optimized("""
        import functools
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P

        failures = []

        def expect(exc, frag, fn):
            try:
                fn()
            except exc as e:
                if frag not in str(e):
                    failures.append(f"{frag!r} not in {e!r}")
            except Exception as e:  # wrong type
                failures.append(f"wanted {exc.__name__} ({frag!r}), "
                                f"got {type(e).__name__}: {e}")
            else:
                failures.append(f"no raise for {frag!r}")

        # train/trainer.py: microbatch divisibility
        from repro.train import trainer
        expect(ValueError, "not divisible",
               lambda: trainer._split_microbatches(
                   {"x": jnp.zeros((5, 2))}, 2))

        # train/pipeline.py: homogeneous-period + microbatch guards
        from repro.configs.base import get_config
        from repro.train import pipeline
        cfg = get_config("smollm-135m")
        expect(ValueError, "period",
               lambda: pipeline.pipeline_block_defs(
                   cfg.replace(period=2), 2))
        expect(ValueError, "not divisible",
               lambda: pipeline.pipeline_lm_loss(
                   {}, {"tokens": jnp.zeros((5, 4), jnp.int32),
                        "labels": jnp.zeros((5, 4), jnp.int32)},
                   cfg, mesh=None, n_stages=2, n_micro=2))

        # models/attention.py: block divisibility + window-chunk refusal
        from repro.common import param as pm
        from repro.models import attention
        q = jnp.zeros((1, 6, 2, 4))
        kv = jnp.zeros((1, 6, 1, 4))
        expect(ValueError, "attention blocks",
               lambda: attention.blockwise_attention(
                   q, kv, kv, q_block=4, kv_block=3))
        ap = pm.materialize(
            attention.attention_defs(8, 2, 1, 4, qk_norm=False,
                                     dtype=jnp.float32),
            jax.random.PRNGKey(0))
        cache = {"k": jnp.zeros((1, 16, 1, 4)),
                 "v": jnp.zeros((1, 16, 1, 4))}
        expect(ValueError, "sliding-window",
               lambda: attention.prefill_attention(
                   ap, jnp.zeros((1, 4, 8)),
                   jnp.zeros((1, 4), jnp.int32), rope_theta=1e4,
                   qk_norm=False, cache=cache, window=8, offset=0))

        # models/lm.py: loss-chunk divisibility
        from repro.models import lm
        expect(ValueError, "loss chunk",
               lambda: lm.chunked_xent({}, jnp.zeros((1, 5, 4)),
                                       jnp.zeros((1, 5), jnp.int32),
                                       cfg, chunk=2))

        # models/ssm.py: scan-chunk divisibility
        from repro.models import ssm
        sp = pm.materialize(
            ssm.mamba_defs(8, d_state=4, d_conv=4, expand=2,
                           dtype=jnp.float32), jax.random.PRNGKey(0))
        expect(ValueError, "scan chunk",
               lambda: ssm.mamba(sp, jnp.zeros((1, 5, 8)), d_state=4,
                                 chunk=2))

        # models/transformer.py: ssm blocks refuse chunked prefill
        from repro.configs.base import layer_kinds
        from repro.models import transformer
        mcfg = get_config("falcon-mamba-7b").replace(
            n_layers=2, d_model=8, vocab_size=64, ssm_d_state=4,
            param_dtype=jnp.float32, compute_dtype=jnp.float32)
        kind = layer_kinds(mcfg)[0]
        bp = pm.materialize(transformer.block_defs(mcfg, kind),
                            jax.random.PRNGKey(0))
        expect(ValueError, "attention mixers",
               lambda: transformer.block_prefill(
                   bp, jnp.zeros((1, 4, 8)), kind, mcfg, None,
                   jnp.zeros((1, 4), jnp.int32), start_pos=16))

        # core/expert_parallel.py: mesh/context/divisibility guards
        from repro.core import expert_parallel as ep_lib
        from repro.core.moe import MoEArgs
        from repro.sharding import context as ctx_lib
        expect(RuntimeError, "needs a mesh",
               lambda: ep_lib.moe_apply_ep({}, None, None))
        mesh = ctx_lib.make_mesh((1,), ("model",))
        manual = ctx_lib.MeshContext.for_mesh(mesh).manual("model")
        expect(RuntimeError, "Manual-mode",
               lambda: ep_lib.moe_apply_ep({}, None, None, ctx=manual))
        a = MoEArgs(n_experts=4, k=2, d_model=8, d_ff=16,
                    dtype=jnp.float32)
        body = functools.partial(ep_lib._local_moe, a=a, train=False,
                                 rng=None, ep_axis="model",
                                 fsdp_axis=None, ep=3, bk=None,
                                 router=None, body_ctx=None)
        expect(ValueError, "must divide",
               lambda: ctx_lib.shard_map(
                   lambda x: body({}, x, None), mesh,
                   (P(),), (P(), P()))(jnp.zeros((4, 8))))

        # sharding/context.py: resolve() without a concrete mesh
        from repro.sharding import partition
        bare = ctx_lib.MeshContext(mesh=None,
                                   rules=partition.PLANS["dp_tp_ep"])
        expect(RuntimeError, "concrete mesh",
               lambda: bare.resolve((4, 4), ("batch", "embed")))

        if failures:
            raise SystemExit("GUARDS FAILED:\\n" + "\\n".join(failures))
        print("GUARDS_OK")
    """)
    assert "GUARDS_OK" in out


# ---------------------------------------------------------------------------
# REPRO_GMM_TUNINGS override validation (kernels/gmm.py)
# ---------------------------------------------------------------------------

@pytest.fixture
def fresh_tunings(monkeypatch):
    from repro.kernels import gmm
    gmm.invalidate_tunings()
    yield monkeypatch
    monkeypatch.delenv(gmm.TUNINGS_ENV, raising=False)
    gmm.invalidate_tunings()


def test_gmm_tunings_env_missing_file_raises(fresh_tunings):
    from repro.kernels import gmm
    from repro.kernels.backend import KernelBackendError
    fresh_tunings.setenv(gmm.TUNINGS_ENV, "/nonexistent/tunings.json")
    with pytest.raises(KernelBackendError, match="missing GMM tunings"):
        gmm.load_tunings()


def test_gmm_tunings_env_invalid_table_raises(fresh_tunings, tmp_path):
    from repro.kernels import gmm
    from repro.kernels.backend import KernelBackendError
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    fresh_tunings.setenv(gmm.TUNINGS_ENV, str(bad))
    with pytest.raises(KernelBackendError, match="not a valid"):
        gmm.load_tunings()
    gmm.invalidate_tunings()
    wrong_shape = tmp_path / "wrong.json"
    wrong_shape.write_text(json.dumps({"4x8x8x8x float32": "not-a-tile"}))
    fresh_tunings.setenv(gmm.TUNINGS_ENV, str(wrong_shape))
    with pytest.raises(KernelBackendError, match="not a valid"):
        gmm.load_tunings()


def test_gmm_tunings_env_empty_means_unset(fresh_tunings):
    from repro.kernels import gmm
    fresh_tunings.setenv(gmm.TUNINGS_ENV, "")
    table = gmm.load_tunings()          # committed table, no raise
    assert isinstance(table, dict)


def test_gmm_tunings_explicit_path_keeps_lenient_default(fresh_tunings):
    """Only the env override is validated: an explicit missing path keeps
    the documented 'missing file -> {}' behavior (fresh checkouts tune
    lazily)."""
    from repro.kernels import gmm
    assert gmm.load_tunings("/nonexistent/tunings.json") == {}


def test_gmm_tunings_valid_override_roundtrips(fresh_tunings, tmp_path):
    from repro.kernels import gmm
    good = tmp_path / "good.json"
    key = gmm.tuning_key(4, 128, 128, 128, "float32")
    good.write_text(json.dumps({key: [64, 64, 64],
                                "_meta": "tuner provenance"}))
    fresh_tunings.setenv(gmm.TUNINGS_ENV, str(good))
    assert gmm.load_tunings()[key] == (64, 64, 64)


# ---------------------------------------------------------------------------
# dryrun launchers: XLA_FLAGS mutation must precede any jax import
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("module", ["repro.launch.dryrun",
                                    "repro.launch.dryrun_pp"])
def test_dryrun_import_after_jax_fails_loudly(module):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c",
         f"import jax\nimport {module}\n"],
        env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode != 0
    assert "RuntimeError" in out.stderr
    assert "imported before jax" in out.stderr


@pytest.mark.parametrize("module", ["repro.launch.dryrun",
                                    "repro.launch.dryrun_pp"])
def test_dryrun_import_fresh_process_ok(module):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c",
         f"import os\nimport {module}\n"
         "print('512' in os.environ['XLA_FLAGS'])"],
        env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "True" in out.stdout
