"""Gating-network unit + property tests (Eqs. 2-5, 8-10, 16-20).

The top-k invariant check runs as a hypothesis property test when
hypothesis is installed (dev requirement) and always as a fixed
parametrized grid, so the module collects and covers the invariant either
way."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import param as pm
from repro.core import gating, losses


def _params(d, e, key=0, scale=1.0):
    p = pm.materialize(gating.gating_defs(d, e), jax.random.PRNGKey(key))
    p["wg"] = scale * jax.random.normal(jax.random.PRNGKey(key + 1), (d, e))
    return p


def test_softmax_gating_rows_sum_to_one():
    p = _params(8, 16)
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 8))
    g = gating.softmax_gating(p, x)
    np.testing.assert_allclose(np.asarray(jnp.sum(g, -1)), 1.0, rtol=1e-5)


def test_zero_init_is_balanced():
    """Appendix A: zero-init Wg/Wnoise => 'no signal and some noise'."""
    p = pm.materialize(gating.gating_defs(8, 16), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4096, 8))
    info = gating.noisy_topk_gating(p, x, 2, train=True,
                                    rng=jax.random.PRNGKey(2))
    # With pure noise, expert selection is uniform: importance CV is small.
    imp = losses.importance(info.gates)
    assert float(losses.cv_squared(imp)) < 0.05
    assert float(losses.cv_squared(info.load)) < 0.05


def _check_noisy_topk_invariants(t, e, k, seed):
    k = min(k, e)
    p = _params(8, e, key=seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 7), (t, 8))
    info = gating.noisy_topk_gating(p, x, k, train=False)
    g = np.asarray(info.gates)
    # exactly k nonzeros per row, summing to 1
    assert (np.count_nonzero(g, axis=1) == k).all()
    np.testing.assert_allclose(g.sum(1), 1.0, rtol=1e-5)
    # combine weights match gates at the top-k indices
    w = np.asarray(info.combine_weights)
    idx = np.asarray(info.expert_index)
    for i in range(t):
        np.testing.assert_allclose(g[i, idx[i]], w[i], rtol=1e-5)
    # weights sorted descending (top-k order)
    assert (np.diff(w, axis=1) <= 1e-6).all()


@pytest.mark.parametrize("t,e,k,seed", [
    (4, 2, 1, 0),
    (16, 8, 2, 11),
    (33, 32, 4, 22),
    (64, 5, 3, 33),
    (7, 4, 4, 44),
])
def test_noisy_topk_invariants(t, e, k, seed):
    _check_noisy_topk_invariants(t, e, k, seed)


def test_noisy_topk_invariants_property():
    pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis (dev req)")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=20)
    @given(t=st.integers(4, 64), e=st.integers(2, 32), k=st.integers(1, 4),
           seed=st.integers(0, 1000))
    def prop(t, e, k, seed):
        _check_noisy_topk_invariants(t, e, k, seed)

    prop()


def test_load_estimator_matches_empirical_load():
    """Appendix A Eq. 10: Load(X) should track the expected number of
    tokens routed to each expert under resampled noise."""
    d, e, t, k = 8, 8, 2048, 2
    p = _params(d, e, key=3, scale=0.3)
    # give the noise some width
    p["wnoise"] = jnp.full((d, e), 0.1)
    x = jax.random.normal(jax.random.PRNGKey(4), (t, d))
    info = gating.noisy_topk_gating(p, x, k, train=True,
                                    rng=jax.random.PRNGKey(5))
    # empirical: re-draw noise many times and count hard assignments
    counts = np.zeros(e)
    for s in range(30):
        i2 = gating.noisy_topk_gating(p, x, k, train=True,
                                      rng=jax.random.PRNGKey(100 + s))
        counts += np.asarray((i2.gates > 0).sum(0))
    counts /= 30
    load = np.asarray(info.load)
    # same ordering and within ~15% on loaded experts
    rho = np.corrcoef(load, counts)[0, 1]
    assert rho > 0.95, (load, counts, rho)


def test_batchwise_gating_exactly_balanced():
    """Appendix F: every expert receives exactly m = k*T/E tokens."""
    p = _params(8, 8, key=6)
    x = jax.random.normal(jax.random.PRNGKey(7), (128, 8))
    info = gating.batchwise_gating(p, x, k=2)
    load = np.asarray(info.load)
    assert (load == load[0]).all() and load[0] == 2 * 128 // 8


def test_threshold_gating_approaches_batchwise():
    """Eq. 20 minimization: learned thresholds reproduce the batchwise mask."""
    d, e, k, t = 8, 8, 2, 256
    p = _params(d, e, key=8)
    thr = pm.materialize(gating.threshold_defs(e), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(9), (t, d))

    def loss(tv):
        return gating.batchwise_threshold_loss(p, {"t": tv["t"]}, x, k)

    lr = 0.05
    for _ in range(200):
        g = jax.grad(lambda tv: loss(tv))(thr)
        thr = {"t": thr["t"] - lr * g["t"]}
    bw = gating.batchwise_gating(p, x, k)
    th = gating.threshold_gating(p, thr, x, k)
    agree = np.mean(np.asarray((bw.gates > 0) == (th.gates > 0)))
    assert agree > 0.9, agree


def test_cv_squared_degenerate():
    assert float(losses.cv_squared(jnp.ones((1,)))) == 0.0
    assert float(losses.cv_squared(jnp.ones((8,)))) < 1e-9
