"""Table 6 reproduction: balancing-loss ablation.

Trains the same MoE LM under the paper's six (w_importance, w_load)
combinations and reports test perplexity, CV(Importance), CV(Load) and
max/mean load.  The paper's qualitative result to reproduce:

  * (0, 0)  -> badly imbalanced (max/mean load ~17.8, worst perplexity)
  * any loss enabled -> near-flat utilization and similar, better perplexity
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.common import param as pm
from repro.data.pipeline import DataConfig, DataIterator, batch_at
from repro.models.paper_lm import PaperLMConfig, paper_lm_defs, paper_lm_loss
from repro.optim import optimizers as opt_lib
from repro.train.trainer import make_train_step

COMBOS = [(0.0, 0.0), (0.2, 0.0), (0.0, 0.2), (0.1, 0.1), (0.01, 0.01),
          (1.0, 1.0)]
# Paper Table 6 reference values for the README-level comparison.
PAPER = {
    (0.0, 0.0): dict(ppl=39.8, cvi=3.04, cvl=3.01, mm=17.80),
    (0.2, 0.0): dict(ppl=35.6, cvi=0.06, cvl=0.17, mm=1.47),
    (0.0, 0.2): dict(ppl=35.7, cvi=0.22, cvl=0.04, mm=1.15),
    (0.1, 0.1): dict(ppl=35.6, cvi=0.06, cvl=0.05, mm=1.14),
    (0.01, 0.01): dict(ppl=35.7, cvi=0.48, cvl=0.11, mm=1.37),
    (1.0, 1.0): dict(ppl=35.7, cvi=0.03, cvl=0.02, mm=1.07),
}


def run(steps: int = 120, n_experts: int = 16):
    dc = DataConfig(vocab_size=128, seq_len=32, batch_size=32,
                    n_clusters=32, noise_prob=0.02, seed=11)
    rows = []
    for wi, wl in COMBOS:
        cfg = PaperLMConfig(vocab_size=dc.vocab_size, variant="moe",
                            n_experts=n_experts, k=2, d_model=32,
                            expert_hidden=64, dropout=0.0,
                            w_importance=wi, w_load=wl,
                            capacity_factor=4.0)
        params = pm.materialize(paper_lm_defs(cfg), jax.random.PRNGKey(0))
        # bias init toward expert 0 so the self-reinforcing imbalance of §4
        # has something to latch onto (CPU-scale runs are short).
        params["moe"]["gate"]["wg"] = \
            params["moe"]["gate"]["wg"].at[:, 0].set(0.5)
        oc = opt_lib.OptConfig(learning_rate=2e-2, warmup_steps=20)
        step = jax.jit(make_train_step(
            lambda p, b, r: paper_lm_loss(p, b, cfg, rng=r), oc))
        state = {"params": params, "opt": opt_lib.init(params, oc)}
        it = DataIterator(dc)
        t0 = time.perf_counter_ns()
        metrics = {}
        for s in range(steps):
            state, metrics = step(state, next(it), jax.random.PRNGKey(s))
        dt = (time.perf_counter_ns() - t0) / steps / 1e3
        test = batch_at(dc, 10_000)
        _, tm = paper_lm_loss(state["params"], test, cfg, train=False)
        row = dict(wi=wi, wl=wl, ppl=float(tm["perplexity"]),
                   cvi=float(metrics["cv_importance"]),
                   cvl=float(metrics["cv_load"]),
                   mm=float(metrics["max_over_mean_load"]))
        rows.append(row)
        ref = PAPER[(wi, wl)]
        emit(f"table6_w_imp={wi}_w_load={wl}", dt,
             f"ppl={row['ppl']:.1f} cv_imp={row['cvi']:.2f} "
             f"cv_load={row['cvl']:.2f} max/mean={row['mm']:.2f} "
             f"(paper: ppl={ref['ppl']} max/mean={ref['mm']})")
    # headline assertion of the table: no-loss run is the most imbalanced
    no_loss = rows[0]
    with_loss = rows[3]
    assert no_loss["mm"] > with_loss["mm"], (no_loss, with_loss)
    return rows


if __name__ == "__main__":
    run()
