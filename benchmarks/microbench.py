"""Micro-benchmarks of the MoE hot path on this host (CPU): gating,
dispatch (sort vs einsum), expert FFN (einsum vs Pallas-interpret), a
full layer step, and the ``kernel_backend`` section — ref vs pallas for
each registry op (gmm, topk_gating, dispatch/combine) so BENCH_micro.json
tracks the backend perf trajectory PR-over-PR.  Wall times are CPU-only
and NOT the TPU numbers (those come from §Roofline; the pallas rows here
measure the *interpret-mode* kernels); `derived` carries the arithmetic
each call performs so the CSV is meaningful across hosts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.common import param as pm
from repro.core import dispatch as dsp
from repro.core import gating
from repro.core.moe import MoEArgs, moe_apply, moe_defs
from repro.kernels import backend as bk_lib

T, D, E, K, FF = 4096, 64, 32, 4, 128


def run():
    a = MoEArgs(n_experts=E, k=K, d_model=D, d_ff=FF, dtype=jnp.float32,
                capacity_factor=2.0)
    params = pm.materialize(moe_defs(a), jax.random.PRNGKey(0))
    params["gate"]["wg"] = 0.3 * jax.random.normal(jax.random.PRNGKey(1),
                                                   (D, E))
    x = jax.random.normal(jax.random.PRNGKey(2), (T, D))

    g = jax.jit(lambda p, x: gating.noisy_topk_gating(
        p, x, K, train=False))
    us = time_call(g, params["gate"], x)
    emit("micro_noisy_topk_gating", us, f"T={T} E={E} k={K}")

    info = g(params["gate"], x)
    cap = dsp.capacity_for(T, E, K, 2.0)
    plan = jax.jit(lambda i, w: dsp.plan(i, w, E, cap))
    us = time_call(plan, info.expert_index, info.combine_weights)
    emit("micro_dispatch_plan_sort", us, f"T*k={T*K} assignments")

    p = plan(info.expert_index, info.combine_weights)
    # jit turned the plan's static int fields into arrays; the kernel
    # backends need them back as Python ints (shape parameters).
    p = p._replace(n_experts=E, capacity=cap)
    # plan carries static ints: close over it rather than passing through jit
    d_sort = jax.jit(lambda x: dsp.dispatch(x, p))
    us = time_call(d_sort, x)
    emit("micro_dispatch_scatter", us, f"[{T},{D}]->[{E},{cap},{D}]")
    d_ein = jax.jit(lambda x: dsp.dispatch_einsum(x, p))
    us = time_call(d_ein, x)
    emit("micro_dispatch_einsum", us, f"one-hot [{T},{E},{cap}]")

    buf = d_sort(x)
    from repro.core.moe import expert_ffn
    f_ein = jax.jit(lambda pr, b: expert_ffn(pr, b, a))
    us = time_call(f_ein, params, buf)
    flops = 2 * E * cap * D * FF * 2
    emit("micro_expert_ffn_einsum", us,
         f"GFLOP={flops/1e9:.2f} (xla)")

    full = jax.jit(lambda pr, x: moe_apply(pr, x, a, train=False)[0])
    us = time_call(full, params, x)
    emit("micro_moe_layer_full", us, f"T={T} E={E} k={K} cap={cap}")

    # --- router section: policy comparison through the registry ---------
    # (noisy_topk vs expert_choice vs dead-slot-masked gating, all through
    # the one RouterSpec path — the BENCH_micro.json trajectory shows what
    # a policy swap costs on the same layer shape.)
    from repro.core import router as rl

    def _router_row(name, spec, mask=None, extra=""):
        aR = MoEArgs(n_experts=E, k=K, d_model=D, d_ff=FF,
                     dtype=jnp.float32, router=spec)
        pR = pm.materialize(moe_defs(aR), jax.random.PRNGKey(0))
        pR["gate"]["wg"] = params["gate"]["wg"]
        fn = jax.jit(lambda pr, x, m: moe_apply(pr, x, aR, train=False,
                                                mask=m)[0])
        us = time_call(fn, pR, x, mask)
        emit(f"router_{name}", us, f"T={T} E={E} k={K}{extra}")

    spec_nt = rl.RouterSpec(policy="noisy_topk", capacity_factor=2.0)
    spec_ec = rl.RouterSpec(policy="expert_choice", capacity_factor=2.0)
    half = jnp.concatenate([jnp.ones((T // 2,)), jnp.zeros((T - T // 2,))])
    _router_row("noisy_topk", spec_nt)
    _router_row("expert_choice", spec_ec)
    _router_row("noisy_topk_masked", spec_nt, mask=half,
                extra=" occupancy=50%")

    # --- kernel_backend section: ref vs pallas per registry op ----------
    # (pallas rows are interpret-mode on CPU hosts — the trajectory shows
    # the dispatch overhead trend, not MXU throughput.)
    logits = info.raw_logits
    for name in ("ref", "pallas"):
        bk = bk_lib.get(name)
        aN = MoEArgs(n_experts=E, k=K, d_model=D, d_ff=FF,
                     dtype=jnp.float32, kernel_backend=name)
        ffn = jax.jit(lambda pr, b, _bk=bk, _a=aN: _bk.expert_ffn(pr, b, _a))
        us = time_call(ffn, params, buf)
        emit(f"kernel_backend_gmm_{name}", us,
             f"expert_ffn GFLOP={flops/1e9:.2f}")
        if bk.topk_impl is None:
            # match the production ref gating path: top-(k+1) values AND
            # indices (load-estimator threshold), softmax over the first k
            def tk_ref(l):
                tv, ti = jax.lax.top_k(l, K + 1)
                return jax.nn.softmax(tv[:, :K], axis=-1), ti, tv
            tk = jax.jit(tk_ref)
        else:
            tk = jax.jit(lambda l, _f=bk.topk_impl: _f(l, K, K + 1))
        us = time_call(tk, logits)
        emit(f"kernel_backend_topk_{name}", us, f"T={T} E={E} k+1={K+1}")
        dc = jax.jit(lambda x, _bk=bk, _a=aN: _bk.combine(
            _bk.dispatch(x, p, _a), p, _a))
        us = time_call(dc, x)
        emit(f"kernel_backend_dispatch_combine_{name}", us,
             f"[{T},{D}]<->[{E},{cap},{D}] fused" if name == "pallas"
             else f"[{T},{D}]<->[{E},{cap},{D}] scatter+gather")

    # --- E-blocked fused dispatch/combine -------------------------------
    # (The resident-buffer pallas row is above; these force the E-blocked
    # kernels on the same shape to price the slab walk — what a config
    # over the VMEM budget pays instead of falling back to ref.  Best-of-N
    # per ROADMAP housekeeping.)
    bkP = bk_lib.get("pallas")
    for eblk in (8, 2):
        aB = MoEArgs(n_experts=E, k=K, d_model=D, d_ff=FF,
                     dtype=jnp.float32, kernel_backend="pallas",
                     dispatch_e_block=eblk)
        dcB = jax.jit(lambda x, _a=aB: bkP.combine(
            bkP.dispatch(x, p, _a), p, _a))
        us = time_call(dcB, x, reduce="best")
        emit(f"kernel_eblock_dispatch_combine_e{eblk}", us,
             f"[{T},{D}]<->[{E},{cap},{D}] e_block={eblk} "
             f"({E // eblk} slabs)")

    # --- fused single-launch decode step --------------------------------
    # (docs/kernels.md §Fused decode step: decode-shaped calls — a
    # handful of slot tokens — are where per-launch overhead dominates;
    # the fused kernel collapses the >=4 unfused launches (top-k,
    # dispatch, 2x GMM, combine) into one.  Interpret-mode wall times on
    # CPU price the host-side dispatch trend, not MXU throughput; the
    # launch-count collapse itself is pinned in test_fused_decode.py.)
    tB = 8
    xB = jax.random.normal(jax.random.PRNGKey(3), (tB, D))
    occ = jnp.ones((tB,))
    for fused in (False, True):
        aF = MoEArgs(n_experts=E, k=K, d_model=D, d_ff=FF,
                     dtype=jnp.float32, kernel_backend="pallas",
                     fused_decode=fused)
        fn = jax.jit(lambda pr, xv, m, _a=aF: moe_apply(
            pr, xv, _a, train=False, mask=m)[0])
        us = time_call(fn, params, xB, occ, reduce="best")
        tag = "fused" if fused else "unfused"
        emit(f"fused_decode_{tag}_pallas", us,
             f"T={tB} E={E} k={K} launches={'1' if fused else '>=5'}")
    # plan-mode variant (routing outside the kernel — the expert_choice
    # and MoA shape): one scatter+FFN+combine launch on a ready plan.
    capB = dsp.capacity_for(tB, E, K, 2.0)
    infoB = g(params["gate"], xB)
    pB = dsp.plan(infoB.expert_index, infoB.combine_weights, E, capB)
    from repro.kernels import ops as kops_fd
    ra = jax.jit(lambda xv: kops_fd.fused_routed_apply(
        xv, pB, pB, params["w1"].astype(jnp.float32),
        params["w2"].astype(jnp.float32), mode="ffn", activation="relu"))
    us = time_call(ra, xB, reduce="best")
    emit("fused_decode_routed_apply_pallas", us,
         f"T={tB} E={E} cap={capB} plan-mode launches=1")

    # --- GMM tiling autotune --------------------------------------------
    # (Static 128 tiles vs the measured table — `make tune-kernels` — on
    # the expert-FFN projection shapes.  plan_blocks resolves the tuned
    # entry when tiles are left unset; the rows pin the win the
    # kernel_backend_gmm_pallas row inherits.  Best-of-N.)
    from repro.kernels import gmm as gmm_lib
    from repro.kernels import ops as kops
    w1 = params["w1"].astype(jnp.float32)
    hid = jnp.maximum(jnp.einsum("ecd,edf->ecf", buf, w1), 0.0)
    for (xg, wg, label, kdim, ndim) in (
            (buf, w1, "up", D, FF),
            (hid, params["w2"].astype(jnp.float32), "down", FF, D)):
        key = gmm_lib.tuning_key(E, cap, kdim, ndim, jnp.float32)
        tuned = gmm_lib.lookup_tiling(E, cap, kdim, ndim, jnp.float32)
        f_def = jax.jit(lambda x_, w_: kops.gmm(x_, w_, bm=128, bn=128,
                                                bk=128))
        us = time_call(f_def, xg, wg, warmup=1, iters=3, reduce="best")
        emit(f"gmm_default_{label}proj", us, f"{key} tiles=(128,128,128)")
        f_tuned = jax.jit(lambda x_, w_: kops.gmm(x_, w_))
        us = time_call(f_tuned, xg, wg, warmup=1, iters=3, reduce="best")
        emit(f"gmm_tuned_{label}proj", us,
             f"{key} tiles={tuned or '(untuned: 128 defaults)'}")


if __name__ == "__main__":
    run()
