"""Micro-benchmarks of the MoE hot path on this host (CPU): gating,
dispatch (sort vs einsum), expert FFN (einsum vs Pallas-interpret), and a
full layer step.  Wall times are CPU-only and NOT the TPU numbers (those
come from §Roofline); `derived` carries the arithmetic each call performs
so the CSV is meaningful across hosts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.common import param as pm
from repro.core import dispatch as dsp
from repro.core import gating
from repro.core.moe import MoEArgs, moe_apply, moe_defs

T, D, E, K, FF = 4096, 64, 32, 4, 128


def run():
    a = MoEArgs(n_experts=E, k=K, d_model=D, d_ff=FF, dtype=jnp.float32,
                capacity_factor=2.0)
    params = pm.materialize(moe_defs(a), jax.random.PRNGKey(0))
    params["gate"]["wg"] = 0.3 * jax.random.normal(jax.random.PRNGKey(1),
                                                   (D, E))
    x = jax.random.normal(jax.random.PRNGKey(2), (T, D))

    g = jax.jit(lambda p, x: gating.noisy_topk_gating(
        p, x, K, train=False))
    us = time_call(g, params["gate"], x)
    emit("micro_noisy_topk_gating", us, f"T={T} E={E} k={K}")

    info = g(params["gate"], x)
    cap = dsp.capacity_for(T, E, K, 2.0)
    plan = jax.jit(lambda i, w: dsp.plan(i, w, E, cap))
    us = time_call(plan, info.expert_index, info.combine_weights)
    emit("micro_dispatch_plan_sort", us, f"T*k={T*K} assignments")

    p = plan(info.expert_index, info.combine_weights)
    # plan carries static ints: close over it rather than passing through jit
    d_sort = jax.jit(lambda x: dsp.dispatch(x, p))
    us = time_call(d_sort, x)
    emit("micro_dispatch_scatter", us, f"[{T},{D}]->[{E},{cap},{D}]")
    d_ein = jax.jit(lambda x: dsp.dispatch_einsum(x, p))
    us = time_call(d_ein, x)
    emit("micro_dispatch_einsum", us, f"one-hot [{T},{E},{cap}]")

    buf = d_sort(x)
    from repro.core.moe import expert_ffn
    f_ein = jax.jit(lambda pr, b: expert_ffn(pr, b, a))
    us = time_call(f_ein, params, buf)
    flops = 2 * E * cap * D * FF * 2
    emit("micro_expert_ffn_einsum", us,
         f"GFLOP={flops/1e9:.2f} (xla)")

    full = jax.jit(lambda pr, x: moe_apply(pr, x, a, train=False)[0])
    us = time_call(full, params, x)
    emit("micro_moe_layer_full", us, f"T={T} E={E} k={K} cap={cap}")


if __name__ == "__main__":
    run()
