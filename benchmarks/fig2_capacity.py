"""Figure 2-left reproduction: perplexity vs capacity at matched compute.

A series of MoE LMs with identical ops/timestep (k=2 active experts each)
and growing expert counts, plus the computationally-matched dense baselines
(MoE-1-Wide / MoE-1-Deep analogues), trained on the latent-sub-language
synthetic corpus whose memorizable structure exceeds the small models'
capacity.  The paper's claim at this scale: test perplexity falls
monotonically(ish) with expert count at flat compute — capacity, not
FLOPs, is the limiter.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.common import param as pm
from repro.data.pipeline import DataConfig, DataIterator, batch_at
from repro.models.paper_lm import PaperLMConfig, paper_lm_defs, paper_lm_loss
from repro.optim import optimizers as opt_lib
from repro.train.trainer import make_train_step

# moe-2 with k=2 is the no-sparsity, compute-matched baseline (all experts
# always active — the paper's MoE-4 role); capacity grows to the right.
VARIANTS = [
    ("moe-2", dict(variant="moe", n_experts=2, k=2)),
    ("moe-4", dict(variant="moe", n_experts=4, k=2)),
    ("moe-8", dict(variant="moe", n_experts=8, k=2)),
    ("moe-16", dict(variant="moe", n_experts=16, k=2)),
    ("moe-16-h", dict(variant="moe", n_experts=16, hierarchical=(4, 4))),
]


def run(steps: int = 500):
    # regime where the small model *saturates* (memorizable structure
    # exceeds its capacity while compute stays matched): 64 sub-languages
    # over a 32-token vocab, tiny d_model/expert width.
    dc = DataConfig(vocab_size=32, seq_len=16, batch_size=64,
                    n_clusters=64, noise_prob=0.01, seed=5)
    results = []
    for name, kw in VARIANTS:
        cfg = PaperLMConfig(vocab_size=dc.vocab_size, d_model=16,
                            expert_hidden=16, dropout=0.0,
                            capacity_factor=2.0, **kw)
        params = pm.materialize(paper_lm_defs(cfg), jax.random.PRNGKey(0))
        n_params = pm.param_count(params)
        oc = opt_lib.OptConfig(learning_rate=3e-2, warmup_steps=30)
        step = jax.jit(make_train_step(
            lambda p, b, r: paper_lm_loss(p, b, cfg, rng=r), oc))
        state = {"params": params, "opt": opt_lib.init(params, oc)}
        it = DataIterator(dc)
        t0 = time.perf_counter_ns()
        for s in range(steps):
            state, _ = step(state, next(it), jax.random.PRNGKey(s))
        us = (time.perf_counter_ns() - t0) / steps / 1e3
        test = batch_at(dc, 20_000)
        _, tm = paper_lm_loss(state["params"], test, cfg, train=False)
        ppl = float(tm["perplexity"])
        results.append((name, n_params, ppl))
        emit(f"fig2_{name}", us, f"params={n_params} test_ppl={ppl:.2f}")
    # headline claim: added capacity at matched compute beats the baseline
    dense_ppl = results[0][2]
    big_moe_ppl = min(r[2] for r in results[2:])
    assert big_moe_ppl < dense_ppl, (results,)
    return results


if __name__ == "__main__":
    run()
