"""Shared benchmark helpers: timing + CSV emission."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 2, iters: int = 5,
              reduce: str = "median") -> float:
    """Wall time per call in microseconds (post-warmup, blocking).

    ``reduce="median"`` for trend rows; ``"best"`` (min) where the ROADMAP
    best-of-N discipline applies — this host has ~10ms fixed per-jitted-
    call cost and ±10–20% wall noise, so comparisons (e.g. the tiling
    tuner) should rank by best-of-N, not single-shot means."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    pick = times[0] if reduce == "best" else times[len(times) // 2]
    return pick * 1e6


ROWS: list[dict] = []           # every emit() lands here for JSON export


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 1),
                 "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")
