"""Shared benchmark helpers: timing + CSV emission.

Timer hygiene (ROADMAP housekeeping): every suite times with
``time.perf_counter_ns`` (monotonic, ns resolution — float seconds from
``perf_counter`` lose precision exactly where µs-scale kernel calls
live) and reports either best-of-N (:func:`best_of`, for cross-commit
comparisons: this host has ~10ms fixed per-jitted-call cost and ±10–20%
wall noise) or an interpolated percentile (:func:`pctl`, for tail
measurements like per-step head-of-line stalls).  Suites must not hand-
roll their own min/percentile loops — one implementation, one set of
conventions.
"""
from __future__ import annotations

import time

import jax


def wall_ns(fn, *args) -> int:
    """One blocking call of ``fn(*args)``, wall time in integer ns."""
    t0 = time.perf_counter_ns()
    jax.block_until_ready(fn(*args))
    return time.perf_counter_ns() - t0


def pctl(samples, p: float) -> float:
    """Interpolated percentile (``p`` in [0, 100]) of a sample list.
    Matches ``numpy.percentile``'s default linear interpolation; kept
    dependency-free so host-only suites can import it."""
    if not samples:
        raise ValueError("pctl of an empty sample list")
    s = sorted(samples)
    if len(s) == 1:
        return float(s[0])
    rank = p / 100.0 * (len(s) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(s) - 1)
    return float(s[lo] + (s[hi] - s[lo]) * (rank - lo))


def best_of(run, n: int = 3, *, warmup: int = 1, key=None):
    """Warm caches with ``warmup`` calls, then return the best of ``n``
    results of ``run()`` — "best" meaning minimal ``key(result)``
    (default: the result's ``"wall_s"`` entry; pass ``key=float`` style
    callables for plain-number runs).  This is the ROADMAP best-of-N
    discipline: scheduling/compute are deterministic, only the wall
    clock varies with host noise, so min is the low-noise estimator."""
    if key is None:
        key = lambda r: r["wall_s"]  # noqa: E731
    for _ in range(warmup):
        run()
    return min((run() for _ in range(n)), key=key)


def time_call(fn, *args, warmup: int = 2, iters: int = 5,
              reduce: str = "median") -> float:
    """Wall time per call in microseconds (post-warmup, blocking).

    ``reduce="median"`` for trend rows; ``"best"`` (min) where the ROADMAP
    best-of-N discipline applies — this host has ~10ms fixed per-jitted-
    call cost and ±10–20% wall noise, so comparisons (e.g. the tiling
    tuner) should rank by best-of-N, not single-shot means."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = sorted(wall_ns(fn, *args) for _ in range(iters))
    pick = times[0] if reduce == "best" else times[len(times) // 2]
    return pick / 1e3


ROWS: list[dict] = []           # every emit() lands here for JSON export


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 1),
                 "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")
