"""Shared benchmark helpers: timing + CSV emission."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (post-warmup, blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


ROWS: list[dict] = []           # every emit() lands here for JSON export


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 1),
                 "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")
