"""Fit a serve cost model from a recorded chrome trace.

Two modes:

* ``--trace PATH`` — fit from an existing trace (any ``ServeEngine`` run
  with ``ServeConfig.trace_path`` set, e.g. ``make trace-serve``);
* no ``--trace`` — record one first: the shared-prefix serve workload
  (serve_bench's ``serve_prefix_on`` shape) runs once untraced and once
  traced (best-of-3 each, shared timer discipline), which also measures
  the tracing overhead the ISSUE bounds (<2%) and checks traced/untraced
  greedy outputs are bit-identical.

Output: a JSON cost table (``--out``, default COSTS_serve.json) of per-op
linear fits ``dur_s ~ a*x + b`` — the input to ``repro.obs.replay`` and
``benchmarks/replay_bench.py`` (docs/observability.md).
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def build_engine_and_trace(trace_path: str | None = None,
                           sync: bool = True):
    """The serve_prefix_on workload: 12 requests sharing a 192-token
    prefix, chunked prefill + aware admission + prefix cache on.
    ``sync=True`` is calibration mode (block inside spans so each span's
    duration is that op's real wall — what the cost model fits on)."""
    from benchmarks.serve_bench import N_SLOTS, _setup
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg, params = _setup()
    rng = np.random.RandomState(7)
    shared = rng.randint(1, cfg.vocab_size, (192,)).astype(np.int32)
    trace = [(np.concatenate([shared,
                              rng.randint(1, cfg.vocab_size, (32,))
                              .astype(np.int32)]),
              8, 0 if i == 0 else 16)
             for i in range(12)]
    sc = ServeConfig(max_len=256, n_slots=N_SLOTS, prefill_chunk=64,
                     prefill_budget=128, admission="aware",
                     prefix_cache=True, trace_path=trace_path,
                     trace_sync=sync)
    return ServeEngine(params, cfg, sc), trace


def record(trace_path: str, pairs: int = 8) -> dict:
    """Run the workload untraced / traced (default mode) / traced
    (calibration mode, ``trace_sync=True``) in interleaved rounds, write
    the calibration trace, and return overhead/identity measurements.

    Interleaving matters: host wall on this workload drifts ±10-20%
    over a script's lifetime, far above the effect being measured, so
    back-to-back best-of-N blocks mostly measure the drift.  Round-robin
    runs with min-vs-min comparison cancel it.  ``overhead`` is the
    default tracing mode (span appends only — what ``--trace`` costs);
    ``overhead_sync`` is calibration mode, which additionally blocks on
    device results inside each span (exact per-op attribution for the
    cost fit, paid for in lost host/device overlap).  Every calibration
    replay's spans are kept (tracer events accumulate) — more samples
    for the fit.
    """
    from benchmarks.serve_bench import _run_trace

    eng_off, trace = build_engine_and_trace(None)
    eng_on, _ = build_engine_and_trace(trace_path + ".default",
                                       sync=False)
    eng_cal, _ = build_engine_and_trace(trace_path, sync=True)
    off0 = _run_trace(eng_off, trace)    # warmup / compile, all engines
    on0 = _run_trace(eng_on, trace)
    _run_trace(eng_cal, trace)
    eng_cal.tracer.clear()  # drop warmup spans: they time jit compiles,
    offs, ons, cals = [], [], []   # not the steady state the model fits
    for _ in range(pairs):
        offs.append(_run_trace(eng_off, trace)["wall_s"])
        eng_on.tracer.clear()
        ons.append(_run_trace(eng_on, trace)["wall_s"])
        cals.append(_run_trace(eng_cal, trace)["wall_s"])
    eng_cal.tracer.save()  # _run_trace drives step() directly, save here
    return {
        "trace_path": trace_path,
        "untraced_wall_s": min(offs),
        "traced_wall_s": min(ons),
        "calibration_wall_s": min(cals),
        "overhead": min(ons) / min(offs) - 1.0,
        "overhead_sync": min(cals) / min(offs) - 1.0,
        "pairs": pairs,
        "events": len(eng_cal.tracer.events),
        "bit_identical": off0["out_tokens"] == on0["out_tokens"],
    }


def fit(trace_path: str):
    from repro.obs.replay import CostModel
    return CostModel.fit_trace(trace_path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=None,
                    help="existing chrome-trace JSON to fit from "
                         "(default: record one from the shared-prefix "
                         "serve workload)")
    ap.add_argument("--record-to", default="/tmp/serve_costs_trace.json",
                    help="where the recorded trace lands when --trace is "
                         "not given")
    ap.add_argument("--out", default="COSTS_serve.json",
                    help="cost-model JSON output path")
    args = ap.parse_args()

    meta = {}
    trace_path = args.trace
    if trace_path is None:
        meta = record(args.record_to)
        trace_path = args.record_to
        print(f"[fit-costs] recorded {meta['events']} events; tracing "
              f"overhead {meta['overhead']*100:+.2f}% "
              f"(calibration mode {meta['overhead_sync']*100:+.2f}%; "
              f"untraced {meta['untraced_wall_s']:.3f}s -> traced "
              f"{meta['traced_wall_s']:.3f}s), "
              f"bit_identical={meta['bit_identical']}")
    model = fit(trace_path)
    print(f"[fit-costs] {len(model.ops)} ops fitted from {trace_path}:")
    for name, oc in sorted(model.ops.items()):
        print(f"  {name:24s} a={oc.a:.3e} s/x  b={oc.b:.3e} s  (n={oc.n})")
    payload = {"trace": trace_path, "ops": model.to_dict(), **meta}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[fit-costs] wrote {args.out}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, ".")
    main()
