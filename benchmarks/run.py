"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes the collected
rows as JSON (default ``BENCH_micro.json``) so the perf trajectory
accumulates across PRs.  Mapping to the paper:

  table7_ops        -> Table 7 (and Table 1): ops/timestep + params vs the
                       paper's published accounting (hard-asserted <12% err)
  table2_mt_ops     -> Tables 2-4 cost columns (85M vs 214M ops/timestep)
  table6_balance    -> Table 6: w_importance/w_load ablation (CV + max/mean)
  fig2_capacity     -> Figure 2-left: perplexity vs capacity, matched ops
  microbench        -> host-side hot-path microbenchmarks
  moa_bench         -> routed vs dense-all-heads attention (beyond-paper;
                       docs/moa.md) — micro rows join the micro suite,
                       the serve_moa row joins the serve suite
  serve_bench       -> static-batch vs continuous-batching serving
                       throughput/latency (beyond-paper; docs/serving.md)
  (Figure 3 is Figure 2 at 100B words; Table 5 needs the 12-pair corpus —
   both noted in EXPERIMENTS.md §Skips.  TPU-side numbers live in
   EXPERIMENTS.md §Roofline, produced by repro.launch.dryrun.)

Usage:
  PYTHONPATH=src python benchmarks/run.py                 # everything
  PYTHONPATH=src python benchmarks/run.py --only micro    # just microbench
  PYTHONPATH=src python benchmarks/run.py --json out.json
"""
from __future__ import annotations

import argparse
import json
import platform
import time

SUITES = ("table7", "table2", "micro", "table6", "fig2", "serve")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=SUITES,
                    help="run a single suite (default: all)")
    ap.add_argument("--json", default=None,
                    help="path for the JSON row dump ('' to disable; "
                         "default BENCH_micro.json for --only micro, "
                         "BENCH_full.json otherwise — so the committed "
                         "micro trajectory is never clobbered by a full "
                         "run)")
    args = ap.parse_args()
    if args.json is None:
        args.json = ("BENCH_micro.json" if args.only == "micro"
                     else "BENCH_full.json")

    print("name,us_per_call,derived")
    from benchmarks import (common, fig2_capacity, microbench, moa_bench,
                            serve_bench, table2_mt_ops, table6_balance,
                            table7_ops)
    runners = {
        "table7": table7_ops.run,
        "table2": table2_mt_ops.run,
        "micro": lambda: (microbench.run(), moa_bench.run_micro()),
        "table6": table6_balance.run,
        "fig2": fig2_capacity.run,
        "serve": serve_bench.run,
    }
    picked = [args.only] if args.only else list(SUITES)
    t0 = time.time()
    for name in picked:
        runners[name]()
    wall_us = (time.time() - t0) * 1e6
    print(f"benchmarks_total,{wall_us:.0f},wall")

    if args.json:
        import jax
        payload = {
            "suites": picked,
            "wall_us": round(wall_us),
            "host": platform.node(),
            "platform": platform.platform(),
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "rows": common.ROWS,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[bench] wrote {len(common.ROWS)} rows to {args.json}")


if __name__ == "__main__":
    main()
