"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Mapping to the paper:

  table7_ops        -> Table 7 (and Table 1): ops/timestep + params vs the
                       paper's published accounting (hard-asserted <12% err)
  table2_mt_ops     -> Tables 2-4 cost columns (85M vs 214M ops/timestep)
  table6_balance    -> Table 6: w_importance/w_load ablation (CV + max/mean)
  fig2_capacity     -> Figure 2-left: perplexity vs capacity, matched ops
  microbench        -> host-side hot-path microbenchmarks
  (Figure 3 is Figure 2 at 100B words; Table 5 needs the 12-pair corpus —
   both noted in EXPERIMENTS.md §Skips.  TPU-side numbers live in
   EXPERIMENTS.md §Roofline, produced by repro.launch.dryrun.)
"""
from __future__ import annotations

import time


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (fig2_capacity, microbench, table2_mt_ops,
                            table6_balance, table7_ops)
    t0 = time.time()
    table7_ops.run()
    table2_mt_ops.run()
    microbench.run()
    table6_balance.run()
    fig2_capacity.run()
    print(f"benchmarks_total,{(time.time()-t0)*1e6:.0f},wall")


if __name__ == "__main__":
    main()
