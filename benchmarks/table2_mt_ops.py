"""Tables 2-4 (MT) cost columns: ops/timestep accounting for the paper's
translation models.

The WMT BLEU numbers need the WMT corpora (unavailable offline), so this
benchmark reproduces the *systems* half of those tables: the 85M
ops/timestep budget of the MoE-2048 model vs GNMT's 214M — the paper's
"40% of the computation, +1.34 BLEU" claim rests on this accounting.

Paper MT model (§E): enc 3 + dec 2 LSTM layers (2048 hidden, 512 proj),
MoE layers in encoder and decoder (2048 experts, k=4 active, each expert
512->2048->512), attention network (n=512).
"""
from __future__ import annotations

from benchmarks.common import emit
from benchmarks.table7_ops import lstm_madds


def run():
    d = 512
    lstm = lstm_madds(d, 2048, d)                 # projected LSTM
    n_lstm = 5                                    # 3 enc + 2 dec
    moe_active = 4 * (d * 2048 + 2048 * d)        # k=4 active experts
    n_moe = 2                                     # enc + dec
    attn = 2 * (d * d)                            # A(x,y): xU and yW per pair
    ops = n_lstm * lstm + n_moe * moe_active + attn + 2 * d * d  # embed proj
    total_m = ops / 1e6
    emit("table2_moe2048_ops", 0.0,
         f"ops/ts={total_m:.0f}M (paper 85M) "
         f"params_moe=2*{2048*(d*2048+2048*d)/1e9:.1f}B (paper ~8B added)")
    assert abs(total_m - 85) / 85 < 0.25, total_m
    # GNMT baseline: 9 enc + 8 dec projected LSTM-2048 layers
    gnmt = 17 * lstm + attn
    emit("table2_gnmt_ops", 0.0,
         f"ops/ts={gnmt/1e6:.0f}M (paper 214M) "
         f"ratio={ops/gnmt:.2f} (paper 85/214=0.40)")
    assert abs(gnmt / 1e6 - 214) / 214 < 0.25, gnmt


if __name__ == "__main__":
    run()
