"""Replay-simulator benchmark: admission policies at 100k-request scale.

Runs the cost-model replay simulator (``repro.obs.replay``) over a 100k
synthetic request trace twice — ``admission="fcfs"`` vs ``"aware"`` —
and emits one row per policy:

  serve_replay_fcfs    us = sim runtime on this host;  derived:
  serve_replay_aware     predicted wall, p50/p95/p99 request latency
                         (steps and predicted seconds), prefix hits

The whole point of the simulator is this comparison: the same scheduler
code the engine runs, driven over traffic volumes no devicebound bench
could touch (100k requests replay in seconds), with wall predictions
from costs fitted to a real traced run.  A third row,
``serve_trace_overhead``, records what the span capture itself costs the
engine (the ISSUE bounds it <2%) whenever this run had to record a fresh
calibration trace.

Standalone (``make bench-replay``) merges rows into BENCH_serve.json the
same way ``serve_bench --prefix-only`` does; pass ``--costs PATH`` to
reuse a COSTS_serve.json from ``make fit-costs`` and skip the device
recording entirely.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import ROWS, emit

N_REQUESTS = 100_000


def _get_model(costs_path: str | None):
    """Cost model from a COSTS_serve.json, else record + fit one now
    (returns the overhead measurements only in the latter case)."""
    from repro.obs.replay import CostModel

    if costs_path and os.path.exists(costs_path):
        with open(costs_path) as f:
            payload = json.load(f)
        return CostModel.from_dict(payload["ops"]), None
    from benchmarks import fit_costs
    meta = fit_costs.record("/tmp/serve_costs_trace.json")
    return CostModel.fit_trace(meta["trace_path"]), meta


def run(costs_path: str | None = None) -> None:
    from repro.obs import replay as rp

    model, meta = _get_model(costs_path)
    if meta is not None:
        emit("serve_trace_overhead", meta["traced_wall_s"] * 1e6,
             f"overhead={meta['overhead']*100:+.2f}%;"
             f"overhead_sync={meta['overhead_sync']*100:+.2f}%;"
             f"untraced_s={meta['untraced_wall_s']:.3f};"
             f"events={meta['events']};"
             f"bit_identical={meta['bit_identical']}")

    # Mixed traffic just under the pool's prefill-limited service rate:
    # 192-token prompts (4 budget-filling chunks each, post-prefix-hit)
    # interleave with 16-token ones, so a long head-of-line prompt
    # claiming a slot with no budget left is common — exactly where the
    # two admission policies diverge.  Sustained *over*load is avoided
    # on purpose: the queue would grow without bound and the aware
    # policy's per-pop fits-scan over it (real RequestQueue behavior)
    # would dominate sim runtime.
    reqs = rp.synthetic_requests(
        N_REQUESTS, prompt_lens=(16, 192), new_tokens=(4, 16),
        arrival_every=1.8, shared_prefix=64, seed=1)
    results = {}
    for adm in ("fcfs", "aware"):
        cfg = rp.ReplayConfig(n_slots=8, admission=adm, prefill_chunk=32,
                              prefill_budget=32, prefix_cache=True,
                              max_len=256)
        t0 = time.perf_counter_ns()
        res = rp.replay(reqs, cfg, model)
        sim_s = (time.perf_counter_ns() - t0) / 1e9
        results[adm] = (res, sim_s)
        steps = res.metrics.get("request_latency_steps")
        secs = res.metrics.get("request_latency_s")
        emit(f"serve_replay_{adm}", sim_s * 1e6,
             f"requests={N_REQUESTS};steps={res.steps};"
             f"pred_wall_s={res.predicted_wall_s:.1f};"
             f"lat_steps_p50={steps.p50:.0f};"
             f"lat_steps_p95={steps.p95:.0f};"
             f"lat_steps_p99={steps.p99:.0f};"
             f"lat_s_p95={secs.p95:.2f};"
             f"prefix_hits={res.stats['prefix_hits']}")
    aware, fcfs = results["aware"][0], results["fcfs"][0]
    p95_f = fcfs.metrics.get("request_latency_steps").p95
    p95_a = aware.metrics.get("request_latency_steps").p95
    print(f"[replay] aware vs fcfs: p95 latency {p95_f:.0f} -> "
          f"{p95_a:.0f} steps ({p95_f / max(p95_a, 1e-9):.2f}x), "
          f"predicted wall {fcfs.predicted_wall_s:.1f}s -> "
          f"{aware.predicted_wall_s:.1f}s")


if __name__ == "__main__":
    import platform
    import sys

    sys.path.insert(0, ".")
    costs = None
    argv = sys.argv[1:]
    if "--costs" in argv:
        costs = argv[argv.index("--costs") + 1]
    start = len(ROWS)
    print("name,us_per_call,derived")
    run(costs)
    import jax
    new_rows = ROWS[start:]
    payload = {
        "suites": ["serve"],
        "host": platform.node(),
        "platform": platform.platform(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "rows": new_rows,
    }
    if os.path.exists("BENCH_serve.json"):
        # merge: replace same-name rows in place, append new ones
        with open("BENCH_serve.json") as f:
            payload = json.load(f)
        by_name = {r["name"]: r for r in new_rows}
        payload["rows"] = [by_name.pop(r["name"], r)
                           for r in payload["rows"]] + list(by_name.values())
    with open("BENCH_serve.json", "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[bench] wrote {len(new_rows)} rows to BENCH_serve.json")
