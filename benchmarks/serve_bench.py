"""Serving benchmark: static-batch vs continuous batching throughput.

Replays the same staggered, mixed-length request trace through the same
``ServeEngine`` twice — once with the batch-drain (``static``) admission
policy, once with continuous batching — at several prompt/output-length
mixes, and emits throughput/latency rows:

  serve_static_<mix>      us = wall time of the run;   derived tok_s/steps
  serve_continuous_<mix>  ...                          + util + speedup

Static batching decodes into dead slots until every sequence in a batch
drains before admitting the next one; continuous batching recycles a slot
the step its sequence finishes, so the same trace completes in fewer
decode steps (each step costs the same jitted call) — that step ratio is
the scheduling win, the wall-clock tok/s ratio is the measured one.

Also emitted: ``serve_occupancy_{masked,unmasked}`` (dead-slot routing
mask under partial occupancy), ``serve_{unchunked,chunked}_long`` —
the same long-prompt staggered traffic with whole-prompt vs chunked
prefill + prompt-length-aware admission, measuring head-of-line blocking
directly as the max/p95 wall time of a single engine step (the time every
live decode slot waits when a monster prefill lands in one step) — and
``serve_prefix_{off,on}``: a shared-prefix arrival trace (every request
opens with the same long system-prompt prefix) replayed with the radix
prefix cache off and on, measuring the prefill-token drop, the per-step
prefill call count under cross-slot chunk batching, and greedy-output
bit-identity between the two runs.

Every timed row is best-of-N (N=3) with per-step p95s — single-shot
means are too host-noise-sensitive to compare across commits (ROADMAP
housekeeping).

Standalone (``make bench-serve``) writes BENCH_serve.json;
``--prefix-only`` (``make bench-serve-prefix``) runs just the
shared-prefix section and merges its rows into an existing
BENCH_serve.json; via ``benchmarks/run.py --only serve`` the rows join
the common JSON dump.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ROWS, best_of, emit, pctl

# (name, prompt-length cycle, new-token cycle): short-uniform traffic, a
# long-prompt mix, and a skewed output mix (the worst case for drains).
# Prompt lengths stay multiples (or divisors) of the reduced q_block=16.
MIXES = (
    ("short", (8, 8, 8, 8), (8, 8, 8, 8)),
    ("mixed", (8, 32, 16, 8), (4, 16, 8, 12)),
    ("skewed", (16, 8, 8, 8), (24, 4, 4, 4)),
)
N_REQUESTS = 16
N_SLOTS = 4
ARRIVALS_PER_STEP = 2   # two requests become visible per engine step


def _requests(rng: np.random.RandomState, vocab: int, plens, nlens):
    return [
        (rng.randint(1, vocab, (plens[i % len(plens)],)).astype(np.int32),
         nlens[i % len(nlens)], i // ARRIVALS_PER_STEP)
        for i in range(N_REQUESTS)
    ]


def _run_trace(engine, trace) -> dict:
    """Replay a trace, timing each engine step individually: the max/p95
    single-step wall time is the head-of-line-blocking measurement (a
    whole-prompt prefill of a monster prompt lands inside one step and
    every live decode slot waits out exactly that wall time)."""
    engine.reset()
    reqs = [engine.submit(p, m, arrival=a) for p, m, a in trace]
    step_walls_ns = []
    t0 = time.perf_counter_ns()
    while engine.queue or engine.sched.active():
        s0 = time.perf_counter_ns()
        engine.step()
        step_walls_ns.append(time.perf_counter_ns() - s0)
    dt = (time.perf_counter_ns() - t0) / 1e9
    assert all(r.done for r in reqs)
    lat = [r.finished_step - r.arrival for r in reqs]
    return {
        "wall_s": dt,
        "tokens": engine.stats["generated_tokens"],
        "tok_s": engine.stats["generated_tokens"] / dt,
        "decode_steps": engine.stats["decode_steps"],
        "util": engine.slot_utilization,
        "mean_latency_steps": float(np.mean(lat)),
        "p95_latency_steps": pctl(lat, 95),
        "step_max_ms": max(step_walls_ns) / 1e6,
        "step_p95_ms": pctl(step_walls_ns, 95) / 1e6,
        # greedy output streams, for cross-config bit-identity checks
        "out_tokens": tuple(tuple(r.tokens) for r in reqs),
    }


def _best_of(engine, trace, n: int = 3) -> dict:
    """Best-of-N trace replays via the shared ``common.best_of`` helper
    (scheduling is deterministic, so stats/outputs are identical across
    replays — only the wall clock varies with host noise)."""
    return best_of(lambda: _run_trace(engine, trace), n)


def _setup():
    import jax
    import jax.numpy as jnp

    from repro.common import param as pm
    from repro.configs.base import get_config
    from repro.models import lm

    cfg = get_config("kimi-k2-1t-a32b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        vocab_size=256, n_experts=8, moe_k=2, moe_d_ff=64,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        q_block=16, kv_block=16, capacity_factor=2.0)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def run_prefix(cfg=None, params=None) -> None:
    """Shared-prefix arrival trace: every request opens with the same
    192-token system-prompt prefix and adds a 32-token unique tail.  The
    first request arrives alone (its retirement seeds the trie); the rest
    arrive together once it has retired, so with the cache on they all
    resume from the cached prefix and prefill only their tails — and
    their same-offset tail chunks batch into shared multi-row prefill
    calls (prefill_calls < prefill_chunks)."""
    from repro.serve.engine import ServeConfig, ServeEngine

    if cfg is None:
        cfg, params = _setup()
    rng = np.random.RandomState(7)
    shared = rng.randint(1, cfg.vocab_size, (192,)).astype(np.int32)
    trace = [(np.concatenate([shared,
                              rng.randint(1, cfg.vocab_size, (32,))
                              .astype(np.int32)]),
              8, 0 if i == 0 else 16)
             for i in range(12)]
    base = dict(max_len=256, n_slots=N_SLOTS, prefill_chunk=64,
                prefill_budget=128, admission="aware")
    results = {}
    for tag, on in (("serve_prefix_off", False), ("serve_prefix_on", True)):
        eng = ServeEngine(params, cfg, ServeConfig(
            prefix_cache=on, **base))
        results[tag] = (_best_of(eng, trace), eng)
    off, offeng = results["serve_prefix_off"]
    on, oneng = results["serve_prefix_on"]
    identical = off["out_tokens"] == on["out_tokens"]
    drop = 1.0 - (oneng.stats["prefill_tokens"]
                  / offeng.stats["prefill_tokens"])
    emit("serve_prefix_off", off["wall_s"] * 1e6,
         f"tok_s={off['tok_s']:.1f};step_p95_ms={off['step_p95_ms']:.1f};"
         f"prefill_tokens={offeng.stats['prefill_tokens']};"
         f"prefill_calls={offeng.stats['prefill_calls']};"
         f"prefill_chunks={offeng.stats['prefill_chunks']}")
    emit("serve_prefix_on", on["wall_s"] * 1e6,
         f"tok_s={on['tok_s']:.1f};step_p95_ms={on['step_p95_ms']:.1f};"
         f"prefill_tokens={oneng.stats['prefill_tokens']};"
         f"prefill_calls={oneng.stats['prefill_calls']};"
         f"prefill_chunks={oneng.stats['prefill_chunks']};"
         f"hits={oneng.stats['prefix_hits']};"
         f"hit_tokens={oneng.stats['prefix_hit_tokens']};"
         f"prefill_token_drop={drop:.2f};"
         f"speedup={on['tok_s'] / off['tok_s']:.2f}x;"
         f"bit_identical={identical}")


def run() -> None:
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg, params = _setup()
    engines = {
        policy: ServeEngine(params, cfg, ServeConfig(
            max_len=64, n_slots=N_SLOTS, policy=policy))
        for policy in ("static", "continuous")
    }

    rng = np.random.RandomState(0)
    for name, plens, nlens in MIXES:
        trace = _requests(rng, cfg.vocab_size, plens, nlens)
        res = {policy: _best_of(engines[policy], trace)
               for policy in ("static", "continuous")}
        s, c = res["static"], res["continuous"]
        emit(f"serve_static_{name}", s["wall_s"] * 1e6,
             f"tok_s={s['tok_s']:.1f};steps={s['decode_steps']};"
             f"util={s['util']:.2f};lat_mean={s['mean_latency_steps']:.1f};"
             f"step_p95_ms={s['step_p95_ms']:.1f}")
        emit(f"serve_continuous_{name}", c["wall_s"] * 1e6,
             f"tok_s={c['tok_s']:.1f};steps={c['decode_steps']};"
             f"util={c['util']:.2f};lat_mean={c['mean_latency_steps']:.1f};"
             f"step_p95_ms={c['step_p95_ms']:.1f};"
             f"speedup={c['tok_s'] / s['tok_s']:.2f}x")

    # --- fused single-launch decode step ---------------------------------
    # (docs/kernels.md §Fused decode step: the pallas backend runs each
    # MoE decode layer as ONE kernel launch instead of >=5; greedy
    # streams must be bit-identical.  A decode-heavy mix — short prompts,
    # long generations — maximizes the share of wall time the fused step
    # covers.  Interpret-mode pallas on CPU hosts: the row tracks the
    # host-side trend; the launch collapse is the accelerator win.)
    fused_cfg = cfg.replace(kernel_backend="pallas")
    decode_mix = [(rng.randint(1, cfg.vocab_size, (8,)).astype(np.int32),
                   24, i // ARRIVALS_PER_STEP) for i in range(N_REQUESTS)]
    fres = {}
    for tag, fused in (("off", False), ("on", True)):
        eng = ServeEngine(params, fused_cfg, ServeConfig(
            max_len=64, n_slots=N_SLOTS, fused_decode=fused))
        fres[tag] = _best_of(eng, decode_mix)
    foff, fon = fres["off"], fres["on"]
    emit("serve_fused_decode", fon["wall_s"] * 1e6,
         f"tok_s={fon['tok_s']:.1f};tok_s_unfused={foff['tok_s']:.1f};"
         f"steps={fon['decode_steps']};"
         f"step_p95_ms={fon['step_p95_ms']:.1f};"
         f"speedup={fon['tok_s'] / foff['tok_s']:.2f}x;"
         f"bit_identical={fon['out_tokens'] == foff['out_tokens']}")

    # --- dead-slot routing mask under partial occupancy ------------------
    # Tight capacity (1 slot/expert) + sparse arrivals keep most of an
    # 8-slot pool empty: with the router's occupancy mask dead slots stop
    # competing for expert capacity, so overflow drops, and the padded-
    # prefill buckets cut the compile count for the non-power-of-two
    # prompt lengths; the unmasked/exact engine is the pre-router
    # baseline (docs/routing.md, docs/serving.md).
    from repro.core.router import RouterSpec
    tight = cfg.replace(router=RouterSpec(capacity_factor=0.5,
                                          capacity_multiple=1))
    sparse = [(rng.randint(1, cfg.vocab_size,
                           ((6, 10, 12, 13)[i % 4],)).astype(np.int32),
               (10, 6, 8, 6)[i % 4], i * 4) for i in range(12)]
    for masked in (False, True):
        eng = ServeEngine(params, tight, ServeConfig(
            max_len=64, n_slots=8, mask_dead_slots=masked,
            prefill_buckets=masked))
        r = _best_of(eng, sparse)
        tag = "masked" if masked else "unmasked"
        emit(f"serve_occupancy_{tag}", r["wall_s"] * 1e6,
             f"tok_s={r['tok_s']:.1f};util={r['util']:.2f};"
             f"overflow={eng.stats['overflow_total']:.0f};"
             f"prefill_compiles={len(eng.prefill_lengths)}")

    # --- chunked prefill + prompt-length-aware admission -----------------
    # Long-prompt traffic is where whole-prompt prefill head-of-line
    # blocks: a 260-token prompt pads to a 512-token bucket and lands
    # inside ONE engine step, so every live decode slot (and every
    # queued short request) waits out that whole ~2x-padded prefill.
    # Chunked prefill bounds per-step prefill work at ``prefill_budget``
    # tokens (chunk work-items interleave with decode steps) and pads to
    # chunk granularity (96) instead of power-of-two buckets; the aware
    # admission lets short prompts pass a long head-of-line prompt
    # within a step's leftover budget.  Head-of-line blocking is
    # measured directly as the p95/max wall time of a single engine
    # step — what live decode slots (and queued requests) wait when a
    # monster prefill lands.  tok/s stays ~flat on this host — the padded-token
    # savings pay for the extra per-chunk dispatch overhead; on a real
    # accelerator (per-call overhead in µs, not ms) the savings are pure
    # win.  A larger model (d_model=384) than the policy mixes keeps
    # device compute dominant; best-of-3 replays cut host noise.
    import jax

    from repro.common import param as pm
    from repro.models import lm

    big = cfg.replace(d_model=384, n_heads=4, n_kv_heads=2, head_dim=32,
                      moe_d_ff=384)
    big_params = pm.materialize(lm.lm_defs(big), jax.random.PRNGKey(0))
    long_mix = [(rng.randint(1, big.vocab_size,
                             ((260, 16, 280, 16)[i % 4],)).astype(np.int32),
                 (8, 16, 8, 16)[i % 4], i * 2) for i in range(12)]
    chunk_cfgs = {
        "serve_unchunked_long": {},
        "serve_chunked_long": dict(prefill_chunk=96, prefill_budget=96,
                                   admission="aware"),
    }
    results = {}
    for tag, kw in chunk_cfgs.items():
        eng = ServeEngine(big_params, big, ServeConfig(
            max_len=512, n_slots=N_SLOTS, **kw))
        results[tag] = (_best_of(eng, long_mix), eng)
    u, c = results["serve_unchunked_long"][0], results["serve_chunked_long"][0]
    emit("serve_unchunked_long", u["wall_s"] * 1e6,
         f"tok_s={u['tok_s']:.1f};util={u['util']:.2f};"
         f"step_max_ms={u['step_max_ms']:.1f};"
         f"step_p95_ms={u['step_p95_ms']:.1f}")
    ceng = results["serve_chunked_long"][1]
    emit("serve_chunked_long", c["wall_s"] * 1e6,
         f"tok_s={c['tok_s']:.1f};util={c['util']:.2f};"
         f"step_max_ms={c['step_max_ms']:.1f};"
         f"step_p95_ms={c['step_p95_ms']:.1f};"
         f"chunks={ceng.stats['prefill_chunks']};"
         f"speedup={c['tok_s'] / u['tok_s']:.2f}x;"
         f"stall_drop_p95={u['step_p95_ms'] / c['step_p95_ms']:.2f}x")

    # --- shared-prefix radix KV cache ------------------------------------
    run_prefix(cfg, params)

    # --- MoA under continuous batching (serve_moa; docs/moa.md) ----------
    from benchmarks import moa_bench
    moa_bench.run_serve()


if __name__ == "__main__":
    import json
    import os
    import platform
    import sys

    sys.path.insert(0, ".")
    prefix_only = "--prefix-only" in sys.argv[1:]
    start = len(ROWS)
    print("name,us_per_call,derived")
    if prefix_only:
        run_prefix()
    else:
        run()
    import jax
    new_rows = ROWS[start:]
    payload = {
        "suites": ["serve"],
        "host": platform.node(),
        "platform": platform.platform(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "rows": new_rows,
    }
    if prefix_only and os.path.exists("BENCH_serve.json"):
        # merge into the full-suite file: replace same-name rows in
        # place, append rows the file has not seen yet
        with open("BENCH_serve.json") as f:
            payload = json.load(f)
        by_name = {r["name"]: r for r in new_rows}
        payload["rows"] = [by_name.pop(r["name"], r)
                           for r in payload["rows"]] + list(by_name.values())
    with open("BENCH_serve.json", "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[bench] wrote {len(new_rows)} rows to BENCH_serve.json")
