"""GMM tiling autotuner: measure -> src/repro/kernels/gmm_tunings.json.

`plan_blocks` consults the emitted table (exact (E, C, K, N, dtype) keys)
before its static 128 defaults whenever a caller leaves bm/bn/bk unset —
see docs/kernels.md §Tiling autotune.  Run via `make tune-kernels`.

Why it wins on this host: the Pallas GMM runs in interpret mode, where
per-grid-step overhead dominates (the ~68x `kernel_backend_gmm_pallas`
gap in BENCH_micro.json) — fewer/bigger blocks cut the step count by the
same factor.  On a real TPU the trade-off is VMEM working set vs. grid
overhead instead, which is exactly why the table is *measured on the
host that will run* rather than derived: re-run the sweep per host class.

The swept shapes are the repo's own hot shapes: the microbench expert-FFN
up/down projections (plus their dw grad shapes — dx shapes coincide with
the opposite projection's forward key) and the big-buffer acceptance
config exercised by tests/test_kernel_eblock.py.  The candidate list
always contains the static default, so a tuned entry is never slower than
the default on the shape it was measured on (best-of-N, ROADMAP
housekeeping).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.kernels import gmm as gmm_lib
from repro.kernels import ops

# (E, C, K, N) per-shard GMM shapes to measure (f32).
SHAPES = [
    # microbench expert FFN (benchmarks/microbench.py: E=32, cap=1024,
    # D=64, FF=128): up / down projections + their dw grad shapes.
    (32, 1024, 64, 128),
    (32, 1024, 128, 64),
    (32, 64, 1024, 128),
    (32, 128, 1024, 64),
    # big-buffer acceptance config (tests/test_kernel_eblock.py: E=64,
    # cap=144, d=512, d_ff=8): fwd + dw shapes for both projections.
    (64, 144, 512, 8),
    (64, 144, 8, 512),
    (64, 512, 144, 8),
    (64, 8, 144, 512),
]

# Tile candidates; plan_blocks clamps each to the padded dims, so many
# collapse to the same resolved plan (deduped below).  (128, 128, 128)
# first — the static default is always in the race.
CANDIDATES = [
    (128, 128, 128),
    (256, 128, 128),
    (512, 128, 128),
    (1024, 128, 128),
    (256, 256, 256),
    (512, 512, 512),
    (1024, 512, 512),
]


def tune_shape(e: int, c: int, k: int, n: int, dtype=jnp.float32,
               *, warmup: int = 1, iters: int = 3):
    """Best (bm, bn, bk) for one shape: returns (tiles, best_us, table)."""
    rng = np.random.default_rng(hash((e, c, k, n)) % (2**32))
    x = jnp.asarray(rng.normal(size=(e, c, k)), dtype)
    w = jnp.asarray(rng.normal(size=(e, k, n)), dtype)
    seen: dict[tuple[int, int, int], float] = {}
    for cand in CANDIDATES:
        bp = gmm_lib.plan_blocks(e, c, k, n, dtype, bm=cand[0], bn=cand[1],
                                 bk=cand[2])
        tiles = (bp.bm, bp.bn, bp.bk)
        if tiles in seen:
            continue
        us = time_call(
            lambda x_, w_, t=tiles: ops.gmm(x_, w_, bm=t[0], bn=t[1],
                                            bk=t[2]),
            x, w, warmup=warmup, iters=iters, reduce="best")
        seen[tiles] = us
        print(f"  {e}x{c}x{k}x{n}: tiles={tiles} grid={bp.grid} "
              f"{us / 1e3:.1f} ms")
    best = min(seen, key=seen.get)
    return best, seen[best], seen


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="table path (default: the path plan_blocks reads "
                         "— src/repro/kernels/gmm_tunings.json or "
                         "$REPRO_GMM_TUNINGS)")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()

    out_path = args.out or gmm_lib.tunings_path()
    table: dict = {
        "_meta": {
            "tuner": "benchmarks/tune_gmm.py",
            "backend": jax.default_backend(),
            "interpret": jax.default_backend() != "tpu",
            "date": time.strftime("%Y-%m-%d"),
            "reduce": f"best-of-{args.iters}",
        },
    }
    for (e, c, k, n) in SHAPES:
        print(f"tuning {e}x{c}x{k}x{n} ...")
        best, best_us, timings = tune_shape(e, c, k, n, iters=args.iters)
        default = next(iter(timings))            # (128,…) resolved first
        key = gmm_lib.tuning_key(e, c, k, n, jnp.float32)
        table[key] = list(best)
        print(f"  -> {key}: {list(best)} ({best_us / 1e3:.1f} ms vs "
              f"default {timings[default] / 1e3:.1f} ms)")
    with open(out_path, "w") as f:
        json.dump(table, f, indent=2, sort_keys=True)
        f.write("\n")
    gmm_lib.invalidate_tunings()
    print(f"wrote {out_path} ({len(table) - 1} shapes)")


if __name__ == "__main__":
    main()
