"""Tables 1/7 (and 2-4's ops column): ops/timestep + parameter accounting
for the paper's exact configurations, validated against the paper's own
published numbers.

ops/timestep = multiply-adds per token in the forward pass, excluding the
softmax layer (the paper's §5.1 metric).  For the paper's LM:
  2 LSTM-512 layers       ~= 2 * 4 * (512*512 + 512*512)  ~= 4.2M
  MoE (k active, h=1024)  ~= k * (512*1024 + 1024*512)    ~= 4 * 1M
totalling the paper's ~8.4M for MoE-4..256.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs.moe_paper import paper_config

# (config, paper ops/timestep (M), paper #params excl embed/softmax (M))
PAPER_TABLE7 = [
    ("lstm-2048-512", 9.4, 9.4),
    ("4xlstm-512", 8.4, 8.4),
    ("moe-1-wide", 8.4, 8.4),
    ("moe-1-deep", 8.4, 8.4),
    ("moe-4", 8.4, 8.4),
    ("moe-32", 8.4, 37.8),
    ("moe-256", 8.6, 272.9),
    ("moe-256-h", 8.4, 272.9),
    ("moe-1024-h", 8.5, 1079.0),
    ("moe-4096-h", 8.9, 4303.4),
]


def lstm_madds(d_in, d_hidden, d_proj=None):
    rec = d_proj or d_hidden
    m = d_in * 4 * d_hidden + rec * 4 * d_hidden
    if d_proj:
        m += d_hidden * d_proj
    return m


def paper_ops_and_params(name: str) -> tuple[float, float]:
    """(ops/timestep, params excl embed+softmax), in raw counts."""
    cfg = paper_config(name)
    d = cfg.d_model
    if cfg.variant == "lstm_2048_512":
        ops = lstm_madds(d, 2048, d)
        return ops, ops
    ops = 2 * lstm_madds(d, d)                      # the two LSTM layers
    par = float(ops)
    if cfg.variant == "lstm_4x":
        ops += 2 * lstm_madds(d, d)
        par += 2 * lstm_madds(d, d)
    elif cfg.variant == "moe_1_wide":
        ops += d * 4096 + 4096 * d
        par += d * 4096 + 4096 * d
    elif cfg.variant == "moe_1_deep":
        ops += d * 1024 + 3 * 1024 * 1024 + 1024 * d
        par += d * 1024 + 3 * 1024 * 1024 + 1024 * d
    else:
        per_expert = d * cfg.expert_hidden + cfg.expert_hidden * d
        k_active = 4 if not cfg.hierarchical else 4   # k=4 flat; 2x2 hier.
        ops += k_active * per_expert
        par += cfg.n_experts * per_expert
        # gating
        ops += d * cfg.n_experts if not cfg.hierarchical else \
            d * (cfg.hierarchical[0] + cfg.hierarchical[1])
    return float(ops), float(par)


def run():
    worst = 0.0
    for name, paper_ops, paper_params in PAPER_TABLE7:
        ops, par = paper_ops_and_params(name)
        rel_ops = abs(ops / 1e6 - paper_ops) / paper_ops
        rel_par = abs(par / 1e6 - paper_params) / paper_params
        worst = max(worst, rel_ops, rel_par)
        emit(f"table7_{name}", 0.0,
             f"ops/ts={ops/1e6:.2f}M (paper {paper_ops}M) "
             f"params={par/1e6:.1f}M (paper {paper_params}M) "
             f"err_ops={rel_ops:.1%} err_params={rel_par:.1%}")
    assert worst < 0.12, f"accounting diverges from paper: {worst:.1%}"


if __name__ == "__main__":
    run()
