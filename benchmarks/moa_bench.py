"""MoA benchmarks: routed attention-head groups vs dense-all-heads.

The claim to pin PR-over-PR (docs/moa.md): per token the routed layer
runs only ``k`` of ``E`` head groups through the Q/O projections and the
score/value contractions — ``k/E`` of the dense attention-head FLOPs —
while producing the *same output* as a dense execution of every head
group weighted by the same gates (the layer equation is linear in the
per-group outputs, so sparse execution is exact, not approximate; any
difference is fp accumulation order).  Rows:

  moa_dense_all_heads[_decode]  every head group computed, gate-weighted
  moa_routed[_decode]           dispatch→gmm→combine sparse execution;
                                derived carries head_gflop, the k/E flop
                                fraction, and max|routed − dense|

Wall times are CPU-host numbers (best-of-N per the ROADMAP discipline);
the ``head_gflop`` field is the host-independent comparison.  The
``serve_moa`` row (via ``benchmarks/serve_bench.py`` →
``BENCH_serve.json``) runs an MoA+MoE LM (reduced ``moa-demo``) under
continuous batching and reports tok/s plus the per-step ``moa_*``
telemetry family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.common import param as pm
from repro.core import moa
from repro.kernels import backend as backend_lib
from repro.models import attention as attn_lib

B, S, D, E, K, HG, HD = 2, 128, 128, 8, 2, 2, 16


def _dense_weights(dec, n_tokens: int, n_experts: int):
    """Token-major dense gate matrix [T, E] from the (possibly capacity-
    truncated) plan — zero for unselected/dropped assignments."""
    w = jnp.zeros((n_tokens, n_experts))
    return w.at[jnp.arange(n_tokens)[:, None],
                dec.plan.expert_index].add(dec.plan.weight)


def _dense_apply(params, x, a: moa.MoAArgs, positions):
    """Dense-all-heads oracle: every head group computes for every token
    (same flash path, E·Hg virtual heads), gate-weighted at the end."""
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    bk = backend_lib.resolve(a)
    dec = moa._route(params, flat, a, bk, train=False, rng=None, mask=None)
    w = _dense_weights(dec, b * s, a.n_experts)
    hg, hd, e = a.n_heads_per_expert, a.head_dim, a.n_experts
    q = jnp.einsum("td,edh->teh", flat, params["wq"].astype(x.dtype))
    q = q.reshape(b, s, e * hg, hd)
    q = moa._norm_rope_q(params, q, positions, a)
    q = moa._to_virtual(q.reshape(b, s, e, hg, hd), a.n_kv_heads)
    k, v = moa._shared_kv(params, x, positions, a)
    kv = a.n_kv_heads
    g = q.shape[2] // kv
    qr = jnp.moveaxis(q.reshape(b, s, kv, g, hd), 1, 3)
    o = attn_lib.flash_attention(
        qr, jnp.moveaxis(k, 1, 3), jnp.moveaxis(v, 1, 2), True, 0,
        moa._block(a.q_block, s), moa._block(a.kv_block, s))
    o = o.reshape(b, kv * g, s, hd).transpose(0, 2, 1, 3)
    o = moa._from_virtual(o, kv, e, hg)                 # [B, S, E, Hg, hd]
    oe = jnp.einsum("tEh,Ehd->tEd", o.reshape(b * s, e, hg * hd),
                    params["wo"].astype(x.dtype))
    return jnp.einsum("tEd,tE->td", oe, w).reshape(b, s, d)


def _dense_decode(params, x, cache, cur_index, a: moa.MoAArgs):
    """Dense-all-heads single-token decode oracle (mirrors moa_decode's
    one-hot cache blend and masked softmax, over all E·Hg heads)."""
    b = x.shape[0]
    cur = jnp.broadcast_to(jnp.asarray(cur_index, jnp.int32).reshape(-1),
                           (b,))
    positions = cur[:, None]
    bk = backend_lib.resolve(a)
    flat = x.reshape(b, x.shape[-1])
    dec = moa._route(params, flat, a, bk, train=False, rng=None, mask=None)
    w = _dense_weights(dec, b, a.n_experts)
    hg, hd, e = a.n_heads_per_expert, a.head_dim, a.n_experts
    q = jnp.einsum("td,edh->teh", flat, params["wq"].astype(x.dtype))
    q = q.reshape(b, 1, e * hg, hd)
    q = moa._norm_rope_q(params, q, positions, a)
    q = moa._to_virtual(q.reshape(b, 1, e, hg, hd), a.n_kv_heads)
    k_new, v_new = moa._shared_kv(params, x, positions, a)
    length = cache["k"].shape[1]
    hit = (jnp.arange(length)[None, :] == cur[:, None])[..., None, None]
    k = jnp.where(hit, k_new.astype(cache["k"].dtype), cache["k"])
    v = jnp.where(hit, v_new.astype(cache["v"].dtype), cache["v"])
    kv = a.n_kv_heads
    g = q.shape[2] // kv
    qr = q.reshape(b, 1, kv, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qr, k,
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    valid = jnp.arange(length)[None, :] <= cur[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, attn_lib.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, kv * g, hd).astype(x.dtype)
    o = moa._from_virtual(o, kv, e, hg)                 # [B, 1, E, Hg, hd]
    oe = jnp.einsum("bEh,Ehd->bEd", o.reshape(b, e, hg * hd),
                    params["wo"].astype(x.dtype))
    return jnp.einsum("bEd,bE->bd", oe, w).reshape(b, 1, -1)


def _head_gflop(heads: int, seq_ctx: int, n_tokens: int) -> float:
    """Head FLOPs for ``n_tokens`` query tokens against ``seq_ctx`` keys:
    Q + O projections (2 matmuls) plus score/value contractions."""
    qo = 2 * 2 * n_tokens * D * heads * HD
    attn = 4 * n_tokens * seq_ctx * heads * HD
    return (qo + attn) / 1e9


def run_micro() -> None:
    a = moa.MoAArgs(n_experts=E, k=K, d_model=D, n_heads_per_expert=HG,
                    head_dim=HD, n_kv_heads=1, dtype=jnp.float32,
                    capacity_factor=2.0, q_block=64, kv_block=64,
                    kernel_backend="ref")
    params = pm.materialize(moa.moa_defs(a), jax.random.PRNGKey(0))
    params["gate"]["wg"] = 0.5 * jax.random.normal(jax.random.PRNGKey(1),
                                                   (D, E))
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    # --- full-sequence forward: routed vs dense-all-heads ---------------
    routed = jax.jit(lambda p, x: moa.moa_apply(p, x, a, positions=pos,
                                                train=False)[0])
    dense = jax.jit(lambda p, x: _dense_apply(p, x, a, pos))
    diff = float(jnp.abs(routed(params, x) - dense(params, x)).max())
    gd = _head_gflop(E * HG, S // 2, B * S)     # causal: ~S/2 mean context
    gr = _head_gflop(K * HG, S // 2, B * S)
    us = time_call(dense, params, x, reduce="best")
    emit("moa_dense_all_heads", us,
         f"B={B} S={S} E={E} heads={E * HG};head_gflop={gd:.3f}")
    us = time_call(routed, params, x, reduce="best")
    emit("moa_routed", us,
         f"B={B} S={S} k={K} heads={K * HG};head_gflop={gr:.3f};"
         f"flop_frac={K / E:.3f};max_diff={diff:.1e};"
         f"allclose={diff < 1e-4}")

    # --- single-token decode against an S-token cache -------------------
    cache = pm.materialize(moa.init_cache_defs(B, S + 8, a),
                           jax.random.PRNGKey(3))
    _, cache = moa.moa_prefill(params, x, pos, a, cache=cache)
    xt = jax.random.normal(jax.random.PRNGKey(4), (B, 1, D))
    cur = jnp.full((B,), S, jnp.int32)
    routed_d = jax.jit(lambda p, x, c: moa.moa_decode(p, x, c, cur, a)[0])
    dense_d = jax.jit(lambda p, x, c: _dense_decode(p, x, c, cur, a))
    diff = float(jnp.abs(routed_d(params, xt, cache)
                         - dense_d(params, xt, cache)).max())
    gd = _head_gflop(E * HG, S + 1, B)
    gr = _head_gflop(K * HG, S + 1, B)
    us = time_call(dense_d, params, xt, cache, reduce="best")
    emit("moa_dense_all_heads_decode", us,
         f"B={B} ctx={S + 1} E={E} heads={E * HG};head_gflop={gd:.4f}")
    us = time_call(routed_d, params, xt, cache, reduce="best")
    emit("moa_routed_decode", us,
         f"B={B} ctx={S + 1} k={K} heads={K * HG};head_gflop={gr:.4f};"
         f"flop_frac={K / E:.3f};max_diff={diff:.1e};"
         f"allclose={diff < 1e-4}")


def run_serve() -> None:
    """``serve_moa``: an MoA+MoE LM (reduced moa-demo) under continuous
    batching — the second sparse hot path the engine keeps full.  Emits
    tok/s plus the per-step ``moa_*`` telemetry aggregates."""
    from benchmarks.serve_bench import _best_of
    from repro.configs.base import get_config
    from repro.models import lm
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = get_config("moa-demo").replace(
        d_model=64, vocab_size=256, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=96, n_experts=4, moe_k=2, moe_d_ff=32,
        moa_experts=4, moa_k=2, moa_heads_per_expert=2,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        q_block=16, kv_block=16)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    trace = [(rng.randint(1, cfg.vocab_size,
                          ((8, 16, 8, 32)[i % 4],)).astype(np.int32),
              (8, 4, 12, 8)[i % 4], i // 2) for i in range(12)]
    eng = ServeEngine(params, cfg, ServeConfig(max_len=64, n_slots=4))
    r = _best_of(eng, trace)
    emit("serve_moa", r["wall_s"] * 1e6,
         f"tok_s={r['tok_s']:.1f};steps={r['decode_steps']};"
         f"util={r['util']:.2f};step_p95_ms={r['step_p95_ms']:.1f};"
         f"moa_overflow={eng.stats['moa_overflow_total']:.0f};"
         f"moe_overflow={eng.stats['overflow_total']:.0f}")


def run() -> None:
    run_micro()
    run_serve()


if __name__ == "__main__":
    import sys
    sys.path.insert(0, ".")
    print("name,us_per_call,derived")
    run()
