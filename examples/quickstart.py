"""Quickstart: build the paper's Sparsely-Gated MoE layer, feed it a batch,
inspect the balance diagnostics, and take one training step.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.common import param as pm
from repro.core.moe import MoEArgs, moe_apply, moe_defs

# 1. A sparsely-gated MoE: 32 experts, top-4 routing (the paper's flat-LM k).
args = MoEArgs(n_experts=32, k=4, d_model=128, d_ff=512,
               activation="relu",            # the paper's experts
               gating_mode="noisy_topk",     # Eqs. 3-5
               w_importance=0.1, w_load=0.1,  # §4 / Appendix A
               dtype=jnp.float32)
params = pm.materialize(moe_defs(args), jax.random.PRNGKey(0))
print(f"experts hold {pm.param_count(params):,} parameters; "
      f"each token touches only {args.k}/{args.n_experts} of them")

# 2. Forward a batch of 1024 tokens ("convolutionally": any [T, d] batch).
x = jax.random.normal(jax.random.PRNGKey(1), (1024, 128))
y, aux = moe_apply(params, x, args, train=True, rng=jax.random.PRNGKey(2))
print(f"out {y.shape}; aux loss {float(aux['aux_loss']):.4f}")
for k, v in aux["metrics"].items():
    print(f"  {k:>20s} = {float(v):.3f}")

# 3. One SGD step on a toy objective — gates, experts and balance losses
#    all train jointly by plain backprop (§2.1).
def loss_fn(p):
    y, aux = moe_apply(p, x, args, train=True, rng=jax.random.PRNGKey(3))
    return jnp.mean((y - jnp.tanh(x)) ** 2) + aux["aux_loss"]

loss, grads = jax.value_and_grad(loss_fn)(params)
params = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g, params, grads)
print(f"step done: loss {float(loss):.4f} -> "
      f"{float(loss_fn(params)):.4f}")
