"""End-to-end driver: train a ~100M-parameter MoE language model for a few
hundred steps on the synthetic corpus, with checkpointing and balance
metrics — the paper's §5.1 setup at laptop scale.

Run: PYTHONPATH=src python examples/train_moe_lm.py [--steps 300]
"""
import argparse

import jax

from repro.common import param as pm
from repro.data.pipeline import DataConfig, DataIterator, optimal_xent
from repro.models.paper_lm import (PaperLMConfig, paper_lm_defs,
                                   paper_lm_loss)
from repro.optim.optimizers import OptConfig
from repro.train.trainer import Trainer, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--experts", type=int, default=64)
    ap.add_argument("--workdir", default="/tmp/repro_moe_lm")
    args = ap.parse_args()

    # MoE-64 with ~1M-param experts (the paper's expert size), d_model 256.
    cfg = PaperLMConfig(vocab_size=8192, variant="moe",
                        n_experts=args.experts, k=4, d_model=256,
                        expert_hidden=1024, dropout=0.0,
                        w_importance=0.1, w_load=0.1)
    params = pm.materialize(paper_lm_defs(cfg), jax.random.PRNGKey(0))
    print(f"model: MoE-{args.experts}, {pm.param_count(params)/1e6:.0f}M "
          f"params total")

    dc = DataConfig(vocab_size=8192, seq_len=64, batch_size=32,
                    n_clusters=512, noise_prob=0.02)
    trainer = Trainer(
        loss_fn=lambda p, b, r: paper_lm_loss(p, b, cfg, rng=r),
        params=params,
        oc=OptConfig(kind="factored",          # the paper's App-D optimizer
                     learning_rate=1e-2, warmup_steps=100),
        loop=TrainLoopConfig(total_steps=args.steps, microbatches=2,
                             checkpoint_every=100, log_every=25),
        data_iter=DataIterator(dc), workdir=args.workdir)
    final = trainer.run()
    print(f"final: xent={final['xent']:.3f} "
          f"(entropy floor {optimal_xent(dc):.3f}) "
          f"ppl={final['perplexity']:.1f} "
          f"max/mean load={final['max_over_mean_load']:.2f}")
    print(f"checkpoints in {args.workdir}/ckpt — rerun to resume")


if __name__ == "__main__":
    main()
