"""Continuous-batching serving example: staggered, mixed-length requests
through a reduced MoE transformer (kimi-k2 family).  Slots are recycled
the moment a request finishes — more requests than slots complete in one
run — and per-step MoE telemetry shows the serving-time expert load.

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.common import param as pm
from repro.configs.base import get_config
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine

cfg = get_config("kimi-k2-1t-a32b").replace(
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    vocab_size=512, n_experts=8, moe_k=2, moe_d_ff=128,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
    q_block=32, kv_block=32, capacity_factor=2.0)
params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
print(f"serving a reduced {cfg.name} ({pm.param_count(params)/1e6:.1f}M "
      f"params, {cfg.n_experts} experts top-{cfg.moe_k})")

engine = ServeEngine(params, cfg,
                     ServeConfig(max_len=128, temperature=0.7, seed=0,
                                 n_slots=3))
rng = np.random.RandomState(0)
reqs = [engine.submit(rng.randint(1, cfg.vocab_size, (plen,)),
                      max_new_tokens=new, arrival=arrival)
        for plen, new, arrival in
        [(24, 16, 0), (8, 8, 0), (16, 12, 1), (24, 4, 3), (8, 16, 4),
         (16, 8, 6)]]
engine.run()

for r in reqs[:4]:
    print(f"  req{r.rid}: prompt[{r.prompt_len}] arrived@{r.arrival} "
          f"-> {len(r.tokens)} tokens ({r.done_reason}): {r.tokens}")
print(f"{len(reqs)} requests over {engine.sc.n_slots} slots in "
      f"{engine.stats['decode_steps']} decode steps "
      f"(slot utilization {engine.slot_utilization:.0%}, "
      f"{engine.stats['prefills']} prefills)")
load = np.sum([t["expert_load"] for t in engine.telemetry], axis=0)
print(f"decode-time expert load: {load.astype(int).tolist()}, "
      f"capacity overflow: {engine.stats['overflow_total']:.0f}")
