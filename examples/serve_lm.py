"""Batched serving example: prefill a batch of prompts through a MoE
transformer (kimi-k2 family, reduced) and decode new tokens with the slot
engine.

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.common import param as pm
from repro.configs.base import get_config
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine

cfg = get_config("kimi-k2-1t-a32b").replace(
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    vocab_size=512, n_experts=8, moe_k=2, moe_d_ff=128,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
    q_block=32, kv_block=32, capacity_factor=2.0)
params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
print(f"serving a reduced {cfg.name} ({pm.param_count(params)/1e6:.1f}M "
      f"params, {cfg.n_experts} experts top-{cfg.moe_k})")

engine = ServeEngine(params, cfg,
                     ServeConfig(max_len=128, temperature=0.7, seed=0))
prompts = np.random.RandomState(0).randint(1, cfg.vocab_size, (8, 24))
out = engine.generate(prompts, max_new_tokens=16)
for i in range(4):
    print(f"  req{i}: prompt[-4:]={prompts[i, -4:].tolist()} "
          f"-> generated {out[i].tolist()}")
print(f"batch of {out.shape[0]} served, {out.shape[1]} tokens each")
