"""Table-6 ablation as a runnable example: train the same MoE with and
without the §4 balancing losses and watch the gate collapse (or not).

Run: PYTHONPATH=src python examples/balance_ablation.py
"""
from benchmarks.table6_balance import run

rows = run(steps=120)
print("\n(w_importance, w_load) -> perplexity, CV(imp), CV(load), max/mean")
for r in rows:
    print(f"  ({r['wi']:>4}, {r['wl']:>4})  ppl={r['ppl']:6.1f}  "
          f"cv_imp={r['cvi']:5.2f}  cv_load={r['cvl']:5.2f}  "
          f"max/mean={r['mm']:5.2f}")
print("\nPaper Table 6: no-loss run collapses (max/mean 17.8, ppl 39.8); "
      "any loss flattens utilization at better perplexity. Same shape here.")
