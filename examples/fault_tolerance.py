"""Fault-tolerance demo: crash a training run mid-flight, then relaunch and
watch it resume bit-exact from the last atomic checkpoint (the data stream
seeks too).

Run: PYTHONPATH=src python examples/fault_tolerance.py
"""
import shutil
import tempfile

import jax

from repro.common import param as pm
from repro.data.pipeline import DataConfig, DataIterator
from repro.models.paper_lm import (PaperLMConfig, paper_lm_defs,
                                   paper_lm_loss)
from repro.optim.optimizers import OptConfig
from repro.train.trainer import Trainer, TrainLoopConfig

workdir = tempfile.mkdtemp(prefix="repro_ft_")
dc = DataConfig(vocab_size=256, seq_len=32, batch_size=16, n_clusters=16)
cfg = PaperLMConfig(vocab_size=256, variant="moe", n_experts=8, k=2,
                    d_model=32, expert_hidden=64, dropout=0.0)


def make(crash_at=None):
    params = pm.materialize(paper_lm_defs(cfg), jax.random.PRNGKey(0))
    return Trainer(
        loss_fn=lambda p, b, r: paper_lm_loss(p, b, cfg, rng=r),
        params=params, oc=OptConfig(learning_rate=1e-2, warmup_steps=20),
        loop=TrainLoopConfig(total_steps=80, checkpoint_every=20,
                             log_every=20),
        data_iter=DataIterator(dc), workdir=workdir,
        crash_at_step=crash_at)


print("=== run 1: will crash at step 50 (simulated node failure) ===")
try:
    make(crash_at=50).run()
except RuntimeError as e:
    print(f"!! {e}")

print("\n=== run 2: relaunch — auto-restores the step-40 checkpoint ===")
final = make().run()
print(f"\nresumed run finished: loss={final['loss']:.4f} "
      f"(straggler events logged: see workdir heartbeat)")
shutil.rmtree(workdir, ignore_errors=True)
