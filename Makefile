# One-word entry points for the tier-1 loop, the slow suite, and the
# micro-benchmarks.  PYTHONPATH=src is baked in so `make test-tier1` is
# the whole tier-1 command.
PY ?= python
export PYTHONPATH := src:.

.PHONY: test-tier1 test-slow test-all test-kernels test-serve \
	test-routing test-moa test-obs bench-micro bench-serve \
	bench-serve-prefix bench-replay trace-serve fit-costs replay \
	tune-kernels lint

# Hard-error lint gate (the CI job's first step): rules pinned in
# pyproject.toml [tool.ruff.lint].  ruff is not vendored — CI installs
# it; locally `pip install ruff` once.
lint:
	@command -v ruff >/dev/null 2>&1 || \
		{ echo "ruff not found: pip install ruff (CI installs it)"; \
		  exit 1; }
	ruff check src tests benchmarks

# Tier-1: everything except slow/tpu (the conftest default selection).
test-tier1:
	$(PY) -m pytest -q

# Kernel parity + gradient + backend-equivalence suite (part of tier-1;
# this target runs just it, pinned to CPU interpret mode).
test-kernels:
	JAX_PLATFORMS=cpu $(PY) -m pytest -q tests/test_kernels.py \
		tests/test_kernel_grads.py tests/test_kernel_backend.py \
		tests/test_kernel_eblock.py

# Measure GMM tilings on this host -> src/repro/kernels/gmm_tunings.json
# (consulted by gmm.plan_blocks before its static 128 defaults; see
# docs/kernels.md §Tiling autotune).
tune-kernels:
	JAX_PLATFORMS=cpu $(PY) benchmarks/tune_gmm.py

# Continuous-batching serving suite (part of tier-1; this target runs
# just it: scheduler/slot-pool + admission/budget invariants, the
# policy x backend x chunked parity matrix, reshard, and the shared-
# prefix radix KV cache).  The slowest cells (pallas, 8-device) are
# marked slow; `make test-slow` runs them.
test-serve:
	$(PY) -m pytest -q tests/test_serve.py tests/test_serve_sched.py \
		tests/test_serve_prefix.py

# Router API suite (part of tier-1): RouterSpec/registry semantics, the
# deprecation shim, policy parity (noisy_topk/expert_choice), masking.
test-routing:
	$(PY) -m pytest -q tests/test_router.py tests/test_gating.py \
		tests/test_moe.py

# Mixture-of-Attention-Heads suite (part of tier-1): dense-oracle layer
# math, ref-vs-pallas values + grads (1- and 8-device), decode/chunked-
# prefill consistency, continuous-batching bit-identity, loud config
# fallbacks (docs/moa.md).
test-moa:
	$(PY) -m pytest -q tests/test_moa.py

# Observability suite (part of tier-1): chrome-trace span schema +
# traced/untraced bit-identity, typed metrics instruments, and the
# replay simulator's fidelity contract against a log_decisions engine
# run (docs/observability.md).
test-obs:
	$(PY) -m pytest -q tests/test_obs.py

# The slow tier (multi-device subprocess equivalence, training curves).
test-slow:
	$(PY) -m pytest -q -m slow

# Both tiers in one run (tpu tests still excluded: TPU CI only).
test-all:
	$(PY) -m pytest -q -m "not tpu"

# Host-side microbenchmarks -> BENCH_micro.json (perf trajectory).
bench-micro:
	$(PY) benchmarks/run.py --only micro --json BENCH_micro.json

# Serving throughput/latency: static-batch vs continuous batching at
# several prompt/output mixes -> BENCH_serve.json.
bench-serve:
	$(PY) benchmarks/serve_bench.py

# Just the shared-prefix radix-cache trace (serve_prefix_{off,on} rows,
# merged into an existing BENCH_serve.json).
bench-serve-prefix:
	$(PY) benchmarks/serve_bench.py --prefix-only

# Capture a chrome trace of the shared-prefix serve workload ->
# /tmp/serve_trace.json (open in Perfetto / chrome://tracing).
trace-serve:
	$(PY) benchmarks/fit_costs.py --record-to /tmp/serve_trace.json \
		--out /dev/null

# Record a traced serve run (measuring tracing overhead on the way) and
# fit the per-op cost model -> COSTS_serve.json.
fit-costs:
	$(PY) benchmarks/fit_costs.py

# Replay 100k synthetic requests through the real scheduler under both
# admission policies -> serve_replay_{fcfs,aware} (+ overhead) rows
# merged into BENCH_serve.json.  Reuses COSTS_serve.json when present.
replay:
	$(PY) benchmarks/replay_bench.py $(if $(wildcard COSTS_serve.json),--costs COSTS_serve.json,)
bench-replay: replay
