"""SPMD pipeline parallelism (GPipe schedule) for the layer stack.

Why: the §Roofline analysis shows trillion-parameter MoE training on a 2D
(data x model) mesh is *structurally* collective-bound — expert weights
(~2 TB for kimi-k2) must either be re-gathered every microbatch (ZeRO-3:
~117 s/step of wire) or their partial sums reduced every microbatch
(expert-TP: ~43 s/step).  Pipelining is the fix the paper's scale demands:
each stage *owns* its layers' weights — zero weight motion — and the only
steady-state communication is the microbatch activation boundary
([tokens_mb, d], ~58 MB for kimi) plus the in-stage EP all-to-all.

Construction (validated fwd+bwd against the sequential stack in
tests/test_pipeline.py):

* mesh axes: the ``data`` axis becomes the ``stage`` ring; ``model`` stays
  tensor/expert-parallel *inside* each stage (the context.shard_map wrapper
  is manual over the stage axis only; GSPMD keeps handling the model axis
  within the stage body, and the stage body's MeshContext records the stage
  axis as Manual so layer constraints strip it).
* layers: stacked [n_stages, layers_per_stage, ...] with the leading dim
  sharded over the stage axis.  Ragged depths (kimi's 61 layers on 16
  stages) pad to the next multiple with *identity* layers — zero output
  projections make a residual block exactly the identity; the padding
  overhead is reported, not hidden.
* schedule: T = n_micro + n_stages - 1 ticks under ``lax.scan``; each tick
  every stage runs one microbatch (bubble ticks compute garbage that is
  masked out — the classic GPipe bubble, fraction (S-1)/T).
* backward: plain ``jax.grad`` through the scan — ``ppermute``'s transpose
  is the reverse shift, so the backward pipeline emerges from autodiff.
  ``jax.checkpoint`` on the stage body keeps the stash at one activation
  boundary per tick.

Embedding and the chunked cross-entropy stay outside the pipelined region
(they are vocab-sharded over the model axis as usual); boundary activations
enter/exit via a masked psum over the stage axis once per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common import param as pm
from repro.configs.base import ModelConfig, layer_kinds
from repro.models import lm, layers, transformer
from repro.optim import optimizers as opt_lib
from repro.sharding import context as ctx_lib


def stages_for(cfg: ModelConfig, n_stages: int) -> tuple[int, int]:
    """(layers_per_stage, padded_total)."""
    per = -(-cfg.n_layers // n_stages)
    return per, per * n_stages


def pipeline_block_defs(cfg: ModelConfig, n_stages: int) -> dict:
    """Stacked [n_stages, layers_per_stage, ...] block params.

    Only homogeneous (period=1) stacks are pipelined here; patterned archs
    would stage at period granularity (not needed for the hillclimb cells).
    """
    if cfg.period != 1:
        raise ValueError(
            f"pipeline stages require homogeneous layers (period=1), got "
            f"period={cfg.period}")
    per, total = stages_for(cfg, n_stages)
    kind = layer_kinds(cfg)[0]
    one = transformer.block_defs(cfg, kind)

    def stack(d: pm.ParamDef):
        return pm.ParamDef((n_stages, per) + d.shape,
                           ("stage", "layers") + d.axes,
                           init=d.init, dtype=d.dtype, fan_in=d.fan_in)
    return jax.tree_util.tree_map(stack, one, is_leaf=pm.is_def)


def zero_identity_padding(params, cfg: ModelConfig, n_stages: int):
    """Zero the output projections of padding layers so they become exact
    identities (residual + zero update)."""
    per, total = stages_for(cfg, n_stages)
    n_pad = total - cfg.n_layers

    def mask_layer(leaf, name_has_out: bool):
        if n_pad == 0 or not name_has_out:
            return leaf
        flat = leaf.reshape((total,) + leaf.shape[2:])
        flat = flat.at[cfg.n_layers:].set(0)
        return flat.reshape(leaf.shape)

    out = dict(params)
    if "attn" in params:
        out["attn"] = dict(params["attn"])
        out["attn"]["wo"] = mask_layer(params["attn"]["wo"], True)
    if "moe" in params:
        out["moe"] = dict(params["moe"])
        out["moe"]["w2"] = mask_layer(params["moe"]["w2"], True)
    if "mlp" in params:
        out["mlp"] = dict(params["mlp"])
        out["mlp"]["w2"] = mask_layer(params["mlp"]["w2"], True)
    if "mamba" in params:
        out["mamba"] = dict(params["mamba"])
        out["mamba"]["out_proj"] = mask_layer(params["mamba"]["out_proj"],
                                              True)
    return out


def pipeline_stack_apply(block_params, x_mb, cfg: ModelConfig, *,
                         mesh, n_stages: int, stage_axis: str = "data",
                         positions, rng, train: bool = True,
                         ctx: ctx_lib.MeshContext | None = None):
    """Run the pipelined layer stack.

    block_params: stacked [S, per, ...] tree (leading dim sharded over the
    stage axis).  x_mb: [n_micro, B_mb, S_seq, d].  Returns
    (y_mb [n_micro, B_mb, S_seq, d], aux_loss scalar).

    The stage body runs under a derived context that records the stage
    axis as Manual — layer-internal constraints strip it automatically
    (no runtime mesh reflection).
    """
    n_micro = x_mb.shape[0]
    kind = layer_kinds(cfg)[0]
    per = stages_for(cfg, n_stages)[0]
    ctx = ctx or ctx_lib.MeshContext.for_mesh(mesh)
    stage_ctx = ctx.manual(stage_axis)

    def stage_body(params_stage, x, mb_rng):
        # params_stage: [per, ...] one stage's layers; x: [B_mb, S, d]
        # aux is rank-1 throughout: 0.4.x shard_map lifts closed-over
        # scalar constants as replicated inputs and its transpose-time
        # unmentioned-axis psum helper assumes ndim >= 1.
        aux = jnp.zeros((1,), jnp.float32)

        def layer_step(carry, xs):
            x, aux = carry
            p_layer, i = xs
            sub = (jax.random.fold_in(mb_rng, i) if mb_rng is not None
                   else None)
            x, a = transformer.block_apply(p_layer, x, kind, cfg,
                                           positions=positions, rng=sub,
                                           train=train, ctx=stage_ctx)
            if a is not None:
                aux = aux + a["aux_loss"]
            return (x, aux), None

        body = jax.checkpoint(layer_step) if cfg.remat else layer_step
        (x, aux), _ = jax.lax.scan(body, (x, aux),
                                   (params_stage, jnp.arange(per)))
        return x, aux

    def per_stage(params_local, xs_all):
        sid = jax.lax.axis_index(stage_axis)
        state = jnp.zeros_like(xs_all[0])
        outputs = jnp.zeros_like(xs_all)
        aux_total = jnp.zeros((1,), jnp.float32)
        t_total = n_micro + n_stages - 1

        def tick(carry, t):
            state, outputs, aux_total = carry
            recv = jax.lax.ppermute(
                state, stage_axis,
                [(i, i + 1) for i in range(n_stages - 1)])
            x_in = jnp.where(sid == 0,
                             xs_all[jnp.clip(t, 0, n_micro - 1)], recv)
            mb = jnp.clip(t - sid, 0, n_micro - 1)
            rng_t = (jax.random.fold_in(rng, mb * n_stages + sid)
                     if rng is not None else None)
            y, aux = stage_body(
                jax.tree_util.tree_map(lambda p: p[0], params_local),
                x_in, rng_t)
            live = (t - sid >= 0) & (t - sid < n_micro)
            aux_total = aux_total + jnp.where(live, aux, 0.0)
            out_mb = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            write = (sid == n_stages - 1) & (t >= n_stages - 1)
            outputs = outputs.at[out_mb].set(
                jnp.where(write, y, outputs[out_mb]))
            return (y, outputs, aux_total), None

        (state, outputs, aux_total), _ = jax.lax.scan(
            tick, (state, outputs, aux_total), jnp.arange(t_total))
        outputs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outputs, 0.0), stage_axis)
        # per-microbatch balance losses averaged over microbatches (same
        # normalization as the grad-accumulation trainer); rank-1, see
        # note above.
        aux_total = jax.lax.psum(aux_total, stage_axis) / n_micro
        return outputs, aux_total

    from jax.sharding import PartitionSpec as P
    fn = ctx_lib.shard_map(
        per_stage, mesh,
        (P(stage_axis), P()),
        (P(), P()),
        manual_axes=(stage_axis,))
    y_mb, aux = fn(block_params, x_mb)
    return y_mb, aux[0]


def pipeline_lm_loss(params, batch, cfg: ModelConfig, *, mesh,
                     n_stages: int, n_micro: int,
                     stage_axis: str = "data", rng=None,
                     train: bool = True,
                     ctx: ctx_lib.MeshContext | None = None):
    """Full LM loss with the block stack pipelined.

    params: {"embed", "blocks" (stacked pipeline defs), "ln_f", "unembed"}.
    batch tokens: [B, S]; B must divide into n_micro microbatches.
    """
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    if b % n_micro != 0:
        raise ValueError(
            f"batch size {b} not divisible into {n_micro} microbatches")
    x = layers.embed(params["embed"], tokens, cfg.compute_dtype)
    x_mb = x.reshape(n_micro, b // n_micro, s, -1)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b // n_micro, s))
    y_mb, aux = pipeline_stack_apply(
        params["blocks"], x_mb, cfg, mesh=mesh, n_stages=n_stages,
        stage_axis=stage_axis, positions=positions, rng=rng, train=train,
        ctx=ctx)
    y = y_mb.reshape(b, s, -1)
    y = layers.rmsnorm(params["ln_f"], y, cfg.norm_eps)
    xent = lm.chunked_xent(params, y, labels, cfg,
                           chunk=min(512, s), ctx=ctx)
    loss = xent + aux
    return loss, {"xent": xent, "aux_loss": aux, "loss": loss}


def pipeline_param_defs(cfg: ModelConfig, n_stages: int) -> dict:
    return {
        "embed": layers.embed_defs(cfg.vocab_size, cfg.d_model,
                                   cfg.param_dtype),
        "blocks": pipeline_block_defs(cfg, n_stages),
        "ln_f": layers.rmsnorm_defs(cfg.d_model),
        "unembed": {"w": pm.ParamDef((cfg.d_model, cfg.vocab_size),
                                     ("embed_fsdp", "vocab"),
                                     dtype=cfg.param_dtype,
                                     fan_in=cfg.d_model)},
    }


def make_pipeline_train_step(cfg: ModelConfig, oc: opt_lib.OptConfig, *,
                             mesh, n_stages: int, n_micro: int,
                             stage_axis: str = "data",
                             ctx: ctx_lib.MeshContext | None = None):
    ctx = ctx or ctx_lib.MeshContext.for_mesh(mesh)

    def loss_fn(params, batch, rng):
        return pipeline_lm_loss(params, batch, cfg, mesh=mesh,
                                n_stages=n_stages, n_micro=n_micro,
                                stage_axis=stage_axis, rng=rng, ctx=ctx)

    def train_step(state, batch, seed):
        rng = jax.random.PRNGKey(seed)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"], batch, rng)
        new_params, new_opt, info = opt_lib.apply_updates(
            state["params"], grads, state["opt"], oc)
        return {"params": new_params, "opt": new_opt}, dict(metrics, **info)

    return train_step
