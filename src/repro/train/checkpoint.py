"""Sharding-agnostic, atomic, async-capable checkpointing.

Design for fault tolerance at 1000+ nodes:

* **Atomic**: a checkpoint is written to ``step_<n>.tmp`` and ``os.rename``d
  into place only when complete — a killed writer never leaves a readable
  half-checkpoint, so restart always finds a consistent state.
* **Sharding-agnostic**: leaves are stored as full host arrays keyed by
  pytree path.  Restore takes target shardings resolved against the
  *current* mesh, so a job can restart on a different topology (elastic
  re-mesh: lose a pod, halve the data axis, keep training).
* **Async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes to disk on a background thread, overlapping I/O with the next
  training steps.
* **Self-pruning**: keeps the newest ``keep`` checkpoints.

Real multi-host deployments would write per-host shards to a distributed
FS; the single-process layout here preserves the exact protocol (manifest +
atomic rename + resharding restore).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat], \
        jax.tree_util.tree_structure(tree)


def _key_to_fname(key: str) -> str:
    return key.replace("/", "_").replace("'", "").replace("[", "(").replace(
        "]", ")") + ".npy"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- write ----------------------------------------------------------
    def save(self, step: int, tree, extra: dict | None = None):
        self.wait()
        self._write(step, self._snapshot(tree), extra or {})

    def save_async(self, step: int, tree, extra: dict | None = None):
        self.wait()
        snap = self._snapshot(tree)           # sync device->host copy
        self._thread = threading.Thread(
            target=self._write, args=(step, snap, extra or {}), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _snapshot(self, tree):
        flat, _ = _flatten(tree)
        return [(k, np.asarray(jax.device_get(v))) for k, v in flat]

    def _write(self, step: int, snap, extra: dict):
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "time": time.time(),
                    "leaves": {}}
        for key, arr in snap:
            fname = _key_to_fname(key)
            dtype_name = str(arr.dtype)
            if arr.dtype.kind not in "biufc":
                # bfloat16 & friends: numpy can't serialize custom dtypes;
                # store the raw bits and record the logical dtype.
                arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                               else np.uint8)
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {"file": fname,
                                       "shape": list(arr.shape),
                                       "dtype": dtype_name}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                 # atomicity boundary
        self._prune()

    def _prune(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- read -----------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like_tree, shardings=None):
        """Restore into the structure of ``like_tree``; if ``shardings``
        (a matching tree of NamedSharding) is given, leaves are placed
        sharded — this is where elastic re-meshing happens."""
        path = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat, _ = _flatten(like_tree)
        treedef = jax.tree_util.tree_structure(like_tree)
        shard_flat = (treedef.flatten_up_to(shardings)
                      if shardings is not None else [None] * len(flat))
        leaves = []
        for (key, like), shd in zip(flat, shard_flat):
            entry = manifest["leaves"].get(key)
            if entry is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(os.path.join(path, entry["file"]))
            if str(arr.dtype) != entry["dtype"]:
                import ml_dtypes
                arr = arr.view(np.dtype(getattr(ml_dtypes, entry["dtype"])))
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"model {like.shape}")
            if shd is not None:
                leaves.append(jax.device_put(arr.astype(like.dtype), shd))
            else:
                leaves.append(jax.numpy.asarray(arr, dtype=like.dtype))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, manifest["extra"], manifest["step"]
