"""Error-feedback int8 gradient compression for slow (cross-pod) links.

At 1000+-node scale the cross-pod / DCN links are the bottleneck for the
data-parallel gradient reduction, not the in-pod ICI.  The classic recipe
(1-bit Adam / EF-SGD lineage):

    e_t   = g_t + ef_{t-1}              (add residual from last step)
    q_t   = int8_quantize(e_t)          (per-tensor scale)
    ef_t  = e_t - dequant(q_t)          (store the quantization error)
    sync  = mean over pods of dequant(q_t)

The collective is an ``all_gather`` of int8 payloads + f32 scales followed
by a local dequantized mean.  On the wire this moves (n-1)·size/4 bytes per
device versus ring all-reduce's 2·(n-1)/n·size — a 4x reduction for n=2
pods and a win for any n < 8, exactly the cross-pod regime it targets.
Error feedback makes the *accumulated* update unbiased: the quantization
error of step t is replayed into step t+1, so compression noise does not
bias the trajectory (tested in tests/test_compression.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import context as ctx_lib


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    xf = jnp.asarray(x, jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_sync(grads, ef_state, axis_name: str):
    """Inside shard_map: synchronize `grads` over `axis_name` with int8 EF.

    Returns (synced_grads, new_ef_state).  ef_state is a float32 tree
    matching grads (zeros at step 0).
    """
    n = ctx_lib.axis_size(axis_name)

    def one(g, ef):
        e = jnp.asarray(g, jnp.float32) + ef
        q, scale = quantize_int8(e)
        new_ef = e - dequantize_int8(q, scale)
        q_all = jax.lax.all_gather(q, axis_name)           # int8 on the wire
        s_all = jax.lax.all_gather(scale, axis_name)
        mean = jnp.sum(q_all.astype(jnp.float32)
                       * s_all.reshape((n,) + (1,) * g.ndim), axis=0) / n
        return mean.astype(g.dtype), new_ef

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))


def init_ef_state(grads_like):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
