"""Training loop: microbatched step builder + fault-tolerant driver.

``make_train_step`` builds the jitted SPMD step:

* gradient accumulation over ``microbatches`` via ``lax.scan`` (the grad
  tree is the carry, so activation memory is one microbatch's worth — how
  train_4k's 1M-token global batches fit);
* loss = token xent + the paper's §4 balancing losses (already summed into
  the model loss);
* global-norm clipping + Adam/factored update (optim/optimizers.py).

``Trainer`` is the fault-tolerance harness:

* auto-restore from the newest complete checkpoint (params, optimizer,
  data-iterator step) — a killed job resumes bit-exact (tested);
* async checkpoint every ``checkpoint_every`` steps;
* heartbeat file + step-time tracking: steps slower than
  ``straggler_factor`` × running median are logged as straggler events
  (the launcher's watchdog restarts/re-meshes on repeated events);
* optional crash injection for the fault-tolerance tests;
* optional chrome-trace capture (``trace_path``): each step records a
  ``train.step`` span (plus ``train.data``/``train.checkpoint`` around
  input and save work) with the trainer's tracer installed as the
  ambient one, so kernel-backend call-site spans from the first traced
  step nest under it (docs/observability.md).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as trace_lib
from repro.optim import optimizers as opt_lib
from repro.sharding import context as ctx_lib
from repro.train.checkpoint import CheckpointManager


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    total_steps: int = 100
    microbatches: int = 1
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    seed: int = 0


def _split_microbatches(batch: dict, n: int) -> dict:
    def reshape(x):
        b = x.shape[0]
        if b % n != 0:
            raise ValueError(
                f"batch size {b} not divisible into {n} microbatches")
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree_util.tree_map(reshape, batch)


def make_train_step(loss_fn: Callable, oc: opt_lib.OptConfig, *,
                    microbatches: int = 1):
    """loss_fn(params, batch, rng) -> (loss, metrics dict of scalars)."""

    def step(state, batch, rng):
        params = state["params"]

        def compute(params, mb, r):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb, r)
            return grads, metrics

        if microbatches > 1:
            mbs = _split_microbatches(batch, microbatches)
            rngs = jax.random.split(rng, microbatches)

            def body(carry, xs):
                acc, met_acc = carry
                mb, r = xs
                grads, metrics = compute(params, mb, r)
                acc = jax.tree_util.tree_map(jnp.add, acc, grads)
                met_acc = jax.tree_util.tree_map(jnp.add, met_acc, metrics)
                return (acc, met_acc), None

            zeros_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mb0 = jax.tree_util.tree_map(lambda x: x[0], mbs)
            _, met0 = jax.eval_shape(lambda: compute(params, mb0, rngs[0]))
            zeros_m = jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), met0)
            (grads, metrics), _ = jax.lax.scan(body, (zeros_g, zeros_m),
                                               (mbs, rngs))
            grads = jax.tree_util.tree_map(lambda g: g / microbatches,
                                           grads)
            metrics = jax.tree_util.tree_map(lambda m: m / microbatches,
                                             metrics)
        else:
            grads, metrics = compute(params, batch, rng)

        new_params, new_opt, info = opt_lib.apply_updates(
            params, grads, state["opt"], oc)
        metrics = dict(metrics, **info)
        return {"params": new_params, "opt": new_opt}, metrics

    return step


class Trainer:
    def __init__(self, *, loss_fn, params, oc: opt_lib.OptConfig,
                 loop: TrainLoopConfig, data_iter, workdir: str,
                 jit: bool = True, crash_at_step: int | None = None,
                 ctx: ctx_lib.MeshContext | None = None,
                 kernel_backend: str | None = None,
                 router=None, trace_path: str | None = None):
        # The sharding context is entered around step tracing so loss
        # closures that consult current_ctx() (instead of binding ctx
        # explicitly) still resolve the right mesh/plan.
        self.ctx = ctx
        # Fail-fast *validation* of the kernel backend the model config is
        # expected to use: raises KernelBackendError at construction
        # instead of mid-trace at the first jitted step.  Selection itself
        # lives in the loss closure's MoEArgs/ModelConfig — this argument
        # does not override it.
        self.kernel_backend = kernel_backend
        if kernel_backend is not None:
            from repro.kernels import backend as backend_lib
            backend_lib.get(kernel_backend)
            print(f"[trainer] kernel backend {kernel_backend!r} validated "
                  "(active backend is set by the model config)")
        # Same fail-fast validation for the RouterSpec the model config is
        # expected to route with: an unknown policy raises RouterError at
        # construction, not mid-trace (docs/routing.md).
        self.router = router
        if router is not None:
            from repro.core import router as router_lib
            router_lib.get_policy(router.policy)
            print(f"[trainer] router policy {router.policy!r} validated "
                  "(active spec is set by the model config)")
        self.loop = loop
        self.data_iter = data_iter
        self.workdir = workdir
        os.makedirs(workdir, exist_ok=True)
        self.ckpt = CheckpointManager(os.path.join(workdir, "ckpt"),
                                      keep=loop.keep_checkpoints)
        self.state = {"params": params, "opt": opt_lib.init(params, oc)}
        step_fn = make_train_step(loss_fn, oc,
                                  microbatches=loop.microbatches)
        self.step_fn = jax.jit(step_fn, donate_argnums=(0,)) if jit \
            else step_fn
        self.start_step = 0
        self.crash_at_step = crash_at_step
        # Chrome-trace capture (docs/observability.md): None => the shared
        # null tracer (each span site costs one no-op context manager).
        self.tracer = (trace_lib.Tracer(trace_path, process_name="train")
                       if trace_path else trace_lib.NULL)
        self.metrics_log: list[dict] = []
        self._durations: list[float] = []
        self.straggler_events: list[dict] = []
        self._maybe_restore()

    # -- fault tolerance --------------------------------------------------
    def _maybe_restore(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return
        self.state, extra, step = self.ckpt.restore(latest, self.state)
        self.start_step = step
        self.data_iter.restore(extra["data"])
        print(f"[trainer] restored checkpoint at step {step}")

    def _heartbeat(self, step: int):
        with open(os.path.join(self.workdir, "heartbeat.json"), "w") as f:
            json.dump({"step": step, "time": time.time()}, f)

    def _check_straggler(self, step: int, dt: float):
        self._durations.append(dt)
        if len(self._durations) >= 8:
            med = float(np.median(self._durations[-32:]))
            if dt > self.loop.straggler_factor * med:
                ev = {"step": step, "duration": dt, "median": med}
                self.straggler_events.append(ev)
                print(f"[trainer] STRAGGLER step {step}: {dt:.3f}s vs "
                      f"median {med:.3f}s")

    # -- main loop ---------------------------------------------------------
    def run(self) -> dict:
        rng = jax.random.PRNGKey(self.loop.seed)
        last_metrics = {}
        for step in range(self.start_step, self.loop.total_steps):
            if self.crash_at_step is not None and step == self.crash_at_step:
                # Test hook: let any in-flight async checkpoint complete so
                # the crash point is deterministic (a real SIGKILL may lose
                # the newest checkpoint; restore falls back to the previous
                # complete one either way).
                self.ckpt.wait()
                raise RuntimeError(f"injected crash at step {step}")
            tr = self.tracer
            with tr.span("train.data", step=step):
                batch = next(self.data_iter)
            t0 = time.perf_counter()
            with trace_lib.use(tr), \
                    tr.span("train.step", step=step,
                            microbatches=self.loop.microbatches), \
                    (self.ctx if self.ctx is not None
                     else ctx_lib.MeshContext.null()):
                self.state, metrics = self.step_fn(
                    self.state, batch, jax.random.fold_in(rng, step))
                jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self._heartbeat(step)
            self._check_straggler(step, dt)
            if (step + 1) % self.loop.log_every == 0 or \
                    step == self.loop.total_steps - 1:
                last_metrics = {k: float(v) for k, v in metrics.items()}
                last_metrics["step"] = step + 1
                last_metrics["step_time_s"] = dt
                self.metrics_log.append(last_metrics)
                print(f"[trainer] step {step+1} "
                      f"loss={last_metrics.get('loss', float('nan')):.4f} "
                      f"({dt:.3f}s)")
            if (step + 1) % self.loop.checkpoint_every == 0:
                with self.tracer.span("train.checkpoint", step=step + 1):
                    self.ckpt.save_async(step + 1, self.state,
                                         {"data": self.data_iter.state()})
        self.ckpt.wait()
        self.ckpt.save(self.loop.total_steps, self.state,
                       {"data": self.data_iter.state()})
        if self.tracer.enabled and self.tracer.path:
            self.tracer.save()
        with open(os.path.join(self.workdir, "metrics.jsonl"), "a") as f:
            for m in self.metrics_log:
                f.write(json.dumps(m) + "\n")
        return last_metrics
