"""MeshContext: the explicit sharding context threaded through the program.

The paper's §3.1 scheme — data-parallel standard layers, model-parallel
experts, combined-batch all-to-all — only composes when every layer agrees
on which mesh it runs under and which of that mesh's axes an enclosing
``shard_map`` already holds in Manual mode.  Following GShard's discipline,
that agreement is *explicit*: a :class:`MeshContext` bundles

* ``mesh``         — the concrete device mesh (or ``None`` off-mesh: the
                     single-host smoke-test / eager path, where every
                     constraint is a no-op),
* ``rules``        — the active :class:`~repro.sharding.partition
                     .ShardingRules` plan (logical axis → mesh axes),
* ``manual_axes``  — mesh axes an enclosing ``shard_map`` holds in Manual
                     mode.  Constraints emitted inside the body strip these
                     axes: only the Auto axes are GSPMD's to place.  The
                     pipeline constructs this at its ``shard_map`` boundary
                     via :meth:`MeshContext.manual` — no runtime reflection.

and is passed down the layer stack as an ordinary argument.  A thin
contextvar (:func:`current_ctx` / ``with ctx:``) covers entry points that
jit a closure and cannot add a traced argument (the serve engine, the test
harness); it is set at the jit/shard_map boundary, read at trace time, and
never mutated inside traced code.

Version compatibility
---------------------
All jax-version probing in the repo lives here (enforced by
tests/test_version_compat.py).  The pinned jax 0.4.x has no abstract-mesh
query, no ``jax.set_mesh``, no top-level ``jax.shard_map`` and no
``axis_types=`` on ``jax.make_mesh``; the shims below degrade gracefully:

* :func:`abstract_mesh_or_none` — ``None`` where the query does not exist,
* :func:`make_mesh` — drops ``axis_types`` when unsupported,
* :func:`use_mesh` — no-op context manager when ``jax.set_mesh`` is absent
  (constraints here are full ``NamedSharding``s, so no ambient mesh is
  needed),
* :func:`shard_map` — top-level API when present, else the experimental
  one with ``auto=`` / ``check_rep=`` spelled for 0.4.x.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import param as pm
from repro.sharding import partition


# ---------------------------------------------------------------------------
# jax-version compat shims (the ONLY place the repo probes jax's API surface)
# ---------------------------------------------------------------------------

def abstract_mesh_or_none():
    """The ambient abstract mesh under jit (jax >= 0.5), or ``None``.

    jax 0.4.x has no ``jax.sharding.get_abstract_mesh``; callers treat
    ``None`` as "no ambient mesh" and fall back to the explicit context.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        return None
    try:
        mesh = get()
    except Exception:
        return None
    if mesh is None or getattr(mesh, "empty", True):
        return None
    return mesh


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                tuple(axis_shapes), tuple(axis_names), devices=devices,
                axis_types=(axis_type.Auto,) * len(tuple(axis_names)))
        except TypeError:
            pass  # make_mesh predates axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                         devices=devices)


def compiled_cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every jax version
    (0.4.x returns a one-element list of dicts, newer returns the dict)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def axis_size(axis_name: str) -> int:
    """Size of a named mapped axis inside shard_map, version-portable.

    ``jax.lax.axis_size`` where it exists; on 0.4.x ``psum(1, axis)``
    constant-folds to the same Python int."""
    sz = getattr(jax.lax, "axis_size", None)
    if sz is not None:
        return sz(axis_name)
    return jax.lax.psum(1, axis_name)


def use_mesh(mesh: Mesh):
    """``jax.set_mesh(mesh)`` where it exists, else a no-op context.

    On jax 0.4.x no ambient mesh is needed: every constraint the repo emits
    is a full ``NamedSharding`` carrying its mesh (see
    :meth:`MeshContext.with_constraint`)."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return contextlib.nullcontext(mesh)


# Whether with_sharding_constraint is usable inside a partially-manual
# shard_map body.  On 0.4.x the partitioner cannot mix a NamedSharding
# constraint with manual axes, so constraints under manual mode degrade to
# identity (the in_specs/out_specs still pin the boundary shardings).
CAN_CONSTRAIN_UNDER_MANUAL = hasattr(jax, "set_mesh")


def shard_map(f, mesh: Mesh, in_specs, out_specs, *,
              manual_axes: Sequence[str] | None = None):
    """Version-portable ``shard_map``.

    ``manual_axes=None`` means fully manual (every mesh axis).  Otherwise
    only the named axes are manual and the rest stay Auto for GSPMD —
    spelled ``axis_names=``/``check_vma=`` on new jax and
    ``auto=``/``check_rep=`` on 0.4.x.
    """
    top = getattr(jax, "shard_map", None)
    if top is not None:
        kw = {}
        if manual_axes is not None:
            kw["axis_names"] = set(manual_axes)
        try:
            return top(f, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False, **kw)
        except TypeError:
            pass  # older top-level signature; fall through
    from jax.experimental.shard_map import shard_map as _sm
    # 0.4.x: partial-auto (`auto=`) lowers axis_index to a PartitionId the
    # old SPMD partitioner rejects, so degrade to fully manual — the
    # unnamed axes become replicated inside the body (numerics unchanged;
    # in-body GSPMD placement of those axes is lost, which is why
    # CAN_CONSTRAIN_UNDER_MANUAL is False here).
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


# ---------------------------------------------------------------------------
# MeshContext
# ---------------------------------------------------------------------------

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_mesh_context", default=None)


def _strip(spec: P, manual: frozenset) -> P:
    """Drop manual mesh axes from a resolved spec (the stage-axis strip)."""
    if not manual:
        return spec

    def one(entry):
        if entry is None:
            return None
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in axes if a not in manual)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]

    return P(*(one(e) for e in spec))


@dataclasses.dataclass(frozen=True)
class MeshContext:
    """mesh + sharding plan + Manual-mode axes of an enclosing shard_map."""

    mesh: Mesh | None
    rules: partition.ShardingRules
    manual_axes: frozenset = frozenset()

    # -- construction -----------------------------------------------------
    @classmethod
    def for_mesh(cls, mesh: Mesh, plan="dp_tp_ep") -> "MeshContext":
        """Context for a concrete mesh; ``plan`` is a PLANS name or rules."""
        return cls(mesh=mesh, rules=_as_rules(plan))

    @classmethod
    def null(cls, plan="dp_tp_ep") -> "MeshContext":
        """Off-mesh context: every constraint is the identity."""
        return cls(mesh=None, rules=_as_rules(plan))

    def with_plan(self, plan) -> "MeshContext":
        return dataclasses.replace(self, rules=_as_rules(plan))

    def manual(self, *axes: str) -> "MeshContext":
        """Derived context for a shard_map body manual over ``axes``."""
        return dataclasses.replace(
            self, manual_axes=self.manual_axes | frozenset(axes))

    # -- resolution -------------------------------------------------------
    @property
    def auto_axes(self) -> tuple[str, ...]:
        if self.mesh is None:
            return ()
        return tuple(a for a in self.mesh.axis_names
                     if a not in self.manual_axes)

    def resolve(self, shape, logical_axes, fallbacks: list | None = None
                ) -> P:
        """Logical axes -> PartitionSpec (manual axes stripped)."""
        if self.mesh is None:
            raise RuntimeError("resolve() needs a concrete mesh")
        spec = partition.resolve_spec(self.rules, self.mesh, shape,
                                      logical_axes, fallbacks)
        return _strip(spec, self.manual_axes)

    def shd(self, shape, logical_axes, fallbacks: list | None = None
            ) -> NamedSharding:
        return NamedSharding(self.mesh,
                             self.resolve(shape, logical_axes, fallbacks))

    def tree_shardings(self, def_tree, fallbacks: list | None = None):
        """NamedSharding tree for a ParamDef tree.

        (For bare PartitionSpec trees — shard_map in_specs — use
        ``partition.tree_pspecs`` with ``ctx.rules``/``ctx.mesh``.)"""
        def one(d: pm.ParamDef):
            return self.shd(d.shape, d.axes, fallbacks)
        return jax.tree_util.tree_map(one, def_tree, is_leaf=pm.is_def)

    # -- resharding -------------------------------------------------------
    def reshard(self, tree, def_tree, fallbacks: list | None = None):
        """Explicitly relayout a materialized tree onto THIS context's plan.

        The plan-boundary primitive (e.g. the serving prefill_tp →
        decode_std handoff): every leaf is ``device_put`` against the
        sharding this context resolves for the matching ``ParamDef`` —
        an eager, observable cross-plan move rather than whatever layout
        the producing jit happened to leave the arrays in.  Off-mesh this
        is the identity.
        """
        if self.mesh is None:
            return tree
        return jax.device_put(tree, self.tree_shardings(def_tree, fallbacks))

    # -- constraints ------------------------------------------------------
    def with_constraint(self, x, logical_axes):
        """Apply a logical sharding constraint inside jit (no-op off-mesh).

        Off-mesh (``mesh is None`` and no ambient abstract mesh) this is the
        identity — the single-device smoke-test path.  Under a Manual-mode
        enclosing shard_map on jax 0.4.x, constraints degrade to identity
        (the partitioner cannot mix NamedSharding constraints with manual
        axes there); the shard_map's own specs still pin the boundaries.
        """
        mesh = self.mesh
        if mesh is None:
            mesh = abstract_mesh_or_none()
            if mesh is None:
                return x
        spec = _strip(
            partition.resolve_spec(self.rules, mesh, x.shape, logical_axes),
            self.manual_axes)
        if all(e is None for e in spec):
            return x
        if self.manual_axes and not CAN_CONSTRAIN_UNDER_MANUAL:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    # -- contextvar plumbing ---------------------------------------------
    def __enter__(self) -> "MeshContext":
        tokens = getattr(self, "_tokens", None)
        if tokens is None:
            tokens = []
            object.__setattr__(self, "_tokens", tokens)
        tokens.append(_CTX.set(self))
        return self

    def __exit__(self, *exc):
        _CTX.reset(getattr(self, "_tokens").pop())
        return False


def _as_rules(plan) -> partition.ShardingRules:
    if isinstance(plan, str):
        return partition.PLANS[plan]
    return plan


def current_ctx() -> MeshContext | None:
    """The innermost active context (``with ctx:``), or ``None``."""
    return _CTX.get()


def with_constraint(x, logical_axes, ctx: MeshContext | None = None):
    """Explicit-first constraint: use ``ctx`` if given, else the contextvar,
    else the ambient abstract mesh (jax >= 0.5), else identity."""
    ctx = ctx or current_ctx()
    if ctx is None:
        mesh = abstract_mesh_or_none()
        if mesh is None:
            return x
        ctx = MeshContext(mesh=mesh, rules=partition.PLANS["dp_tp_ep"])
    return ctx.with_constraint(x, logical_axes)
