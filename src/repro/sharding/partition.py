"""Logical-axis → mesh-axis resolution (MaxText-style sharding rules).

Every parameter/activation dimension carries a *logical* name ("embed",
"experts", "batch", …).  A :class:`ShardingRules` table maps each logical
name to zero or more mesh axes.  Resolution enforces divisibility: if a
dimension is not divisible by the product of its assigned mesh axes we fall
back to progressively fewer axes (and finally to replication) rather than
failing the compile — the fallback is recorded so the dry-run can report it.

Plans
-----
``PLANS`` holds named rule-sets:

* ``dp_tp_ep``     — batch over (pod, data); tensor-parallel + expert-parallel
                     over model; FSDP of params over data.  The default, and
                     the modern mapping of the paper's "data-parallel standard
                     layers + model-parallel experts" scheme (§3.1).
* ``dp_only``      — pure data parallel (small models / baselines).
* ``decode_long``  — long-context decode: batch cannot shard (B=1), so the KV
                     cache / SSM sequence axis shards over data instead.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import param as pm

log = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> tuple of mesh axis names (in priority order)."""
    table: Mapping[str, tuple[str, ...]]
    name: str = "custom"

    def lookup(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        return tuple(self.table.get(logical, ()))


def _mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def resolve_spec(
    rules: ShardingRules,
    mesh: Mesh,
    shape: Sequence[int],
    logical_axes: Sequence,
    fallbacks: list | None = None,
) -> P:
    """Resolve logical axes to a PartitionSpec, enforcing divisibility.

    Mesh axes already claimed by an earlier dimension of the same tensor are
    skipped (XLA forbids reusing a mesh axis within one PartitionSpec).
    """
    used: set[str] = set()
    out = []
    for dim, logical in zip(shape, logical_axes):
        want = [a for a in rules.lookup(logical)
                if a in mesh.shape and a not in used]
        # Largest prefix of `want` whose product divides dim.
        chosen: list[str] = []
        prod = 1
        for a in want:
            if dim % (prod * _mesh_axis_size(mesh, a)) == 0:
                chosen.append(a)
                prod *= _mesh_axis_size(mesh, a)
            else:
                if fallbacks is not None:
                    fallbacks.append((tuple(shape), logical, a, dim))
                break
        used.update(chosen)
        if not chosen:
            out.append(None)
        elif len(chosen) == 1:
            out.append(chosen[0])
        else:
            out.append(tuple(chosen))
    return P(*out)


def tree_shardings(rules: ShardingRules, mesh: Mesh, def_tree,
                   fallbacks: list | None = None):
    """NamedSharding tree for a ParamDef tree."""
    def one(d: pm.ParamDef):
        spec = resolve_spec(rules, mesh, d.shape, d.axes, fallbacks)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map(one, def_tree, is_leaf=pm.is_def)


def tree_pspecs(rules: ShardingRules, mesh: Mesh, def_tree,
                fallbacks: list | None = None):
    """PartitionSpec tree for a ParamDef tree (for shard_map in_specs)."""
    def one(d: pm.ParamDef):
        return resolve_spec(rules, mesh, d.shape, d.axes, fallbacks)
    return jax.tree_util.tree_map(one, def_tree, is_leaf=pm.is_def)


def shd(rules: ShardingRules, mesh: Mesh, shape, axes,
        fallbacks: list | None = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(rules, mesh, shape, axes,
                                            fallbacks))


# (Constraints live in repro.sharding.context: pass a MeshContext down the
# stack and call ``context.with_constraint(x, logical_axes, ctx)``.)


# ---------------------------------------------------------------------------
# Named plans
# ---------------------------------------------------------------------------

def _plan(name, **table):
    return ShardingRules(table=table, name=name)


PLANS: dict[str, ShardingRules] = {
    # The workhorse: DP over (pod,data), TP/EP over model, FSDP over data.
    "dp_tp_ep": _plan(
        "dp_tp_ep",
        batch=("pod", "data"),
        # flattened token dim inside the MoE: sharded over EVERY axis —
        # the paper's §3.1 combined-batch trick (each expert's batch comes
        # from all data-parallel replicas; entry reshard is a free slice,
        # exit is one all-gather over model, and the k-way a2a shrinks by M).
        tokens=("pod", "data", "model"),
        seq=(),                    # sequence replicated in train/prefill
        kv_seq=(),                 # cache length replicated (short contexts)
        embed=(),                  # d_model activations replicated
        embed_fsdp=("data",),      # d_model *param* dim -> FSDP over data
        vocab=("model",),
        heads=("model",),
        kv_heads=("model",),
        head_dim=(),
        mlp=("model",),            # d_ff tensor-parallel
        experts=("model",),        # expert-parallel (paper §3.1)
        expert_capacity=(),
        expert_embed=(),           # expert d_model: unsharded (weights stay)
        expert_mlp=("data",),      # TP-within-expert over data: no per-
                                   # microbatch weight gathers (cf. FSDP)
        expert_groups=("model",),  # hierarchical MoE primary branch
        ssm_inner=("model",),      # mamba d_inner tensor-parallel
        ssm_state=(),
        conv=(),
        layers=(),                 # stacked-layer leading axis never sharded
    ),
    # Baseline variant for §Perf: experts FSDP over data (ZeRO-3-style
    # per-microbatch weight gathers) instead of expert-TP.  Measurably
    # collective-bound for kimi-k2; kept for the before/after comparison.
    "dp_fsdp_ep": _plan(
        "dp_fsdp_ep",
        batch=("pod", "data"),
        tokens=("pod", "data", "model"),
        seq=(), kv_seq=(),
        embed=(),
        embed_fsdp=("data",),
        vocab=("model",),
        heads=("model",), kv_heads=("model",), head_dim=(),
        mlp=("model",),
        experts=("model",),
        expert_capacity=("data",),
        expert_embed=("data",),    # ZeRO-3 experts
        expert_mlp=(),
        expert_groups=("model",),
        ssm_inner=("model",), ssm_state=(), conv=(), layers=(),
    ),
    # Pure data-parallel (paper's small baselines, CPU smoke tests).
    "dp_only": _plan(
        "dp_only",
        batch=("pod", "data", "model"),
        embed_fsdp=(),
        vocab=(), heads=(), kv_heads=(), mlp=(), experts=(),
        expert_mlp=(), expert_groups=(), ssm_inner=(),
    ),
    # Prefill: like dp_tp_ep but the MoE dispatch buffer's capacity axis
    # shards over data (a 1M-token prefill dispatch buffer is ~150 GB for
    # kimi-k2; train avoids this via microbatching, prefill cannot).
    "prefill_tp": _plan(
        "prefill_tp",
        batch=("pod", "data"),
        tokens=("pod", "data", "model"),
        seq=(), kv_seq=(),
        embed=(),
        embed_fsdp=("data",),
        vocab=("model",),
        heads=("model",), kv_heads=("model",), head_dim=(),
        mlp=("model",),
        experts=("model",),
        expert_capacity=("data",),
        expert_embed=(),
        expert_mlp=("data",),      # weights must shard over data too (a
                                   # 2 TB expert set cannot live 16-way)
        expert_groups=("model",),
        ssm_inner=("model",), ssm_state=(), conv=(), layers=(),
    ),
    # Small-model plan: no tensor parallelism at all — batch shards over
    # every axis, parameters replicated for compute (FSDP storage over
    # data).  The §Perf fix for archs whose head counts cannot split the
    # model axis (smollm's 9 heads).
    "dp_wide": _plan(
        "dp_wide",
        batch=("pod", "data", "model"),
        tokens=("pod", "data", "model"),
        seq=(), kv_seq=(),
        embed=(),
        embed_fsdp=("data",),
        vocab=(),
        heads=(), kv_heads=(), head_dim=(),
        mlp=(),
        experts=(),
        expert_capacity=("data", "model"),
        expert_embed=(), expert_mlp=(),
        expert_groups=(),
        ssm_inner=(), ssm_state=(), conv=(), layers=(),
    ),
    # Standard decode (decode_32k): weight-gathering FSDP is wrong for
    # decode (one gather per generated token), so weights live sharded:
    # experts over model + within-expert d_ff tensor-parallel over data.
    # KV caches shard batch over (pod,data) and sequence over model
    # (flash-decoding style; GQA kv_heads often don't divide the model
    # axis, the sequence always does).
    "decode_std": _plan(
        "decode_std",
        batch=("pod", "data"),
        tokens=("pod", "data"),
        seq=(),
        kv_seq=("model",),
        embed=(),
        embed_fsdp=(),
        vocab=("model",),
        heads=("model",),
        kv_heads=(),
        head_dim=(),
        mlp=("model",),
        experts=("model",),
        expert_capacity=(),
        expert_embed=(),
        expert_mlp=("data",),      # TP-within-expert instead of FSDP
        expert_groups=("model",),
        ssm_inner=("model",),
        ssm_state=(),
        conv=(),
        layers=(),
    ),
    # Long-context decode: B=1 cannot shard; shard the cache sequence axis
    # over (data, model) instead.
    "decode_long": _plan(
        "decode_long",
        batch=(),
        tokens=(),
        seq=(),
        kv_seq=("data", "model"),
        embed=(),
        embed_fsdp=(),
        vocab=("model",),
        heads=("model",),
        kv_heads=(),
        head_dim=(),
        mlp=("model",),
        experts=("model",),
        expert_capacity=(),
        expert_embed=(),
        expert_mlp=("data",),
        expert_groups=("model",),
        ssm_inner=("model",),
        ssm_state=(),
        conv=(),
        layers=(),
    ),
}


def plan_for(shape_name: str) -> str:
    """Pick the sharding plan for a named input-shape kind."""
    if shape_name.startswith("long"):
        return "decode_long"
    if shape_name.startswith("decode"):
        return "decode_std"
    if shape_name.startswith("prefill"):
        return "prefill_tp"
    return "dp_tp_ep"
