"""Deterministic, seekable synthetic LM data pipeline.

Fault-tolerance requirement: after a restart at step N, the pipeline must
produce *exactly* the batch it would have produced without the failure.
Every batch is a pure function of (seed, step), so "resume" is just setting
the step counter — no iterator state to snapshot beyond one integer (which
the trainer stores in the checkpoint manifest).

The generator is a **mixture of latent sub-languages** — each sequence
samples a cluster c and follows that cluster's affine bigram rule
``next = (mult_c * prev + add_c) % vocab`` with occasional uniform noise.
More clusters ⇒ more memorizable structure ⇒ model *capacity* (not compute)
determines achievable perplexity.  This gives the Figure-2-left
reproduction a real capacity axis on CPU-scale models: MoEs with more
experts reach lower perplexity at matched ops/timestep (see
benchmarks/capacity_scaling.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 32_000
    seq_len: int = 128
    batch_size: int = 32
    n_clusters: int = 256       # latent sub-languages (capacity knob)
    noise_prob: float = 0.05
    seed: int = 0


def _cluster_tables(dc: DataConfig) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.RandomState(dc.seed ^ 0x5EED)
    # Odd multipliers are invertible mod 2^k-ish vocab; any value works as a
    # deterministic rule, oddness just avoids degenerate cycles.
    mult = rng.randint(1, dc.vocab_size, size=dc.n_clusters) | 1
    add = rng.randint(0, dc.vocab_size, size=dc.n_clusters)
    return mult, add


def batch_at(dc: DataConfig, step: int) -> dict:
    """The batch for a given step — pure function of (config, step)."""
    mult, add = _cluster_tables(dc)
    rng = np.random.RandomState((dc.seed * 1_000_003 + step) % (2**31 - 1))
    b, s = dc.batch_size, dc.seq_len
    clusters = rng.randint(0, dc.n_clusters, size=b)
    toks = np.zeros((b, s + 1), np.int64)
    toks[:, 0] = rng.randint(0, dc.vocab_size, size=b)
    m = mult[clusters][:, None]
    a = add[clusters][:, None]
    noise = rng.rand(b, s) < dc.noise_prob
    rand_tok = rng.randint(0, dc.vocab_size, size=(b, s))
    for t in range(s):
        nxt = (toks[:, t] * mult[clusters] + add[clusters]) % dc.vocab_size
        toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


class DataIterator:
    """Stateful wrapper with exact-resume semantics."""

    def __init__(self, dc: DataConfig, start_step: int = 0):
        self.dc = dc
        self.step = start_step

    def __next__(self) -> dict:
        batch = batch_at(self.dc, self.step)
        self.step += 1
        return batch

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict):
        self.step = int(state["step"])


def optimal_xent(dc: DataConfig) -> float:
    """Entropy floor of the generator (for benchmark calibration): a model
    that has memorized every cluster rule still faces the noise."""
    p_noise = dc.noise_prob
    # With prob (1-p)+p/V the next token is the rule token; else uniform.
    p_rule = (1 - p_noise) + p_noise / dc.vocab_size
    h = -(p_rule * np.log(p_rule)
          + (dc.vocab_size - 1) * (p_noise / dc.vocab_size)
          * np.log(p_noise / dc.vocab_size))
    return float(h)
