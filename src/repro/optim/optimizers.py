"""Optimizers: Adam and the paper's memory-factored variant (Appendix D).

The paper trained its 137B-parameter MoE with a modified Adam: β1 = 0 (no
first moment) and, for matrix parameters, the full second-moment estimator
replaced by the outer product of row-wise and column-wise running averages
divided by the mean of either — the direct ancestor of Adafactor.  That is
``kind="factored"`` here, and it is what lets a 1T-param model keep optimizer
state at ~1/10,000th of Adam's.

Learning-rate schedule (§C.1): linear warmup then inverse-sqrt decay.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "factored"        # adam | factored
    learning_rate: float = 1e-3
    warmup_steps: int = 1000      # paper: 1000 (LM) / 2000 (MT)
    b1: float = 0.9               # adam only; factored uses b1=0 (App. D)
    b2: float = 0.999
    eps: float = 1e-8
    clip_norm: float = 1.0
    weight_decay: float = 0.0
    factored_min_rank: int = 2    # factor matrices and higher-rank tensors


def schedule(oc: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup, then proportional to 1/sqrt(step) (§C.1)."""
    step = jnp.maximum(step, 1).astype(jnp.float32)
    w = jnp.asarray(float(oc.warmup_steps), jnp.float32)
    warm = step / w
    decay = jnp.sqrt(w) / jnp.sqrt(step)
    return oc.learning_rate * jnp.minimum(warm, decay)


def _is_factored(x, oc: OptConfig) -> bool:
    return x.ndim >= oc.factored_min_rank and oc.kind == "factored"


def init(params, oc: OptConfig):
    def one(p):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return {}
        if _is_factored(p, oc):
            # Row/col second-moment averages over the last two dims; leading
            # dims (stacked layers / experts) are carried elementwise.
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        state = {"v": jnp.zeros(p.shape, jnp.float32)}
        if oc.kind == "adam" and oc.b1 > 0:
            state["m"] = jnp.zeros(p.shape, jnp.float32)
        return state
    return {"mu": jax.tree_util.tree_map(one, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(jnp.asarray(g, jnp.float32)))
              for g in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_updates(params, grads, state, oc: OptConfig):
    """Returns (new_params, new_state, info)."""
    step = state["step"] + 1
    lr = schedule(oc, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if oc.clip_norm > 0 else 1.0

    def one(p, g, s):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return p, s
        g = jnp.asarray(g, jnp.float32) * scale
        if _is_factored(p, oc):
            g2 = g * g + 1e-30
            vr = oc.b2 * s["vr"] + (1 - oc.b2) * jnp.mean(g2, axis=-1)
            vc = oc.b2 * s["vc"] + (1 - oc.b2) * jnp.mean(g2, axis=-2)
            # Appendix D: estimator = outer(vr, vc) / mean(vr).
            denom = jnp.sqrt(
                vr[..., None] * vc[..., None, :]
                / jnp.maximum(jnp.mean(vr, axis=-1,
                                       keepdims=True)[..., None], 1e-30))
            upd = g / jnp.maximum(denom, oc.eps)
            new_s = {"vr": vr, "vc": vc}
        else:
            v = oc.b2 * s["v"] + (1 - oc.b2) * g * g
            vh = v / (1 - oc.b2 ** step.astype(jnp.float32))
            upd = g / (jnp.sqrt(vh) + oc.eps)
            new_s = {"v": v}
            if "m" in s:
                m = oc.b1 * s["m"] + (1 - oc.b1) * g
                upd = (m / (1 - oc.b1 ** step.astype(jnp.float32))) \
                    / (jnp.sqrt(vh) + oc.eps)
                new_s["m"] = m
        if oc.weight_decay:
            upd = upd + oc.weight_decay * jnp.asarray(p, jnp.float32)
        new_p = (jnp.asarray(p, jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, new_s

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["mu"])
    out = [one(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_params, {"mu": new_mu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


def state_bytes(state) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(state))


def state_defs(param_defs, oc: OptConfig):
    """ParamDef tree for the optimizer state (for abstract dry-run lowering).

    Factored row/col estimators inherit the parameter's logical axes minus
    the reduced dimension, so they shard exactly like their parameter.
    """
    from repro.common import param as pm

    def one(d: pm.ParamDef):
        if _is_factored_shape(d.shape, oc):
            return {
                "vr": pm.ParamDef(d.shape[:-1], d.axes[:-1], init="zeros",
                                  dtype=jnp.float32),
                "vc": pm.ParamDef(d.shape[:-2] + d.shape[-1:],
                                  d.axes[:-2] + d.axes[-1:], init="zeros",
                                  dtype=jnp.float32),
            }
        state = {"v": pm.ParamDef(d.shape, d.axes, init="zeros",
                                  dtype=jnp.float32)}
        if oc.kind == "adam" and oc.b1 > 0:
            state["m"] = pm.ParamDef(d.shape, d.axes, init="zeros",
                                     dtype=jnp.float32)
        return state

    mu = jax.tree_util.tree_map(one, param_defs, is_leaf=pm.is_def)
    return {"mu": mu, "step": pm.ParamDef((), (), init="zeros",
                                          dtype=jnp.int32)}


def _is_factored_shape(shape, oc: OptConfig) -> bool:
    return len(shape) >= oc.factored_min_rank and oc.kind == "factored"
