"""Request admission and slot-pool scheduling for the serving engine.

The engine owns a fixed pool of ``n_slots`` sequence slots (static shapes:
the decode step is one jitted call over the whole pool every step).  The
scheduler's job is the part XLA cannot do — deciding *which* request
occupies which slot at which step, and *how much prefill work* a step may
carry:

* :class:`Request` — one generation job: prompt, budget, and (as the
  engine runs) the prefill progress, sampled tokens and completion state.
* :class:`RequestQueue` — FIFO admission with per-request ``arrival``
  steps, so staggered traffic can be replayed deterministically.
* :class:`Scheduler` — the slot pool.  ``policy="continuous"`` admits a
  queued request the moment any slot frees (continuous batching — no
  batch-drain stalls); ``policy="static"`` only admits into an *empty*
  pool (the classic static-batch baseline, kept for the serve benchmark's
  before/after comparison).

Prompt-length-aware admission (docs/serving.md): :meth:`Scheduler.
schedule_prefill` plans each engine step's prefill work as a list of
:class:`PrefillWork` chunk items.  With ``prefill_chunk > 0`` a long
prompt becomes a *sequence* of fixed-size chunk work-items spread over
consecutive steps (chunked prefill — decode keeps running between
chunks); with ``prefill_budget > 0`` no step ever plans more than that
many prompt tokens of prefill.  ``admission="fcfs"`` admits strictly in
arrival order — a head request whose next chunk does not fit the
remaining budget still claims its slot (its chunks start on the next
step's budget), and later arrivals may fill the leftover budget behind
it; ``admission="aware"`` (prompt-length-aware) instead skips such
requests entirely, leaving the slot to the earliest request that fits —
short prompts are never stuck behind a long head-of-line prompt.

All of this is host-side bookkeeping over numpy/python state; device work
(prefill, decode, KV writes) stays in ``engine.py`` / ``kv_cache.py``.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle state."""

    rid: int
    prompt: np.ndarray                  # [S0] int32
    max_new_tokens: int
    arrival: int = 0                    # engine step at which it may admit
    # Filled in by the engine:
    tokens: list = dataclasses.field(default_factory=list)
    done_reason: str | None = None      # "eos" | "length"
    admitted_step: int | None = None
    finished_step: int | None = None
    prefill_pos: int = 0                # prompt tokens prefilled so far
    first_token_step: int | None = None  # step the first token sampled at

    @property
    def done(self) -> bool:
        return self.done_reason is not None

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.prompt).shape[-1])

    @property
    def prefilling(self) -> bool:
        """Admitted but the prompt is not fully ingested yet (a chunked
        prefill in flight across engine steps)."""
        return self.prefill_pos < self.prompt_len


@dataclasses.dataclass(frozen=True)
class PrefillWork:
    """One prefill work-item: ingest ``length`` prompt tokens of ``req``
    starting at prompt position ``start`` into ``slot``'s cache page.
    Whole-prompt prefill is the single item (0, prompt_len); chunked
    prefill emits one item per chunk."""
    slot: int
    req: Request
    start: int
    length: int


@dataclasses.dataclass(frozen=True)
class StepDecision:
    """Everything the scheduler decided in one ``schedule_prefill`` call —
    the record the replay simulator must reproduce exactly (the fidelity
    contract in docs/observability.md).  Comparable across a real
    ``ServeEngine`` run and a cost-model replay because both drive the
    *same* ``Scheduler``/``RequestQueue``/``PrefixCache`` classes.

    ``admitted``: rids in admission order (slot claim order);
    ``work``: the planned chunk items as ``(rid, slot, start, length)``;
    ``prefix_hits``: ``(rid, hit_tokens)`` for admissions that resumed
    from a cached prefix (``on_admit`` advanced ``prefill_pos``)."""
    step: int
    admitted: tuple
    work: tuple
    prefix_hits: tuple


def chunk_rounds(by_slot: dict) -> list:
    """Group per-slot ordered prefill work-items into execution rounds.

    Each slot's items are consecutive prompt ranges that must run in
    order (chunk N+1 resumes chunk N's page), but items of *different*
    slots are independent — so execution proceeds in rounds of every
    slot's head item, with same-offset heads grouped into one multi-row
    batched prefill call.  Returns ``[(offset, [(slot, work), ...]),
    ...]`` in execution order.

    Shared by ``ServeEngine`` (which runs each group as one device call)
    and the replay simulator (which charges each group one fitted
    prefill-chunk cost) — the grouping IS the scheduling decision, so
    both must compute it identically.
    """
    queues = {slot: list(items) for slot, items in by_slot.items()}
    rounds: list = []
    while queues:
        heads: dict[int, list] = {}
        for slot in sorted(queues):
            w = queues[slot][0]
            heads.setdefault(w.start, []).append((slot, w))
        for off in sorted(heads):
            rounds.append((off, heads[off]))
        for slot in list(queues):
            queues[slot].pop(0)
            if not queues[slot]:
                del queues[slot]
    return rounds


class RequestQueue:
    """FIFO queue with arrival times (for replaying staggered traffic).

    Indexed two-heap layout (the replay-sim bottleneck under sustained
    overload was the old linear scan over *every* queued request per
    pop): not-yet-arrived requests wait in an arrival-keyed ``_pending``
    heap and are admitted to the submission-ordered ``_ready`` heap the
    first time ``pop_ready`` sees their arrival step.  The common fcfs
    pop is then O(log n) off the ready head, and a ``fits`` scan only
    walks requests that are actually poppable this step — never the
    backlog of future arrivals.  ``pop_ready`` semantics are
    bit-identical to the linear scan (pinned by tests/test_serve_sched.py):
    earliest-*submitted* ready request wins, not earliest-arrived."""

    def __init__(self):
        self._seq = 0                    # submission order (FIFO tiebreak)
        self._pending: list = []         # heap of (arrival, seq, req)
        self._ready: list = []           # heap of (seq, req)

    def push(self, req: Request) -> None:
        heapq.heappush(self._pending, (req.arrival, self._seq, req))
        self._seq += 1

    def pop_ready(self, step: int, fits=None) -> Request | None:
        """Earliest-submitted request whose arrival step has passed.

        ``fits`` (optional predicate) restricts the pop to requests the
        caller can start right now — the prompt-length-aware admission
        policy passes a next-chunk-fits-the-budget check here, so a long
        head-of-line prompt is skipped (not starved: every step's budget
        resets, and a chunk never exceeds the budget by construction, so
        the head admits as soon as a slot is free at step start).
        Without ``fits`` (fcfs) the head is popped regardless — it
        claims its slot even when no budget is left for its chunks this
        step."""
        while self._pending and self._pending[0][0] <= step:
            _, seq, req = heapq.heappop(self._pending)
            heapq.heappush(self._ready, (seq, req))
        skipped = []
        found = None
        while self._ready:
            seq, req = heapq.heappop(self._ready)
            # Re-check arrival: a caller may legally probe an *earlier*
            # step than the one that admitted this request to ready.
            if req.arrival <= step and (fits is None or fits(req)):
                found = req
                break
            skipped.append((seq, req))
        for item in skipped:
            heapq.heappush(self._ready, item)
        return found

    def __len__(self) -> int:
        return len(self._pending) + len(self._ready)

    def __bool__(self) -> bool:
        return bool(self._pending) or bool(self._ready)


class Scheduler:
    """Fixed slot pool with continuous (default) or batch-drain admission.

    ``prefill_chunk``: chunk size in tokens (0 = whole-prompt prefill).
    ``prefill_budget``: max prompt tokens planned per engine step
    (0 = unlimited).  ``admission``: "fcfs" | "aware" (see module doc).

    Shared-prefix hooks (both optional — the engine wires them when its
    prefix cache is on):

    * ``prefix_probe(req) -> int`` — cached-prefix length (tokens) a new
      request would resume from.  Admission cost accounting uses it so
      the "aware" fits-predicate charges only the *uncached tail* against
      the budget: a long prompt whose prefix is cached competes like the
      short prompt it effectively is.
    * ``on_admit(slot, req)`` — called the moment a request claims a
      slot, *before* its chunks are planned.  The engine's hook performs
      the prefix-cache lookup, pins the entry, stages the cached page
      into the slot and advances ``req.prefill_pos`` to the hit length —
      so chunk planning (and the budget) naturally sees only the tail.
    """

    def __init__(self, n_slots: int, policy: str = "continuous", *,
                 admission: str = "fcfs", prefill_chunk: int = 0,
                 prefill_budget: int = 0, prefix_probe=None,
                 on_admit=None):
        if policy not in ("continuous", "static"):
            raise ValueError(f"unknown scheduling policy {policy!r}")
        if admission not in ("fcfs", "aware"):
            raise ValueError(f"unknown admission policy {admission!r}")
        if prefill_chunk > 0 and prefill_budget > 0 \
                and prefill_chunk > prefill_budget:
            raise ValueError(
                f"prefill_chunk ({prefill_chunk}) > prefill_budget "
                f"({prefill_budget}): no chunk could ever be scheduled")
        self.n_slots = n_slots
        self.policy = policy
        self.admission = admission
        self.prefill_chunk = prefill_chunk
        self.prefill_budget = prefill_budget
        self.prefix_probe = prefix_probe
        self.on_admit = on_admit
        self.slots: list[Request | None] = [None] * n_slots
        self.admitted = 0
        self.retired = 0
        self.max_concurrent = 0
        # Optional decision capture: when a list is assigned here, every
        # schedule_prefill call that admitted or planned anything appends
        # a StepDecision — the fidelity contract the replay simulator is
        # tested against (docs/observability.md).  None (default) keeps
        # the hot path allocation-free.
        self.decision_log: list[StepDecision] | None = None

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def active(self) -> list[tuple[int, Request]]:
        """Occupied slots (prefilling or decoding)."""
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    def decoding(self) -> list[tuple[int, Request]]:
        """Occupied slots whose prompt is fully ingested — the slots the
        fused decode step feeds (a mid-prefill slot has no token to feed
        and must not decode garbage)."""
        return [(i, r) for i, r in enumerate(self.slots)
                if r is not None and not r.prefilling]

    # -- per-step prefill planning ---------------------------------------
    def _next_cost(self, req: Request) -> int:
        """Prompt tokens the request's next work-item ingests.  For a
        not-yet-admitted request with a cached prefix, the first work-item
        starts at the hit position (``on_admit`` advances ``prefill_pos``
        there), so the cost is charged from the probe result — only the
        uncached tail counts against the budget."""
        pos = req.prefill_pos
        if self.prefix_probe is not None and req.admitted_step is None:
            pos = max(pos, self.prefix_probe(req))
        remaining = req.prompt_len - pos
        if self.prefill_chunk <= 0:
            return remaining
        return min(self.prefill_chunk, remaining)

    def _emit_chunks(self, slot: int, req: Request, planned: dict,
                     spent: int, budget: int | None
                     ) -> tuple[list[PrefillWork], int]:
        """Chunk work-items for one request, up to the remaining budget.
        ``planned`` tracks positions planned this step but not yet
        executed (the engine runs the items after planning finishes)."""
        items: list[PrefillWork] = []
        pos = planned.get(req.rid, req.prefill_pos)
        while pos < req.prompt_len:
            n = (req.prompt_len - pos if self.prefill_chunk <= 0
                 else min(self.prefill_chunk, req.prompt_len - pos))
            if budget is not None and spent + n > budget:
                break
            items.append(PrefillWork(slot, req, pos, n))
            spent += n
            pos += n
            if self.prefill_chunk <= 0:
                break
        planned[req.rid] = pos
        return items, spent

    def schedule_prefill(self, queue: RequestQueue | None, step: int
                         ) -> list[PrefillWork]:
        """Plan one engine step's prefill work.

        1. continue in-flight chunked prefills (slot order — deterministic);
        2. admit ready requests from the queue into free slots, each with
           as many chunk work-items as the remaining budget allows.

        The total token count of the returned items never exceeds
        ``prefill_budget`` (the hypothesis suite pins this invariant);
        continuous admission fills every free slot the budget can feed,
        static admission waits for the whole pool to drain.
        """
        budget = self.prefill_budget if self.prefill_budget > 0 else None
        planned: dict[int, int] = {}
        out: list[PrefillWork] = []
        spent = 0
        for slot, req in self.active():
            if req.prefilling:
                items, spent = self._emit_chunks(slot, req, planned,
                                                 spent, budget)
                out.extend(items)
        can_admit = queue is not None and not (
            self.policy == "static"
            and any(r is not None for r in self.slots))
        admitted_rids: list[int] = []
        prefix_hits: list[tuple[int, int]] = []
        if can_admit:
            fits = None
            if self.admission == "aware" and budget is not None:
                # Reads the *current* spent at each pop: prompt-length-
                # aware admission skips requests whose next chunk would
                # overflow what is left of this step's budget.
                fits = lambda r: self._next_cost(r) <= budget - spent  # noqa: E731
            for slot in self.free_slots():
                if budget is not None and spent >= budget:
                    break
                req = queue.pop_ready(step, fits)
                if req is None:
                    break
                req.admitted_step = step
                self.slots[slot] = req
                self.admitted += 1
                admitted_rids.append(req.rid)
                if self.on_admit is not None:
                    # Prefix-cache hook: may stage a cached page and
                    # advance req.prefill_pos past the hit, so the chunk
                    # plan below covers only the uncached tail.
                    self.on_admit(slot, req)
                    if req.prefill_pos > 0:
                        prefix_hits.append((req.rid, req.prefill_pos))
                items, spent = self._emit_chunks(slot, req, planned,
                                                 spent, budget)
                out.extend(items)
        self.max_concurrent = max(self.max_concurrent, len(self.active()))
        if self.decision_log is not None and (out or admitted_rids):
            self.decision_log.append(StepDecision(
                step=step, admitted=tuple(admitted_rids),
                work=tuple((w.req.rid, w.slot, w.start, w.length)
                           for w in out),
                prefix_hits=tuple(prefix_hits)))
        return out

    def admit(self, queue: RequestQueue, step: int
              ) -> list[tuple[int, Request]]:
        """Legacy whole-prompt admission (kept for scheduler-level tests):
        equivalent to ``schedule_prefill`` with no chunking or budget,
        returning the admitted (slot, request) pairs."""
        if self.prefill_chunk > 0 or self.prefill_budget > 0:
            # Calling the legacy entry point on a chunking/budget config
            # would silently drop both knobs — a real exception, not an
            # assert that `python -O` strips (same policy as retire below).
            raise ValueError(
                "Scheduler.admit() is whole-prompt only; use "
                "schedule_prefill when prefill_chunk/prefill_budget are "
                "configured")
        before = {id(r) for r in self.slots if r is not None}
        return [(w.slot, w.req)
                for w in self.schedule_prefill(queue, step)
                if id(w.req) not in before]

    def retire(self, slot: int) -> Request:
        req = self.slots[slot]
        if req is None:
            # A double retire desynchronizes admitted/retired accounting
            # and could free another request's slot — a real exception,
            # not an assert that `python -O` strips.
            raise ValueError(f"retire of empty slot {slot}")
        self.slots[slot] = None
        self.retired += 1
        return req
