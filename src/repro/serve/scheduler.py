"""Request admission and slot-pool scheduling for the serving engine.

The engine owns a fixed pool of ``n_slots`` sequence slots (static shapes:
the decode step is one jitted call over the whole pool every step).  The
scheduler's job is the part XLA cannot do — deciding *which* request
occupies which slot at which step:

* :class:`Request` — one generation job: prompt, budget, and (as the
  engine runs) the sampled tokens and completion state.
* :class:`RequestQueue` — FIFO admission with per-request ``arrival``
  steps, so staggered traffic can be replayed deterministically.
* :class:`Scheduler` — the slot pool.  ``policy="continuous"`` admits a
  queued request the moment any slot frees (continuous batching — no
  batch-drain stalls); ``policy="static"`` only admits into an *empty*
  pool (the classic static-batch baseline, kept for the serve benchmark's
  before/after comparison).

All of this is host-side bookkeeping over numpy/python state; device work
(prefill, decode, KV writes) stays in ``engine.py`` / ``kv_cache.py``.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle state."""

    rid: int
    prompt: np.ndarray                  # [S0] int32
    max_new_tokens: int
    arrival: int = 0                    # engine step at which it may admit
    # Filled in by the engine:
    tokens: list = dataclasses.field(default_factory=list)
    done_reason: str | None = None      # "eos" | "length"
    admitted_step: int | None = None
    finished_step: int | None = None

    @property
    def done(self) -> bool:
        return self.done_reason is not None

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.prompt).shape[-1])


class RequestQueue:
    """FIFO queue with arrival times (for replaying staggered traffic)."""

    def __init__(self):
        self._q: list[Request] = []

    def push(self, req: Request) -> None:
        self._q.append(req)

    def pop_ready(self, step: int) -> Request | None:
        """Earliest-submitted request whose arrival step has passed."""
        for i, req in enumerate(self._q):
            if req.arrival <= step:
                return self._q.pop(i)
        return None

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)


class Scheduler:
    """Fixed slot pool with continuous (default) or batch-drain admission."""

    def __init__(self, n_slots: int, policy: str = "continuous"):
        if policy not in ("continuous", "static"):
            raise ValueError(f"unknown scheduling policy {policy!r}")
        self.n_slots = n_slots
        self.policy = policy
        self.slots: list[Request | None] = [None] * n_slots
        self.admitted = 0
        self.retired = 0
        self.max_concurrent = 0

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def active(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    def admit(self, queue: RequestQueue, step: int
              ) -> list[tuple[int, Request]]:
        """Move ready requests from the queue into free slots.

        Continuous policy fills every free slot; static policy only admits
        when the whole pool has drained (the baseline's stall, on purpose).
        """
        if self.policy == "static" and any(r is not None for r in self.slots):
            return []
        out = []
        for slot in self.free_slots():
            req = queue.pop_ready(step)
            if req is None:
                break
            req.admitted_step = step
            self.slots[slot] = req
            out.append((slot, req))
        self.admitted += len(out)
        self.max_concurrent = max(self.max_concurrent,
                                  len(self.active()))
        return out

    def retire(self, slot: int) -> Request:
        req = self.slots[slot]
        assert req is not None, f"retire of empty slot {slot}"
        self.slots[slot] = None
        self.retired += 1
        return req
