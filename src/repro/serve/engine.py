"""Continuous-batching serving engine.

The engine owns ``n_slots`` sequence slots and runs a step loop of

    schedule (admission + chunk planning under the prefill-token budget)
             -> run this step's prefill work-items -> fused decode step
             -> sample -> retire finished slots

Requests are admitted and retired *independently* (continuous batching):
the moment a sequence finishes — EOS or length budget, checked uniformly
for every sampled token including the last — its slot returns to the pool
and the next queued request prefills into it.  No batch-drain stalls: a
mixed-length batch never decodes into dead slots while stragglers finish
(the static-batch baseline that does is kept as ``policy="static"`` for
the serve benchmark).

Prompt ingestion is either whole-prompt (power-of-two buckets) or — with
``ServeConfig.prefill_chunk`` — *chunked*: long prompts become a sequence
of fixed-size chunk work-items spread over consecutive steps, each
resuming the slot's cache page where the previous chunk ended
(``lm_prefill(start_pos=...)``), so one monster prompt no longer stalls
every live decode slot for a whole prefill.  ``prefill_budget`` bounds
the prompt tokens any step may ingest and ``admission="aware"`` lets
short prompts pass a long head-of-line prompt within the leftover budget
(scheduler.py has the planning; docs/serving.md the design).

Device-side structure per step: at most ``prefill_budget`` tokens of
batch-1 prefill work (one jit per bucket, one per chunk offset) plus
exactly one fused decode call over the fully-ingested slots with
*per-slot* positions (``lm_decode`` takes a [n_slots] position vector —
slots of mixed age each attend at their own offset; mid-prefill slots
are masked out like dead ones).

Plans: prefill runs under ``prefill_tp`` (dispatch capacity sharded over
data), decode under ``decode_std`` (weights stay sharded, KV sequence over
model).  The handoff is an explicit ``MeshContext.reshard`` — device_put
of the prefilled page onto the decode plan — before the page is inserted
into the slot pool (ROADMAP: the prefill→decode boundary now reshards).

Observability (docs/observability.md): the engine's bookkeeping lives in
a typed ``MetricsRegistry`` (``engine.metrics``; the legacy ``.stats``
dict is a property view over it), per-step MoE expert load / overflow
aggregates into bounded histogram/counter instruments plus a
``keep_last_n`` ring of raw entries (``engine.telemetry``), and — with
``ServeConfig.trace_path`` set — every step emits chrome-trace spans
(admission, prefix probe/hit, chunk-group prefills with [G, C] attrs,
blend, reshard, decode, sample, retire) that load in Perfetto and feed
the cost-model replay simulator (``repro.obs.replay``).  Tracing off is
the default and costs one no-op context manager per span site.

Batching-invariance caveat: all pool slots (active *and* dead) share the
MoE capacity buffers of one fused decode, so greedy outputs are
bit-identical to sequential generation only while no decode-time
capacity overflow occurs (ample ``capacity_factor`` relative to
``n_slots``).  Under routing skew past capacity, which sequences share a
step determines what drops — exactly the events the per-step
``overflow`` telemetry counts, so the regime is observable.
"""
from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import param as pm
from repro.configs.base import ModelConfig
from repro.models import lm
from repro.obs import metrics as metrics_lib
from repro.obs import trace as trace_lib
from repro.serve.kv_cache import PrefixCache, SlotKVCache
from repro.serve.scheduler import (Request, RequestQueue, Scheduler,
                                   chunk_rounds)
from repro.sharding import context as ctx_lib


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256           # slot page length (prompt + new tokens)
    temperature: float = 0.0     # 0 => greedy
    eos_id: int = -1             # -1 => never stop early
    seed: int = 0
    n_slots: int = 8             # slot-pool size == decode batch width
    policy: str = "continuous"   # "continuous" | "static" (drain baseline)
    prefill_plan: str = "prefill_tp"
    decode_plan: str = "decode_std"
    # Dead-slot masking: pass slot occupancy into routing (the router's
    # token-validity mask) so empty pool slots neither route through the
    # MoE nor consume expert capacity — observable as lower capacity-
    # overflow telemetry under partial occupancy.
    mask_dead_slots: bool = True
    # Bucketed prefill: right-pad prompts to power-of-two length buckets
    # so jit compiles once per bucket instead of once per distinct prompt
    # length.  Padded positions are masked out of MoE routing and their
    # garbage KV is never attended (causal mask + sequential overwrite),
    # so outputs stay bit-identical to exact-length prefill while prefill
    # routing does not overflow at the exact length (capacity is sized
    # from the padded count, so padding only ever ADDS slots; under a
    # factor tight enough to drop prompt tokens the two runs keep
    # different assignments — docs/serving.md).  Disabled automatically
    # for ssm/hybrid (stateful scan) and sliding-window models
    # (ring-buffer caches would retain padded positions).
    prefill_buckets: bool = True
    min_bucket: int = 8          # smallest prefill bucket length
    # Chunked prefill (docs/serving.md): prompts longer than
    # ``prefill_chunk`` tokens are ingested as a sequence of fixed-size
    # chunk work-items spread over consecutive engine steps, each resuming
    # the cache where the previous chunk ended (lm_prefill start_pos) —
    # decode keeps running between chunks, so one long prompt no longer
    # stalls every live decode slot for a whole monster prefill.  0
    # disables (whole-prompt prefill, the pre-chunking behavior).  Same
    # architecture restrictions as bucketing (ssm/hybrid, sliding-window):
    # the engine falls back loudly (RuntimeWarning) when unsupported.
    prefill_chunk: int = 0
    # Max prompt tokens of prefill work any single engine step may carry
    # (0 = unlimited).  Enforced by the Scheduler; with chunking enabled
    # the chunk size must fit the budget.  The budget counts *real*
    # prompt tokens: device work is chunk-/bucket-granular (a final
    # partial chunk pads to the chunk size, a whole prompt to its
    # power-of-two bucket), so the per-step device-token bound is the
    # budget rounded up to those granularities — use chunking for tight
    # stall bounds (buckets can pad up to 2x).
    prefill_budget: int = 0
    # Admission policy: "fcfs" pops strictly in arrival order; "aware"
    # (prompt-length-aware) skips requests whose next chunk does not fit
    # the step's remaining prefill budget and admits the earliest one
    # that does, so short prompts never queue behind a long head-of-line
    # prompt.
    admission: str = "fcfs"
    # Shared-prefix radix KV cache (docs/serving.md §Shared-prefix KV
    # cache): retired slot pages are inserted into a prefix trie keyed by
    # prefill_chunk-token prompt blocks; a new request resumes from the
    # longest cached block-aligned prefix and prefills only the tail.
    # Requires chunked prefill (prefill_chunk > 0) — hits land on the
    # chunk grid, so a resumed prefill replays the exact jitted chunk
    # calls a cold one would and greedy outputs stay bit-identical with
    # the cache on or off.  Architectures that refuse chunking
    # (ssm/hybrid, sliding-window) also disable the prefix cache (with
    # the same RuntimeWarning fallback).
    prefix_cache: bool = False
    # LRU byte budget for cached prefix pages (<= 0 = unlimited).
    # Accounting charges the full per-page byte size for every entry;
    # pinned entries (in-flight prefills) are never evicted.
    prefix_cache_bytes: int = 1 << 30
    # Chrome-trace span capture (docs/observability.md): when set, every
    # engine step records spans (schedule, prefix probe/hit, chunk-group
    # prefill with [G, C] attrs, blend, reshard, decode, sample, retire)
    # and ``run()`` writes a Perfetto-loadable trace here.  None (the
    # default) installs the null tracer: the hot path pays one no-op
    # context manager per span site and outputs stay bit-identical.
    trace_path: str | None = None
    # Calibration tracing: block on device results *inside* the prefill/
    # decode spans so each span's duration is that op's real wall (what
    # the replay cost model fits on — ``make fit-costs`` sets this).
    # Off (the default), spans record dispatch time and device time
    # drains at the step's natural sync points: the trace stays accurate
    # at step granularity and the capture overhead is the span appends
    # alone (<1% on the serve bench; the syncs cost another ~2% in lost
    # host/device overlap — docs/observability.md §Overhead discipline).
    trace_sync: bool = False
    # Capture scheduler decisions (admission order, chunk plan, prefix
    # hits) as StepDecision records on ``engine.sched.decision_log`` —
    # the fidelity contract the replay simulator reproduces.
    log_decisions: bool = False
    # Raw per-step MoE telemetry entries kept for inspection (a bounded
    # ring — the aggregate histogram/counter instruments in
    # ``engine.metrics`` cover the full run, so a week-long serve no
    # longer grows an unbounded list).
    telemetry_keep_last_n: int = 512
    # Fused single-launch MoE decode (docs/kernels.md §Fused decode
    # step): each MoE/MoA layer's decode hot path runs routing + scatter
    # + expert FFN + combine as ONE kernel launch.  Greedy outputs are
    # bit-identical on/off (pinned by the serve parity matrix); the
    # backend falls back per call (RuntimeWarning) when the fused slab
    # exceeds the VMEM budget.  Decode-only — prefill stays unfused.
    fused_decode: bool = False


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, sc: ServeConfig,
                 ctx: ctx_lib.MeshContext | None = None):
        if sc.fused_decode:
            # Flows to decode-shaped MoE/MoA calls only (the model layer
            # gates on decode=True); the jitted closures below capture
            # this local cfg, so flip it before they are built.
            cfg = cfg.replace(fused_decode=True)
        self.params = params
        self.cfg = cfg
        self.sc = sc
        # Tracing off => the shared null tracer: every span site below
        # costs one attribute read + a no-op context manager.
        self.tracer = (trace_lib.Tracer(sc.trace_path, process_name="serve")
                       if sc.trace_path else trace_lib.NULL)
        self._trace_sync = self.tracer.enabled and sc.trace_sync
        self.ctx = ctx or ctx_lib.MeshContext.null(plan=sc.decode_plan)
        on_mesh = self.ctx.mesh is not None
        self.decode_ctx = (self.ctx.with_plan(sc.decode_plan) if on_mesh
                           else self.ctx)
        self.prefill_ctx = (self.ctx.with_plan(sc.prefill_plan) if on_mesh
                            else self.ctx)
        # Bucketed prefill is only sound when a padded tail can neither
        # leak into recurrent state (ssm/hybrid mixers scan sequentially)
        # nor linger in a ring-buffer KV cache (sliding-window layers).
        from repro.configs.base import layer_kinds
        stateless = (not cfg.sliding_window
                     and all(k.mixer != "mamba" for k in layer_kinds(cfg)))
        self._can_bucket = sc.prefill_buckets and stateless
        # Chunked prefill shares the restriction (resuming mid-prompt
        # needs the whole prefix recoverable from the KV cache): refuse
        # loudly and fall back to whole-prompt prefill otherwise.
        self._chunk = 0
        if sc.prefill_chunk > 0:
            if not stateless:
                import warnings
                warnings.warn(
                    "chunked prefill requires stateless attention caches; "
                    "ssm/hybrid state scans and sliding-window ring "
                    "buffers cannot resume mid-prompt — falling back to "
                    "whole-prompt prefill (docs/serving.md)",
                    RuntimeWarning, stacklevel=2)
            else:
                c = sc.prefill_chunk
                if c % cfg.kv_block != 0 or (c > cfg.q_block
                                             and c % cfg.q_block != 0):
                    raise ValueError(
                        f"prefill_chunk={c} must be a multiple of "
                        f"kv_block={cfg.kv_block} (and of q_block="
                        f"{cfg.q_block} when larger) so chunk boundaries "
                        "stay block-aligned with whole-prompt prefill")
                if c > sc.max_len:
                    raise ValueError(
                        f"prefill_chunk={c} > max_len={sc.max_len}: even "
                        "a single chunk's cache write would not fit the "
                        "slot page")
                if jnp.dtype(cfg.param_dtype) != jnp.dtype(
                        cfg.compute_dtype):
                    # The cached prefix K/V a chunk attends round-trips
                    # through the cache dtype; a whole-prompt prefill
                    # attends fresh compute-dtype K/V, so a narrower
                    # cache breaks the bit-identical-to-whole-prompt
                    # guarantee (outputs stay valid, streams may differ).
                    import warnings
                    warnings.warn(
                        "chunked prefill with cache dtype "
                        f"{jnp.dtype(cfg.param_dtype).name} != compute "
                        f"dtype {jnp.dtype(cfg.compute_dtype).name}: "
                        "chunk attention reads the cached prefix at "
                        "cache precision, so outputs are not guaranteed "
                        "bit-identical to whole-prompt prefill "
                        "(docs/serving.md)", RuntimeWarning, stacklevel=2)
                self._chunk = c
        # Shared-prefix cache: hits must land on the chunk grid (a resumed
        # prefill replays the same jitted chunk calls a cold one would, so
        # greedy outputs stay bit-identical) — hence it requires chunked
        # prefill, and inherits the architecture fallback above.
        self._prefix_on = False
        if sc.prefix_cache:
            if sc.prefill_chunk <= 0:
                raise ValueError(
                    "prefix_cache requires chunked prefill "
                    "(prefill_chunk > 0): cache hits resume mid-prompt "
                    "on the chunk grid — whole-prompt prefill has no "
                    "resume path (docs/serving.md)")
            if self._chunk == 0:
                import warnings
                warnings.warn(
                    "prefix cache disabled: this architecture refused "
                    "chunked prefill (ssm/sliding-window), and prefix "
                    "hits can only resume through the chunk path "
                    "(docs/serving.md)", RuntimeWarning, stacklevel=2)
            else:
                self._prefix_on = True
        self._prefill = jax.jit(
            lambda p, b, c, li, v: lm.lm_prefill(p, b, c, cfg,
                                                 ctx=self.prefill_ctx,
                                                 last_index=li, valid=v))
        # One jitted chunk function per chunk *offset* (chunk length is
        # fixed, so compile count is O(max_len / prefill_chunk)); the
        # static offset keeps the blockwise kv ranges pruned above the
        # shifted diagonal.
        self._chunk_fns: dict[int, object] = {}
        self._decode = jax.jit(
            lambda p, t, c, i, v: lm.lm_decode(p, t, c, i, cfg,
                                               ctx=self.decode_ctx,
                                               valid=v,
                                               return_telemetry=True))
        self._argmax = jax.jit(lambda l: jnp.argmax(l, axis=-1)
                               .astype(jnp.int32))
        if sc.temperature > 0.0:
            self._categorical = jax.jit(jax.vmap(
                lambda key, l: jax.random.categorical(
                    key, l / sc.temperature).astype(jnp.int32)))
        self.reset()

    # -- lifecycle --------------------------------------------------------
    def reset(self) -> None:
        """Fresh queue/pool/stats/request ids (so a replayed trace samples
        the same per-request streams); compiled step functions are
        retained."""
        self._rid = 0
        self.kv = SlotKVCache(self.cfg, self.sc.n_slots, self.sc.max_len,
                              ctx=self.decode_ctx)
        # One immutable blank page, reused by every prefill (jax arrays
        # are never mutated in place, so sharing is safe).
        self._blank_page = pm.materialize(self.kv.seq_defs,
                                          jax.random.PRNGKey(0))
        # Shared-prefix radix cache over retired pages.  Page byte size is
        # the dense per-sequence page (every leaf of seq_defs) — uniform,
        # so LRU accounting is a multiple of one constant.
        self.prefix: PrefixCache | None = None
        self._pins: dict[int, object] = {}   # rid -> pinned trie entry
        if self._prefix_on:
            page_bytes = sum(
                int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
                for leaf in jax.tree_util.tree_leaves(self._blank_page))
            self.prefix = PrefixCache(
                block=self._chunk, page_bytes=page_bytes,
                max_bytes=self.sc.prefix_cache_bytes)
        self.queue = RequestQueue()
        self.sched = Scheduler(
            self.sc.n_slots, policy=self.sc.policy,
            admission=self.sc.admission,
            prefill_chunk=self._chunk,
            prefill_budget=self.sc.prefill_budget,
            prefix_probe=self._prefix_probe if self._prefix_on else None,
            on_admit=self._on_admit if self._prefix_on else None)
        if self.sc.log_decisions:
            self.sched.decision_log = []
        self.step_count = 0
        self.prefill_lengths: set[int] = set()   # distinct compiled shapes
        self.chunk_offsets: set[int] = set()     # distinct chunk compiles
        # Raw per-step MoE telemetry: a bounded ring (the full-run view
        # lives in the aggregate instruments below).
        self._telemetry = collections.deque(
            maxlen=max(self.sc.telemetry_keep_last_n, 0) or None)
        # Typed metrics registry (docs/observability.md).  The counter
        # names are the legacy engine.stats keys — the ``stats`` property
        # renders them as the same plain dict existing tests/benches read.
        # prefill_calls counts device prefill calls: < prefill_chunks when
        # cross-slot chunk batching groups same-offset work-items into one
        # multi-row call.
        self.metrics = metrics_lib.MetricsRegistry()
        self._c = {name: self.metrics.counter(name) for name in (
            "prefills", "decode_steps", "reshards", "generated_tokens",
            "slot_steps_active", "slot_steps_total", "overflow_total",
            "prefill_chunks", "prefill_tokens", "prefill_calls",
            "prefix_hits", "prefix_hit_tokens")}
        self._h_overflow = self.metrics.histogram("decode_overflow_per_step")
        self._h_active = self.metrics.histogram("decode_active_slots")
        self._c_expert_load = self.metrics.counter("decode_expert_load",
                                                   labels=("expert",))
        # MoA (routed attention head groups, docs/moa.md): separate
        # instrument families — head-group load is not FFN-expert load.
        self._c_moa_overflow = self.metrics.counter("moa_overflow_total")
        self._h_moa_overflow = self.metrics.histogram(
            "decode_moa_overflow_per_step")
        self._c_moa_load = self.metrics.counter("decode_moa_load",
                                                labels=("expert",))

    def submit(self, prompt, max_new_tokens: int, arrival: int = 0
               ) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            # The engine samples a first token unconditionally when a
            # prefill completes, so a zero budget would still return one
            # token (off-by-one); reject at the front door instead.
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens}: "
                "prefill always samples the first token")
        if prompt.shape[0] + max_new_tokens > self.sc.max_len:
            raise ValueError(
                f"prompt ({prompt.shape[0]}) + max_new_tokens "
                f"({max_new_tokens}) exceeds max_len {self.sc.max_len}")
        if (self._chunk == 0 and self.sc.prefill_budget > 0
                and prompt.shape[0] > self.sc.prefill_budget):
            why = ("this architecture refused chunked prefill "
                   "(ssm/sliding-window — see the construction warning), "
                   "so the whole prompt must fit the budget"
                   if self.sc.prefill_chunk > 0 else
                   "chunked prefill is off — enable "
                   "ServeConfig.prefill_chunk to split it")
            raise ValueError(
                f"prompt ({prompt.shape[0]}) exceeds the per-step prefill "
                f"budget ({self.sc.prefill_budget}) and {why}")
        if self._chunk and prompt.shape[0] > self._chunk:
            # Every chunk ships a full prefill_chunk-token buffer (the
            # final one padded), so its cache write spans
            # [start, start + chunk); a window past max_len would make
            # the dynamic_update_slice clamp its start and silently
            # overwrite already-cached prefix positions.
            padded = -(-int(prompt.shape[0]) // self._chunk) * self._chunk
            if padded > self.sc.max_len:
                raise ValueError(
                    f"prompt ({prompt.shape[0]}) rounds up to {padded} "
                    f"chunk-padded tokens > max_len {self.sc.max_len}: "
                    "the final chunk's cache write would not fit the "
                    "page — raise max_len or lower prefill_chunk")
        req = Request(rid=self._rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, arrival=arrival)
        self._rid += 1
        self.queue.push(req)
        return req

    # -- sampling ---------------------------------------------------------
    def _req_key(self, req: Request):
        """Per-request stream: deterministic regardless of which batch the
        request happens to share a decode step with."""
        return jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.sc.seed), req.rid),
            len(req.tokens))

    def _sample_rows(self, logits, reqs: list[Request | None]) -> np.ndarray:
        """logits: [B, V] -> [B] int32 (row i sampled for reqs[i])."""
        if self.sc.temperature <= 0.0:
            return np.asarray(self._argmax(logits))
        keys = jnp.stack([
            self._req_key(r) if r is not None
            else jax.random.PRNGKey(0) for r in reqs])
        return np.asarray(self._categorical(keys, logits))

    # -- the step loop ----------------------------------------------------
    def _append_token(self, req: Request, tok: int, slot: int) -> None:
        """Record a sampled token and retire uniformly on EOS/length.

        EOS is checked for *every* sampled token — including the final one
        of the budget (the old static engine skipped the check when
        ``i == max_new_tokens - 1``, so a terminal EOS was reported as a
        length stop)."""
        req.tokens.append(int(tok))
        self._c["generated_tokens"].inc()
        if self.sc.eos_id >= 0 and int(tok) == self.sc.eos_id:
            req.done_reason = "eos"
        elif len(req.tokens) >= req.max_new_tokens:
            req.done_reason = "length"
        if req.done:
            req.finished_step = self.step_count
            with self.tracer.span("serve.retire", rid=req.rid, slot=slot,
                                  reason=req.done_reason):
                self.sched.retire(slot)
                if self.prefix is not None and not self.prefix.covered(
                        req.prompt):
                    # Retirement feeds the trie: the slot page's prompt
                    # span [0, prompt_len) is canonical chunk-prefill
                    # output (KV the decode steps wrote lives at positions
                    # >= prompt_len — inside the page but outside any
                    # possible hit, so it rides along inert).  covered()
                    # keeps the hot path free of extracts when the prefix
                    # is already cached.
                    self.prefix.insert(req.prompt, self.kv.extract(slot))
                self.kv.release(slot)

    def _bucket_len(self, plen: int) -> int:
        """Power-of-two length bucket for a prompt (clamped to the page)."""
        if not self._can_bucket:
            return plen
        b = max(self.sc.min_bucket, 1)
        while b < plen:
            b *= 2
        return min(b, self.sc.max_len)

    def _start(self, slot: int, req: Request) -> None:
        """Prefill a newly admitted request and seed its slot.

        Prompts are right-padded to a power-of-two bucket (one jit compile
        per *bucket* instead of per distinct prompt length); the padded
        tail is masked out of MoE routing (router token-validity mask) and
        its KV is causally invisible at the logits position and
        overwritten slot-by-slot as decode proceeds, so bucketing is
        bit-identical to exact-length prefill as long as prefill routing
        does not overflow at the exact length (padding only adds
        capacity; see docs/serving.md)."""
        plen = req.prompt_len
        blen = self._bucket_len(plen)
        padded = np.zeros((blen,), np.int32)
        padded[:plen] = req.prompt
        valid = np.zeros((1, blen), np.float32)
        valid[0, :plen] = 1.0
        self.prefill_lengths.add(blen)
        tokens = jnp.asarray(padded, jnp.int32)[None, :]
        tr = self.tracer
        with tr.span("serve.prefill", rid=req.rid, slot=slot, plen=plen,
                     tokens=blen):
            logits, page = self._prefill(self.params, {"tokens": tokens},
                                         self._blank_page,
                                         jnp.asarray(plen - 1, jnp.int32),
                                         jnp.asarray(valid))
            if self._trace_sync:
                logits = jax.block_until_ready(logits)
        if self.ctx.mesh is not None:
            # prefill_tp -> decode_std boundary: explicit reshard of the
            # page onto the decode plan before it joins the slot pool.
            with tr.span("serve.reshard", rid=req.rid, slot=slot):
                page = self.decode_ctx.reshard(page, self.kv.seq_defs)
            self._c["reshards"].inc()
        with tr.span("serve.kv_insert", slot=slot):
            self.kv.insert(slot, page, req.prompt_len)
        self._c["prefills"].inc()
        self._c["prefill_calls"].inc()
        self._c["prefill_tokens"].inc(plen)
        req.prefill_pos = plen
        req.first_token_step = self.step_count
        with tr.span("serve.sample", rows=1):
            tok = self._sample_rows(logits, [req])[0]
        self._append_token(req, tok, slot)

    # -- shared-prefix cache hooks ----------------------------------------
    def _prefix_probe(self, req: Request) -> int:
        """Scheduler hook: cached-prefix length a new request would resume
        from (admission charges only the uncached tail)."""
        with self.tracer.span("serve.prefix_probe", rid=req.rid):
            return self.prefix.probe(req.prompt)

    def _on_admit(self, slot: int, req: Request) -> None:
        """Scheduler hook, fired the moment a request claims a slot:
        alias the longest cached block-aligned prefix page into the slot
        (staged, exactly like a partial chunked-prefill page) and advance
        ``prefill_pos`` so chunk planning covers only the tail.  The trie
        entry stays pinned until the prefill completes."""
        hit, page, entry = self.prefix.lookup(req.prompt)
        if hit <= 0:
            return
        with self.tracer.span("serve.prefix_hit", rid=req.rid, slot=slot,
                              hit_tokens=hit):
            self._pins[req.rid] = entry
            req.prefill_pos = hit
            # Zero-copy alias: jax pages are immutable, so staging the
            # cached page is safe — the tail chunk's cache update
            # materializes the "copy" as fresh arrays.
            self.kv.append(slot, page, hit, last=False)
        self._c["prefix_hits"].inc()
        self._c["prefix_hit_tokens"].inc(hit)

    # -- chunked prefill ---------------------------------------------------
    def _chunk_fn(self, off: int):
        """Jitted prefill for one chunk offset (static start_pos).  One
        function object per offset; jit itself specializes per [G, C]
        batch shape, so grouped calls of different widths coexist."""
        fn = self._chunk_fns.get(off)
        if fn is None:
            fn = jax.jit(lambda p, b, c, li, v, _o=off: lm.lm_prefill(
                p, b, c, self.cfg, ctx=self.prefill_ctx, last_index=li,
                valid=v, start_pos=_o))
            self._chunk_fns[off] = fn
        return fn

    def _resume_page(self, slot: int):
        """Base page a slot's next chunk resumes from: the staged
        in-flight page, else blank.  Explicit ``is None`` — ``staged(...)
        or blank`` would ask the page pytree for truthiness, which
        raises on bare jax-array leaves and silently restarts the
        prefill for empty-container ones."""
        page = self.kv.staged(slot)
        return self._blank_page if page is None else page

    def _run_chunk_rounds(self, by_slot: dict) -> None:
        """Ingest this step's chunk work-items, batching across slots.

        The round/grouping plan comes from ``scheduler.chunk_rounds`` —
        the same function the replay simulator charges costs against, so
        the simulated call pattern is the real one by construction.
        Under a per-step budget most slots carry exactly one chunk, so a
        round typically batches the whole step's chunk work into one or
        two device calls (``_run_chunk_group``)."""
        for off, group in chunk_rounds(by_slot):
            self._run_chunk_group(off, group)

    def _run_chunk_group(self, off: int, group: list) -> None:
        """One multi-row prefill call for same-offset chunk work-items of
        ``len(group)`` different slots.  Rows are padded to a power-of-two
        batch (pad rows: blank page, all-zero validity — masked out of
        routing exactly like dead decode slots).  In-flight pages stay
        *staged* in the SlotKVCache between steps and fold into the pool
        only on the completing chunk — a mid-prefill slot never decodes,
        so per-chunk pool blends (and on-mesh reshards) would be pure
        hot-path overhead.  Completing rows sample their first token."""
        c = self._chunk
        g = len(group)
        gp = 1 << (g - 1).bit_length()          # power-of-two batch bucket
        tokens = np.zeros((gp, c), np.int32)
        valid = np.zeros((gp, c), np.float32)
        li = np.full((gp,), c - 1, np.int32)    # pad rows: clamped, unread
        pages = []
        for i, (slot, w) in enumerate(group):
            req = w.req
            tokens[i, :w.length] = req.prompt[w.start:w.start + w.length]
            valid[i, :w.length] = 1.0
            # Chunk-local index of the final prompt token (only read on a
            # row's last chunk; clamped elsewhere).
            li[i] = min(req.prompt_len - 1 - off, c - 1)
            pages.append(self._resume_page(slot))
        pages.extend([self._blank_page] * (gp - g))
        page_in = pages[0] if gp == 1 else self.kv.stack_pages(pages)
        self.chunk_offsets.add(off)
        tr = self.tracer
        with tr.span("serve.prefill_chunk", offset=off, G=g, Gp=gp, C=c,
                     tokens=gp * c):
            logits, page_out = self._chunk_fn(off)(
                self.params, {"tokens": jnp.asarray(tokens)}, page_in,
                jnp.asarray(li), jnp.asarray(valid))
            if self._trace_sync:
                logits = jax.block_until_ready(logits)
        self._c["prefill_calls"].inc()
        self._c["prefill_chunks"].inc(g)
        out_pages = ([page_out] if gp == 1
                     else self.kv.split_pages(page_out, g))
        rows: list[Request | None] = [None] * gp
        done_rows = []
        for i, (slot, w) in enumerate(group):
            req = w.req
            req.prefill_pos = w.start + w.length
            self._c["prefill_tokens"].inc(w.length)
            page = out_pages[i]
            done = not req.prefilling
            if done and self.ctx.mesh is not None:
                # staged pages stayed on the prefill plan; each finished
                # page reshards once, exactly like a whole-prompt page.
                with tr.span("serve.reshard", rid=req.rid, slot=slot):
                    page = self.decode_ctx.reshard(page, self.kv.seq_defs)
                self._c["reshards"].inc()
            with tr.span("serve.kv_insert", slot=slot):
                self.kv.append(slot, page, req.prefill_pos, last=done)
            if done:
                self._c["prefills"].inc()
                req.first_token_step = self.step_count
                if self.prefix is not None:
                    entry = self._pins.pop(req.rid, None)
                    if entry is not None:
                        # the tail chunks no longer read the cached base
                        # page — the entry is evictable again.
                        self.prefix.unpin(entry)
                rows[i] = req
                done_rows.append((i, slot, req))
        if done_rows:
            with tr.span("serve.sample", rows=len(done_rows)):
                toks = self._sample_rows(logits, rows)
            for i, slot, req in done_rows:
                self._append_token(req, toks[i], slot)

    def step(self) -> int:
        """One engine step: plan prefill work (admission + chunks under
        the per-step token budget), run it, then one fused decode over
        the fully-prefilled slots, sample, retire.  Returns the number of
        slots that were active in the decode."""
        tr = self.tracer
        with trace_lib.use(tr), tr.span("serve.step", step=self.step_count):
            return self._step_body(tr)

    def _step_body(self, tr) -> int:
        by_slot: dict[int, list] = {}
        with tr.span("serve.schedule", queued=len(self.queue)):
            work = self.sched.schedule_prefill(self.queue, self.step_count)
        for w in work:
            if (not self._prefix_on and w.start == 0
                    and w.length == w.req.prompt_len):
                self._start(w.slot, w.req)   # whole prompt: bucketed path
            else:
                # With the prefix cache on, even single-chunk prompts take
                # the chunk path: every cached page must be built from the
                # canonical same-offset chunk calls, or a later resumed
                # prefill would mix pages from differently-shaped jits and
                # forfeit bit-identity with the cache off.
                by_slot.setdefault(w.slot, []).append(w)
        self._run_chunk_rounds(by_slot)
        active = self.sched.decoding()
        if active:
            n = self.sc.n_slots
            toks = np.zeros((n,), np.int32)
            pos = np.zeros((n,), np.int32)
            occ = np.zeros((n,), np.float32)
            rows: list[Request | None] = [None] * n
            for slot, req in active:
                toks[slot] = req.tokens[-1]
                # position of the token being fed (the one just sampled).
                pos[slot] = req.prompt_len + len(req.tokens) - 1
                occ[slot] = 1.0
                rows[slot] = req
            # Slot-occupancy mask: dead slots are masked out of MoE
            # routing so they stop consuming expert capacity (ROADMAP).
            if not self.sc.mask_dead_slots:
                occ[:] = 1.0
            with tr.span("serve.decode", active=len(active), slots=n):
                logits, self.kv.cache, telem = self._decode(
                    self.params, jnp.asarray(toks), self.kv.cache,
                    jnp.asarray(pos), jnp.asarray(occ))
                if self._trace_sync:
                    logits = jax.block_until_ready(logits)
            with tr.span("serve.sample", rows=len(active)):
                nxt = self._sample_rows(logits, rows)
            self._record_telemetry(telem, len(active))
            self._c["decode_steps"].inc()
            self._c["slot_steps_active"].inc(len(active))
            self._c["slot_steps_total"].inc(n)
            for slot, req in active:
                # the fed token's KV was just written at pos[slot]
                self.kv.lengths[slot] = int(pos[slot]) + 1
                self._append_token(req, nxt[slot], slot)
        if tr.enabled:
            tr.counter("serve.queue", depth=len(self.queue))
            tr.counter("serve.slots", active=len(active))
        self.step_count += 1
        return len(active)

    def run(self, max_steps: int | None = None) -> None:
        """Drive the step loop until every submitted request completes;
        with tracing on, the trace file is (re)written at the end."""
        steps = 0
        while self.queue or self.sched.active():
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        if self.tracer.enabled and self.tracer.path:
            self.tracer.save()

    # -- telemetry --------------------------------------------------------
    def _record_telemetry(self, telem, n_active: int) -> None:
        if telem is None:
            return
        entry = {"step": self.step_count, "active": n_active}
        # Aggregate instruments cover the whole run in bounded memory;
        # the raw entry lands in the keep_last_n ring for inspection.
        # MoE FFN counters and MoA head-group counters are independent
        # families — a model may have either or both.
        if "expert_load" in telem:
            entry.update(expert_load=np.asarray(telem["expert_load"]),
                         overflow=np.asarray(telem["overflow"]),
                         n_moe=float(telem["n_moe"]))
            self._c["overflow_total"].inc(float(entry["overflow"].sum()))
            self._h_overflow.observe(float(entry["overflow"].sum()))
            for e, load in enumerate(entry["expert_load"].tolist()):
                self._c_expert_load.child(expert=e).inc(float(load))
        if "moa_load" in telem:
            entry.update(moa_load=np.asarray(telem["moa_load"]),
                         moa_overflow=np.asarray(telem["moa_overflow"]),
                         n_moa=float(telem["n_moa"]))
            self._c_moa_overflow.inc(float(entry["moa_overflow"].sum()))
            self._h_moa_overflow.observe(float(entry["moa_overflow"].sum()))
            for e, load in enumerate(entry["moa_load"].tolist()):
                self._c_moa_load.child(expert=e).inc(float(load))
        self._h_active.observe(n_active)
        self._telemetry.append(entry)

    @property
    def telemetry(self) -> list:
        """Recent raw per-step MoE telemetry entries (bounded ring of the
        last ``telemetry_keep_last_n`` decode steps, as a list)."""
        return list(self._telemetry)

    @property
    def stats(self) -> dict:
        """Legacy flat stats view over the typed metrics registry."""
        return self.metrics.stats()

    @property
    def slot_utilization(self) -> float:
        total = self._c["slot_steps_total"].value
        return self._c["slot_steps_active"].value / total if total else 0.0

    # -- static-batch-compatible front door -------------------------------
    def generate(self, prompts: np.ndarray, max_new_tokens: int
                 ) -> np.ndarray:
        """prompts: [B, S0] int32 (same length). Returns [B, new] tokens.

        Convenience wrapper over submit/run on a freshly reset engine: all
        B requests arrive at step 0 and rows finishing early (EOS) are
        padded with ``eos_id``."""
        prompts = np.asarray(prompts)
        if prompts.shape[0] > self.sc.n_slots:
            raise ValueError(
                f"{prompts.shape[0]} prompts > n_slots={self.sc.n_slots}; "
                f"submit() + run() handles oversubscription")
        self.reset()
        reqs = [self.submit(p, max_new_tokens) for p in prompts]
        self.run()
        width = max(len(r.tokens) for r in reqs)
        pad = self.sc.eos_id if self.sc.eos_id >= 0 else 0
        out = np.full((len(reqs), width), pad, np.int32)
        for i, r in enumerate(reqs):
            out[i, :len(r.tokens)] = r.tokens
        return out
