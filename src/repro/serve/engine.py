"""Batched serving engine: prefill + decode with a fixed slot pool.

Continuous-batching-lite: the engine owns ``batch_size`` sequence slots.
``generate`` prefills a batch of prompts (right-aligned padding-free — all
prompts padded to the same length with position masking via the causal
mask) and then runs jitted single-token decode steps, sampling with
temperature / greedy.  Finished sequences (EOS or length) keep decoding
into dead slots until the batch drains — the standard static-batch serving
pattern; slot recycling across batches is the Trainer-side loop's job.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import param as pm
from repro.configs.base import ModelConfig
from repro.models import lm, transformer
from repro.sharding import context as ctx_lib


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0     # 0 => greedy
    eos_id: int = -1             # -1 => never stop early
    seed: int = 0


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, sc: ServeConfig,
                 ctx: ctx_lib.MeshContext | None = None):
        self.params = params
        self.cfg = cfg
        self.sc = sc
        self.ctx = ctx or ctx_lib.MeshContext.null(
            plan="decode_std")
        self._prefill = jax.jit(
            lambda p, b, c: lm.lm_prefill(
                p, b, c, cfg, ctx=self.ctx.with_plan("prefill_tp")
                if self.ctx.mesh is not None else self.ctx))
        self._decode = jax.jit(
            lambda p, t, c, i: lm.lm_decode(p, t, c, i, cfg, ctx=self.ctx))

    def _sample(self, logits: jax.Array, rng) -> jax.Array:
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            rng, logits / self.sc.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int
                 ) -> np.ndarray:
        """prompts: [B, S0] int32 (same length). Returns [B, new] tokens."""
        b, s0 = prompts.shape
        cache = pm.materialize(
            transformer.cache_defs(self.cfg, b, self.sc.max_len),
            jax.random.PRNGKey(0))
        logits, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(prompts, jnp.int32)}, cache)
        rng = jax.random.PRNGKey(self.sc.seed)
        out = []
        tok = self._sample(logits, rng)
        done = np.zeros((b,), bool)
        for i in range(max_new_tokens):
            out.append(np.asarray(tok))
            if self.sc.eos_id >= 0:
                done |= np.asarray(tok) == self.sc.eos_id
                if done.all():
                    break
            if i == max_new_tokens - 1:
                break
            rng, sub = jax.random.split(rng)
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.int32(s0 + i))
            tok = self._sample(logits, sub)
        return np.stack(out, axis=1)
