"""SlotKVCache: the per-slot decode-cache pool behind continuous batching.

``transformer.cache_defs(cfg, n_slots, max_len)`` declares one cache page
per slot (KV ring/full buffers for attention layers, conv/ssm state for
mamba layers), stacked on the batch axis.  This module owns that pool and
the three slot operations the scheduler needs:

* ``insert(slot, seq_cache, length)`` — blend a freshly prefilled batch-1
  cache (already resharded onto the decode plan — see
  ``MeshContext.reshard``) into one slot.  The write is a one-hot
  ``where`` over the batch axis rather than a ``dynamic_update_slice``:
  a DUS at a traced offset on a sharded axis makes GSPMD all-gather the
  pool every insert, the blend stays shard-local.
* ``evict(slot)`` — zero a slot's pages (``release`` is the cheap logical
  variant: insert fully overwrites a page, so retirement only needs the
  length bookkeeping reset).
* ``compact(perm)`` — permute slots (gather over the batch axis), e.g. to
  pack active slots into a prefix before shrinking the pool.

The batch axis is located *per leaf* from the ParamDef axes — stacked
period leaves carry a leading "layers" axis, tail leaves do not.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import param as pm
from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.sharding import context as ctx_lib


# The slot ops are module-level jits over flattened leaves with the batch
# axes as a static tuple: every SlotKVCache of the same shape family
# (including the pools a ServeEngine.reset() rebuilds) shares one
# compilation instead of retracing per instance.

@functools.partial(jax.jit, static_argnames=("axes",))
def _insert_op(cache_leaves, seq_leaves, slot, *, axes):
    def one(ax, a, b):
        hit = jnp.arange(a.shape[ax]) == slot
        shape = [1] * a.ndim
        shape[ax] = a.shape[ax]
        return jnp.where(hit.reshape(shape), b.astype(a.dtype), a)
    return tuple(one(ax, a, b)
                 for ax, a, b in zip(axes, cache_leaves, seq_leaves))


@functools.partial(jax.jit, static_argnames=("axes",))
def _evict_op(cache_leaves, slot, *, axes):
    def one(ax, a):
        hit = jnp.arange(a.shape[ax]) == slot
        shape = [1] * a.ndim
        shape[ax] = a.shape[ax]
        return jnp.where(hit.reshape(shape), jnp.zeros((), a.dtype), a)
    return tuple(one(ax, a) for ax, a in zip(axes, cache_leaves))


@functools.partial(jax.jit, static_argnames=("axes",))
def _compact_op(cache_leaves, perm, *, axes):
    return tuple(jnp.take(a, perm, axis=ax)
                 for ax, a in zip(axes, cache_leaves))


class SlotKVCache:
    """Fixed pool of per-sequence cache pages with slot-indexed updates."""

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 ctx: ctx_lib.MeshContext | None = None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.ctx = ctx
        self.defs = transformer.cache_defs(cfg, n_slots, max_len)
        # Per-sequence (batch-1) page layout: what prefill produces and
        # what insert consumes.
        self.seq_defs = transformer.cache_defs(cfg, 1, max_len)
        self._batch_axes = jax.tree_util.tree_map(
            lambda d: d.axes.index("batch"), self.defs, is_leaf=pm.is_def)
        self._axes_flat = tuple(
            jax.tree_util.tree_leaves(self._batch_axes))
        self._treedef = jax.tree_util.tree_structure(self._batch_axes)
        cache = pm.materialize(self.defs, jax.random.PRNGKey(0))
        if ctx is not None and ctx.mesh is not None:
            cache = ctx.reshard(cache, self.defs)
        self.cache = cache
        self.lengths = np.zeros((n_slots,), np.int64)   # tokens cached/slot
        # In-flight partial pages (chunked prefill): staged per slot and
        # folded into the pooled cache only when the prompt completes —
        # one full-pool blend per prompt instead of one per chunk group.
        self._staged: dict[int, object] = {}

    def _leaves(self, tree) -> tuple:
        return tuple(jax.tree_util.tree_leaves(tree))

    def _unflatten(self, leaves):
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    # -- public API -------------------------------------------------------
    def insert(self, slot: int, seq_cache, length: int) -> None:
        """Write a prefilled batch-1 cache into ``slot`` (overwrites the
        whole page, so stale data from the previous tenant cannot leak)."""
        self.cache = self._unflatten(_insert_op(
            self._leaves(self.cache), self._leaves(seq_cache),
            jnp.int32(slot), axes=self._axes_flat))
        self.lengths[slot] = length
        self._staged.pop(slot, None)

    def append(self, slot: int, seq_cache, length: int, *,
               last: bool = True) -> None:
        """Append a *partial-prompt* batch-1 page for ``slot``.

        Chunked prefill delivers the page after every step's chunk group
        (each page carries the whole prompt prefix [0, length), a
        superset of the previous one).  Intermediate pages are *staged* —
        the next chunk resumes from :meth:`staged`, and a slot mid-
        prefill never decodes, so blending them into the pool would be
        pure overhead on the serving hot path.  ``last=True`` (the
        completing chunk group) folds the finished page into the pool:
        one full-pool blend per prompt.  ``length`` must grow
        monotonically while a prompt is in flight.
        """
        assert length >= self.lengths[slot], \
            f"append shrank slot {slot}: {length} < {self.lengths[slot]}"
        if last:
            self.insert(slot, seq_cache, length)
        else:
            self._staged[slot] = seq_cache
            self.lengths[slot] = length

    def staged(self, slot: int):
        """The slot's in-flight partial page (None when no chunked
        prefill is in flight — chunk 0 starts from a blank page)."""
        return self._staged.get(slot)

    def release(self, slot: int) -> None:
        """Logical free: the next insert overwrites the page in full."""
        self.lengths[slot] = 0
        self._staged.pop(slot, None)

    def evict(self, slot: int) -> None:
        """Zero a slot's pages (release + hygiene, e.g. for checkpoints)."""
        self.cache = self._unflatten(_evict_op(
            self._leaves(self.cache), jnp.int32(slot),
            axes=self._axes_flat))
        self.lengths[slot] = 0
        self._staged.pop(slot, None)

    def compact(self, perm) -> None:
        """Permute slots: page i of the new pool is page perm[i] of the
        old one (gather over the batch axis, shard-local under GSPMD)."""
        perm = np.asarray(perm)
        assert sorted(perm.tolist()) == list(range(self.n_slots)), perm
        self.cache = self._unflatten(_compact_op(
            self._leaves(self.cache), jnp.asarray(perm, jnp.int32),
            axes=self._axes_flat))
        self.lengths = self.lengths[perm]
        self._staged = {i: self._staged[int(p)] for i, p in enumerate(perm)
                        if int(p) in self._staged}
