"""SlotKVCache: the per-slot decode-cache pool behind continuous batching.

``transformer.cache_defs(cfg, n_slots, max_len)`` declares one cache page
per slot (KV ring/full buffers for attention layers, conv/ssm state for
mamba layers), stacked on the batch axis.  This module owns that pool and
the slot operations the scheduler needs:

* ``insert(slot, seq_cache, length)`` — blend a freshly prefilled batch-1
  cache (already resharded onto the decode plan — see
  ``MeshContext.reshard``) into one slot.  The write is a one-hot
  ``where`` over the batch axis rather than a ``dynamic_update_slice``:
  a DUS at a traced offset on a sharded axis makes GSPMD all-gather the
  pool every insert, the blend stays shard-local.
* ``evict(slot)`` — zero a slot's pages (``release`` is the cheap logical
  variant: insert fully overwrites a page, so retirement only needs the
  length bookkeeping reset).
* ``compact(perm)`` — permute slots (gather over the batch axis), e.g. to
  pack active slots into a prefix before shrinking the pool.
* ``extract(slot)`` — gather one slot back out as a batch-1 page (the
  retirement path of the shared-prefix cache re-inserts finished pages).
* ``stack_pages`` / ``split_pages`` — concatenate G batch-1 pages into one
  [G, ...] page and slice it back apart: the cross-slot batched prefill
  runs one multi-row chunk call over same-offset work-items from
  different slots.

The batch axis is located *per leaf* from the ParamDef axes — stacked
period leaves carry a leading "layers" axis, tail leaves do not.

This module also owns :class:`PrefixCache`, the refcounted radix
(prefix-trie) cache of finished pages behind shared-prefix KV reuse
(docs/serving.md §Shared-prefix KV cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import param as pm
from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.sharding import context as ctx_lib


# The slot ops are module-level jits over flattened leaves with the batch
# axes as a static tuple: every SlotKVCache of the same shape family
# (including the pools a ServeEngine.reset() rebuilds) shares one
# compilation instead of retracing per instance.

@functools.partial(jax.jit, static_argnames=("axes",))
def _insert_op(cache_leaves, seq_leaves, slot, *, axes):
    def one(ax, a, b):
        hit = jnp.arange(a.shape[ax]) == slot
        shape = [1] * a.ndim
        shape[ax] = a.shape[ax]
        return jnp.where(hit.reshape(shape), b.astype(a.dtype), a)
    return tuple(one(ax, a, b)
                 for ax, a, b in zip(axes, cache_leaves, seq_leaves))


@functools.partial(jax.jit, static_argnames=("axes",))
def _evict_op(cache_leaves, slot, *, axes):
    def one(ax, a):
        hit = jnp.arange(a.shape[ax]) == slot
        shape = [1] * a.ndim
        shape[ax] = a.shape[ax]
        return jnp.where(hit.reshape(shape), jnp.zeros((), a.dtype), a)
    return tuple(one(ax, a) for ax, a in zip(axes, cache_leaves))


@functools.partial(jax.jit, static_argnames=("axes",))
def _compact_op(cache_leaves, perm, *, axes):
    return tuple(jnp.take(a, perm, axis=ax)
                 for ax, a in zip(axes, cache_leaves))


@functools.partial(jax.jit, static_argnames=("axes",))
def _extract_op(cache_leaves, slot, *, axes):
    return tuple(jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=ax)
                 for ax, a in zip(axes, cache_leaves))


@functools.partial(jax.jit, static_argnames=("axes",))
def _stack_op(page_leaves, *, axes):
    # page_leaves: per-page leaf tuples; concatenate each leaf position
    # over the batch axis (G batch-1 pages -> one batch-G page).
    return tuple(jnp.concatenate([p[i] for p in page_leaves], axis=ax)
                 for i, ax in enumerate(axes))


@functools.partial(jax.jit, static_argnames=("axes", "g"))
def _split_op(batched_leaves, *, axes, g):
    # Inverse of _stack_op: G per-page leaf tuples from one batch-G page
    # (static indices — a plain slice, no gather).
    return tuple(tuple(jax.lax.slice_in_dim(a, i, i + 1, axis=ax)
                       for ax, a in zip(axes, batched_leaves))
                 for i in range(g))


class SlotKVCache:
    """Fixed pool of per-sequence cache pages with slot-indexed updates."""

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 ctx: ctx_lib.MeshContext | None = None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.ctx = ctx
        self.defs = transformer.cache_defs(cfg, n_slots, max_len)
        # Per-sequence (batch-1) page layout: what prefill produces and
        # what insert consumes.
        self.seq_defs = transformer.cache_defs(cfg, 1, max_len)
        self._batch_axes = jax.tree_util.tree_map(
            lambda d: d.axes.index("batch"), self.defs, is_leaf=pm.is_def)
        self._axes_flat = tuple(
            jax.tree_util.tree_leaves(self._batch_axes))
        self._treedef = jax.tree_util.tree_structure(self._batch_axes)
        cache = pm.materialize(self.defs, jax.random.PRNGKey(0))
        if ctx is not None and ctx.mesh is not None:
            cache = ctx.reshard(cache, self.defs)
        self.cache = cache
        self.lengths = np.zeros((n_slots,), np.int64)   # tokens cached/slot
        # In-flight partial pages (chunked prefill): staged per slot and
        # folded into the pooled cache only when the prompt completes —
        # one full-pool blend per prompt instead of one per chunk group.
        self._staged: dict[int, object] = {}

    def _leaves(self, tree) -> tuple:
        return tuple(jax.tree_util.tree_leaves(tree))

    def _unflatten(self, leaves):
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    # -- public API -------------------------------------------------------
    def insert(self, slot: int, seq_cache, length: int) -> None:
        """Write a prefilled batch-1 cache into ``slot`` (overwrites the
        whole page, so stale data from the previous tenant cannot leak)."""
        self.cache = self._unflatten(_insert_op(
            self._leaves(self.cache), self._leaves(seq_cache),
            jnp.int32(slot), axes=self._axes_flat))
        self.lengths[slot] = length
        self._staged.pop(slot, None)

    def append(self, slot: int, seq_cache, length: int, *,
               last: bool = True) -> None:
        """Append a *partial-prompt* batch-1 page for ``slot``.

        Chunked prefill delivers the page after every step's chunk group
        (each page carries the whole prompt prefix [0, length), a
        superset of the previous one).  Intermediate pages are *staged* —
        the next chunk resumes from :meth:`staged`, and a slot mid-
        prefill never decodes, so blending them into the pool would be
        pure overhead on the serving hot path.  ``last=True`` (the
        completing chunk group) folds the finished page into the pool:
        one full-pool blend per prompt.  ``length`` must grow
        monotonically while a prompt is in flight.

        A shrinking ``length`` means the caller is replaying an earlier
        chunk over a later page — KV corruption, not a recoverable state
        — so the guard is a real exception (an ``assert`` would vanish
        under ``python -O`` and turn it into silent wrong output).
        """
        if length < self.lengths[slot]:
            raise ValueError(
                f"append shrank slot {slot}: {length} < "
                f"{self.lengths[slot]} (chunk replayed over a later page)")
        if last:
            self.insert(slot, seq_cache, length)
        else:
            self._staged[slot] = seq_cache
            self.lengths[slot] = length

    def staged(self, slot: int):
        """The slot's in-flight partial page (None when no chunked
        prefill is in flight — chunk 0 starts from a blank page)."""
        return self._staged.get(slot)

    def release(self, slot: int) -> None:
        """Logical free: the next insert overwrites the page in full."""
        self.lengths[slot] = 0
        self._staged.pop(slot, None)

    def evict(self, slot: int) -> None:
        """Zero a slot's pages (release + hygiene, e.g. for checkpoints)."""
        self.cache = self._unflatten(_evict_op(
            self._leaves(self.cache), jnp.int32(slot),
            axes=self._axes_flat))
        self.lengths[slot] = 0
        self._staged.pop(slot, None)

    def compact(self, perm) -> None:
        """Permute slots: page i of the new pool is page perm[i] of the
        old one (gather over the batch axis, shard-local under GSPMD)."""
        perm = np.asarray(perm)
        if sorted(perm.tolist()) != list(range(self.n_slots)):
            # Not a permutation: the gather would duplicate one page and
            # drop another — silent KV corruption under `python -O` if
            # this were an assert.
            raise ValueError(f"compact perm {perm} is not a permutation "
                             f"of range({self.n_slots})")
        self.cache = self._unflatten(_compact_op(
            self._leaves(self.cache), jnp.asarray(perm, jnp.int32),
            axes=self._axes_flat))
        self.lengths = self.lengths[perm]
        self._staged = {i: self._staged[int(p)] for i, p in enumerate(perm)
                        if int(p) in self._staged}

    def extract(self, slot: int):
        """Gather one slot back out of the pool as a batch-1 page (the
        ``seq_defs`` layout insert consumes) — the retirement path of the
        shared-prefix cache re-inserts a finished slot's page into the
        prefix trie.  jax arrays are immutable, so the extracted page
        aliases the pool's buffers safely: later inserts into the slot
        build new pool arrays and never mutate the extracted view."""
        return self._unflatten(_extract_op(
            self._leaves(self.cache), jnp.int32(slot),
            axes=self._axes_flat))

    def stack_pages(self, pages: list):
        """Concatenate G batch-1 pages into one batch-G page — the input
        of a cross-slot batched chunk-prefill call (each row resumes a
        different slot's in-flight prefix)."""
        return self._unflatten(_stack_op(
            tuple(self._leaves(p) for p in pages), axes=self._axes_flat))

    def split_pages(self, batched, g: int) -> list:
        """Slice a batch-G page back into G batch-1 pages (rows of a
        batched chunk call scatter into their own slots).  Inverse of
        :meth:`stack_pages`; rows past ``g`` (power-of-two padding) are
        dropped."""
        return [self._unflatten(leaves) for leaves in _split_op(
            self._leaves(batched), axes=self._axes_flat, g=g)]


# ---------------------------------------------------------------------------
# Shared-prefix radix cache (docs/serving.md §Shared-prefix KV cache)
# ---------------------------------------------------------------------------

class _TrieNode:
    """One prompt block (``block`` tokens) on a radix path.  ``entry`` is
    the cached page covering the prompt prefix [0, depth*block) — multiple
    nodes on one path may share the same entry (a deep page covers every
    shallower prefix on its own path)."""

    __slots__ = ("key", "parent", "children", "entry", "depth")

    def __init__(self, key: bytes | None, parent: "_TrieNode | None"):
        self.key = key
        self.parent = parent
        self.children: dict[bytes, _TrieNode] = {}
        self.entry: _PageEntry | None = None
        self.depth = 0 if parent is None else parent.depth + 1


class _PageEntry:
    """One cached page and its bookkeeping: the trie nodes that alias it,
    the pin refcount (in-flight prefills reading the page), and the LRU
    tick.  Pinned entries are never evicted."""

    __slots__ = ("page", "nodes", "pins", "tick")

    def __init__(self, page, nodes: list, tick: int):
        self.page = page
        self.nodes = nodes
        self.pins = 0
        self.tick = tick


class PrefixCache:
    """Refcounted, block-aligned radix cache of finished KV pages.

    The trie is keyed by ``block``-token prompt blocks (the serving engine
    passes its chunk size, itself a multiple of ``kv_block``, so hits land
    on the chunk grid and a resumed prefill replays the *same* jitted
    chunk calls a cold prefill would — the bit-identity argument in
    docs/serving.md).  ``lookup`` pins the longest cached block-aligned
    prefix strictly shorter than the prompt — the tail always keeps >= 1
    token, because only a freshly computed final chunk yields the logits
    that sample the first token.

    Aliasing vs copying: pages are immutable jax pytrees, so a hit hands
    the caller the cached page itself (zero-copy alias); the "copy"
    materializes only when the tail chunk's cache update builds new
    arrays.  Pins therefore do not protect memory (Python refcounts do) —
    they are the accounting that makes eviction observable and testable:
    an entry is evictable iff no admitted request is still prefilling on
    top of it.

    Eviction: LRU over entries under ``max_bytes`` (``<= 0`` = unlimited),
    ``page_bytes`` charged per stored page.  Freeing an entry detaches it
    from every aliasing node and prunes childless, entryless nodes so the
    trie cannot grow without bound.

    Pages are opaque objects — the class never touches jax, so the
    property suite drives it host-only with token arrays and sentinel
    pages.
    """

    def __init__(self, block: int, page_bytes: int, max_bytes: int = 0):
        if block <= 0:
            raise ValueError(f"block must be positive, got {block}")
        self.block = block
        self.page_bytes = int(page_bytes)
        self.max_bytes = int(max_bytes)
        self.root = _TrieNode(None, None)
        self._entries: list[_PageEntry] = []
        self._tick = 0
        self.stats = {"hits": 0, "misses": 0, "hit_tokens": 0,
                      "inserts": 0, "evictions": 0}

    # -- bookkeeping ------------------------------------------------------
    @property
    def n_pages(self) -> int:
        return len(self._entries)

    @property
    def bytes(self) -> int:
        return len(self._entries) * self.page_bytes

    def _keys(self, prompt, n_blocks: int) -> list[bytes]:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        b = self.block
        return [prompt[i * b:(i + 1) * b].tobytes()
                for i in range(n_blocks)]

    def _walk(self, prompt, n_blocks: int):
        """Deepest entry on the prompt's path within ``n_blocks`` blocks:
        ``(entry, depth_in_blocks)`` — ``(None, 0)`` when nothing on the
        path is cached."""
        node, best, depth = self.root, None, 0
        for key in self._keys(prompt, n_blocks):
            node = node.children.get(key)
            if node is None:
                break
            if node.entry is not None:
                best, depth = node.entry, node.depth
        return best, depth

    # -- read path --------------------------------------------------------
    def probe(self, prompt) -> int:
        """Hit length (tokens) a :meth:`lookup` would return, without
        pinning — the scheduler's prefix-aware admission charges only the
        uncached tail against the prefill budget."""
        plen = int(np.asarray(prompt).shape[-1])
        _, depth = self._walk(prompt, (plen - 1) // self.block)
        return depth * self.block

    def lookup(self, prompt):
        """Longest cached block-aligned strict-prefix of ``prompt``.

        Returns ``(hit_tokens, page, entry)`` — ``(0, None, None)`` on a
        miss.  The entry is *pinned* (refcount +1); the caller must
        :meth:`unpin` it once its prefill no longer reads the page.  The
        hit is capped at ``((plen - 1) // block) * block`` so the tail
        keeps at least one token to recompute.
        """
        plen = int(np.asarray(prompt).shape[-1])
        best, depth = self._walk(prompt, (plen - 1) // self.block)
        if best is None:
            self.stats["misses"] += 1
            return 0, None, None
        self._tick += 1
        best.tick = self._tick
        best.pins += 1
        hit = depth * self.block
        self.stats["hits"] += 1
        self.stats["hit_tokens"] += hit
        return hit, best.page, best

    def unpin(self, entry: _PageEntry) -> None:
        if entry.pins <= 0:
            raise ValueError("unpin would drive a refcount negative "
                             "(double unpin of a prefix-cache entry)")
        entry.pins -= 1

    # -- write path -------------------------------------------------------
    def covered(self, prompt) -> bool:
        """True when every block of the prompt's aligned prefix already
        has a cached entry — the retirement hot path probes this before
        paying for a device->trie page extract."""
        plen = int(np.asarray(prompt).shape[-1])
        n_blocks = plen // self.block
        if n_blocks == 0:
            return True
        node = self.root
        for key in self._keys(prompt, n_blocks):
            node = node.children.get(key)
            if node is None or node.entry is None:
                return False
        return True

    def insert(self, prompt, page) -> int:
        """Cache ``page`` (KV for prompt positions [0, plen) — decode
        positions past the prompt ride along inert, a hit never exposes
        them) under the prompt's block-aligned prefix.  Only nodes without
        an entry adopt the page; fully covered prefixes store nothing
        (returns 0) so duplicate retirements are free.  Returns the
        number of newly covered blocks."""
        plen = int(np.asarray(prompt).shape[-1])
        n_blocks = plen // self.block
        if n_blocks == 0:
            return 0
        node, missing = self.root, []
        self._tick += 1
        for key in self._keys(prompt, n_blocks):
            child = node.children.get(key)
            if child is None:
                child = _TrieNode(key, node)
                node.children[key] = child
            if child.entry is None:
                missing.append(child)
            else:
                child.entry.tick = self._tick   # touch: path is hot
            node = child
        if not missing:
            return 0
        entry = _PageEntry(page, missing, self._tick)
        for n in missing:
            n.entry = entry
        self._entries.append(entry)
        self.stats["inserts"] += 1
        self._evict_to_budget()
        return len(missing)

    # -- eviction ---------------------------------------------------------
    def _evict_to_budget(self) -> None:
        if self.max_bytes <= 0:
            return
        while self.bytes > self.max_bytes:
            victims = [e for e in self._entries if e.pins == 0]
            if not victims:
                return      # everything pinned: overshoot, never corrupt
            self._free(min(victims, key=lambda e: e.tick))
            self.stats["evictions"] += 1

    def _free(self, entry: _PageEntry) -> None:
        self._entries.remove(entry)
        for node in entry.nodes:
            node.entry = None
            self._prune(node)
        entry.nodes = []
        entry.page = None

    def _prune(self, node: _TrieNode) -> None:
        """Drop childless, entryless nodes bottom-up so evicted paths do
        not leak trie nodes."""
        while (node is not None and node.parent is not None
               and not node.children and node.entry is None):
            parent = node.parent
            del parent.children[node.key]
            node = parent
