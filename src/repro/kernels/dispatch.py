"""Fused dispatch/combine scatter kernels for the capacity-buffer hot path.

``core/dispatch.py`` builds the [E, C, d] expert buffers either with an XLA
scatter (``sort``) or GShard one-hot einsums (``einsum``, O(T·E·C) traffic).
The TPU-native shape is a single kernel pass: the (expert, position) plan
arrays ride in as *scalar-prefetch* operands (SMEM, available before the
body runs — exactly what `PrefetchScalarGridSpec` exists for), the grid
walks blocks of the T·k assignment list, and each step copies token rows
into their slots with dynamic VMEM indexing.  The weighted combine fuses
the gather and the ``sum_k w_k * E_k(x)`` reduction (Eq. 2) in one pass,
accumulating at f32 — the [T, k, d] gathered intermediate of the jnp path
never materializes.

The destination buffer stays VMEM-resident across the whole grid (constant
index map — a revolving output block).  VMEM budget: the full [E_local, C,
d] buffer, e.g. 8 experts x 512 slots x 512 dims at f32 = 8 MiB, under the
~16 MiB budget for every assigned shape; larger buffers need an E-blocked
variant (future work, noted in docs/kernels.md).

Dropped assignments (position >= capacity, including the zero-weight
padding the plan assigns position==capacity) write nothing / combine at
weight 0 — identical semantics to ``core/dispatch.py``.

Both directions carry ``jax.custom_vjp`` so the Pallas path trains:

* dispatch is a (duplicating) copy, so its cotangent is the *unit-weight*
  combine of the output cotangent — the same fused kernel;
* combine's buffer cotangent is the dispatch scatter of ``w_k * dy[t]``
  (the kernel takes an optional per-assignment scale for exactly this),
  and its weight cotangent is the per-assignment dot <dy[t], buf[e, p]>.

On this CPU build host kernels run in interpret mode; ``interpret=False``
is the TPU path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.gmm import round_up as _round_up

# VMEM budget for the revolving [E, C, d] output (dispatch) / input
# (combine) buffer that stays resident across the whole grid, plus the
# token block.  Shapes past the limit need the E-blocked variant (future
# work, docs/kernels.md); until then the guard fails loudly — or, via the
# backend registry, falls back to the ref scatter — instead of silently
# OOMing the core.
DEFAULT_VMEM_LIMIT = 16 * 1024 * 1024


class DispatchVMEMError(RuntimeError):
    """Fused dispatch/combine buffer exceeds the configured VMEM budget."""


def vmem_bytes(n_experts: int, capacity: int, d: int, dtype,
               n_tokens: int = 0) -> int:
    """Estimated resident VMEM for one fused dispatch/combine call: the
    [E, C, d] buffer (constant index map — never rotated out) plus the
    [T, d] token block."""
    item = jnp.dtype(dtype).itemsize
    return int((n_experts * capacity * d + n_tokens * d) * item)


def check_vmem(n_experts: int, capacity: int, d: int, dtype, *,
               n_tokens: int = 0, limit: int | None = None) -> int:
    """Raise DispatchVMEMError when the estimate exceeds ``limit``
    (None -> DEFAULT_VMEM_LIMIT).  Returns the estimate."""
    limit = DEFAULT_VMEM_LIMIT if limit is None else limit
    need = vmem_bytes(n_experts, capacity, d, dtype, n_tokens)
    if need > limit:
        raise DispatchVMEMError(
            f"fused dispatch/combine buffer [E={n_experts}, C={capacity}, "
            f"d={d}] ({jnp.dtype(dtype).name}) needs ~{need} B VMEM "
            f"> limit {limit} B; shrink capacity/shard the experts, raise "
            f"the limit, or use the ref backend (E-blocked kernel is "
            f"future work)")
    return need


# ---------------------------------------------------------------------------
# dispatch: [T, d] -> [E, C, d] scatter (optionally scaled per assignment)
# ---------------------------------------------------------------------------

def _dispatch_kernel(eidx_ref, pos_ref, scale_ref, x_ref, o_ref, *,
                     k: int, capacity: int, block_a: int):
    @pl.when(pl.program_id(0) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    base = pl.program_id(0) * block_a

    def body(i, carry):
        a = base + i
        e = eidx_ref[a]
        p = pos_ref[a]
        kept = p < capacity                     # padding carries p==capacity
        pc = jnp.where(kept, p, 0)
        row = x_ref[a // k] * scale_ref[a]
        cur = o_ref[e, pc]
        o_ref[e, pc] = jnp.where(kept, row.astype(o_ref.dtype), cur)
        return carry

    jax.lax.fori_loop(0, block_a, body, 0)


def _dispatch_raw(x, eidx, pos, scale, n_experts, capacity, block_a,
                  interpret):
    t, d = x.shape
    k = eidx.shape[1]
    n = t * k
    block_a = min(block_a, n)
    npad = _round_up(n, block_a)
    ef = jnp.zeros((npad,), jnp.int32).at[:n].set(eidx.reshape(-1))
    # Padded assignments get position == capacity => dropped in-kernel.
    pf = jnp.full((npad,), capacity, jnp.int32).at[:n].set(pos.reshape(-1))
    sf = jnp.zeros((npad,), jnp.float32).at[:n].set(scale.reshape(-1))
    kernel = functools.partial(_dispatch_kernel, k=k, capacity=capacity,
                               block_a=block_a)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(npad // block_a,),
            in_specs=[pl.BlockSpec((t, d), lambda i, *_: (0, 0))],
            out_specs=pl.BlockSpec((n_experts, capacity, d),
                                   lambda i, *_: (0, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_experts, capacity, d), x.dtype),
        interpret=interpret,
    )(ef, pf, sf, x)


# ---------------------------------------------------------------------------
# combine: [E, C, d] -> [T, d] weighted gather-reduce
# ---------------------------------------------------------------------------

def _combine_kernel(eidx_ref, pos_ref, w_ref, buf_ref, o_ref, *,
                    k: int, capacity: int, block_t: int):
    base = pl.program_id(0) * block_t
    d = o_ref.shape[-1]

    def body(i, carry):
        t = base + i
        acc = jnp.zeros((d,), jnp.float32)
        for j in range(k):                      # k <= 8: static unroll
            a = t * k + j
            e = eidx_ref[a]
            p = pos_ref[a]
            pc = jnp.where(p < capacity, p, 0)
            w = jnp.where(p < capacity, w_ref[a], 0.0)
            acc = acc + w * buf_ref[e, pc].astype(jnp.float32)
        o_ref[i] = acc.astype(o_ref.dtype)
        return carry

    jax.lax.fori_loop(0, block_t, body, 0)


def _combine_raw(buf, w, eidx, pos, out_dtype, block_t, interpret):
    n_experts, capacity, d = buf.shape
    t, k = eidx.shape
    n = t * k
    block_t = min(block_t, t)
    tpad = _round_up(t, block_t)
    npad = tpad * k
    ef = jnp.zeros((npad,), jnp.int32).at[:n].set(eidx.reshape(-1))
    pf = jnp.full((npad,), capacity, jnp.int32).at[:n].set(pos.reshape(-1))
    wf = jnp.zeros((npad,), jnp.float32).at[:n].set(
        w.astype(jnp.float32).reshape(-1))
    kernel = functools.partial(_combine_kernel, k=k, capacity=capacity,
                               block_t=block_t)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(tpad // block_t,),
            in_specs=[pl.BlockSpec((n_experts, capacity, d),
                                   lambda i, *_: (0, 0, 0))],
            out_specs=pl.BlockSpec((block_t, d), lambda i, *_: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((tpad, d), out_dtype),
        interpret=interpret,
    )(ef, pf, wf, buf)
    return out[:t] if tpad != t else out


# ---------------------------------------------------------------------------
# differentiable public ops
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _dispatch(x, eidx, pos, n_experts, capacity, block_a, interpret):
    ones = jnp.ones((x.shape[0], eidx.shape[1]), jnp.float32)
    return _dispatch_raw(x, eidx, pos, ones, n_experts, capacity, block_a,
                         interpret)


def _dispatch_fwd(x, eidx, pos, n_experts, capacity, block_a, interpret):
    return (_dispatch(x, eidx, pos, n_experts, capacity, block_a, interpret),
            (eidx, pos))


def _dispatch_bwd(n_experts, capacity, block_a, interpret, res, g):
    eidx, pos = res
    # The scatter duplicates x[t] into its kept slots, so dx is the
    # unit-weight combine of the cotangent buffer (same fused kernel).
    unit = jnp.ones(eidx.shape, jnp.float32)
    dx = _combine_raw(g, unit, eidx, pos, g.dtype, 128, interpret)
    return dx, None, None


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _combine(buf, w, eidx, pos, out_dtype, block_t, interpret):
    return _combine_raw(buf, w, eidx, pos, out_dtype, block_t, interpret)


def _combine_fwd(buf, w, eidx, pos, out_dtype, block_t, interpret):
    return (_combine_raw(buf, w, eidx, pos, out_dtype, block_t, interpret),
            (buf, w, eidx, pos))


def _combine_bwd(out_dtype, block_t, interpret, res, g):
    buf, w, eidx, pos = res
    n_experts, capacity, _ = buf.shape
    gf = g.astype(jnp.float32)
    # d_buf[e_k, p_k] += w_k * dy[t]: the scaled dispatch scatter.
    dbuf = _dispatch_raw(gf, eidx, pos, w.astype(jnp.float32), n_experts,
                         capacity, 256, interpret).astype(buf.dtype)
    # d_w[t, k] = <dy[t], buf[e_k, p_k]> for kept slots (XLA gather: the
    # [T, k, d] intermediate only exists in backward).
    kept = pos < capacity
    gathered = buf[eidx, jnp.clip(pos, 0, capacity - 1)]       # [T, k, d]
    dw = jnp.sum(gf[:, None, :] * gathered.astype(jnp.float32), axis=-1)
    dw = jnp.where(kept, dw, 0.0).astype(w.dtype)
    return dbuf, dw, None, None


_combine.defvjp(_combine_fwd, _combine_bwd)


def dispatch(x: jax.Array, eidx: jax.Array, pos: jax.Array, *,
             n_experts: int, capacity: int, block_a: int = 256,
             interpret: bool = True,
             vmem_limit: int | None = None) -> jax.Array:
    """[T, d] -> [E, C, d]: fused capacity-buffer build.

    ``eidx``/``pos`` are the [T, k] DispatchPlan arrays; assignments with
    ``pos >= capacity`` are dropped, matching ``core.dispatch.dispatch``.
    Raises :class:`DispatchVMEMError` when the resident buffer estimate
    exceeds ``vmem_limit`` (None -> DEFAULT_VMEM_LIMIT).
    """
    check_vmem(n_experts, capacity, x.shape[-1], x.dtype,
               n_tokens=x.shape[0], limit=vmem_limit)
    return _dispatch_jit(x, eidx, pos, n_experts, capacity, block_a,
                         interpret)


@functools.partial(jax.jit, static_argnames=("n_experts", "capacity",
                                             "block_a", "interpret"))
def _dispatch_jit(x, eidx, pos, n_experts, capacity, block_a, interpret):
    return _dispatch(x, eidx, pos, n_experts, capacity, block_a, interpret)


def combine(buf: jax.Array, w: jax.Array, eidx: jax.Array, pos: jax.Array,
            *, out_dtype=None, block_t: int = 128,
            interpret: bool = True,
            vmem_limit: int | None = None) -> jax.Array:
    """[E, C, d] -> [T, d]: fused weighted gather, y = sum_k w_k E_{e_k}(x).

    Raises :class:`DispatchVMEMError` when the resident buffer estimate
    exceeds ``vmem_limit`` (None -> DEFAULT_VMEM_LIMIT)."""
    out_dtype = out_dtype or buf.dtype
    check_vmem(buf.shape[0], buf.shape[1], buf.shape[2], buf.dtype,
               n_tokens=min(block_t, eidx.shape[0]), limit=vmem_limit)
    return _combine_jit(buf, w, eidx, pos, out_dtype, block_t, interpret)


@functools.partial(jax.jit, static_argnames=("out_dtype", "block_t",
                                             "interpret"))
def _combine_jit(buf, w, eidx, pos, out_dtype, block_t, interpret):
    return _combine(buf, w, eidx, pos, out_dtype, block_t, interpret)
