"""Fused dispatch/combine scatter kernels for the capacity-buffer hot path.

``core/dispatch.py`` builds the [E, C, d] expert buffers either with an XLA
scatter (``sort``) or GShard one-hot einsums (``einsum``, O(T·E·C) traffic).
The TPU-native shape is a single kernel pass: the (expert, position) plan
arrays ride in as *scalar-prefetch* operands (SMEM, available before the
body runs — exactly what `PrefetchScalarGridSpec` exists for), the grid
walks blocks of the T·k assignment list, and each step copies token rows
into their slots with dynamic VMEM indexing.  The weighted combine fuses
the gather and the ``sum_k w_k * E_k(x)`` reduction (Eq. 2) in one pass,
accumulating at f32 — the [T, k, d] gathered intermediate of the jnp path
never materializes.

Two buffer regimes, selected per call by :func:`select_e_block`:

* **resident** — the destination buffer stays VMEM-resident across the
  whole grid (constant index map — a revolving output block).  VMEM
  budget: the full [E_local, C, d] buffer, e.g. 8 experts x 512 slots x
  512 dims at f32 = 8 MiB, under the ~16 MiB budget.
* **E-blocked** — past the budget the expert dimension joins the grid and
  only an [e_block, C, d] slab is live per step (the Pallas pipeline
  double-buffers slab transfers, so the estimate charges two slabs).
  Assignments are pre-bucketed per expert block: every kept assignment
  owns a unique (expert, position) cell, so its bucket slot is just
  ``e*C + p`` — an O(T·k) scatter, no sort — and the bucketed plan rides
  scalar-prefetch like the resident plan does.  This is what keeps
  paper-scale E on the fused path (§3.2's compute-dense experts) instead
  of bailing to the ref scatter.

Dropped assignments (position >= capacity, including the zero-weight
padding the plan assigns position==capacity) write nothing / combine at
weight 0 — identical semantics to ``core/dispatch.py``.

Both directions carry ``jax.custom_vjp`` so the Pallas path trains:

* dispatch is a (duplicating) copy, so its cotangent is the *unit-weight*
  combine of the output cotangent — the same fused kernel;
* combine's buffer cotangent is the dispatch scatter of ``w_k * dy[t]``
  (the kernel takes an optional per-assignment scale for exactly this),
  and its weight cotangent is the per-assignment dot <dy[t], buf[e, p]>.

The chosen ``e_block`` threads through both VJPs, so forward and backward
run the same buffer regime.

On this CPU build host kernels run in interpret mode; ``interpret=False``
is the TPU path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.gmm import round_up as _round_up

# VMEM budget for the buffer that stays resident across the whole grid
# (resident regime: the full [E, C, d] output/input; E-blocked regime: a
# double-buffered [e_block, C, d] slab pair), plus the token block.  The
# guard *selects a regime* (select_e_block) instead of failing; only a
# shape whose single-expert slab still exceeds the limit raises — or, via
# the backend registry, falls back to the ref scatter.
DEFAULT_VMEM_LIMIT = 16 * 1024 * 1024

# Token-block default for the fused combine.  The backend registry's
# pre-call VMEM estimate and ops.combine's own guard both derive their
# token-block term from THIS constant — one source of truth, so a
# borderline shape cannot pass one guard and trip the other.
COMBINE_BLOCK_T = 128


class DispatchVMEMError(RuntimeError):
    """Fused dispatch/combine buffer exceeds the configured VMEM budget."""


def vmem_bytes(n_experts: int, capacity: int, d: int, dtype,
               n_tokens: int = 0) -> int:
    """Estimated resident VMEM for one *resident-regime* call: the
    [E, C, d] buffer (constant index map — never rotated out) plus the
    [T, d] token block."""
    item = jnp.dtype(dtype).itemsize
    return int((n_experts * capacity * d + n_tokens * d) * item)


def eblock_vmem_bytes(e_block: int, capacity: int, d: int, dtype,
                      n_tokens: int = 0) -> int:
    """Estimated resident VMEM for one *E-blocked* call: two in-flight
    [e_block, C, d] slabs (the Pallas pipeline double-buffers block
    transfers) plus the [T, d] token block."""
    item = jnp.dtype(dtype).itemsize
    return int((2 * e_block * capacity * d + n_tokens * d) * item)


def check_vmem(n_experts: int, capacity: int, d: int, dtype, *,
               n_tokens: int = 0, limit: int | None = None) -> int:
    """Raise DispatchVMEMError when the resident-regime estimate exceeds
    ``limit`` (None -> DEFAULT_VMEM_LIMIT).  Returns the estimate.

    Callers that can run E-blocked should prefer :func:`select_e_block`,
    which picks a slab size instead of raising."""
    limit = DEFAULT_VMEM_LIMIT if limit is None else limit
    need = vmem_bytes(n_experts, capacity, d, dtype, n_tokens)
    if need > limit:
        raise DispatchVMEMError(
            f"fused dispatch/combine buffer [E={n_experts}, C={capacity}, "
            f"d={d}] ({jnp.dtype(dtype).name}) needs ~{need} B VMEM "
            f"> limit {limit} B; use the E-blocked kernel (e_block / "
            f"select_e_block), shrink capacity, raise the limit, or use "
            f"the ref backend")
    return need


def select_e_block(n_experts: int, capacity: int, d: int, dtype, *,
                   n_tokens: int = 0, limit: int | None = None
                   ) -> int | None:
    """Pick the fused kernels' buffer regime for a shape.

    Returns ``None`` when the whole [E, C, d] buffer fits ``limit``
    (resident-buffer kernels), else the largest power-of-two expert-block
    size whose double-buffered [e_block, C, d] slab pair (plus the [T, d]
    token block) fits.  Raises :class:`DispatchVMEMError` only when even
    a one-expert slab exceeds the limit.
    """
    limit = DEFAULT_VMEM_LIMIT if limit is None else limit
    if vmem_bytes(n_experts, capacity, d, dtype, n_tokens) <= limit:
        return None
    blk = 1
    while (blk * 2 < n_experts
           and eblock_vmem_bytes(blk * 2, capacity, d, dtype,
                                 n_tokens) <= limit):
        blk *= 2
    if eblock_vmem_bytes(blk, capacity, d, dtype, n_tokens) > limit:
        raise DispatchVMEMError(
            f"fused dispatch/combine slab [e_block=1, C={capacity}, "
            f"d={d}] ({jnp.dtype(dtype).name}) needs "
            f"~{eblock_vmem_bytes(1, capacity, d, dtype, n_tokens)} B VMEM "
            f"> limit {limit} B even E-blocked; shrink capacity/d, raise "
            f"the limit, or use the ref backend")
    return blk


# ---------------------------------------------------------------------------
# dispatch: [T, d] -> [E, C, d] scatter (optionally scaled per assignment)
# ---------------------------------------------------------------------------

def _dispatch_kernel(eidx_ref, pos_ref, scale_ref, x_ref, o_ref, *,
                     k: int, capacity: int, block_a: int):
    @pl.when(pl.program_id(0) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    base = pl.program_id(0) * block_a
    t = x_ref.shape[0]

    def body(i, carry):
        a = base + i
        e = eidx_ref[a]
        p = pos_ref[a]
        kept = p < capacity                     # padding carries p==capacity
        pc = jnp.where(kept, p, 0)
        # Padded assignments (a >= T*k) would index x past T-1; clamp so the
        # load is in-bounds on the non-interpret TPU path (the value is
        # discarded by `kept` either way).
        row = x_ref[jnp.minimum(a // k, t - 1)] * scale_ref[a]
        cur = o_ref[e, pc]
        o_ref[e, pc] = jnp.where(kept, row.astype(o_ref.dtype), cur)
        return carry

    jax.lax.fori_loop(0, block_a, body, 0)


def _dispatch_raw(x, eidx, pos, scale, n_experts, capacity, block_a,
                  interpret):
    t, d = x.shape
    k = eidx.shape[1]
    n = t * k
    block_a = min(block_a, n)
    npad = _round_up(n, block_a)
    ef = jnp.zeros((npad,), jnp.int32).at[:n].set(eidx.reshape(-1))
    # Padded assignments get position == capacity => dropped in-kernel.
    pf = jnp.full((npad,), capacity, jnp.int32).at[:n].set(pos.reshape(-1))
    sf = jnp.zeros((npad,), jnp.float32).at[:n].set(scale.reshape(-1))
    kernel = functools.partial(_dispatch_kernel, k=k, capacity=capacity,
                               block_a=block_a)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(npad // block_a,),
            in_specs=[pl.BlockSpec((t, d), lambda i, *_: (0, 0))],
            out_specs=pl.BlockSpec((n_experts, capacity, d),
                                   lambda i, *_: (0, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_experts, capacity, d), x.dtype),
        interpret=interpret,
    )(ef, pf, sf, x)


# ---------------------------------------------------------------------------
# E-blocked dispatch: the grid gains an expert-block dimension; only an
# [e_block, C, d] slab is live per step
# ---------------------------------------------------------------------------

def _bucket_assignments(eidx, pos, scale, n_experts, capacity, e_block):
    """Invert the [T, k] plan into per-expert-block slot tables.

    Every *kept* assignment owns a unique (expert, position) buffer cell,
    so its bucket slot is simply ``e*C + p`` — no sort.  Returns flat
    [E_pad * C] arrays: ``btok[e*C + p]`` is the token row feeding expert
    e's slot p (-1 when the slot is empty) and ``bscale`` the
    per-assignment scale.  Dropped assignments (p >= capacity) scatter
    out-of-bounds and are discarded by ``mode="drop"``.
    """
    t, k = eidx.shape
    e_pad = _round_up(n_experts, e_block)
    ef = eidx.reshape(-1)
    pf = pos.reshape(-1)
    kept = pf < capacity
    slot = jnp.where(kept, ef * capacity + pf, e_pad * capacity)
    tok = jnp.arange(t * k, dtype=jnp.int32) // k
    btok = jnp.full((e_pad * capacity,), -1, jnp.int32).at[slot].set(
        tok, mode="drop")
    bscale = jnp.zeros((e_pad * capacity,), jnp.float32).at[slot].set(
        scale.astype(jnp.float32).reshape(-1), mode="drop")
    return btok, bscale


def _dispatch_eblock_kernel(btok_ref, bscale_ref, x_ref, o_ref, *,
                            capacity: int, e_block: int):
    base = pl.program_id(0) * (e_block * capacity)
    t = x_ref.shape[0]

    def body(s, carry):
        tok = btok_ref[base + s]
        filled = tok >= 0
        row = x_ref[jnp.where(filled, tok, 0)] * bscale_ref[base + s]
        # Each output cell is visited exactly once (slots are unique), so
        # empty cells are zeroed here instead of a separate pass.
        o_ref[s // capacity, s % capacity] = jnp.where(
            filled, row, 0.0).astype(o_ref.dtype)
        return carry

    jax.lax.fori_loop(0, e_block * capacity, body, 0)


def _dispatch_eblock_raw(x, eidx, pos, scale, n_experts, capacity, e_block,
                         interpret):
    t, d = x.shape
    e_pad = _round_up(n_experts, e_block)
    btok, bscale = _bucket_assignments(eidx, pos, scale, n_experts,
                                       capacity, e_block)
    kernel = functools.partial(_dispatch_eblock_kernel, capacity=capacity,
                               e_block=e_block)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(e_pad // e_block,),
            in_specs=[pl.BlockSpec((t, d), lambda b, *_: (0, 0))],
            out_specs=pl.BlockSpec((e_block, capacity, d),
                                   lambda b, *_: (b, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((e_pad, capacity, d), x.dtype),
        interpret=interpret,
    )(btok, bscale, x)
    return out[:n_experts] if e_pad != n_experts else out


def _dispatch_raw_any(x, eidx, pos, scale, n_experts, capacity, block_a,
                      e_block, interpret):
    if e_block is None:
        return _dispatch_raw(x, eidx, pos, scale, n_experts, capacity,
                             block_a, interpret)
    return _dispatch_eblock_raw(x, eidx, pos, scale, n_experts, capacity,
                                e_block, interpret)


# ---------------------------------------------------------------------------
# combine: [E, C, d] -> [T, d] weighted gather-reduce
# ---------------------------------------------------------------------------

def _combine_kernel(eidx_ref, pos_ref, w_ref, buf_ref, o_ref, *,
                    k: int, capacity: int, block_t: int):
    base = pl.program_id(0) * block_t
    d = o_ref.shape[-1]

    def body(i, carry):
        t = base + i
        acc = jnp.zeros((d,), jnp.float32)
        for j in range(k):                      # k <= 8: static unroll
            a = t * k + j
            e = eidx_ref[a]
            p = pos_ref[a]
            pc = jnp.where(p < capacity, p, 0)
            w = jnp.where(p < capacity, w_ref[a], 0.0)
            acc = acc + w * buf_ref[e, pc].astype(jnp.float32)
        o_ref[i] = acc.astype(o_ref.dtype)
        return carry

    jax.lax.fori_loop(0, block_t, body, 0)


def _combine_raw(buf, w, eidx, pos, out_dtype, block_t, interpret):
    n_experts, capacity, d = buf.shape
    t, k = eidx.shape
    n = t * k
    block_t = min(block_t, t)
    tpad = _round_up(t, block_t)
    npad = tpad * k
    ef = jnp.zeros((npad,), jnp.int32).at[:n].set(eidx.reshape(-1))
    pf = jnp.full((npad,), capacity, jnp.int32).at[:n].set(pos.reshape(-1))
    wf = jnp.zeros((npad,), jnp.float32).at[:n].set(
        w.astype(jnp.float32).reshape(-1))
    kernel = functools.partial(_combine_kernel, k=k, capacity=capacity,
                               block_t=block_t)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(tpad // block_t,),
            in_specs=[pl.BlockSpec((n_experts, capacity, d),
                                   lambda i, *_: (0, 0, 0))],
            out_specs=pl.BlockSpec((block_t, d), lambda i, *_: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((tpad, d), out_dtype),
        interpret=interpret,
    )(ef, pf, wf, buf)
    return out[:t] if tpad != t else out


# ---------------------------------------------------------------------------
# E-blocked combine: grid (T-blocks, E-blocks) with the expert dimension
# innermost; partial sums accumulate in an f32 scratch across slabs
# ---------------------------------------------------------------------------

def _combine_eblock_kernel(eidx_ref, pos_ref, w_ref, buf_ref, o_ref,
                           acc_ref, *, k: int, capacity: int, block_t: int,
                           e_block: int, n_eblk: int):
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    base_t = pl.program_id(0) * block_t
    base_e = eb * e_block
    d = o_ref.shape[-1]

    def body(i, carry):
        t = base_t + i
        acc = jnp.zeros((d,), jnp.float32)
        for j in range(k):                      # k <= 8: static unroll
            a = t * k + j
            e = eidx_ref[a]
            p = pos_ref[a]
            hit = (e >= base_e) & (e < base_e + e_block) & (p < capacity)
            el = jnp.where(hit, e - base_e, 0)
            pc = jnp.where(hit, p, 0)
            w = jnp.where(hit, w_ref[a], 0.0)
            acc = acc + w * buf_ref[el, pc].astype(jnp.float32)
        acc_ref[i] = acc_ref[i] + acc
        return carry

    jax.lax.fori_loop(0, block_t, body, 0)

    @pl.when(eb == n_eblk - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _combine_eblock_raw(buf, w, eidx, pos, out_dtype, block_t, e_block,
                        interpret):
    n_experts, capacity, d = buf.shape
    t, k = eidx.shape
    n = t * k
    block_t = min(block_t, t)
    tpad = _round_up(t, block_t)
    npad = tpad * k
    e_pad = _round_up(n_experts, e_block)
    n_eblk = e_pad // e_block
    if e_pad != n_experts:
        # Padded experts are never referenced (e < n_experts in the plan),
        # but the slab walk needs a whole number of blocks.
        buf = jnp.pad(buf, ((0, e_pad - n_experts), (0, 0), (0, 0)))
    ef = jnp.zeros((npad,), jnp.int32).at[:n].set(eidx.reshape(-1))
    pf = jnp.full((npad,), capacity, jnp.int32).at[:n].set(pos.reshape(-1))
    wf = jnp.zeros((npad,), jnp.float32).at[:n].set(
        w.astype(jnp.float32).reshape(-1))
    kernel = functools.partial(_combine_eblock_kernel, k=k,
                               capacity=capacity, block_t=block_t,
                               e_block=e_block, n_eblk=n_eblk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            # Row-major grid walk: for each token block the expert slabs
            # iterate consecutively over the revolving output block.
            grid=(tpad // block_t, n_eblk),
            in_specs=[pl.BlockSpec((e_block, capacity, d),
                                   lambda i, j, *_: (j, 0, 0))],
            out_specs=pl.BlockSpec((block_t, d), lambda i, j, *_: (i, 0)),
            scratch_shapes=[pltpu.VMEM((block_t, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((tpad, d), out_dtype),
        interpret=interpret,
    )(ef, pf, wf, buf)
    return out[:t] if tpad != t else out


def _combine_raw_any(buf, w, eidx, pos, out_dtype, block_t, e_block,
                     interpret):
    if e_block is None:
        return _combine_raw(buf, w, eidx, pos, out_dtype, block_t,
                            interpret)
    return _combine_eblock_raw(buf, w, eidx, pos, out_dtype, block_t,
                               e_block, interpret)


# ---------------------------------------------------------------------------
# differentiable public ops
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _dispatch(x, eidx, pos, n_experts, capacity, block_a, interpret,
              e_block):
    ones = jnp.ones((x.shape[0], eidx.shape[1]), jnp.float32)
    return _dispatch_raw_any(x, eidx, pos, ones, n_experts, capacity,
                             block_a, e_block, interpret)


def _dispatch_fwd(x, eidx, pos, n_experts, capacity, block_a, interpret,
                  e_block):
    return (_dispatch(x, eidx, pos, n_experts, capacity, block_a, interpret,
                      e_block),
            (eidx, pos))


def _dispatch_bwd(n_experts, capacity, block_a, interpret, e_block, res, g):
    eidx, pos = res
    # The scatter duplicates x[t] into its kept slots, so dx is the
    # unit-weight combine of the cotangent buffer (same fused kernel,
    # same buffer regime).
    unit = jnp.ones(eidx.shape, jnp.float32)
    dx = _combine_raw_any(g, unit, eidx, pos, g.dtype, COMBINE_BLOCK_T,
                          e_block, interpret)
    return dx, None, None


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _combine(buf, w, eidx, pos, out_dtype, block_t, interpret, e_block):
    return _combine_raw_any(buf, w, eidx, pos, out_dtype, block_t, e_block,
                            interpret)


def _combine_fwd(buf, w, eidx, pos, out_dtype, block_t, interpret, e_block):
    return (_combine_raw_any(buf, w, eidx, pos, out_dtype, block_t, e_block,
                             interpret),
            (buf, w, eidx, pos))


def _combine_bwd(out_dtype, block_t, interpret, e_block, res, g):
    buf, w, eidx, pos = res
    n_experts, capacity, _ = buf.shape
    gf = g.astype(jnp.float32)
    # d_buf[e_k, p_k] += w_k * dy[t]: the scaled dispatch scatter (same
    # buffer regime as forward).
    dbuf = _dispatch_raw_any(gf, eidx, pos, w.astype(jnp.float32),
                             n_experts, capacity, 256, e_block,
                             interpret).astype(buf.dtype)
    # d_w[t, k] = <dy[t], buf[e_k, p_k]> for kept slots (XLA gather: the
    # [T, k, d] intermediate only exists in backward).
    kept = pos < capacity
    gathered = buf[eidx, jnp.clip(pos, 0, capacity - 1)]       # [T, k, d]
    dw = jnp.sum(gf[:, None, :] * gathered.astype(jnp.float32), axis=-1)
    dw = jnp.where(kept, dw, 0.0).astype(w.dtype)
    return dbuf, dw, None, None


_combine.defvjp(_combine_fwd, _combine_bwd)


def dispatch(x: jax.Array, eidx: jax.Array, pos: jax.Array, *,
             n_experts: int, capacity: int, block_a: int = 256,
             interpret: bool = True,
             vmem_limit: int | None = None,
             e_block: int | None = None) -> jax.Array:
    """[T, d] -> [E, C, d]: fused capacity-buffer build.

    ``eidx``/``pos`` are the [T, k] DispatchPlan arrays; assignments with
    ``pos >= capacity`` are dropped, matching ``core.dispatch.dispatch``.
    ``e_block=None`` auto-selects the buffer regime from ``vmem_limit``
    (None -> DEFAULT_VMEM_LIMIT): whole-buffer resident when it fits,
    else the largest fitting E-block slab; an explicit int forces that
    slab size.  Raises :class:`DispatchVMEMError` when even a one-expert
    slab exceeds the limit.
    """
    if e_block is None:
        e_block = select_e_block(n_experts, capacity, x.shape[-1], x.dtype,
                                 n_tokens=x.shape[0], limit=vmem_limit)
    elif e_block < 1:
        raise ValueError(f"e_block must be >= 1, got {e_block}")
    return _dispatch_jit(x, eidx, pos, n_experts, capacity, block_a,
                         interpret, e_block)


@functools.partial(jax.jit, static_argnames=("n_experts", "capacity",
                                             "block_a", "interpret",
                                             "e_block"))
def _dispatch_jit(x, eidx, pos, n_experts, capacity, block_a, interpret,
                  e_block):
    return _dispatch(x, eidx, pos, n_experts, capacity, block_a, interpret,
                     e_block)


def combine(buf: jax.Array, w: jax.Array, eidx: jax.Array, pos: jax.Array,
            *, out_dtype=None, block_t: int = COMBINE_BLOCK_T,
            interpret: bool = True,
            vmem_limit: int | None = None,
            e_block: int | None = None) -> jax.Array:
    """[E, C, d] -> [T, d]: fused weighted gather, y = sum_k w_k E_{e_k}(x).

    ``e_block`` selects the buffer regime exactly as in :func:`dispatch`;
    raises :class:`DispatchVMEMError` when even a one-expert slab exceeds
    ``vmem_limit`` (None -> DEFAULT_VMEM_LIMIT)."""
    out_dtype = out_dtype or buf.dtype
    if e_block is None:
        e_block = select_e_block(
            buf.shape[0], buf.shape[1], buf.shape[2], buf.dtype,
            n_tokens=min(block_t, eidx.shape[0]), limit=vmem_limit)
    elif e_block < 1:
        raise ValueError(f"e_block must be >= 1, got {e_block}")
    return _combine_jit(buf, w, eidx, pos, out_dtype, block_t, interpret,
                        e_block)


@functools.partial(jax.jit, static_argnames=("out_dtype", "block_t",
                                             "interpret", "e_block"))
def _combine_jit(buf, w, eidx, pos, out_dtype, block_t, interpret, e_block):
    return _combine(buf, w, eidx, pos, out_dtype, block_t, interpret,
                    e_block)
