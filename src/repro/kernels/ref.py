"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gmm_ref(x: jax.Array, w: jax.Array, *, activation: str = "none"
            ) -> jax.Array:
    """Grouped (per-expert) matmul: [E,C,K] x [E,K,N] -> [E,C,N]."""
    out = jnp.einsum("eck,ekn->ecn", x.astype(jnp.float32),
                     w.astype(jnp.float32))
    if activation == "relu":
        out = jax.nn.relu(out)
    elif activation == "silu":
        out = jax.nn.silu(out)
    return out.astype(x.dtype)


def expert_ffn_ref(x: jax.Array, w1: jax.Array, w2: jax.Array,
                   w3: jax.Array | None = None) -> jax.Array:
    """The paper's one-hidden-layer ReLU expert (§3.2), or gated-SiLU when
    w3 is given.  [E,C,d] -> [E,C,d]."""
    h = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w1.astype(jnp.float32))
    if w3 is None:
        h = jax.nn.relu(h)
    else:
        g = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                       w3.astype(jnp.float32))
        h = jax.nn.silu(h) * g
    out = jnp.einsum("ecf,efd->ecd", h, w2.astype(jnp.float32))
    return out.astype(x.dtype)


def fused_decode_ref(x: jax.Array, wg: jax.Array, w1: jax.Array,
                     w2: jax.Array, w3: jax.Array | None = None,
                     valid: jax.Array | None = None, *, k: int,
                     capacity: int):
    """Oracle for the fused decode step (kernels/fused_decode.py).

    Deliberately written with the *other* formulations — ``lax.top_k``
    routing, stable-argsort slot assignment (the ``core.dispatch.plan``
    algorithm), einsum FFN, vectorized gather-combine — so it is an
    independent check of the kernel's argmax-round / running-count /
    fori-loop implementation.  Returns ``(y [T, d], expert_load [E],
    overflow [E])``.
    """
    t, d = x.shape
    e = wg.shape[-1]
    logits = jnp.dot(x.astype(jnp.float32), wg.astype(jnp.float32))
    vals, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(vals, axis=-1)
    if valid is not None:
        w = w * valid.astype(jnp.float32).reshape(t, 1)

    flat_e = idx.reshape(-1).astype(jnp.int32)
    flat_w = w.reshape(-1)
    assigned = flat_w > 0
    key = flat_e * 2 + (~assigned).astype(jnp.int32)
    order = jnp.argsort(key)                    # jnp.argsort is stable
    sorted_e = flat_e[order]
    sorted_w = flat_w[order]
    counts = jnp.zeros((e,), jnp.int32).at[sorted_e].add(1)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    pos_sorted = jnp.where(sorted_w > 0, rank, capacity)
    pos = jnp.zeros_like(flat_e).at[order].set(pos_sorted)
    kept = pos < capacity
    w_eff = jnp.where(kept, flat_w, 0.0)

    xk = jnp.repeat(x, k, axis=0)
    buf = jnp.zeros((e, capacity, d), x.dtype).at[flat_e, pos].set(
        xk, mode="drop")
    out = expert_ffn_ref(buf, w1, w2, w3)
    gathered = out[flat_e, jnp.clip(pos, 0, capacity - 1)]
    y = jnp.sum((w_eff[:, None] * gathered.astype(jnp.float32)
                 ).reshape(t, k, d), axis=1).astype(x.dtype)

    load = jnp.zeros((e,), jnp.float32).at[flat_e].add(
        assigned.astype(jnp.float32))
    overflow = jnp.zeros((e,), jnp.float32).at[flat_e].add(
        (assigned & ~kept).astype(jnp.float32))
    return y, load, overflow


def topk_gating_ref(logits: jax.Array, k: int):
    """Softmax-over-top-k (Eq. 3/5, deterministic part).

    logits: [T, E] float32 -> (weights [T,k], idx [T,k] int32, gates [T,E]).
    """
    vals, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(vals, axis=-1)
    gates = jnp.zeros_like(logits).at[
        jnp.arange(logits.shape[0])[:, None], idx].set(w)
    return w, idx.astype(jnp.int32), gates
