"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gmm_ref(x: jax.Array, w: jax.Array, *, activation: str = "none"
            ) -> jax.Array:
    """Grouped (per-expert) matmul: [E,C,K] x [E,K,N] -> [E,C,N]."""
    out = jnp.einsum("eck,ekn->ecn", x.astype(jnp.float32),
                     w.astype(jnp.float32))
    if activation == "relu":
        out = jax.nn.relu(out)
    elif activation == "silu":
        out = jax.nn.silu(out)
    return out.astype(x.dtype)


def expert_ffn_ref(x: jax.Array, w1: jax.Array, w2: jax.Array,
                   w3: jax.Array | None = None) -> jax.Array:
    """The paper's one-hidden-layer ReLU expert (§3.2), or gated-SiLU when
    w3 is given.  [E,C,d] -> [E,C,d]."""
    h = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   w1.astype(jnp.float32))
    if w3 is None:
        h = jax.nn.relu(h)
    else:
        g = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                       w3.astype(jnp.float32))
        h = jax.nn.silu(h) * g
    out = jnp.einsum("ecf,efd->ecd", h, w2.astype(jnp.float32))
    return out.astype(x.dtype)


def topk_gating_ref(logits: jax.Array, k: int):
    """Softmax-over-top-k (Eq. 3/5, deterministic part).

    logits: [T, E] float32 -> (weights [T,k], idx [T,k] int32, gates [T,E]).
    """
    vals, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(vals, axis=-1)
    gates = jnp.zeros_like(logits).at[
        jnp.arange(logits.shape[0])[:, None], idx].set(w)
    return w, idx.astype(jnp.int32), gates
