"""Grouped expert matmul (megablox-style GMM) as a Pallas TPU kernel.

The expert FFN over capacity-dispatched buffers — einsum('ecd,edf->ecf') —
is the paper's compute hot-spot (§3.2: the experts carry ~40% of total
FLOPs in the paper's models, and "we can increase computational efficiency
simply by using a larger hidden layer").  On GPU the reference batches
per-expert GEMMs; the TPU-native shape is one kernel whose grid walks
(expert, row-block, col-block, k-block) with an f32 VMEM accumulator,
MXU-aligned 128x128 tiles, and the activation fused into the final k-step
epilogue so the [E, C, d_ff] hidden never round-trips HBM at f32.

Grid iteration order is (e, m, n, k) with k innermost: the accumulator tile
stays VMEM-resident across the k loop (revolving output), and the x
row-block is reused across all n — the standard TPU blocked-matmul
schedule.  VMEM working set per step (bm=bn=bk=128): x tile 32 KiB +
w tile 32 KiB + f32 acc 64 KiB ~= 128 KiB, far under the ~16 MiB budget;
larger bn/bk amortize grid overhead until the d_ff dimension is consumed.

Non-tile-aligned shapes are zero-padded up to the block plan (see
:func:`plan_blocks`) and the output trimmed — zero rows/columns are inert
through the matmul and the fused activations (relu(0) == silu(0) == 0), so
padding never changes the visible result.

Training: :func:`gmm` carries a ``jax.custom_vjp`` so the Pallas path is
differentiable end-to-end.  Both cotangents are themselves grouped matmuls
and reuse the same kernel —

    dx = gmm(dyʹ, wᵀ)          [E,C,N] x [E,N,K] -> [E,C,K]
    dw = gmm(xᵀ, dyʹ)          [E,K,C] x [E,C,N] -> [E,K,N]

where dyʹ folds the activation derivative in: the pre-activation z is
rematerialized with one extra no-activation GMM (the Appendix-D
"recompute expert activations on the backward pass" policy) rather than
saved, keeping forward residuals at (x, w).

Tile sizes come from a **measured tuning table** when the caller leaves
them unset: ``plan_blocks`` consults ``gmm_tunings.json`` (seeded by
``make tune-kernels``, exact (E, C, K, N, dtype) keys) before its static
128 defaults — on this interpret-mode host per-grid-step overhead
dominates, so fewer/bigger blocks win by integer factors (the
``kernel_backend_gmm_pallas`` gap in BENCH_micro.json).  Explicit
``bm/bn/bk`` arguments always override the table.

On this CPU build host kernels run in interpret mode (the kernel body
executes as Python/jnp); ``interpret=False`` is the TPU path.
"""
from __future__ import annotations

import functools
import json
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def round_up(x: int, m: int) -> int:
    """Smallest multiple of m >= x (shared by the kernel modules)."""
    return -(-x // m) * m


def _sublane(dtype) -> int:
    """Minimum TPU sublane tile for a dtype (second-to-last dim)."""
    return 16 if dtype == jnp.bfloat16 else 8


# --- measured tiling table (docs/kernels.md §Tiling autotune) --------------

# Static fallback tile edge when a shape has no measured entry.
DEFAULT_TILE = 128

# Env var overriding the committed table path (tests point it at tmp
# files; an empty value falls through to the default).
TUNINGS_ENV = "REPRO_GMM_TUNINGS"
_DEFAULT_TUNINGS_PATH = os.path.join(os.path.dirname(__file__),
                                     "gmm_tunings.json")

_tunings_cache: tuple[str, dict] | None = None


def tunings_path() -> str:
    return os.environ.get(TUNINGS_ENV) or _DEFAULT_TUNINGS_PATH


def tuning_key(e: int, c: int, k: int, n: int, dtype) -> str:
    """Exact-shape table key: ``{E}x{C}x{K}x{N}x{dtype}``."""
    return f"{e}x{c}x{k}x{n}x{jnp.dtype(dtype).name}"


def load_tunings(path: str | None = None) -> dict:
    """Load the measured shape -> (bm, bn, bk) table (missing file -> {}).

    Keys beginning with ``_`` are metadata (tuner provenance) and are
    skipped.  Cached per path; call :func:`invalidate_tunings` after
    re-tuning or pointing ``REPRO_GMM_TUNINGS`` elsewhere mid-process.

    When ``REPRO_GMM_TUNINGS`` supplies the path, the override is
    *validated*: a missing or unparseable file raises
    ``KernelBackendError`` instead of silently falling back to the static
    defaults (an empty value keeps the documented "unset" meaning — the
    committed table).
    """
    global _tunings_cache
    env_override = path is None and bool(os.environ.get(TUNINGS_ENV))
    path = path or tunings_path()
    if _tunings_cache is not None and _tunings_cache[0] == path:
        return _tunings_cache[1]
    table: dict = {}
    try:
        with open(path) as f:
            raw = json.load(f)
        table = {key: tuple(int(v) for v in val)
                 for key, val in raw.items() if not key.startswith("_")}
    except FileNotFoundError:
        if env_override:
            from repro.kernels.backend import KernelBackendError
            raise KernelBackendError(
                f"{TUNINGS_ENV}={path!r} points at a missing GMM tunings "
                "file — fix the path or unset the variable (an empty "
                "value means 'use the committed table')") from None
    except (json.JSONDecodeError, ValueError, TypeError) as err:
        if env_override:
            from repro.kernels.backend import KernelBackendError
            raise KernelBackendError(
                f"{TUNINGS_ENV}={path!r} is not a valid GMM tunings "
                f"table: {err}") from err
        raise
    _tunings_cache = (path, table)
    return table


def invalidate_tunings() -> None:
    """Drop the cached table (next lookup re-reads the file).

    Note: jitted callers that already traced with ``bm=bn=bk=None``
    resolved the table at trace time; the jit cache must also be cleared
    (or explicit tiles passed) for a changed table to take effect.
    """
    global _tunings_cache
    _tunings_cache = None


def lookup_tiling(e: int, c: int, k: int, n: int,
                  dtype) -> tuple[int, int, int] | None:
    """Measured (bm, bn, bk) for an exact shape, or None (use defaults)."""
    return load_tunings().get(tuning_key(e, c, k, n, dtype))


class BlockPlan(NamedTuple):
    """A per-shard block spec for one grouped matmul: padded operand shapes
    plus the (bm, bn, bk) tile walk.  ``padded == shape`` iff the local
    dims were already tile-aligned."""
    e: int
    c: int          # padded row dim (capacity)
    k: int          # padded contraction dim
    n: int          # padded output dim
    bm: int
    bn: int
    bk: int

    @property
    def grid(self) -> tuple[int, int, int, int]:
        return (self.e, self.c // self.bm, self.n // self.bn,
                self.k // self.bk)


def plan_blocks(e: int, c: int, k: int, n: int, dtype=jnp.float32, *,
                bm: int | None = None, bn: int | None = None,
                bk: int | None = None) -> BlockPlan:
    """Derive the block plan for a (possibly non-tile-aligned) local shape.

    Tile sizes left as ``None`` consult the measured tuning table first
    (:func:`lookup_tiling`, exact-shape keys) and fall back to
    ``DEFAULT_TILE``; explicit values always win.  Blocks are clamped to
    the (tile-rounded) dims so small problems don't pad all the way to
    128, and dims are padded up to a whole number of blocks instead of
    asserting divisibility.
    """
    if bm is None and bn is None and bk is None:
        tuned = lookup_tiling(e, c, k, n, dtype)
        if tuned is not None:
            bm, bn, bk = tuned
    bm = DEFAULT_TILE if bm is None else bm
    bn = DEFAULT_TILE if bn is None else bn
    bk = DEFAULT_TILE if bk is None else bk
    sub = _sublane(dtype)
    bm = min(bm, round_up(c, sub))
    bn = min(bn, round_up(n, 128))
    bk = min(bk, round_up(k, 128))
    return BlockPlan(e=e, c=round_up(c, bm), k=round_up(k, bk),
                     n=round_up(n, bn), bm=bm, bn=bn, bk=bk)


def _pad3(x: jax.Array, d1: int, d2: int) -> jax.Array:
    """Zero-pad the trailing two dims of [E, a, b] up to (d1, d2)."""
    e, a, b = x.shape
    if a == d1 and b == d2:
        return x
    return jnp.pad(x, ((0, 0), (0, d1 - a), (0, d2 - b)))


def _act(out: jax.Array, activation: str) -> jax.Array:
    if activation == "relu":
        return jnp.maximum(out, 0.0)
    if activation == "silu":
        return out * (1.0 / (1.0 + jnp.exp(-out)))
    if activation != "none":
        # Real exception, not an assert: under `python -O` an assert is
        # stripped and an unknown activation would silently run identity.
        raise ValueError(f"unknown gmm activation: {activation!r} "
                         f"(expected 'none', 'relu', or 'silu')")
    return out


def _act_grad(z: jax.Array, activation: str) -> jax.Array:
    """d act(z) / dz at f32."""
    if activation == "relu":
        return (z > 0.0).astype(jnp.float32)
    if activation == "silu":
        s = jax.nn.sigmoid(z)
        return s * (1.0 + z * (1.0 - s))
    if activation != "none":
        # Same `python -O` hazard as _act: stripped assert -> grad of 1s.
        raise ValueError(f"unknown gmm activation: {activation!r} "
                         f"(expected 'none', 'relu', or 'silu')")
    return jnp.ones_like(z)


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int, activation: str):
    @pl.when(pl.program_id(3) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_k - 1)
    def _epilogue():
        o_ref[0] = _act(acc_ref[...], activation).astype(o_ref.dtype)


def _gmm_raw(x: jax.Array, w: jax.Array, activation: str,
             bm: int, bn: int, bk: int, interpret: bool) -> jax.Array:
    """Pad -> pallas_call -> trim.  No autodiff rule (see ``gmm``)."""
    e, c, k = x.shape
    _, _, n = w.shape
    bp = plan_blocks(e, c, k, n, x.dtype, bm=bm, bn=bn, bk=bk)
    xp = _pad3(x, bp.c, bp.k)
    wp = _pad3(w, bp.k, bp.n)
    n_k = bp.k // bp.bk
    kernel = functools.partial(_gmm_kernel, n_k=n_k, activation=activation)
    out = pl.pallas_call(
        kernel,
        grid=bp.grid,
        in_specs=[
            pl.BlockSpec((1, bp.bm, bp.bk), lambda e, m, n_, k_: (e, m, k_)),
            pl.BlockSpec((1, bp.bk, bp.bn), lambda e, m, n_, k_: (e, k_, n_)),
        ],
        out_specs=pl.BlockSpec((1, bp.bm, bp.bn),
                               lambda e, m, n_, k_: (e, m, n_)),
        out_shape=jax.ShapeDtypeStruct((e, bp.c, bp.n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bp.bm, bp.bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    if (bp.c, bp.n) != (c, n):
        out = out[:, :c, :n]
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _gmm(x, w, activation, bm, bn, bk, interpret):
    return _gmm_raw(x, w, activation, bm, bn, bk, interpret)


def _gmm_fwd(x, w, activation, bm, bn, bk, interpret):
    return _gmm_raw(x, w, activation, bm, bn, bk, interpret), (x, w)


def _gmm_bwd(activation, bm, bn, bk, interpret, res, g):
    x, w = res
    if activation != "none":
        # Rematerialize the pre-activation z (one extra GMM) and fold the
        # activation derivative into the incoming cotangent.
        z = _gmm_raw(x, w, "none", bm, bn, bk, interpret)
        g = (g.astype(jnp.float32)
             * _act_grad(z.astype(jnp.float32), activation)).astype(g.dtype)
    dx = _gmm_raw(g, jnp.swapaxes(w, 1, 2), "none", bm, bn, bk, interpret)
    dw = _gmm_raw(jnp.swapaxes(x, 1, 2), g, "none", bm, bn, bk, interpret)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_gmm.defvjp(_gmm_fwd, _gmm_bwd)


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bn", "bk",
                                             "interpret"))
def gmm(x: jax.Array, w: jax.Array, *, activation: str = "none",
        bm: int | None = None, bn: int | None = None, bk: int | None = None,
        interpret: bool = True) -> jax.Array:
    """[E, C, K] x [E, K, N] -> [E, C, N] with optional fused activation.

    Differentiable (custom VJP); non-tile-aligned C/K/N are zero-padded to
    the :func:`plan_blocks` boundaries and the output trimmed.  Tile sizes
    left as ``None`` use the measured tuning table / static defaults via
    :func:`plan_blocks` — each backward-pass GMM re-plans for its own
    operand shapes, so grad matmuls get their own tuned tiles.
    """
    return _gmm(x, w, activation, bm, bn, bk, interpret)
