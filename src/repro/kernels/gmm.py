"""Grouped expert matmul (megablox-style GMM) as a Pallas TPU kernel.

The expert FFN over capacity-dispatched buffers — einsum('ecd,edf->ecf') —
is the paper's compute hot-spot (§3.2: the experts carry ~40% of total
FLOPs in the paper's models, and "we can increase computational efficiency
simply by using a larger hidden layer").  On GPU the reference batches
per-expert GEMMs; the TPU-native shape is one kernel whose grid walks
(expert, row-block, col-block, k-block) with an f32 VMEM accumulator,
MXU-aligned 128x128 tiles, and the activation fused into the final k-step
epilogue so the [E, C, d_ff] hidden never round-trips HBM at f32.

Grid iteration order is (e, m, n, k) with k innermost: the accumulator tile
stays VMEM-resident across the k loop (revolving output), and the x
row-block is reused across all n — the standard TPU blocked-matmul
schedule.  VMEM working set per step (bm=bn=bk=128): x tile 32 KiB +
w tile 32 KiB + f32 acc 64 KiB ~= 128 KiB, far under the ~16 MiB budget;
larger bn/bk amortize grid overhead until the d_ff dimension is consumed.

On this CPU build host kernels run in interpret mode (the kernel body
executes as Python/jnp); ``interpret=False`` is the TPU path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int, activation: str):
    @pl.when(pl.program_id(3) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_k - 1)
    def _epilogue():
        out = acc_ref[...]
        if activation == "relu":
            out = jnp.maximum(out, 0.0)
        elif activation == "silu":
            out = out * (1.0 / (1.0 + jnp.exp(-out)))
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bn", "bk",
                                             "interpret"))
def gmm(x: jax.Array, w: jax.Array, *, activation: str = "none",
        bm: int = 128, bn: int = 128, bk: int = 128,
        interpret: bool = True) -> jax.Array:
    """[E, C, K] x [E, K, N] -> [E, C, N] with optional fused activation."""
    e, c, k = x.shape
    _, _, n = w.shape
    bm, bn, bk = min(bm, c), min(bn, n), min(bk, k)
    assert c % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, w.shape,
                                                         (bm, bn, bk))
    n_k = k // bk
    grid = (e, c // bm, n // bn, n_k)
    kernel = functools.partial(_gmm_kernel, n_k=n_k, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, m, n_, k_: (e, m, k_)),
            pl.BlockSpec((1, bk, bn), lambda e, m, n_, k_: (e, k_, n_)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, m, n_, k_: (e, m, n_)),
        out_shape=jax.ShapeDtypeStruct((e, c, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
