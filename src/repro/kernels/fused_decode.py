"""Fused single-launch MoE decode step.

At serve-time decode the MoE hot path runs once per layer per token batch
of B slot rows — and the unfused pipeline pays >= 4 kernel launches for
it (top-k gating, dispatch scatter, expert GMM x2, weighted combine).
On this host a jitted call costs ~10 ms fixed, so at decode the launch
count — not the FLOPs — dominates exactly where the paper's §3
conditional-computation argument promises efficiency.  This module fuses
the whole layer into ONE ``pallas_call``:

* :func:`decode_step` — the full fusion for the ``noisy_topk`` eval path
  (the serve decode default): in-kernel clean-logit routing (Eqs. 3/5,
  deterministic part), capacity-slot assignment (the exact
  ``core.dispatch.plan`` non-priority semantics, computed as an exclusive
  running count instead of a sort), the scatter into the [E, C, d]
  capacity buffer, the per-expert FFN (§3.2 one-hidden-layer ReLU, or
  gated-SiLU), and the weighted combine — plus the serving telemetry
  counters (``route_telemetry``'s load/overflow) as extra outputs, so
  the fused layer emits the same counter families the unfused path does.
* :func:`routed_apply` — the plan-mode fusion: routing happens outside
  (any registered policy — expert_choice's batch-global column top-k
  cannot be computed per-token in-kernel) and the kernel fuses
  dispatch -> grouped matmul(s) -> combine over explicit in/out plan
  views.  MoA's assignment-major [T·k, 1] plans run through the same
  kernel (``mode="proj"``), so routed-attention decode gets the
  single-launch win for each of its Q/O projections too.

Inference-only: no custom VJP — the train path keeps the individually
differentiable kernels.  Everything (weights included) is VMEM-resident
for the one grid step, which is the right regime for decode shapes
(B <= slot-pool size, C = O(B·k/E)); :func:`decode_vmem_bytes` /
:func:`routed_vmem_bytes` estimate the slab so the backend can fall back
loudly (``RuntimeWarning``) past the budget, mirroring the dispatch VMEM
fallback.

Bit-parity discipline: every stage reproduces the unfused pallas path's
math op-for-op (same dots with ``preferred_element_type=jnp.float32``,
same cast points, same ascending-k f32 combine accumulation, same
argmax-round top-k tie-breaking), so greedy decode streams are
bit-identical fused vs unfused (pinned by tests/test_fused_decode.py and
the serve parity matrix).

On this CPU build host kernels run in interpret mode; ``interpret=False``
is the TPU path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


# ---------------------------------------------------------------------------
# VMEM slab estimates (the backend's fallback guard)
# ---------------------------------------------------------------------------

def decode_vmem_bytes(t: int, d: int, f: int, n_experts: int,
                      capacity: int, x_dtype, w_dtype, *,
                      gated: bool = False) -> int:
    """Estimated VMEM for one fully-fused decode step: the [E, C, d]
    dispatch and output buffers, the per-expert [C, f] hidden tile, the
    expert weights (w1/w2 and w3 when gated), the gate matrix, and the
    token block — everything is resident for the single grid step."""
    xi = jnp.dtype(x_dtype).itemsize
    wi = jnp.dtype(w_dtype).itemsize
    e = n_experts
    bufs = 2 * e * capacity * d * xi            # dispatch + expert-out
    hidden = capacity * f * 4                   # one f32 [C, f] tile
    weights = (3 if gated else 2) * e * d * f * wi + d * e * wi
    tokens = 2 * t * d * xi + t * e * 4         # x/y + logits
    return int(bufs + hidden + weights + tokens)


def routed_vmem_bytes(t: int, d_in: int, d_out: int, f: int,
                      n_experts: int, capacity: int, x_dtype, w_dtype, *,
                      mode: str = "ffn", gated: bool = False) -> int:
    """Estimated VMEM for one plan-mode fused call (``routed_apply``)."""
    xi = jnp.dtype(x_dtype).itemsize
    wi = jnp.dtype(w_dtype).itemsize
    e = n_experts
    bufs = e * capacity * (d_in + d_out) * xi
    if mode == "ffn":
        weights = (3 if gated else 2) * e * d_in * f * wi
        hidden = capacity * f * 4
    else:
        weights = e * d_in * d_out * wi
        hidden = 0
    tokens = t * d_in * xi + t * d_out * xi
    return int(bufs + hidden + weights + tokens)


# ---------------------------------------------------------------------------
# shared in-kernel stages
# ---------------------------------------------------------------------------

def _scatter_into(buf_ref, x, flat_e, flat_p, *, k: int, capacity: int):
    """The dispatch scatter (``kernels.dispatch._dispatch_kernel`` body):
    row a//k of ``x`` lands in buffer cell (flat_e[a], flat_p[a]); dropped
    assignments (p >= capacity) write nothing."""
    buf_ref[...] = jnp.zeros_like(buf_ref)
    t = x.shape[0]
    n = flat_e.shape[0]

    def body(a, carry):
        e = flat_e[a]
        p = flat_p[a]
        kept = p < capacity
        pc = jnp.where(kept, p, 0)
        row = x[jnp.minimum(a // k, t - 1)]
        cur = buf_ref[e, pc]
        buf_ref[e, pc] = jnp.where(kept, row.astype(buf_ref.dtype), cur)
        return carry

    jax.lax.fori_loop(0, n, body, 0)


def _expert_ffn_into(out_ref, buf_ref, w1_ref, w2_ref, w3_ref, *,
                     n_experts: int, activation: str):
    """Per-expert FFN over the capacity buffers, mirroring the unfused
    ``ops.expert_ffn`` math exactly: dt-weight dots at preferred f32,
    activation in f32, casts at the same points (gmm applies silu before
    its output cast; the swiglu product happens in f32)."""
    dt = buf_ref.dtype

    def body(ei, carry):
        be = buf_ref[ei]                                       # [C, d_in]
        h = jnp.dot(be, w1_ref[ei].astype(dt),
                    preferred_element_type=jnp.float32)
        if activation == "swiglu":
            s = jax.nn.silu(h).astype(dt)
            g = jnp.dot(be, w3_ref[ei].astype(dt),
                        preferred_element_type=jnp.float32).astype(dt)
            h = (s.astype(jnp.float32) * g.astype(jnp.float32)).astype(dt)
        else:
            h = jax.nn.relu(h).astype(dt)
        out_ref[ei] = jnp.dot(h, w2_ref[ei].astype(dt),
                              preferred_element_type=jnp.float32
                              ).astype(dt)
        return carry

    jax.lax.fori_loop(0, n_experts, body, 0)


def _proj_into(out_ref, buf_ref, w_ref, *, n_experts: int):
    """Single grouped matmul (the MoA routed Q/O projection), mirroring
    ``ops.gmm`` with ``activation="none"``."""
    dt = buf_ref.dtype

    def body(ei, carry):
        out_ref[ei] = jnp.dot(buf_ref[ei], w_ref[ei].astype(dt),
                              preferred_element_type=jnp.float32
                              ).astype(dt)
        return carry

    jax.lax.fori_loop(0, n_experts, body, 0)


def _combine_rows(y_ref, out_ref, flat_e, flat_p, flat_w, *, k: int,
                  capacity: int):
    """The weighted gather-reduce (``kernels.dispatch._combine_kernel``
    body): y[t] = sum_j w_j * out[e_j, p_j], accumulated in f32 in
    ascending-j order (bit-identical reduction order to the unfused
    combine kernel)."""
    t = y_ref.shape[0]
    d = y_ref.shape[-1]
    ob = out_ref[...]

    def body(i, carry):
        acc = jnp.zeros((d,), jnp.float32)
        for j in range(k):                      # k <= 8: static unroll
            a = i * k + j
            e = flat_e[a]
            p = flat_p[a]
            pc = jnp.where(p < capacity, p, 0)
            w = jnp.where(p < capacity, flat_w[a], 0.0)
            acc = acc + w * ob[e, pc].astype(jnp.float32)
        y_ref[i] = acc.astype(y_ref.dtype)
        return carry

    jax.lax.fori_loop(0, t, body, 0)


# ---------------------------------------------------------------------------
# the fully-fused decode step (noisy_topk eval routing in-kernel)
# ---------------------------------------------------------------------------

def _decode_kernel(*refs, k: int, capacity: int, activation: str):
    if activation == "swiglu":
        (x_ref, valid_ref, wg_ref, w1_ref, w2_ref, w3_ref,
         y_ref, load_ref, over_ref, buf_ref, out_ref) = refs
    else:
        (x_ref, valid_ref, wg_ref, w1_ref, w2_ref,
         y_ref, load_ref, over_ref, buf_ref, out_ref) = refs
        w3_ref = None

    x = x_ref[...]                                             # [T, d]
    t = x.shape[0]
    xf = x.astype(jnp.float32)
    wg = wg_ref[...].astype(jnp.float32)                       # [d, E]
    e = wg.shape[-1]

    # --- routing: Eqs. (3)/(5), eval path (clean logits, no noise).
    # Rounds of masked argmax — same algorithm and lowest-index
    # tie-breaking as the fused top-k gating kernel / lax.top_k.
    logits = jnp.dot(xf, wg, preferred_element_type=jnp.float32)
    work = logits
    vals = []
    idxs = []
    for _ in range(k):
        m = jnp.max(work, axis=-1)
        i = jnp.argmax(work, axis=-1).astype(jnp.int32)
        vals.append(m)
        idxs.append(i)
        work = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, (t, e), 1) == i[:, None],
            NEG, work)
    vk = jnp.stack(vals, axis=-1)                              # [T, k] desc
    mx = vk[:, 0:1]                                            # top-1 = max
    p = jnp.exp(vk - mx)
    combine = p / jnp.sum(p, axis=-1, keepdims=True)           # [T, k] f32
    combine = combine * valid_ref[...]                         # [T, 1] mask
    eidx = jnp.stack(idxs, axis=-1)                            # [T, k] i32

    # --- capacity-slot assignment: the exact ``core.dispatch.plan``
    # non-priority semantics.  A positive assignment's slot is the count
    # of positive same-expert assignments strictly earlier in flat
    # token-major order (what the stable argsort there computes), here an
    # exclusive running count over the one-hot assignment matrix; zero-
    # weight assignments (masked/underflowed) take position == capacity.
    a_n = t * k
    flat_e = eidx.reshape(a_n)
    flat_w = combine.reshape(a_n)
    assigned = flat_w > 0.0
    hot = jnp.where(
        (jax.lax.broadcasted_iota(jnp.int32, (a_n, e), 1)
         == flat_e[:, None]) & assigned[:, None], 1.0, 0.0)    # [A, E]
    rank = jnp.cumsum(hot, axis=0) - hot                       # exclusive
    pos_f = jnp.sum(rank * hot, axis=-1)                       # [A]
    flat_p = jnp.where(assigned, pos_f.astype(jnp.int32), capacity)
    kept = flat_p < capacity
    flat_wk = jnp.where(kept, flat_w, 0.0)

    # --- serving telemetry (``router.route_telemetry`` counters): hard
    # assignment counts and capacity-truncation drops per expert.
    load_ref[...] = jnp.sum(hot, axis=0)[None, :]
    over_ref[...] = jnp.sum(
        hot * jnp.where(kept, 0.0, 1.0)[:, None], axis=0)[None, :]

    # --- scatter -> expert FFN -> weighted combine.
    _scatter_into(buf_ref, x, flat_e, flat_p, k=k, capacity=capacity)
    _expert_ffn_into(out_ref, buf_ref, w1_ref, w2_ref, w3_ref,
                     n_experts=e, activation=activation)
    _combine_rows(y_ref, out_ref, flat_e, flat_p, flat_wk, k=k,
                  capacity=capacity)


@functools.partial(jax.jit, static_argnames=("k", "capacity", "activation",
                                             "interpret"))
def decode_step(x: jax.Array, valid: jax.Array, wg: jax.Array,
                w1: jax.Array, w2: jax.Array, w3: jax.Array | None = None,
                *, k: int, capacity: int, activation: str = "relu",
                interpret: bool = True):
    """One fused MoE decode step (noisy_topk eval routing).

    x: [T, d] decode batch; valid: [T] f32 slot-occupancy mask; wg:
    [d, E] gate; w1/w2(/w3): [E, d, f]/[E, f, d]/([E, d, f]) expert
    weights.  Returns ``(y [T, d], expert_load [E] f32, overflow [E]
    f32)`` — output and telemetry bit-identical to the unfused
    route -> dispatch -> expert_ffn -> combine pipeline.
    """
    t, d = x.shape
    e = wg.shape[-1]
    f = w1.shape[-1]
    if k < 1 or k > e:
        raise ValueError(f"fused decode needs 1 <= k <= E: k={k}, E={e}")
    gated = activation == "swiglu"
    if gated and w3 is None:
        raise ValueError("activation='swiglu' needs w3")
    valid2 = valid.astype(jnp.float32).reshape(t, 1)
    kernel = functools.partial(_decode_kernel, k=k, capacity=capacity,
                               activation=activation)
    in_specs = [
        pl.BlockSpec((t, d), lambda i: (0, 0)),                # x
        pl.BlockSpec((t, 1), lambda i: (0, 0)),                # valid
        pl.BlockSpec((d, e), lambda i: (0, 0)),                # wg
        pl.BlockSpec((e, d, f), lambda i: (0, 0, 0)),          # w1
        pl.BlockSpec((e, f, d), lambda i: (0, 0, 0)),          # w2
    ]
    operands = [x, valid2, wg, w1, w2]
    if gated:
        in_specs.append(pl.BlockSpec((e, d, f), lambda i: (0, 0, 0)))
        operands.append(w3)
    y, load, over = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=0,
            grid=(1,),
            in_specs=in_specs,
            out_specs=(pl.BlockSpec((t, d), lambda i: (0, 0)),
                       pl.BlockSpec((1, e), lambda i: (0, 0)),
                       pl.BlockSpec((1, e), lambda i: (0, 0))),
            scratch_shapes=[pltpu.VMEM((e, capacity, d), x.dtype),
                            pltpu.VMEM((e, capacity, d), x.dtype)],
        ),
        out_shape=(jax.ShapeDtypeStruct((t, d), x.dtype),
                   jax.ShapeDtypeStruct((1, e), jnp.float32),
                   jax.ShapeDtypeStruct((1, e), jnp.float32)),
        interpret=interpret,
    )(*operands)
    return y, load.reshape(e), over.reshape(e)


# ---------------------------------------------------------------------------
# plan-mode fusion: dispatch -> grouped matmul(s) -> combine over explicit
# plans (expert_choice MoE, MoA routed projections)
# ---------------------------------------------------------------------------

def _routed_kernel(in_e_ref, in_p_ref, out_e_ref, out_p_ref, out_w_ref,
                   x_ref, *rest, k_in: int, k_out: int, capacity: int,
                   n_experts: int, mode: str, activation: str):
    if mode == "ffn":
        if activation == "swiglu":
            w1_ref, w2_ref, w3_ref, y_ref, buf_ref, out_ref = rest
        else:
            w1_ref, w2_ref, y_ref, buf_ref, out_ref = rest
            w3_ref = None
    else:
        w_ref, y_ref, buf_ref, out_ref = rest

    x = x_ref[...]
    _scatter_into(buf_ref, x, in_e_ref, in_p_ref, k=k_in,
                  capacity=capacity)
    if mode == "ffn":
        _expert_ffn_into(out_ref, buf_ref, w1_ref, w2_ref, w3_ref,
                         n_experts=n_experts, activation=activation)
    else:
        _proj_into(out_ref, buf_ref, w_ref, n_experts=n_experts)
    _combine_rows(y_ref, out_ref, out_e_ref, out_p_ref, out_w_ref,
                  k=k_out, capacity=capacity)


@functools.partial(jax.jit, static_argnames=("n_experts", "capacity",
                                             "mode", "activation",
                                             "out_dtype", "interpret"))
def routed_apply(x: jax.Array, in_eidx: jax.Array, in_pos: jax.Array,
                 out_eidx: jax.Array, out_pos: jax.Array,
                 out_w: jax.Array, w1: jax.Array,
                 w2: jax.Array | None = None, w3: jax.Array | None = None,
                 *, n_experts: int, capacity: int, mode: str = "ffn",
                 activation: str = "relu", out_dtype=None,
                 interpret: bool = True) -> jax.Array:
    """Fused dispatch -> grouped matmul(s) -> combine over explicit plans.

    ``in_eidx``/``in_pos`` ([T_in, k_in]) scatter rows of ``x`` into the
    [E, C, d_in] buffer; ``mode="ffn"`` applies the two(/three)-matrix
    expert FFN, ``mode="proj"`` the single grouped projection ``w1``;
    ``out_eidx``/``out_pos``/``out_w`` ([T_out, k_out]) drive the
    weighted gather back to rows.  Token-major [T, k] and MoA's
    assignment-major [T·k, 1] plan views both work — k is just a shape.
    """
    t_in, d_in = x.shape
    k_in = in_eidx.shape[1]
    k_out = out_eidx.shape[1]
    t_out = out_eidx.shape[0]
    e = n_experts
    if mode == "ffn":
        f = w1.shape[-1]
        d_out = w2.shape[-1]
    else:
        d_out = w1.shape[-1]
    out_dtype = out_dtype or x.dtype
    ie = in_eidx.reshape(-1)
    ip = in_pos.reshape(-1)
    oe = out_eidx.reshape(-1)
    op = out_pos.reshape(-1)
    ow = out_w.astype(jnp.float32).reshape(-1)
    kernel = functools.partial(_routed_kernel, k_in=k_in, k_out=k_out,
                               capacity=capacity, n_experts=e, mode=mode,
                               activation=activation)
    in_specs = [pl.BlockSpec((t_in, d_in), lambda i, *_: (0, 0))]
    operands = [x]
    if mode == "ffn":
        in_specs += [pl.BlockSpec((e, d_in, f), lambda i, *_: (0, 0, 0)),
                     pl.BlockSpec((e, f, d_out), lambda i, *_: (0, 0, 0))]
        operands += [w1, w2]
        if activation == "swiglu":
            if w3 is None:
                raise ValueError("activation='swiglu' needs w3")
            in_specs.append(
                pl.BlockSpec((e, d_in, f), lambda i, *_: (0, 0, 0)))
            operands.append(w3)
    else:
        in_specs.append(
            pl.BlockSpec((e, d_in, d_out), lambda i, *_: (0, 0, 0)))
        operands.append(w1)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(1,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((t_out, d_out), lambda i, *_: (0, 0)),
            scratch_shapes=[pltpu.VMEM((e, capacity, d_in), x.dtype),
                            pltpu.VMEM((e, capacity, d_out), x.dtype)],
        ),
        out_shape=jax.ShapeDtypeStruct((t_out, d_out), out_dtype),
        interpret=interpret,
    )(ie, ip, oe, op, ow, *operands)
