"""Kernel backend registry: the one switch between the jnp reference path
and the Pallas hot path.

The MoE layer's three compute hot-spots — top-k gating (Eqs. 3/5), the
dispatch/combine scatter, and the expert FFN grouped matmul (§3.2: the
experts carry ~40% of total FLOPs) — each exist twice in this repo: a pure
jnp/XLA reference and a fused Pallas kernel.  A :class:`KernelBackend`
bundles one coherent set of the three; ``moe_apply``, the expert-parallel
schedule, the trainer, and the microbenchmarks all go through
:func:`resolve` instead of importing kernels ad hoc.

Resolution is **explicit**: a backend that fails to import registers as
broken and ``get()`` raises :class:`KernelBackendError` with the original
import error — never a silent fall-back to the slow path (the lazy
``from repro.kernels import ops`` in old ``core/moe.py`` would degrade
with no signal; this registry is the fix).  Selection order:
``MoEArgs.kernel_backend`` if set, else the legacy ``expert_impl`` field
("pallas" -> pallas, anything else -> ref).

Observability: every backend call site (dispatch / expert-FFN GMM /
combine) runs under an ambient-tracer span (``kernel.dispatch`` /
``kernel.gmm`` / ``kernel.combine`` with backend + shape attrs,
``repro.obs.trace.current()``).  These sites execute during ``jax.jit``
*tracing*, so a recorded span measures trace/staging time at the step
that triggered compilation — per-call device time lives in the host-side
step spans (serve/train) that block on results.  With no tracer
installed the span is the shared no-op (docs/observability.md).

MeshContext awareness
---------------------
Backends consume the explicit sharding context (ROADMAP open item 3):

* :func:`shard_shape` maps a global logical shape to the per-shard view
  under ``ctx`` — dims shrink by the mesh axes that are both assigned by
  the plan *and* held Manual by an enclosing ``shard_map`` (that is what
  the kernel actually sees inside the expert-parallel body);
* :func:`block_plan` turns the per-shard ``[E_local, C, d] x d_ff`` FFN
  shapes into the Pallas block spec (tile sizes + padded dims) via
  ``gmm.plan_blocks`` — non-tile-aligned C/d_ff pad to tile boundaries
  instead of asserting;
* the pallas backend's ``expert_ffn`` validates its buffer against the
  per-shard expectation and fails loudly on a mesh/shape mismatch.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import dispatch as dsp
from repro.obs import trace as trace_lib
from repro.sharding import context as ctx_lib

log = logging.getLogger(__name__)


class KernelBackendError(RuntimeError):
    """Unknown, broken, or mis-shaped kernel backend — never swallowed."""


# ---------------------------------------------------------------------------
# MeshContext -> per-shard shapes / block specs
# ---------------------------------------------------------------------------

def shard_shape(ctx: "ctx_lib.MeshContext | None", shape, logical_axes
                ) -> tuple:
    """Global logical shape -> the per-shard shape a kernel body sees.

    Only mesh axes that the plan assigns to the logical dim *and* that the
    context holds in Manual mode shrink the dim (an enclosing ``shard_map``
    hands the body local blocks; Auto axes are GSPMD's and the kernel still
    sees the global dim at trace time).  Off-mesh this is the identity.
    """
    if ctx is None or ctx.mesh is None or not ctx.manual_axes:
        return tuple(shape)
    out = []
    for dim, logical in zip(shape, logical_axes):
        denom = 1
        for ax in ctx.rules.lookup(logical):
            if ax not in ctx.mesh.shape or ax not in ctx.manual_axes:
                continue
            size = ctx.mesh.shape[ax]
            if dim % (denom * size) == 0:
                denom *= size
        out.append(dim // denom)
    return tuple(out)


def block_plan(a, capacity: int, ctx: "ctx_lib.MeshContext | None" = None,
               *, dtype=None):
    """Per-shard Pallas block plan for the expert FFN's up-projection GMM:
    ``[E_local, C_local, d] x [E_local, d, f_local]``.

    Planning/introspection view of the same derivation the pallas
    ``expert_ffn`` performs on its (per-shard) operands at trace time:
    given the *global* MoE config + capacity, returns the ``gmm.BlockPlan``
    a shard will run — padded dims show exactly how a non-tile-aligned
    capacity / d_ff will be zero-padded on that shard.
    """
    from repro.kernels import gmm as gmm_lib
    e, c, d = shard_shape(
        ctx, (a.n_experts, capacity, a.d_model),
        ("experts", "expert_capacity", "embed"))
    (f,) = shard_shape(ctx, (a.d_ff,), ("expert_mlp",))
    return gmm_lib.plan_blocks(e, c, d, f, dtype or a.dtype)


def _check_local_buffer(x, a, ctx, backend_name: str):
    """Validate a dispatched [E?, C?, d] buffer against the per-shard view."""
    want_e, _, want_d = shard_shape(
        ctx, (a.n_experts, 1, a.d_model),
        ("experts", "expert_capacity", "embed"))
    if x.ndim != 3 or x.shape[2] != want_d or x.shape[0] % want_e != 0:
        raise KernelBackendError(
            f"backend {backend_name!r}: buffer {x.shape} does not match the "
            f"per-shard expert view [E_local={want_e}, C, d={want_d}] under "
            f"ctx manual axes {sorted(ctx.manual_axes) if ctx else None}")


# ---------------------------------------------------------------------------
# the backend record + registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelBackend:
    """One coherent implementation set for the MoE hot path.

    ``topk_impl`` is ``None`` for the jnp path (gating falls back to
    ``lax.top_k``); otherwise ``(noisy_logits, k, kk) -> (combine [T,k],
    idx [T,k], raw top values [T,kk])`` with the softmax fused.
    """
    name: str
    expert_ffn: Callable     # (params, x, a, *, ctx=None) -> [E, C, d]
    dispatch: Callable       # (x, plan, a, *, ctx=None)   -> [E, C, d]
    combine: Callable        # (buf, plan, a, *, dtype=None, ctx=None) -> [T,d]
    topk_impl: Callable | None = None
    # Single grouped matmul over capacity buffers: (x [E,C,K], w [E,K,N],
    # a, *, ctx=None) -> [E,C,N].  The MoA layer's routed Q/O projections
    # use this directly (one projection each, no FFN activation between).
    gmm: Callable | None = None


_REGISTRY: dict[str, "KernelBackend | Exception"] = {}


def register(backend: KernelBackend) -> None:
    _REGISTRY[backend.name] = backend


def register_broken(name: str, err: Exception) -> None:
    """Record an import failure so ``get(name)`` re-raises it explicitly."""
    _REGISTRY[name] = err


def available() -> list[str]:
    return sorted(n for n, b in _REGISTRY.items()
                  if isinstance(b, KernelBackend))


def get(name: str) -> KernelBackend:
    entry = _REGISTRY.get(name)
    if entry is None:
        raise KernelBackendError(
            f"unknown kernel backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}")
    if isinstance(entry, Exception):
        raise KernelBackendError(
            f"kernel backend {name!r} failed to import: {entry!r}"
        ) from entry
    return entry


def resolve(a) -> KernelBackend:
    """Backend for a MoEArgs-like config (``kernel_backend`` field, else the
    legacy ``expert_impl`` spelling).  Raises KernelBackendError — the MoE
    layer never silently degrades to a different implementation."""
    name = getattr(a, "kernel_backend", None)
    if name is None:
        legacy = getattr(a, "expert_impl", "einsum")
        if legacy != "einsum":
            import warnings
            warnings.warn(
                f"expert_impl={legacy!r} is a deprecated spelling; set "
                "kernel_backend explicitly (docs/kernels.md)",
                DeprecationWarning, stacklevel=2)
        name = "pallas" if legacy == "pallas" else "ref"
    backend = get(name)
    log.debug("kernel backend resolved: %s", name)
    return backend


# ---------------------------------------------------------------------------
# plan unwrapping + dispatch flavour
# ---------------------------------------------------------------------------

def _as_plan(p) -> dsp.DispatchPlan:
    """Backends accept a router ``RouteDecision`` wherever they accept a
    ``DispatchPlan`` — the typed decision carries the plan."""
    return getattr(p, "plan", p)


def _dispatch_impl(a) -> str:
    """Scatter flavour for the ref backend: the RouterSpec's ``dispatch``
    field when a spec is configured, else the legacy ``dispatch_impl``."""
    spec = getattr(a, "router", None)
    if spec is not None:
        return spec.dispatch
    return getattr(a, "dispatch_impl", "sort")


# ---------------------------------------------------------------------------
# "ref" — the pure jnp/XLA reference path
# ---------------------------------------------------------------------------

def _ref_expert_ffn(params, x, a, *, ctx=None):
    with trace_lib.current().span("kernel.gmm", backend="ref",
                                  shape=tuple(x.shape)):
        w1 = params["w1"].astype(a.dtype)
        w2 = params["w2"].astype(a.dtype)
        h = jnp.einsum("ecd,edf->ecf", x, w1,
                       preferred_element_type=jnp.float32)
        if a.activation == "swiglu":
            g = jnp.einsum("ecd,edf->ecf", x, params["w3"].astype(a.dtype),
                           preferred_element_type=jnp.float32)
            h = jax.nn.silu(h) * g
        else:
            h = jax.nn.relu(h)
        h = h.astype(a.dtype)
        return jnp.einsum("ecf,efd->ecd", h, w2,
                          preferred_element_type=jnp.float32).astype(a.dtype)


def _ref_dispatch(x, p, a, *, ctx=None):
    p = _as_plan(p)
    with trace_lib.current().span("kernel.dispatch", backend="ref",
                                  tokens=int(x.shape[0])):
        if _dispatch_impl(a) == "einsum":
            return dsp.dispatch_einsum(x, p)
        return dsp.dispatch(x, p)


def _ref_combine(buf, p, a, *, dtype=None, ctx=None):
    p = _as_plan(p)
    with trace_lib.current().span("kernel.combine", backend="ref",
                                  shape=tuple(buf.shape)):
        if _dispatch_impl(a) == "einsum":
            return dsp.combine_einsum(buf, p, dtype=dtype)
        return dsp.combine(buf, p, dtype=dtype)


def _ref_gmm(x, w, a, *, ctx=None):
    with trace_lib.current().span("kernel.gmm", backend="ref",
                                  shape=tuple(x.shape)):
        return jnp.einsum(
            "eck,ekn->ecn", x, w.astype(x.dtype),
            preferred_element_type=jnp.float32).astype(x.dtype)


register(KernelBackend(name="ref", expert_ffn=_ref_expert_ffn,
                       dispatch=_ref_dispatch, combine=_ref_combine,
                       topk_impl=None, gmm=_ref_gmm))


# ---------------------------------------------------------------------------
# "pallas" — the fused kernel path (registered broken if the import fails)
# ---------------------------------------------------------------------------

def _register_pallas() -> None:
    try:
        from repro.kernels import dispatch as dispatch_lib
        from repro.kernels import ops
    except Exception as err:  # noqa: BLE001 — recorded, re-raised on use
        register_broken("pallas", err)
        log.warning("pallas kernel backend unavailable: %r", err)
        return

    def _plan_e_block(a, n_experts, capacity, d, dtype, n_tokens, what):
        """Fused-kernel buffer-regime planning: ``(use_pallas, e_block)``.

        ``e_block=None`` keeps the whole [E, C, d] buffer VMEM-resident;
        an int runs the E-blocked kernels with that slab size.  The
        selection comes from ``dispatch_lib.select_e_block`` against the
        (configurable) budget, so past ~16 MiB the backend now *blocks*
        the expert dimension instead of bailing — only a shape whose
        single-expert slab still exceeds the budget falls back to the ref
        scatter (with a warning).  ``MoEArgs.dispatch_e_block`` forces a
        slab size explicitly."""
        forced = getattr(a, "dispatch_e_block", None)
        if forced is not None:
            return True, forced
        limit = getattr(a, "dispatch_vmem_limit", None)
        try:
            return True, dispatch_lib.select_e_block(
                n_experts, capacity, d, dtype, n_tokens=n_tokens,
                limit=limit)
        except dispatch_lib.DispatchVMEMError as err:
            log.warning(
                "pallas %s: %s; falling back to the ref path for this "
                "call", what, err)
            return False, None

    def _pallas_expert_ffn(params, x, a, *, ctx=None):
        if ctx is not None:
            _check_local_buffer(x, a, ctx, "pallas")
        # Tile choice: leave bm/bn/bk unset so each GMM plans its own
        # per-shard operand shapes (the operands here ARE the per-shard
        # view — a shard_map body hands local blocks, validated above) —
        # consulting the measured tuning table first, static defaults
        # otherwise.  `MoEArgs.gmm_autotune=False` pins the defaults.
        tiles = {}
        if not getattr(a, "gmm_autotune", True):
            from repro.kernels import gmm as gmm_lib
            tiles = dict(bm=gmm_lib.DEFAULT_TILE, bn=gmm_lib.DEFAULT_TILE,
                         bk=gmm_lib.DEFAULT_TILE)
        with trace_lib.current().span("kernel.gmm", backend="pallas",
                                      shape=tuple(x.shape)):
            return ops.expert_ffn(params, x, activation=a.activation,
                                  **tiles)

    def _pallas_dispatch(x, p, a, *, ctx=None):
        p = _as_plan(p)
        # p.n_experts is authoritative: the EP schedule dispatches local
        # tokens into *global*-E buffers before its all_to_all exchange —
        # exactly where E-blocking matters most.
        ok, e_block = _plan_e_block(a, p.n_experts, p.capacity,
                                    x.shape[-1], x.dtype, x.shape[0],
                                    "dispatch")
        with trace_lib.current().span("kernel.dispatch", backend="pallas",
                                      tokens=int(x.shape[0]), fused=ok):
            if not ok:
                return dsp.dispatch(x, p)
            return ops.dispatch(x, p.expert_index, p.position,
                                n_experts=p.n_experts, capacity=p.capacity,
                                vmem_limit=getattr(a, "dispatch_vmem_limit",
                                                   None),
                                e_block=e_block)

    def _pallas_combine(buf, p, a, *, dtype=None, ctx=None):
        p = _as_plan(p)
        # Same token-block term as ops.combine's own guard — both derive
        # from COMBINE_BLOCK_T, so a borderline shape cannot pass this
        # guard and trip (or regime-mismatch) the one a layer down.
        n_tok = min(dispatch_lib.COMBINE_BLOCK_T, p.expert_index.shape[0])
        ok, e_block = _plan_e_block(a, buf.shape[0], buf.shape[1],
                                    buf.shape[2], buf.dtype, n_tok,
                                    "combine")
        with trace_lib.current().span("kernel.combine", backend="pallas",
                                      shape=tuple(buf.shape), fused=ok):
            if not ok:
                return dsp.combine(buf, p, dtype=dtype)
            return ops.combine(buf, p.weight, p.expert_index, p.position,
                               out_dtype=dtype or buf.dtype,
                               vmem_limit=getattr(a, "dispatch_vmem_limit",
                                                  None),
                               e_block=e_block)

    def _pallas_topk(noisy, k, kk):
        w, idx, vals = ops.topk_gating_full(noisy, k, extra=kk - k)
        return w, idx[:, :k], vals

    def _pallas_gmm(x, w, a, *, ctx=None):
        tiles = {}
        if not getattr(a, "gmm_autotune", True):
            from repro.kernels import gmm as gmm_lib
            tiles = dict(bm=gmm_lib.DEFAULT_TILE, bn=gmm_lib.DEFAULT_TILE,
                         bk=gmm_lib.DEFAULT_TILE)
        with trace_lib.current().span("kernel.gmm", backend="pallas",
                                      shape=tuple(x.shape)):
            return ops.gmm(x, w.astype(x.dtype), activation="none",
                           **tiles)

    register(KernelBackend(name="pallas", expert_ffn=_pallas_expert_ffn,
                           dispatch=_pallas_dispatch,
                           combine=_pallas_combine,
                           topk_impl=_pallas_topk, gmm=_pallas_gmm))


_register_pallas()
