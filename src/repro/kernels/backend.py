"""Kernel backend registry: the one switch between the jnp reference path
and the Pallas hot path.

The MoE layer's three compute hot-spots — top-k gating (Eqs. 3/5), the
dispatch/combine scatter, and the expert FFN grouped matmul (§3.2: the
experts carry ~40% of total FLOPs) — each exist twice in this repo: a pure
jnp/XLA reference and a fused Pallas kernel.  A :class:`KernelBackend`
bundles one coherent set of the three; ``moe_apply``, the expert-parallel
schedule, the trainer, and the microbenchmarks all go through
:func:`resolve` instead of importing kernels ad hoc.

Resolution is **explicit**: a backend that fails to import registers as
broken and ``get()`` raises :class:`KernelBackendError` with the original
import error — never a silent fall-back to the slow path (the lazy
``from repro.kernels import ops`` in old ``core/moe.py`` would degrade
with no signal; this registry is the fix).  Selection order:
``MoEArgs.kernel_backend`` if set, else the legacy ``expert_impl`` field
("pallas" -> pallas, anything else -> ref).

Observability: every backend call site (dispatch / expert-FFN GMM /
combine) runs under an ambient-tracer span (``kernel.dispatch`` /
``kernel.gmm`` / ``kernel.combine`` with backend + shape attrs,
``repro.obs.trace.current()``).  These sites execute during ``jax.jit``
*tracing*, so a recorded span measures trace/staging time at the step
that triggered compilation — per-call device time lives in the host-side
step spans (serve/train) that block on results.  With no tracer
installed the span is the shared no-op (docs/observability.md).

MeshContext awareness
---------------------
Backends consume the explicit sharding context (ROADMAP open item 3):

* :func:`shard_shape` maps a global logical shape to the per-shard view
  under ``ctx`` — dims shrink by the mesh axes that are both assigned by
  the plan *and* held Manual by an enclosing ``shard_map`` (that is what
  the kernel actually sees inside the expert-parallel body);
* :func:`block_plan` turns the per-shard ``[E_local, C, d] x d_ff`` FFN
  shapes into the Pallas block spec (tile sizes + padded dims) via
  ``gmm.plan_blocks`` — non-tile-aligned C/d_ff pad to tile boundaries
  instead of asserting;
* the pallas backend's ``expert_ffn`` validates its buffer against the
  per-shard expectation and fails loudly on a mesh/shape mismatch.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import dispatch as dsp
from repro.obs import trace as trace_lib
from repro.sharding import context as ctx_lib

log = logging.getLogger(__name__)


class KernelBackendError(RuntimeError):
    """Unknown, broken, or mis-shaped kernel backend — never swallowed."""


# ---------------------------------------------------------------------------
# MeshContext -> per-shard shapes / block specs
# ---------------------------------------------------------------------------

def shard_shape(ctx: "ctx_lib.MeshContext | None", shape, logical_axes
                ) -> tuple:
    """Global logical shape -> the per-shard shape a kernel body sees.

    Only mesh axes that the plan assigns to the logical dim *and* that the
    context holds in Manual mode shrink the dim (an enclosing ``shard_map``
    hands the body local blocks; Auto axes are GSPMD's and the kernel still
    sees the global dim at trace time).  Off-mesh this is the identity.
    """
    if ctx is None or ctx.mesh is None or not ctx.manual_axes:
        return tuple(shape)
    out = []
    for dim, logical in zip(shape, logical_axes):
        denom = 1
        for ax in ctx.rules.lookup(logical):
            if ax not in ctx.mesh.shape or ax not in ctx.manual_axes:
                continue
            size = ctx.mesh.shape[ax]
            if dim % (denom * size) == 0:
                denom *= size
        out.append(dim // denom)
    return tuple(out)


def block_plan(a, capacity: int, ctx: "ctx_lib.MeshContext | None" = None,
               *, dtype=None):
    """Per-shard Pallas block plan for the expert FFN's up-projection GMM:
    ``[E_local, C_local, d] x [E_local, d, f_local]``.

    Planning/introspection view of the same derivation the pallas
    ``expert_ffn`` performs on its (per-shard) operands at trace time:
    given the *global* MoE config + capacity, returns the ``gmm.BlockPlan``
    a shard will run — padded dims show exactly how a non-tile-aligned
    capacity / d_ff will be zero-padded on that shard.
    """
    from repro.kernels import gmm as gmm_lib
    e, c, d = shard_shape(
        ctx, (a.n_experts, capacity, a.d_model),
        ("experts", "expert_capacity", "embed"))
    (f,) = shard_shape(ctx, (a.d_ff,), ("expert_mlp",))
    return gmm_lib.plan_blocks(e, c, d, f, dtype or a.dtype)


def _check_local_buffer(x, a, ctx, backend_name: str):
    """Validate a dispatched [E?, C?, d] buffer against the per-shard view."""
    want_e, _, want_d = shard_shape(
        ctx, (a.n_experts, 1, a.d_model),
        ("experts", "expert_capacity", "embed"))
    if x.ndim != 3 or x.shape[2] != want_d or x.shape[0] % want_e != 0:
        raise KernelBackendError(
            f"backend {backend_name!r}: buffer {x.shape} does not match the "
            f"per-shard expert view [E_local={want_e}, C, d={want_d}] under "
            f"ctx manual axes {sorted(ctx.manual_axes) if ctx else None}")


# ---------------------------------------------------------------------------
# the backend record + registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelBackend:
    """One coherent implementation set for the MoE hot path.

    ``topk_impl`` is ``None`` for the jnp path (gating falls back to
    ``lax.top_k``); otherwise ``(noisy_logits, k, kk) -> (combine [T,k],
    idx [T,k], raw top values [T,kk])`` with the softmax fused.
    """
    name: str
    expert_ffn: Callable     # (params, x, a, *, ctx=None) -> [E, C, d]
    dispatch: Callable       # (x, plan, a, *, ctx=None)   -> [E, C, d]
    combine: Callable        # (buf, plan, a, *, dtype=None, ctx=None) -> [T,d]
    topk_impl: Callable | None = None
    # Single grouped matmul over capacity buffers: (x [E,C,K], w [E,K,N],
    # a, *, ctx=None) -> [E,C,N].  The MoA layer's routed Q/O projections
    # use this directly (one projection each, no FFN activation between).
    gmm: Callable | None = None
    # One-launch serve decode step (inference-only; docs/kernels.md §Fused
    # decode step): (params, x [T,d], a, *, mask=None, ctx=None) ->
    # (y [T,d], telemetry dict with expert_load/overflow [E]).  The fused
    # kernel emits the same counter families route_telemetry does, so the
    # serve telemetry path is unchanged fused vs unfused.
    decode_step: Callable | None = None
    # One-launch routed projection over explicit plans (MoA decode):
    # (x, w [E,K,N], plan_in, plan_out, a, *, dtype=None, ctx=None) ->
    # [T_out, N] — fuses dispatch(plan_in) -> gmm -> combine(plan_out).
    decode_proj: Callable | None = None


_REGISTRY: dict[str, "KernelBackend | Exception"] = {}


def register(backend: KernelBackend) -> None:
    _REGISTRY[backend.name] = backend


def register_broken(name: str, err: Exception) -> None:
    """Record an import failure so ``get(name)`` re-raises it explicitly."""
    _REGISTRY[name] = err


def available() -> list[str]:
    return sorted(n for n, b in _REGISTRY.items()
                  if isinstance(b, KernelBackend))


def get(name: str) -> KernelBackend:
    entry = _REGISTRY.get(name)
    if entry is None:
        raise KernelBackendError(
            f"unknown kernel backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}")
    if isinstance(entry, Exception):
        raise KernelBackendError(
            f"kernel backend {name!r} failed to import: {entry!r}"
        ) from entry
    return entry


def resolve(a) -> KernelBackend:
    """Backend for a MoEArgs-like config (``kernel_backend`` field, else the
    legacy ``expert_impl`` spelling).  Raises KernelBackendError — the MoE
    layer never silently degrades to a different implementation."""
    name = getattr(a, "kernel_backend", None)
    if name is None:
        legacy = getattr(a, "expert_impl", "einsum")
        if legacy != "einsum":
            import warnings
            warnings.warn(
                f"expert_impl={legacy!r} is a deprecated spelling; set "
                "kernel_backend explicitly (docs/kernels.md)",
                DeprecationWarning, stacklevel=2)
        name = "pallas" if legacy == "pallas" else "ref"
    backend = get(name)
    log.debug("kernel backend resolved: %s", name)
    return backend


# ---------------------------------------------------------------------------
# plan unwrapping + dispatch flavour
# ---------------------------------------------------------------------------

def _as_plan(p) -> dsp.DispatchPlan:
    """Backends accept a router ``RouteDecision`` wherever they accept a
    ``DispatchPlan`` — the typed decision carries the plan."""
    return getattr(p, "plan", p)


def _dispatch_impl(a) -> str:
    """Scatter flavour for the ref backend: the RouterSpec's ``dispatch``
    field when a spec is configured, else the legacy ``dispatch_impl``."""
    spec = getattr(a, "router", None)
    if spec is not None:
        return spec.dispatch
    return getattr(a, "dispatch_impl", "sort")


# ---------------------------------------------------------------------------
# unfused decode-step composition (the ref decode_step, and the pallas
# backend's loud fallback when the fused slab exceeds the VMEM budget)
# ---------------------------------------------------------------------------

def _decode_step_via(bk: "KernelBackend", params, x, a, *, mask=None,
                     ctx=None):
    """Route -> dispatch -> expert FFN -> combine through ``bk``'s ops, in
    exactly ``moe_apply``'s order and constraint placement — the unfused
    semantics the fused kernel must be bit-identical to."""
    from repro.core import router as router_lib
    router = router_lib.build(a, topk_impl=bk.topk_impl)
    dec = router.route(params, x, train=False, rng=None, mask=mask)
    token_axis = "tokens" if getattr(a, "wide_dispatch", True) else "batch"
    x = ctx_lib.with_constraint(x, (token_axis, "embed"), ctx)
    buf = bk.dispatch(x, dec, a, ctx=ctx)
    buf = ctx_lib.with_constraint(
        buf, ("experts", "expert_capacity", "embed"), ctx)
    out = bk.expert_ffn(params, buf, a, ctx=ctx)
    out = ctx_lib.with_constraint(
        out, ("experts", "expert_capacity", "embed"), ctx)
    y = bk.combine(out, dec, a, dtype=x.dtype, ctx=ctx)
    return y, dec.telemetry


def _decode_proj_via(bk: "KernelBackend", x, w, plan_in, plan_out, a, *,
                     dtype=None, ctx=None):
    """dispatch(plan_in) -> gmm -> combine(plan_out) through ``bk``'s ops —
    the MoA routed-projection sequence (core/moa.py ``_routed_q``/
    ``_routed_o``); d_model-shaped buffers get the expert-view constraint
    exactly where those helpers place it."""
    d_model = getattr(a, "d_model", None)
    buf = bk.dispatch(x, plan_in, a, ctx=ctx)
    if buf.shape[-1] == d_model:
        buf = ctx_lib.with_constraint(
            buf, ("experts", "expert_capacity", "embed"), ctx)
    out = bk.gmm(buf, w, a, ctx=ctx)
    if out.shape[-1] == d_model:
        out = ctx_lib.with_constraint(
            out, ("experts", "expert_capacity", "embed"), ctx)
    return bk.combine(out, plan_out, a, dtype=dtype, ctx=ctx)


# ---------------------------------------------------------------------------
# "ref" — the pure jnp/XLA reference path
# ---------------------------------------------------------------------------

def _ref_expert_ffn(params, x, a, *, ctx=None):
    with trace_lib.current().span("kernel.gmm", backend="ref",
                                  shape=tuple(x.shape)):
        w1 = params["w1"].astype(a.dtype)
        w2 = params["w2"].astype(a.dtype)
        h = jnp.einsum("ecd,edf->ecf", x, w1,
                       preferred_element_type=jnp.float32)
        if a.activation == "swiglu":
            g = jnp.einsum("ecd,edf->ecf", x, params["w3"].astype(a.dtype),
                           preferred_element_type=jnp.float32)
            h = jax.nn.silu(h) * g
        else:
            h = jax.nn.relu(h)
        h = h.astype(a.dtype)
        return jnp.einsum("ecf,efd->ecd", h, w2,
                          preferred_element_type=jnp.float32).astype(a.dtype)


def _ref_dispatch(x, p, a, *, ctx=None):
    p = _as_plan(p)
    with trace_lib.current().span("kernel.dispatch", backend="ref",
                                  tokens=int(x.shape[0])):
        if _dispatch_impl(a) == "einsum":
            return dsp.dispatch_einsum(x, p)
        return dsp.dispatch(x, p)


def _ref_combine(buf, p, a, *, dtype=None, ctx=None):
    p = _as_plan(p)
    with trace_lib.current().span("kernel.combine", backend="ref",
                                  shape=tuple(buf.shape)):
        if _dispatch_impl(a) == "einsum":
            return dsp.combine_einsum(buf, p, dtype=dtype)
        return dsp.combine(buf, p, dtype=dtype)


def _ref_gmm(x, w, a, *, ctx=None):
    with trace_lib.current().span("kernel.gmm", backend="ref",
                                  shape=tuple(x.shape)):
        return jnp.einsum(
            "eck,ekn->ecn", x, w.astype(x.dtype),
            preferred_element_type=jnp.float32).astype(x.dtype)


def _ref_decode_step(params, x, a, *, mask=None, ctx=None):
    with trace_lib.current().span("kernel.decode_step", backend="ref",
                                  tokens=int(x.shape[0])):
        return _decode_step_via(get("ref"), params, x, a, mask=mask,
                                ctx=ctx)


def _ref_decode_proj(x, w, plan_in, plan_out, a, *, dtype=None, ctx=None):
    with trace_lib.current().span("kernel.decode_proj", backend="ref",
                                  tokens=int(x.shape[0])):
        return _decode_proj_via(get("ref"), x, w, plan_in, plan_out, a,
                                dtype=dtype, ctx=ctx)


register(KernelBackend(name="ref", expert_ffn=_ref_expert_ffn,
                       dispatch=_ref_dispatch, combine=_ref_combine,
                       topk_impl=None, gmm=_ref_gmm,
                       decode_step=_ref_decode_step,
                       decode_proj=_ref_decode_proj))


# ---------------------------------------------------------------------------
# "pallas" — the fused kernel path (registered broken if the import fails)
# ---------------------------------------------------------------------------

def _register_pallas() -> None:
    try:
        from repro.kernels import dispatch as dispatch_lib
        from repro.kernels import ops
    except Exception as err:  # noqa: BLE001 — recorded, re-raised on use
        register_broken("pallas", err)
        log.warning("pallas kernel backend unavailable: %r", err)
        return

    def _plan_e_block(a, n_experts, capacity, d, dtype, n_tokens, what):
        """Fused-kernel buffer-regime planning: ``(use_pallas, e_block)``.

        ``e_block=None`` keeps the whole [E, C, d] buffer VMEM-resident;
        an int runs the E-blocked kernels with that slab size.  The
        selection comes from ``dispatch_lib.select_e_block`` against the
        (configurable) budget, so past ~16 MiB the backend now *blocks*
        the expert dimension instead of bailing — only a shape whose
        single-expert slab still exceeds the budget falls back to the ref
        scatter (with a warning).  ``MoEArgs.dispatch_e_block`` forces a
        slab size explicitly."""
        forced = getattr(a, "dispatch_e_block", None)
        if forced is not None:
            return True, forced
        limit = getattr(a, "dispatch_vmem_limit", None)
        try:
            return True, dispatch_lib.select_e_block(
                n_experts, capacity, d, dtype, n_tokens=n_tokens,
                limit=limit)
        except dispatch_lib.DispatchVMEMError as err:
            log.warning(
                "pallas %s: %s; falling back to the ref path for this "
                "call", what, err)
            return False, None

    def _pallas_expert_ffn(params, x, a, *, ctx=None):
        if ctx is not None:
            _check_local_buffer(x, a, ctx, "pallas")
        # Tile choice: leave bm/bn/bk unset so each GMM plans its own
        # per-shard operand shapes (the operands here ARE the per-shard
        # view — a shard_map body hands local blocks, validated above) —
        # consulting the measured tuning table first, static defaults
        # otherwise.  `MoEArgs.gmm_autotune=False` pins the defaults.
        tiles = {}
        if not getattr(a, "gmm_autotune", True):
            from repro.kernels import gmm as gmm_lib
            tiles = dict(bm=gmm_lib.DEFAULT_TILE, bn=gmm_lib.DEFAULT_TILE,
                         bk=gmm_lib.DEFAULT_TILE)
        with trace_lib.current().span("kernel.gmm", backend="pallas",
                                      shape=tuple(x.shape)):
            return ops.expert_ffn(params, x, activation=a.activation,
                                  **tiles)

    def _pallas_dispatch(x, p, a, *, ctx=None):
        p = _as_plan(p)
        # p.n_experts is authoritative: the EP schedule dispatches local
        # tokens into *global*-E buffers before its all_to_all exchange —
        # exactly where E-blocking matters most.
        ok, e_block = _plan_e_block(a, p.n_experts, p.capacity,
                                    x.shape[-1], x.dtype, x.shape[0],
                                    "dispatch")
        with trace_lib.current().span("kernel.dispatch", backend="pallas",
                                      tokens=int(x.shape[0]), fused=ok):
            if not ok:
                return dsp.dispatch(x, p)
            return ops.dispatch(x, p.expert_index, p.position,
                                n_experts=p.n_experts, capacity=p.capacity,
                                vmem_limit=getattr(a, "dispatch_vmem_limit",
                                                   None),
                                e_block=e_block)

    def _pallas_combine(buf, p, a, *, dtype=None, ctx=None):
        p = _as_plan(p)
        # Same token-block term as ops.combine's own guard — both derive
        # from COMBINE_BLOCK_T, so a borderline shape cannot pass this
        # guard and trip (or regime-mismatch) the one a layer down.
        n_tok = min(dispatch_lib.COMBINE_BLOCK_T, p.expert_index.shape[0])
        ok, e_block = _plan_e_block(a, buf.shape[0], buf.shape[1],
                                    buf.shape[2], buf.dtype, n_tok,
                                    "combine")
        with trace_lib.current().span("kernel.combine", backend="pallas",
                                      shape=tuple(buf.shape), fused=ok):
            if not ok:
                return dsp.combine(buf, p, dtype=dtype)
            return ops.combine(buf, p.weight, p.expert_index, p.position,
                               out_dtype=dtype or buf.dtype,
                               vmem_limit=getattr(a, "dispatch_vmem_limit",
                                                  None),
                               e_block=e_block)

    def _pallas_topk(noisy, k, kk):
        w, idx, vals = ops.topk_gating_full(noisy, k, extra=kk - k)
        return w, idx[:, :k], vals

    def _fused_budget_ok(a, need: int, what: str) -> bool:
        """Guard the fused decode slab against the VMEM budget.  Everything
        (weights included) is resident for the single grid step, so past
        the limit we warn *loudly* (RuntimeWarning — same contract as the
        dispatch VMEM fallback) and run the unfused pallas pipeline."""
        limit = (getattr(a, "dispatch_vmem_limit", None)
                 or dispatch_lib.DEFAULT_VMEM_LIMIT)
        if need <= limit:
            return True
        import warnings
        warnings.warn(
            f"pallas {what}: fused slab needs ~{need / 1e6:.1f} MB VMEM "
            f"> limit {limit / 1e6:.1f} MB; falling back to the unfused "
            "kernel pipeline for this call (docs/kernels.md §Fused decode "
            "step)", RuntimeWarning, stacklevel=3)
        return False

    def _pallas_decode_step(params, x, a, *, mask=None, ctx=None):
        from repro.core import router as router_lib
        from repro.kernels import fused_decode as fused_lib
        spec = router_lib.resolve_spec(a)
        t, d = x.shape
        e = a.n_experts
        k = min(spec.k, e)
        capacity = spec.capacity(t, e, train=False)
        gated = a.activation == "swiglu"
        wdt = params["w1"].dtype
        with trace_lib.current().span("kernel.decode_step",
                                      backend="pallas", tokens=int(t)):
            if spec.policy == "noisy_topk" and not spec.priority_dispatch:
                # Full fusion: eval routing is the deterministic clean-
                # logit top-k, computed in-kernel alongside everything
                # else; telemetry comes back as kernel outputs.
                need = fused_lib.decode_vmem_bytes(
                    t, d, a.d_ff, e, capacity, x.dtype, wdt, gated=gated)
                if not _fused_budget_ok(a, need, "decode_step"):
                    return _decode_step_via(get("pallas"), params, x, a,
                                            mask=mask, ctx=ctx)
                valid = (jnp.ones((t,), jnp.float32) if mask is None
                         else jnp.asarray(mask, jnp.float32).reshape(-1))
                y, load, overflow = ops.fused_decode_step(
                    x, valid, params["gate"]["wg"], params["w1"],
                    params["w2"], params.get("w3") if gated else None,
                    k=k, capacity=capacity, activation=a.activation)
                return y, {"expert_load": load, "overflow": overflow}
            # Any other policy (expert_choice's batch-global column top-k,
            # Appendix-F batchwise/threshold, priority dispatch): routing
            # runs outside as plain XLA ops — still zero extra kernel
            # launches — and the plan-mode kernel fuses the rest.
            router = router_lib.build(a, topk_impl=None)
            dec = router.route(params, x, train=False, rng=None, mask=mask)
            p = _as_plan(dec)
            need = fused_lib.routed_vmem_bytes(
                t, d, d, a.d_ff, e, p.capacity, x.dtype, wdt,
                mode="ffn", gated=gated)
            if not _fused_budget_ok(a, need, "decode_step"):
                return _decode_step_via(get("pallas"), params, x, a,
                                        mask=mask, ctx=ctx)
            y = ops.fused_routed_apply(
                x, p, p, params["w1"], params["w2"],
                params.get("w3") if gated else None,
                mode="ffn", activation=a.activation, out_dtype=x.dtype)
            return y, dec.telemetry

    def _pallas_decode_proj(x, w, plan_in, plan_out, a, *, dtype=None,
                            ctx=None):
        from repro.kernels import fused_decode as fused_lib
        p_in = _as_plan(plan_in)
        p_out = _as_plan(plan_out)
        with trace_lib.current().span("kernel.decode_proj",
                                      backend="pallas",
                                      tokens=int(x.shape[0])):
            need = fused_lib.routed_vmem_bytes(
                x.shape[0], x.shape[-1], w.shape[-1], 0, p_in.n_experts,
                p_in.capacity, x.dtype, w.dtype, mode="proj")
            if not _fused_budget_ok(a, need, "decode_proj"):
                return _decode_proj_via(get("pallas"), x, w, p_in, p_out,
                                        a, dtype=dtype, ctx=ctx)
            return ops.fused_routed_apply(
                x, p_in, p_out, w, mode="proj",
                out_dtype=dtype or x.dtype)

    def _pallas_gmm(x, w, a, *, ctx=None):
        tiles = {}
        if not getattr(a, "gmm_autotune", True):
            from repro.kernels import gmm as gmm_lib
            tiles = dict(bm=gmm_lib.DEFAULT_TILE, bn=gmm_lib.DEFAULT_TILE,
                         bk=gmm_lib.DEFAULT_TILE)
        with trace_lib.current().span("kernel.gmm", backend="pallas",
                                      shape=tuple(x.shape)):
            return ops.gmm(x, w.astype(x.dtype), activation="none",
                           **tiles)

    register(KernelBackend(name="pallas", expert_ffn=_pallas_expert_ffn,
                           dispatch=_pallas_dispatch,
                           combine=_pallas_combine,
                           topk_impl=_pallas_topk, gmm=_pallas_gmm,
                           decode_step=_pallas_decode_step,
                           decode_proj=_pallas_decode_proj))


_register_pallas()
