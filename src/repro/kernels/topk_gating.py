"""Fused top-k gating kernel (Eqs. 3/5, deterministic part).

One pass over a [T_blk, E] logits tile in VMEM produces the top-k values
and indices via rounds of masked argmax (k <= 8 in every assigned arch)
plus the softmax over the k survivors — fusing what XLA would otherwise
lower as sort + gather + scatter + softmax with four HBM round-trips of the
[T, E] logits.  E is small (<= 384 here) so a whole expert row fits a tile:
a 256x384 f32 tile is 384 KiB of VMEM.

Beyond the k softmaxed winners the kernel can emit ``extra`` additional raw
top values (``topk_gating_full``): the noisy gating path needs the
(k+1)-th noisy logit for the Appendix-A smooth load estimator, and fusing
that extra argmax round is free compared to a second sort.

T need not divide the block: trailing rows are zero-padded and trimmed.

Training: a ``jax.custom_vjp`` scatters the softmax-jacobian cotangent (and
any cotangent on the raw values) back to the winning logit positions —
exactly the VJP of ``lax.top_k`` + ``jax.nn.softmax``, so gradients match
the jnp oracle bit-for-bit up to reduction order.

Noise injection and the load estimator stay outside the kernel (they are
bandwidth-trivial elementwise ops XLA already fuses well); the kernel
covers the sort-like part that XLA lowers poorly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gmm import round_up as _round_up

NEG = -1e30


def _topk_kernel(logits_ref, w_ref, idx_ref, vals_ref, *, k: int, kk: int):
    x = logits_ref[...].astype(jnp.float32)           # [T_blk, E]
    t, e = x.shape
    vals = []
    idxs = []
    work = x
    for _ in range(kk):
        m = jnp.max(work, axis=-1)                    # [T_blk]
        i = jnp.argmax(work, axis=-1).astype(jnp.int32)
        vals.append(m)
        idxs.append(i)
        work = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, (t, e), 1) == i[:, None],
            NEG, work)
    v = jnp.stack(vals, axis=-1)                      # [T_blk, kk]
    # softmax over the k kept entries (Eq. 3: Softmax(KeepTopK(...)))
    vk = v[:, :k]
    mx = vk[:, 0:1]                                   # top-1 is the max
    p = jnp.exp(vk - mx)
    w_ref[...] = (p / jnp.sum(p, axis=-1, keepdims=True)).astype(
        w_ref.dtype)
    idx_ref[...] = jnp.stack(idxs, axis=-1)
    vals_ref[...] = v


def _topk_raw(logits, k, extra, block_t, interpret):
    t, e = logits.shape
    kk = k + extra
    if kk > e:
        # Real exception, not an assert: `python -O` would strip the check
        # and the kernel would silently pick from out-of-range lanes.
        raise ValueError(
            f"top-k gating needs k + extra <= n_experts: "
            f"k={k} + extra={extra} > E={e}")
    bt = min(block_t, _round_up(t, 8))
    tp = _round_up(t, bt)
    lp = jnp.pad(logits, ((0, tp - t), (0, 0))) if tp != t else logits
    kernel = functools.partial(_topk_kernel, k=k, kk=kk)
    w, idx, vals = pl.pallas_call(
        kernel,
        grid=(tp // bt,),
        in_specs=[pl.BlockSpec((bt, e), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((bt, kk), lambda i: (i, 0)),
                   pl.BlockSpec((bt, kk), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((tp, k), jnp.float32),
                   jax.ShapeDtypeStruct((tp, kk), jnp.int32),
                   jax.ShapeDtypeStruct((tp, kk), jnp.float32)),
        interpret=interpret,
    )(lp)
    if tp != t:
        w, idx, vals = w[:t], idx[:t], vals[:t]
    return w, idx, vals


# NOTE: the custom_vjp boundary must not return integer outputs — under
# lax.scan + remat (the transformer stack) jax linearizes through it and
# instantiates float0 cotangents for int dtypes, which downstream integer
# arithmetic (the dispatch plan's argsort keys) cannot consume.  The
# vjp'd core therefore carries the indices as f32 (E <= 384, exact) and
# the public wrappers cast back to int32 outside the boundary.

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def _topk(logits, k, extra, block_t, interpret):
    w, idx, vals = _topk_raw(logits, k, extra, block_t, interpret)
    return w, idx.astype(jnp.float32), vals


def _topk_fwd(logits, k, extra, block_t, interpret):
    w, idx, vals = _topk_raw(logits, k, extra, block_t, interpret)
    # The backward pass needs only logits' static shape/dtype for the
    # scatter target; a zero-row slice carries both without keeping the
    # [T, E] noisy-logits tensor alive as a residual (it matters under the
    # transformer stack's remat budget).
    return (w, idx.astype(jnp.float32), vals), (logits[:0], w, idx)


def _topk_bwd(k, extra, block_t, interpret, res, cts):
    empty, w, idx = res                       # empty: [0, E], logits dtype
    dw, _, dvals = cts                        # index output carries no grad
    # Softmax jacobian over the k kept entries: dv_i = w_i (dw_i - <w, dw>).
    dw = dw.astype(jnp.float32)
    dv = w * (dw - jnp.sum(w * dw, axis=-1, keepdims=True))
    dv_full = dvals.astype(jnp.float32).at[:, :k].add(dv)   # [T, kk]
    t = idx.shape[0]
    dlogits = jnp.zeros((t, empty.shape[1]), jnp.float32).at[
        jnp.arange(t)[:, None], idx].add(dv_full)
    return (dlogits.astype(empty.dtype),)


_topk.defvjp(_topk_fwd, _topk_bwd)


def _topk_int(logits, k, extra, block_t, interpret):
    w, idx_f, vals = _topk(logits, k, extra, block_t, interpret)
    idx = jax.lax.stop_gradient(idx_f).astype(jnp.int32)
    return w, idx, vals


@functools.partial(jax.jit, static_argnames=("k", "extra", "block_t",
                                             "interpret"))
def topk_gating_full(logits: jax.Array, k: int, extra: int = 0, *,
                     block_t: int = 256, interpret: bool = True):
    """logits: [T, E] -> (weights [T, k] f32 softmaxed over the top-k,
    indices [T, k+extra] i32, raw top values [T, k+extra] f32)."""
    return _topk_int(logits, k, extra, block_t, interpret)


@functools.partial(jax.jit, static_argnames=("k", "block_t", "interpret"))
def topk_gating(logits: jax.Array, k: int, *, block_t: int = 256,
                interpret: bool = True):
    """logits: [T, E] -> (weights [T, k] f32, indices [T, k] i32)."""
    w, idx, _ = _topk_int(logits, k, 0, block_t, interpret)
    return w, idx
