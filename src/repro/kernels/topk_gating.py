"""Fused top-k gating kernel (Eqs. 3/5, deterministic part).

One pass over a [T_blk, E] logits tile in VMEM produces the top-k values
and indices via k rounds of masked argmax (k <= 8 in every assigned arch)
plus the softmax over the k survivors — fusing what XLA would otherwise
lower as sort + gather + scatter + softmax with four HBM round-trips of the
[T, E] logits.  E is small (<= 384 here) so a whole expert row fits a tile:
a 256x384 f32 tile is 384 KiB of VMEM.

Noise injection and the load estimator stay outside the kernel (they are
bandwidth-trivial elementwise ops XLA already fuses well); the kernel
covers the sort-like part that XLA lowers poorly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _topk_kernel(logits_ref, w_ref, idx_ref, *, k: int):
    x = logits_ref[...].astype(jnp.float32)           # [T_blk, E]
    t, e = x.shape
    vals = []
    idxs = []
    work = x
    for _ in range(k):
        m = jnp.max(work, axis=-1)                    # [T_blk]
        i = jnp.argmax(work, axis=-1).astype(jnp.int32)
        vals.append(m)
        idxs.append(i)
        work = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, (t, e), 1) == i[:, None],
            NEG, work)
    v = jnp.stack(vals, axis=-1)                      # [T_blk, k]
    # softmax over the k kept entries (Eq. 3: Softmax(KeepTopK(...)))
    mx = v[:, 0:1]                                    # top-1 is the max
    p = jnp.exp(v - mx)
    w_ref[...] = (p / jnp.sum(p, axis=-1, keepdims=True)).astype(
        w_ref.dtype)
    idx_ref[...] = jnp.stack(idxs, axis=-1)


@functools.partial(jax.jit, static_argnames=("k", "block_t", "interpret"))
def topk_gating(logits: jax.Array, k: int, *, block_t: int = 256,
                interpret: bool = True):
    """logits: [T, E] -> (weights [T, k] f32, indices [T, k] i32)."""
    t, e = logits.shape
    block_t = min(block_t, t)
    assert t % block_t == 0, (t, block_t)
    kernel = functools.partial(_topk_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(t // block_t,),
        in_specs=[pl.BlockSpec((block_t, e), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((block_t, k), lambda i: (i, 0)),
                   pl.BlockSpec((block_t, k), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((t, k), jnp.float32),
                   jax.ShapeDtypeStruct((t, k), jnp.int32)),
        interpret=interpret,
    )(logits)
