"""Jit'd public wrappers around the Pallas kernels.

On TPU these dispatch the compiled kernels; on the CPU build host they run
in interpret mode (kernel bodies executed with jnp), which is how the
allclose tests against ``ref.py`` validate them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import gmm as gmm_lib
from repro.kernels import topk_gating as topk_lib

_INTERPRET = jax.default_backend() != "tpu"


def gmm(x, w, *, activation: str = "none", bm=128, bn=128, bk=128):
    return gmm_lib.gmm(x, w, activation=activation, bm=bm, bn=bn, bk=bk,
                       interpret=_INTERPRET)


def expert_ffn(params, x, *, activation: str = "relu"):
    """Two fused GMMs: up-projection (+act) then down-projection.

    x: [E, C, d]; params carries w1 [E,d,f], w2 [E,f,d], (w3 for swiglu).
    """
    dt = x.dtype
    w1 = params["w1"].astype(dt)
    w2 = params["w2"].astype(dt)
    if activation == "swiglu":
        h = gmm(x, w1, activation="silu")
        g = gmm(x, params["w3"].astype(dt), activation="none")
        h = (h.astype(jnp.float32) * g.astype(jnp.float32)).astype(dt)
    else:
        h = gmm(x, w1, activation="relu")
    return gmm(h, w2, activation="none")


def topk_gating(logits, k: int, block_t: int = 256):
    return topk_lib.topk_gating(logits, k, block_t=block_t,
                                interpret=_INTERPRET)
