"""Jit'd public wrappers around the Pallas kernels.

On TPU these dispatch the compiled kernels; on the CPU build host they run
in interpret mode (kernel bodies executed with jnp), which is how the
allclose tests against ``ref.py`` validate them.

All ops are differentiable (each kernel carries a ``jax.custom_vjp``).
The MeshContext-aware layer lives one level up in
``repro.kernels.backend``: the registry's pallas backend derives the
*per-shard* ``[E_local, C, d]`` view from a ``MeshContext`` and validates
buffers against it before handing the local shapes to these wrappers
(whose kernels pad non-tile-aligned dims internally).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch as dispatch_lib
from repro.kernels import fused_decode as fused_lib
from repro.kernels import gmm as gmm_lib
from repro.kernels import topk_gating as topk_lib

_INTERPRET = jax.default_backend() != "tpu"


def gmm(x, w, *, activation: str = "none", bm=None, bn=None, bk=None):
    return gmm_lib.gmm(x, w, activation=activation, bm=bm, bn=bn, bk=bk,
                       interpret=_INTERPRET)


def expert_ffn(params, x, *, activation: str = "relu",
               bm=None, bn=None, bk=None):
    """Two fused GMMs: up-projection (+act) then down-projection.

    x: [E, C, d]; params carries w1 [E,d,f], w2 [E,f,d], (w3 for swiglu).
    Differentiable end-to-end via the GMM custom VJP.  ``bm/bn/bk`` cap
    the tile walk; left as ``None`` each GMM plans its own operand shapes
    (measured tuning table, then static defaults — see gmm.plan_blocks).
    """
    dt = x.dtype
    w1 = params["w1"].astype(dt)
    w2 = params["w2"].astype(dt)
    blocks = dict(bm=bm, bn=bn, bk=bk)
    if activation == "swiglu":
        h = gmm(x, w1, activation="silu", **blocks)
        g = gmm(x, params["w3"].astype(dt), activation="none", **blocks)
        h = (h.astype(jnp.float32) * g.astype(jnp.float32)).astype(dt)
    else:
        h = gmm(x, w1, activation="relu", **blocks)
    return gmm(h, w2, activation="none", **blocks)


def topk_gating(logits, k: int, block_t: int = 256):
    return topk_lib.topk_gating(logits, k, block_t=block_t,
                                interpret=_INTERPRET)


def topk_gating_full(logits, k: int, extra: int = 0, block_t: int = 256):
    """(weights [T,k], indices [T,k+extra], raw top values [T,k+extra]).

    The ``extra`` raw values feed the Appendix-A load estimator (the noisy
    gating path needs the (k+1)-th noisy logit as threshold).
    """
    return topk_lib.topk_gating_full(logits, k, extra, block_t=block_t,
                                     interpret=_INTERPRET)


def dispatch(x, eidx, pos, *, n_experts: int, capacity: int,
             vmem_limit: int | None = None, e_block: int | None = None):
    """Fused capacity-buffer build, [T, d] -> [E, C, d].

    ``e_block=None`` auto-selects the buffer regime against the VMEM
    budget (resident when it fits, E-blocked slabs otherwise); raises
    ``DispatchVMEMError`` only when even a one-expert slab exceeds it
    (see kernels/dispatch.py)."""
    return dispatch_lib.dispatch(x, eidx, pos, n_experts=n_experts,
                                 capacity=capacity, interpret=_INTERPRET,
                                 vmem_limit=vmem_limit, e_block=e_block)


def fused_decode_step(x, valid, wg, w1, w2, w3=None, *, k: int,
                      capacity: int, activation: str = "relu"):
    """One fused MoE decode step (routing + scatter + expert FFN +
    combine in a single pallas launch).  Inference-only — no custom VJP;
    see kernels/fused_decode.py.  Returns (y, expert_load, overflow)."""
    return fused_lib.decode_step(x, valid, wg, w1, w2, w3, k=k,
                                 capacity=capacity, activation=activation,
                                 interpret=_INTERPRET)


def fused_routed_apply(x, plan_in, plan_out, w1, w2=None, w3=None, *,
                       mode: str = "ffn", activation: str = "relu",
                       out_dtype=None):
    """Fused dispatch -> grouped matmul(s) -> combine over explicit
    ``DispatchPlan``s (any routing policy; MoA's assignment-major plan
    views included).  Inference-only; see kernels/fused_decode.py."""
    return fused_lib.routed_apply(
        x, plan_in.expert_index, plan_in.position,
        plan_out.expert_index, plan_out.position, plan_out.weight,
        w1, w2, w3, n_experts=plan_in.n_experts,
        capacity=plan_in.capacity, mode=mode, activation=activation,
        out_dtype=out_dtype, interpret=_INTERPRET)


def combine(buf, w, eidx, pos, *, out_dtype=None,
            vmem_limit: int | None = None, e_block: int | None = None):
    """Fused weighted combine, [E, C, d] -> [T, d].  Buffer regime as in
    :func:`dispatch`; raises ``DispatchVMEMError`` only when even a
    one-expert slab exceeds the budget (see kernels/dispatch.py)."""
    return dispatch_lib.combine(buf, w, eidx, pos, out_dtype=out_dtype,
                                interpret=_INTERPRET,
                                vmem_limit=vmem_limit, e_block=e_block)
