"""Typed metrics instruments: Counter / Gauge / Histogram in a registry.

Replaces stringly-typed stats dicts and unbounded per-step telemetry
lists (docs/observability.md).  Three instrument kinds:

* :class:`Counter` — monotonically non-decreasing float (``inc``);
* :class:`Gauge` — set/inc/dec to any value;
* :class:`Histogram` — fixed-bucket distribution with **bounded memory**
  (one int per bucket, never a sample list): ``observe`` is O(log B),
  ``percentile`` interpolates within the covering bucket, exact min/max
  are tracked separately.  This is what per-step serve telemetry
  aggregates into instead of growing a python list for the lifetime of
  the engine.

Instruments may be *labelled* (``registry.counter("expert_load",
labels=("expert",))``): ``.labels(expert=3)`` get-or-creates one child
per label value, Prometheus-style, and the registry snapshot flattens
children as ``name{expert=3}``.

``MetricsRegistry.stats()`` renders every unlabelled counter/gauge as a
plain ``{name: number}`` dict — the back-compat view behind
``ServeEngine.stats`` (integral values come back as ``int`` so existing
``== 6`` comparisons keep their type).
"""
from __future__ import annotations

import bisect
import math


class MetricError(ValueError):
    """Instrument redeclared with a different type/labels, or misused."""


# Default histogram buckets: geometric, 1e-9 .. 1e6 at ~1.26x steps (ten
# per decade).  Wide enough for step wall times in seconds at the low end
# and token counts / latencies-in-steps at the high end, fine enough that
# an interpolated percentile sits within ~26% of the exact one; 151 ints
# of memory per histogram, forever.
DEFAULT_BUCKETS = tuple(10.0 ** (-9 + i / 10.0) for i in range(151))


class Counter:
    """Monotonic counter (float; negative increments are an error)."""

    __slots__ = ("name", "_v")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self._v = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise MetricError(
                f"counter {self.name!r}: negative increment {n} "
                "(use a Gauge for values that go down)")
        self._v += n

    @property
    def value(self) -> float:
        return self._v

    def snapshot(self) -> dict:
        return {"kind": self.kind, "value": self._v}


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "_v")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self._v = 0.0

    def set(self, v: float) -> None:
        self._v = float(v)

    def inc(self, n: float = 1.0) -> None:
        self._v += n

    def dec(self, n: float = 1.0) -> None:
        self._v -= n

    @property
    def value(self) -> float:
        return self._v

    def snapshot(self) -> dict:
        return {"kind": self.kind, "value": self._v}


class Histogram:
    """Fixed-bucket histogram: bounded memory, interpolated percentiles.

    ``buckets`` are ascending upper bounds; values past the last bound
    land in a +inf overflow bucket.  ``percentile`` walks the cumulative
    counts to the covering bucket and interpolates linearly inside it,
    clamped to the exact observed min/max (so p0/p100 are exact and a
    single-sample histogram reports that sample at every percentile).
    """

    __slots__ = ("name", "_bounds", "_counts", "_n", "_sum", "_min", "_max")
    kind = "histogram"

    def __init__(self, name: str, buckets=None):
        self.name = name
        bounds = tuple(float(b) for b in (buckets or DEFAULT_BUCKETS))
        if list(bounds) != sorted(set(bounds)):
            raise MetricError(
                f"histogram {self.name!r}: bucket bounds must be strictly "
                f"ascending, got {bounds}")
        self._bounds = bounds
        self._counts = [0] * (len(bounds) + 1)   # +1: overflow bucket
        self._n = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self._counts[bisect.bisect_left(self._bounds, v)] += 1
        self._n += 1
        self._sum += v
        self._min = v if v < self._min else self._min
        self._max = v if v > self._max else self._max

    @property
    def count(self) -> int:
        return self._n

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._n if self._n else 0.0

    def percentile(self, p: float) -> float:
        """Interpolated percentile, ``p`` in [0, 100]."""
        if not 0.0 <= p <= 100.0:
            raise MetricError(f"percentile {p} outside [0, 100]")
        if self._n == 0:
            return 0.0
        rank = p / 100.0 * self._n
        cum = 0
        for i, c in enumerate(self._counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo = self._bounds[i - 1] if i > 0 else 0.0
                hi = self._bounds[i] if i < len(self._bounds) else self._max
                frac = (rank - cum) / c
                est = lo + (hi - lo) * min(max(frac, 0.0), 1.0)
                return min(max(est, self._min), self._max)
            cum += c
        return self._max

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    def snapshot(self) -> dict:
        return {"kind": self.kind, "count": self._n, "sum": self._sum,
                "min": self._min if self._n else 0.0,
                "max": self._max if self._n else 0.0,
                "p50": self.p50, "p95": self.p95, "p99": self.p99}


class _Family:
    """A labelled instrument: one child per label-value tuple."""

    __slots__ = ("name", "labels", "child_kind", "_make", "_children")
    kind = "family"

    def __init__(self, name: str, label_names: tuple, make, child_kind):
        self.name = name
        self.labels = tuple(label_names)
        self.child_kind = child_kind
        self._make = make
        self._children = {}

    def child(self, **labels):
        if tuple(sorted(labels)) != tuple(sorted(self.labels)):
            raise MetricError(
                f"{self.name!r} declared with labels {self.labels}, "
                f"got {tuple(labels)}")
        key = tuple(labels[k] for k in self.labels)
        inst = self._children.get(key)
        if inst is None:
            tag = ",".join(f"{k}={labels[k]}" for k in self.labels)
            inst = self._make(f"{self.name}{{{tag}}}")
            self._children[key] = inst
        return inst

    def children(self) -> dict:
        return dict(self._children)

    def snapshot(self) -> dict:
        return {"kind": self.kind, "labels": list(self.labels),
                "children": {inst.name: inst.snapshot()
                             for inst in self._children.values()}}


class MetricsRegistry:
    """Declared, typed instruments under unique names.

    Re-requesting a name returns the existing instrument when the type
    (and labels / buckets) match, and raises :class:`MetricError`
    otherwise — typos cannot silently fork a second counter.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _declare(self, name: str, make, kind: str, labels=None):
        inst = self._metrics.get(name)
        if inst is None:
            inst = (_Family(name, tuple(labels), make, kind) if labels
                    else make(name))
            self._metrics[name] = inst
            return inst
        ok = ((inst.kind == "family" and labels
               and tuple(inst.labels) == tuple(labels)
               and inst.child_kind == kind)
              or (inst.kind == kind and not labels))
        if not ok:
            have = (f"family[{inst.child_kind}] labels={inst.labels}"
                    if inst.kind == "family" else inst.kind)
            raise MetricError(
                f"metric {name!r} already declared as {have}; cannot "
                f"redeclare as {kind} labels={tuple(labels or ())}")
        return inst

    def counter(self, name: str, labels=None):
        return self._declare(name, Counter, "counter", labels)

    def gauge(self, name: str, labels=None):
        return self._declare(name, Gauge, "gauge", labels)

    def histogram(self, name: str, buckets=None, labels=None):
        def make(n, _b=buckets):
            return Histogram(n, buckets=_b)
        return self._declare(name, make, "histogram", labels)

    def get(self, name: str):
        inst = self._metrics.get(name)
        if inst is None:
            raise MetricError(f"unknown metric {name!r}; declared: "
                              f"{sorted(self._metrics)}")
        return inst

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __iter__(self):
        return iter(self._metrics.values())

    # -- views -------------------------------------------------------------
    def stats(self) -> dict:
        """Back-compat flat view: unlabelled counters/gauges as plain
        numbers (ints where integral, so old ``== 6`` asserts hold)."""
        out = {}
        for name, inst in self._metrics.items():
            if inst.kind in ("counter", "gauge"):
                v = inst.value
                out[name] = int(v) if float(v).is_integer() else v
        return out

    def snapshot(self) -> dict:
        """Full typed dump (JSON-ready), histograms with percentiles."""
        return {name: inst.snapshot()
                for name, inst in self._metrics.items()}
