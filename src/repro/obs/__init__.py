"""Serve/train observability subsystem (docs/observability.md).

Three layers, each usable on its own:

* :mod:`repro.obs.trace` — chrome-trace span capture (Perfetto /
  ``chrome://tracing`` loadable JSON) with a null tracer so untraced hot
  paths pay a single attribute read;
* :mod:`repro.obs.metrics` — typed Counter/Gauge/Histogram instruments in
  a :class:`~repro.obs.metrics.MetricsRegistry` (bounded memory,
  p50/p95/p99 from fixed buckets);
* :mod:`repro.obs.replay` — a :class:`~repro.obs.replay.CostModel` fitted
  from recorded traces plus a replay simulator that re-runs the *real*
  scheduler stack against simulated step costs (imported lazily — it
  pulls in the serve stack; ``import repro.obs.replay`` explicitly).

Only the dependency-free layers are imported eagerly so low-level modules
(kernels, models) can import ``repro.obs.trace`` without cycles.
"""
from repro.obs import metrics, trace  # noqa: F401
