"""Cost-model replay: re-run the real serve scheduler without a device.

Two pieces (docs/observability.md):

* :class:`CostModel` — per-op linear costs (``a·x + b`` seconds) fitted
  by least squares from the chrome-trace spans a real ``ServeEngine`` run
  recorded (``serve.prefill`` scales with bucketed tokens,
  ``serve.prefill_chunk`` with the padded ``Gp·C`` token count,
  ``serve.decode`` with active slots, ``serve.sample`` with rows; the
  rest fit as constants).
* :func:`replay` — drives the **real** :class:`~repro.serve.scheduler.
  Scheduler` / :class:`~repro.serve.scheduler.RequestQueue` /
  :class:`~repro.serve.kv_cache.PrefixCache` through the engine's exact
  host-side step structure (admission, chunk planning via the shared
  :func:`~repro.serve.scheduler.chunk_rounds`, prefix probe/hit/pin,
  retire-time trie inserts) while charging fitted costs instead of
  running device work.  Pages are opaque sentinels — the ``PrefixCache``
  never touches jax, so hit/miss/eviction behavior is the engine's by
  construction.

Because the scheduling classes are shared rather than re-implemented,
the sim's :class:`~repro.serve.scheduler.StepDecision` log is directly
comparable to a real engine run with ``ServeConfig.log_decisions`` — the
fidelity contract the test suite pins.  That makes the simulator safe
for what it is for: comparing scheduler policies (``admission="aware"``
vs ``"fcfs"``, budgets, chunk sizes, slot counts) on p50/p95/p99 request
latency over 100k+ request traces in seconds on a laptop, no device or
params needed.

Semantics the sim does *not* model: EOS stops (token values are never
sampled, so every request runs to ``max_new_tokens`` — length-stop
traffic replays exactly), device memory, and capacity overflow inside
the MoE.  Arrival injection assumes the submit order of equal-arrival
requests is rid order (the engine's queue scan sees all submitted
requests at once; the sim injects lazily, sorted by ``(arrival, rid)``,
so out-of-order arrivals would change nothing observable).
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.obs import metrics as metrics_lib
from repro.obs import trace as trace_lib
from repro.serve.kv_cache import PrefixCache
from repro.serve.scheduler import (Request, RequestQueue, Scheduler,
                                   chunk_rounds)

# x-extraction per op: which span attr the linear term scales with.
# Ops not listed fit (and predict) as constants.
OP_X = {
    "serve.prefill": "tokens",        # bucketed prompt length
    "serve.prefill_chunk": "tokens",  # padded Gp * C of the grouped call
    "serve.decode": "active",         # occupied decode slots
    "serve.sample": "rows",
}


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Fitted per-call cost of one span name: ``a·x + b`` seconds."""
    a: float
    b: float
    n: int = 0           # spans the fit saw

    def predict(self, x: float = 1.0) -> float:
        return max(self.a * x + self.b, 0.0)


class CostModel:
    """Per-op linear cost table fitted from recorded trace spans."""

    def __init__(self, ops: dict | None = None):
        self.ops: dict[str, OpCost] = dict(ops or {})

    def cost(self, op: str, x: float = 1.0) -> float:
        oc = self.ops.get(op)
        return oc.predict(x) if oc is not None else 0.0

    # -- fitting ------------------------------------------------------------
    @classmethod
    def fit(cls, events) -> "CostModel":
        """Least-squares fit from chrome-trace events (``ph == "X"``
        complete spans; ``dur`` is microseconds).  Ops in :data:`OP_X`
        fit ``dur ~ a·x + b`` on their scaling attr; everything else
        fits a constant (``a = 0``, ``b = mean``).  OLS with an
        intercept has zero-sum residuals, so replaying the *same*
        trace's op sequence reproduces its total recorded op time
        exactly — the calibration property the tests pin."""
        samples: dict[str, list] = collections.defaultdict(list)
        for ev in events:
            if ev.get("ph") != "X" or "dur" not in ev:
                continue
            name = ev["name"]
            attr = OP_X.get(name)
            x = float((ev.get("args") or {}).get(attr, 1.0)) if attr else 1.0
            samples[name].append((x, float(ev["dur"]) / 1e6))
        ops = {}
        for name, pts in samples.items():
            xs = np.asarray([p[0] for p in pts])
            ys = np.asarray([p[1] for p in pts])
            if np.ptp(xs) == 0.0:
                a, b = 0.0, float(ys.mean())
            else:
                design = np.stack([xs, np.ones_like(xs)], axis=1)
                (a, b), *_ = np.linalg.lstsq(design, ys, rcond=None)
            ops[name] = OpCost(float(a), float(b), n=len(pts))
        return cls(ops)

    @classmethod
    def fit_trace(cls, path: str) -> "CostModel":
        return cls.fit(trace_lib.load(path))

    # -- (de)serialization ----------------------------------------------------
    def to_dict(self) -> dict:
        return {name: {"a": oc.a, "b": oc.b, "n": oc.n}
                for name, oc in sorted(self.ops.items())}

    @classmethod
    def from_dict(cls, d: dict) -> "CostModel":
        return cls({name: OpCost(v["a"], v["b"], int(v.get("n", 0)))
                    for name, v in d.items()})


@dataclasses.dataclass
class ReplayConfig:
    """Scheduler-relevant slice of ``ServeConfig`` (no device fields).
    Field names match ``ServeConfig`` so configs translate one-to-one."""
    n_slots: int = 8
    policy: str = "continuous"
    admission: str = "fcfs"
    prefill_chunk: int = 0
    prefill_budget: int = 0
    prefix_cache: bool = False
    prefix_cache_bytes: int = 1 << 30
    page_bytes: int = 1          # per-page LRU charge (engine derives it
    #                              from array shapes; the sim has none)
    prefill_buckets: bool = True
    min_bucket: int = 8
    max_len: int = 256


@dataclasses.dataclass
class ReplayResult:
    metrics: metrics_lib.MetricsRegistry
    decisions: tuple             # StepDecision log (fidelity contract)
    requests: list               # the replayed Request objects, mutated
    steps: int                   # engine steps simulated (incl. idle skips)
    predicted_wall_s: float      # sum of fitted per-step costs

    @property
    def stats(self) -> dict:
        return self.metrics.stats()


class _Simulator:
    """One replay run: the engine's host-side step loop, costs charged
    from the model instead of device calls.  Mirrors ``ServeEngine.step``
    branch-for-branch — the comments below name the engine code each
    block shadows."""

    def __init__(self, cfg: ReplayConfig, model: CostModel):
        self.cfg = cfg
        self.model = model
        if cfg.prefix_cache and cfg.prefill_chunk <= 0:
            raise ValueError(
                "prefix_cache requires chunked prefill (prefill_chunk > 0)"
                " — same contract as ServeConfig")
        self.prefix = (PrefixCache(block=cfg.prefill_chunk,
                                   page_bytes=cfg.page_bytes,
                                   max_bytes=cfg.prefix_cache_bytes)
                       if cfg.prefix_cache else None)
        self._pins: dict[int, object] = {}
        self.queue = RequestQueue()
        self.sched = Scheduler(
            cfg.n_slots, policy=cfg.policy, admission=cfg.admission,
            prefill_chunk=cfg.prefill_chunk,
            prefill_budget=cfg.prefill_budget,
            prefix_probe=self._probe if self.prefix is not None else None,
            on_admit=self._on_admit if self.prefix is not None else None)
        self.sched.decision_log = []
        self.step_count = 0
        self.wall = 0.0
        self._t = 0.0                       # current step's charged cost
        self._arrival_wall: dict[int, float] = {}
        self._finish_wall: dict[int, float] = {}
        self._finished_this_step: list[int] = []
        self.metrics = metrics_lib.MetricsRegistry()
        self._c = {name: self.metrics.counter(name) for name in (
            "prefills", "decode_steps", "generated_tokens",
            "slot_steps_active", "slot_steps_total",
            "prefill_chunks", "prefill_tokens", "prefill_calls",
            "prefix_hits", "prefix_hit_tokens")}
        self._h_steps = self.metrics.histogram("request_latency_steps")
        self._h_secs = self.metrics.histogram("request_latency_s")

    def _charge(self, op: str, x: float = 1.0) -> None:
        self._t += self.model.cost(op, x)

    # -- prefix-cache hooks (ServeEngine._prefix_probe / ._on_admit) -------
    def _probe(self, req: Request) -> int:
        self._charge("serve.prefix_probe")
        return self.prefix.probe(req.prompt)

    def _on_admit(self, slot: int, req: Request) -> None:
        hit, _page, entry = self.prefix.lookup(req.prompt)
        if hit <= 0:
            return
        self._charge("serve.prefix_hit")
        self._pins[req.rid] = entry
        req.prefill_pos = hit
        self._c["prefix_hits"].inc()
        self._c["prefix_hit_tokens"].inc(hit)

    # -- per-request completion (ServeEngine._append_token) -----------------
    def _append(self, req: Request, slot: int) -> None:
        req.tokens.append(0)                # values are never sampled
        self._c["generated_tokens"].inc()
        if len(req.tokens) >= req.max_new_tokens:
            req.done_reason = "length"
            req.finished_step = self.step_count
            self._finished_this_step.append(req.rid)
            self._charge("serve.retire")
            self.sched.retire(slot)
            if self.prefix is not None and not self.prefix.covered(
                    req.prompt):
                self.prefix.insert(req.prompt, ("page", req.rid))

    def _finish_prefill(self, slot: int, req: Request) -> None:
        """A slot's prompt is fully ingested: unpin, count, first token."""
        self._c["prefills"].inc()
        req.first_token_step = self.step_count
        if self.prefix is not None:
            entry = self._pins.pop(req.rid, None)
            if entry is not None:
                self.prefix.unpin(entry)

    def _bucket_len(self, plen: int) -> int:
        if not self.cfg.prefill_buckets:
            return plen
        b = max(self.cfg.min_bucket, 1)
        while b < plen:
            b *= 2
        return min(b, self.cfg.max_len)

    # -- one engine step (ServeEngine.step) ---------------------------------
    def step(self) -> int:
        self._t = 0.0
        self._charge("serve.schedule")
        by_slot: dict[int, list] = {}
        work = self.sched.schedule_prefill(self.queue, self.step_count)
        prefix_on = self.prefix is not None
        for w in work:
            if (not prefix_on and w.start == 0
                    and w.length == w.req.prompt_len):
                # whole-prompt bucketed path (ServeEngine._start)
                blen = self._bucket_len(w.req.prompt_len)
                self._charge("serve.prefill", blen)
                self._charge("serve.kv_insert")
                self._charge("serve.sample", 1)
                self._c["prefill_calls"].inc()
                self._c["prefill_tokens"].inc(w.length)
                w.req.prefill_pos = w.length
                self._finish_prefill(w.slot, w.req)
                self._append(w.req, w.slot)
            else:
                by_slot.setdefault(w.slot, []).append(w)
        # chunk path (ServeEngine._run_chunk_rounds / _run_chunk_group) —
        # the grouping comes from the SAME chunk_rounds the engine runs.
        c = self.cfg.prefill_chunk
        for _off, group in chunk_rounds(by_slot):
            g = len(group)
            gp = 1 << (g - 1).bit_length()
            self._charge("serve.prefill_chunk", gp * c)
            self._c["prefill_calls"].inc()
            self._c["prefill_chunks"].inc(g)
            done = []
            for slot, w in group:
                req = w.req
                req.prefill_pos = w.start + w.length
                self._c["prefill_tokens"].inc(w.length)
                self._charge("serve.kv_insert")
                if not req.prefilling:
                    self._finish_prefill(slot, req)
                    done.append((slot, req))
            if done:
                self._charge("serve.sample", len(done))
                for slot, req in done:
                    self._append(req, slot)
        active = self.sched.decoding()
        if active:
            self._charge("serve.decode", len(active))
            self._charge("serve.sample", len(active))
            self._c["decode_steps"].inc()
            self._c["slot_steps_active"].inc(len(active))
            self._c["slot_steps_total"].inc(self.cfg.n_slots)
            for slot, req in active:
                self._append(req, slot)
        self.wall += self._t
        # A request finishing during step S pays all of step S: its
        # finish wall is the cumulative wall after this step's costs.
        for rid in self._finished_this_step:
            self._finish_wall[rid] = self.wall
        self._finished_this_step.clear()
        self.step_count += 1
        return len(active)

    def run(self, requests: list[Request],
            max_steps: int | None = None) -> ReplayResult:
        pending = collections.deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid)))
        steps = 0
        while pending or self.queue or self.sched.active():
            if (not self.queue and not self.sched.active()
                    and pending and pending[0].arrival > self.step_count):
                # idle fast-forward: nothing in flight, next arrival is
                # in the future — idle engine steps plan nothing and the
                # decision log skips them, so jumping is free.
                self.step_count = pending[0].arrival
            while pending and pending[0].arrival <= self.step_count:
                req = pending.popleft()
                self._arrival_wall[req.rid] = self.wall
                self.queue.push(req)
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        for req in requests:
            if req.finished_step is None:
                continue
            self._h_steps.observe(req.finished_step - req.arrival)
            self._h_secs.observe(
                self.wall_at_finish(req) - self._arrival_wall[req.rid])
        return ReplayResult(metrics=self.metrics,
                            decisions=tuple(self.sched.decision_log),
                            requests=requests, steps=self.step_count,
                            predicted_wall_s=self.wall)

    def wall_at_finish(self, req: Request) -> float:
        return self._finish_wall.get(req.rid, self.wall)


def replay(requests, cfg: ReplayConfig,
           cost_model: CostModel | None = None,
           max_steps: int | None = None) -> ReplayResult:
    """Replay ``requests`` through the real scheduler under ``cfg``.

    ``requests``: an iterable of ``(prompt, max_new_tokens, arrival)``
    tuples (prompt: int array / list) or prebuilt ``Request`` objects
    (rids must then be unique).  ``cost_model=None`` charges zero cost
    everywhere — scheduling decisions and step/latency *counts* are
    still exact; only the predicted wall needs a fitted model.
    """
    sim = _Simulator(cfg, cost_model or CostModel())
    reqs = []
    for i, spec in enumerate(requests):
        if isinstance(spec, Request):
            reqs.append(spec)
            continue
        prompt, max_new, arrival = spec
        reqs.append(Request(rid=i, prompt=np.asarray(prompt, np.int32),
                            max_new_tokens=int(max_new),
                            arrival=int(arrival)))
    return sim.run(reqs, max_steps=max_steps)


def synthetic_requests(n: int, *, prompt_lens=(16, 64), new_tokens=(4, 16),
                       arrival_every: float = 0.0, shared_prefix: int = 0,
                       vocab: int = 512, seed: int = 0) -> list:
    """Deterministic synthetic request trace for replay benchmarks/tests:
    prompt lengths and budgets uniform over the given inclusive ranges,
    arrivals every ``arrival_every`` steps (0 = all at step 0), the first
    ``shared_prefix`` tokens identical across requests (exercises the
    prefix cache)."""
    rng = np.random.RandomState(seed)
    shared = rng.randint(1, vocab, size=max(shared_prefix, 0))
    out = []
    for i in range(n):
        plen = int(rng.randint(prompt_lens[0], prompt_lens[1] + 1))
        tail = rng.randint(1, vocab, size=max(plen - shared.shape[0], 0))
        prompt = np.concatenate([shared[:plen], tail]).astype(np.int32)
        mnt = int(rng.randint(new_tokens[0], new_tokens[1] + 1))
        out.append((prompt, mnt, int(i * arrival_every)))
    return out
