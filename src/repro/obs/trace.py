"""Chrome-trace span capture for the serve engine, trainer and kernels.

A :class:`Tracer` records *complete* span events (``ph="X"``), counter
series (``ph="C"``) and instants (``ph="i"``) in the chrome trace-event
format — the emitted JSON loads directly in Perfetto or
``chrome://tracing``.  Timestamps come from ``time.perf_counter_ns``
relative to the tracer's epoch, reported in microseconds (the format's
native unit).

Overhead discipline (docs/observability.md): the *off* path is one
attribute read plus a no-op context manager —

    tr = trace.current()            # module-level, defaults to NULL
    with tr.span("serve.decode", active=n):
        ...

``NULL.span`` returns a shared singleton whose ``__enter__``/``__exit__``
do nothing, so call sites need no ``if tracing:`` guards.  The *on* path
is two ``perf_counter_ns`` reads and one tuple append per span — the
chrome event dicts are materialized lazily by :attr:`Tracer.events` /
:meth:`Tracer.save`, never while the workload runs.

Instrumented code reads the ambient tracer via :func:`current`; owners
(``ServeEngine``, ``Trainer``) install theirs for the duration of a step
with :func:`use`.  Spans recorded inside ``jax.jit`` *tracing* (e.g. the
kernel backend's dispatch/gmm/combine call sites) measure trace/compile
time at the step that triggered compilation — per-call device time lives
in the host-side step spans that block on results; both are real wall
time a serve step paid.

Attr values must be JSON-serializable; numpy scalars are coerced on save.
"""
from __future__ import annotations

import json
import os
import threading
import time


class _NullSpan:
    """Shared no-op context manager: the entire cost of tracing-off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Tracer-shaped no-op; ``trace.NULL`` is the ambient default."""

    enabled = False

    def span(self, name, **attrs):
        return _NULL_SPAN

    def instant(self, name, **attrs):
        pass

    def counter(self, name, **values):
        pass

    def clear(self):
        pass

    def save(self, path=None):
        raise ValueError("NullTracer has nothing to save; construct a "
                         "Tracer(path=...) to capture spans")

    @property
    def events(self):
        return []


NULL = NullTracer()


_perf_ns = time.perf_counter_ns
_ident = threading.get_ident


class _Span:
    """One live span: appends a raw ``(name, t0, t1, tid, attrs)`` tuple
    on exit; the ``X`` (complete) event dict is built at save time."""

    __slots__ = ("_events", "_name", "_attrs", "_t0")

    def __init__(self, events, name, attrs):
        self._events = events
        self._name = name
        self._attrs = attrs

    def __enter__(self):
        self._t0 = _perf_ns()
        return self

    def __exit__(self, et, ev, tb):
        self._events.append(
            (self._name, self._t0, _perf_ns(), _ident(), self._attrs))
        return False


def _jsonable(v):
    """Coerce numpy scalars/arrays and other strays to JSON types."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    item = getattr(v, "item", None)
    if item is not None and getattr(v, "ndim", 1) == 0:
        return item()
    tolist = getattr(v, "tolist", None)
    if tolist is not None:
        return tolist()
    return str(v)


class Tracer:
    """Chrome-trace event recorder.

    ``path`` is where :meth:`save` writes by default (the owner decides
    when — e.g. ``ServeEngine.run`` saves at trace end).  Events
    accumulate across :meth:`save` calls; :meth:`clear` drops them (the
    serve benchmark replays a trace best-of-N and keeps every replay's
    spans — more samples for the cost fit).
    """

    enabled = True

    def __init__(self, path: str | None = None, *,
                 process_name: str = "repro"):
        self.path = path
        self.pid = os.getpid()
        self._epoch = time.perf_counter_ns()
        self._events: list[dict] = []
        self._meta = [{
            "name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
            "args": {"name": process_name},
        }]

    # -- recording --------------------------------------------------------
    def span(self, name: str, **attrs) -> _Span:
        """Context manager timing one named span; ``attrs`` become the
        event's ``args`` (shapes, counts — what the cost model fits on)."""
        return _Span(self._events, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        self._events.append({
            "name": name, "ph": "i", "s": "t", "cat": "repro",
            "ts": (time.perf_counter_ns() - self._epoch) / 1e3,
            "pid": self.pid, "tid": threading.get_ident() & 0xFFFFFFFF,
            "args": attrs,
        })

    def counter(self, name: str, **values) -> None:
        """One sample of a counter track (Perfetto draws it as a graph)."""
        self._events.append({
            "name": name, "ph": "C", "cat": "repro",
            "ts": (time.perf_counter_ns() - self._epoch) / 1e3,
            "pid": self.pid, "tid": 0,
            "args": values,
        })

    # -- output -----------------------------------------------------------
    @property
    def events(self) -> list[dict]:
        """Recorded events as chrome-trace dicts (span tuples from the
        hot path are materialized here, off the timed path)."""
        epoch, pid = self._epoch, self.pid
        out = []
        for e in self._events:
            if type(e) is tuple:
                name, t0, t1, tid, attrs = e
                out.append({
                    "name": name, "ph": "X", "cat": "repro",
                    "ts": (t0 - epoch) / 1e3, "dur": (t1 - t0) / 1e3,
                    "pid": pid, "tid": tid & 0xFFFFFFFF, "args": attrs,
                })
            else:
                out.append(e)
        return out

    def clear(self) -> None:
        self._events.clear()

    def save(self, path: str | None = None) -> str:
        """Write ``{"traceEvents": [...]}`` JSON; returns the path."""
        path = path or self.path
        if path is None:
            raise ValueError("no trace path: pass save(path=...) or "
                             "construct Tracer(path=...)")
        payload = {
            "traceEvents": _jsonable(self._meta + self.events),
            "displayTimeUnit": "ms",
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)
        return path


def load(path: str) -> list[dict]:
    """Read a trace file back as its event list (both the ``traceEvents``
    object form this module writes and a bare JSON array)."""
    with open(path) as f:
        data = json.load(f)
    return data["traceEvents"] if isinstance(data, dict) else data


# ---------------------------------------------------------------------------
# ambient tracer (instrumented library code reads, owners install)
# ---------------------------------------------------------------------------

_STACK: list = [NULL]


def current():
    """The ambient tracer — ``NULL`` unless an owner installed one."""
    return _STACK[-1]


class _Use:
    """Context manager installing ``tracer`` as the ambient one."""

    __slots__ = ("_tracer",)

    def __init__(self, tracer):
        self._tracer = tracer

    def __enter__(self):
        _STACK.append(self._tracer)
        return self._tracer

    def __exit__(self, *exc):
        _STACK.pop()
        return False


def use(tracer) -> _Use:
    return _Use(tracer)
