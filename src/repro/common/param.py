"""Parameter-definition trees.

Architectures declare parameters as pytrees of :class:`ParamDef` — pure
shape/axes/init metadata, no allocation.  From one definition tree we derive:

* ``materialize(tree, key)``      — real ``jnp`` arrays (smoke tests, training)
* ``abstract(tree)``              — ``jax.ShapeDtypeStruct`` stand-ins (dry-run;
                                    a 1T-param model never touches memory)
* ``logical_specs(tree)``         — ``PartitionSpec`` tree of *logical* axis
                                    names, resolved to mesh axes by
                                    ``repro.sharding.partition``.

Keeping shapes, shardings and initializers in a single declaration prevents
the three from drifting apart as the model zoo grows.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# A logical axis name, e.g. "embed", "mlp", "experts", or None (unsharded).
Axis = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative description of one parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[Axis, ...]           # logical axis names, len == len(shape)
    init: str = "normal"             # normal | zeros | ones | embed | uniform_scale
    dtype: Any = jnp.bfloat16
    # fan_in override for "normal" (default: product of all but last dim is
    # wrong for conv-like params, so layers may set it explicitly).
    fan_in: int | None = None

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"shape {self.shape} and axes {self.axes} rank mismatch")

    @property
    def size(self) -> int:
        return math.prod(self.shape)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _tree_map(f: Callable[[ParamDef], Any], tree):
    return jax.tree_util.tree_map(f, tree, is_leaf=is_def)


def _init_one(d: ParamDef, key) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape, jnp.float32)).astype(d.dtype)
    if d.init == "normal":
        fan_in = d.fan_in if d.fan_in is not None else (
            d.shape[-2] if len(d.shape) >= 2 else d.shape[-1])
        std = 1.0 / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, d.shape, jnp.float32)).astype(d.dtype)
    if d.init == "uniform_scale":
        fan_in = d.fan_in if d.fan_in is not None else d.shape[0]
        lim = math.sqrt(3.0 / max(fan_in, 1))
        return jax.random.uniform(
            key, d.shape, jnp.float32, -lim, lim).astype(d.dtype)
    raise ValueError(f"unknown init {d.init!r}")


def materialize(tree, key: jax.Array):
    """Allocate real arrays for every ParamDef leaf (deterministic per-path)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_def)
    keys = jax.random.split(key, max(len(leaves), 1))
    arrs = [_init_one(d, k) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract(tree, dtype_override=None):
    """ShapeDtypeStruct stand-ins — no allocation; safe for 1T-param models."""
    return _tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype_override or d.dtype),
        tree)


def logical_specs(tree):
    """PartitionSpec tree over *logical* axis names."""
    return _tree_map(lambda d: P(*d.axes), tree)


def param_count(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_def)
    return sum(l.size if is_def(l) else l.size for l in leaves)


def param_bytes(tree) -> int:
    def nbytes(l):
        if is_def(l):
            return l.size * jnp.dtype(l.dtype).itemsize
        return l.size * l.dtype.itemsize
    return sum(nbytes(l) for l in
               jax.tree_util.tree_leaves(tree, is_leaf=is_def))


def cast(tree, dtype):
    """Cast a materialized tree (no-op on non-float leaves)."""
    def _c(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(_c, tree)
