"""moa-demo [moe]: Mixture-of-Attention-Heads demo arch (docs/moa.md).

Alternates plain-attention + MoE-FFN blocks with MoA-mixer blocks: odd
positions route each token through 2 of 8 attention head groups (2 query
heads each) against one shared K/V head (the MoA paper's MQA setting),
through the same Router API / kernel backends as the FFN experts.  Sized
so a dev host trains and serves it un-reduced.
"""
from repro.configs.base import ModelConfig, register


@register("moa-demo")
def config() -> ModelConfig:
    return ModelConfig(
        name="moa-demo", family="moe",
        n_layers=4, period=2, d_model=512, vocab_size=32_000,
        n_heads=8, n_kv_heads=1, head_dim=64, d_ff=1024,
        # position 0: plain attention + MoE FFN
        moe_positions=(0,), n_experts=8, moe_k=2, moe_d_ff=1024,
        # position 1: MoA mixer + dense FFN
        moa_positions=(1,), moa_experts=8, moa_k=2, moa_heads_per_expert=2,
        rope_theta=10000.0, activation="swiglu",
    )
