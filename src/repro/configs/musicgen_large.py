"""musicgen-large [audio]: decoder-only transformer over EnCodec tokens.

48L d_model=2048 32H (kv=32 ⇒ full MHA, head_dim=64) d_ff=8192 vocab=2048.
[arXiv:2306.05284; hf]

The EnCodec/conditioning frontend is a STUB per the assignment:
``input_specs`` supplies precomputed conditioning frame embeddings for the
first 64 positions; the token stream is a single codebook (the 4-codebook
interleaving pattern is a frontend concern, noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig, register


@register("musicgen-large")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large", family="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab_size=2048,
        activation="gelu",
        frontend="audio", n_prefix=64,
    )
