"""llama3-8b [dense]: GQA, 128k vocab.

32L d_model=4096 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=128256.
[arXiv:2407.21783; unverified]
"""
from repro.configs.base import ModelConfig, register


@register("llama3-8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=128256,
        rope_theta=5e5, activation="swiglu",
    )
