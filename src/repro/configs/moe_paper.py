"""The paper's own model family (§C.1 / Table 7) as named configs.

These use :mod:`repro.models.paper_lm` (LSTM→MoE→LSTM), not the transformer
stack.  Vocab defaults to 32k wordpieces rather than the 1BW 793k word-level
vocab so the CPU-scale benchmark harness can train them; the dry-run uses
the full sizes.
"""
from __future__ import annotations

from repro.models.paper_lm import PaperLMConfig

PAPER_VOCAB = 32_000


def paper_config(name: str, vocab_size: int = PAPER_VOCAB) -> PaperLMConfig:
    table = {
        # Table 7 rows (flat then hierarchical), k=4 flat / k=2 per level.
        "moe-4":      dict(variant="moe", n_experts=4, k=4),
        "moe-32":     dict(variant="moe", n_experts=32, k=4),
        "moe-256":    dict(variant="moe", n_experts=256, k=4),
        "moe-256-h":  dict(variant="moe", n_experts=256,
                           hierarchical=(16, 16)),
        "moe-1024-h": dict(variant="moe", n_experts=1024,
                           hierarchical=(16, 64)),
        "moe-4096-h": dict(variant="moe", n_experts=4096,
                           hierarchical=(16, 256)),
        # Computationally-matched baselines (§C.1).
        "moe-1-wide": dict(variant="moe_1_wide"),
        "moe-1-deep": dict(variant="moe_1_deep"),
        "4xlstm-512": dict(variant="lstm_4x"),
        "lstm-2048-512": dict(variant="lstm_2048_512"),
    }
    if name not in table:
        raise KeyError(f"unknown paper config {name!r}; have {sorted(table)}")
    return PaperLMConfig(vocab_size=vocab_size, **table[name])


PAPER_CONFIGS = ("moe-4", "moe-32", "moe-256", "moe-256-h", "moe-1024-h",
                 "moe-4096-h", "moe-1-wide", "moe-1-deep", "4xlstm-512",
                 "lstm-2048-512")
