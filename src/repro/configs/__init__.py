"""Architecture registry: importing this package registers every config."""
from repro.configs import (  # noqa: F401
    arctic_480b,
    falcon_mamba_7b,
    gemma3_27b,
    jamba_v01_52b,
    kimi_k2_1t_a32b,
    llama3_8b,
    moa_demo,
    moe_paper,
    musicgen_large,
    pixtral_12b,
    qwen3_1p7b,
    smollm_135m,
)
from repro.configs.base import (  # noqa: F401
    ModelConfig,
    count_params,
    get_config,
    layer_kinds,
    list_configs,
)
