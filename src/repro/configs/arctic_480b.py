"""arctic-480b [moe]: 128 experts top-2 + parallel dense residual FFN.

35L d_model=7168 56H (GQA kv=8, head_dim=128) expert d_ff=4864 vocab=32000.
[hf:Snowflake/snowflake-arctic-base; hf]

Arctic's dense-MoE hybrid: every layer computes a small dense FFN *in
parallel* with the top-2 MoE and sums both into the residual stream
(``dense_residual=True``).  The dense branch width is set to d_model
(assumption recorded in DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig, register


@register("arctic-480b")
def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe",
        n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=7168, vocab_size=32000,
        moe_positions=(0,), dense_residual=True,
        n_experts=128, moe_k=2, moe_d_ff=4864,
        capacity_factor=1.25, activation="swiglu",
    )
