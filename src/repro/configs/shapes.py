"""Assigned input shapes × per-arch input_specs (ShapeDtypeStruct stand-ins).

The four LM shapes (seq_len × global_batch):

* ``train_4k``     4,096 × 256   → lowers ``train_step``
* ``prefill_32k``  32,768 × 32   → lowers ``prefill_step``
* ``decode_32k``   32,768 × 128  → lowers ``serve_step`` (1 token, 32k cache)
* ``long_500k``    524,288 × 1   → ``serve_step``; sub-quadratic archs only

``input_specs`` returns exactly what the lowered function takes — shape and
dtype stand-ins, never allocated (the 1T-param kimi-k2 cells would not fit
on the build host otherwise).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.common import param as pm
from repro.configs.base import ModelConfig
from repro.models import transformer


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def cell_supported(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """Is (arch × shape) runnable?  long_500k needs sub-quadratic attention
    (decode against a full-attention 500k KV cache is memory-infeasible for
    every layer; see DESIGN.md §Skips)."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k-token KV cache on "
                       "every layer exceeds the per-chip HBM budget; "
                       "skip recorded in DESIGN.md")
    return True, ""


def batch_inputs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs for the *data* inputs of the step function."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if cfg.n_prefix:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_prefix, cfg.d_model), cfg.compute_dtype)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.n_prefix:
            specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_prefix, cfg.d_model), cfg.compute_dtype)
        return specs
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b,), jnp.int32)}
    raise ValueError(shape.kind)


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Abstract decode/prefill cache for this (arch × shape)."""
    defs = transformer.cache_defs(cfg, shape.global_batch, shape.seq_len)
    return pm.abstract(defs), defs


def logical_batch_axes(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Logical axes for each batch input (for in_shardings resolution)."""
    if shape.kind == "train":
        axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    elif shape.kind == "prefill":
        axes = {"tokens": ("batch", "seq")}
    else:
        axes = {"tokens": ("batch",)}
    if cfg.n_prefix and shape.kind != "decode":
        axes["prefix_embeds"] = ("batch", None, "embed")
    return axes
