"""falcon-mamba-7b [ssm]: pure Mamba-1, attention-free.

64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16.
[arXiv:2410.05355; unverified]

Every layer is a Mamba block (no FFN, d_ff=0 per the assignment).  Being
attention-free it runs all four shapes including ``long_500k`` with O(1)
per-token decode state.

Arch-applicability note (DESIGN.md): the paper's MoE technique targets FFN
capacity; falcon-mamba has no FFN, so it is built WITHOUT the technique.
"""
from repro.configs.base import ModelConfig, register


@register("falcon-mamba-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", family="ssm",
        n_layers=64, d_model=4096, vocab_size=65024,
        d_ff=0,
        ssm_d_state=16, ssm_d_conv=4, ssm_expand=2,
    )
