"""pixtral-12b [vlm]: Pixtral-ViT frontend (stub) + Mistral-Nemo-style decoder.

40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=131072.
[hf:mistralai/Pixtral-12B-2409; unverified]

The vision frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed patch embeddings for the first 64 positions.
"""
from repro.configs.base import ModelConfig, register


@register("pixtral-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=131072,
        rope_theta=1e6, activation="swiglu",
        frontend="vision", n_prefix=64,
    )
