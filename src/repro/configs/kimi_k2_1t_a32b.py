"""kimi-k2-1t-a32b [moe]: trillion-parameter MoE (paper-table config).

61L d_model=7168 64H (GQA kv=8, head_dim=128) expert d_ff=2048
vocab=163840, MoE 384 experts top-8.  [arXiv:2501.kimi2; unverified]

This is the zoo's direct analogue of the paper's "outrageously large"
regime: ~1T total parameters, ~32B active — conditional computation at a
~32x capacity-to-compute ratio (the paper's Figure 2-left axis, scaled up
a decade).
"""
from repro.configs.base import ModelConfig, register


@register("kimi-k2-1t-a32b")
def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=0, vocab_size=163840,
        moe_positions=(0,),          # every layer is MoE
        n_experts=384, moe_k=8, moe_d_ff=2048,
        capacity_factor=1.25, activation="swiglu",
    )
