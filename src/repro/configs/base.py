"""Model configuration schema + architecture registry.

Every assigned architecture is a ``ModelConfig``; the transformer stack
interprets it through ``layer_kinds(cfg)`` which expands the per-period
layer pattern (attention vs mamba mixers, dense vs MoE FFNs, local vs
global attention) into one :class:`LayerKind` per position-in-period.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

from repro.core.router import DEFAULT_CAPACITY_FACTOR, RouterSpec


@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str = "attn"        # attn | attn_local | mamba | moa
    ffn: str = "dense"         # dense | moe | moe+dense | none


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    # --- layer pattern -----------------------------------------------------
    period: int = 1             # layout repeats with this period
    attn_positions: tuple[int, ...] = ()   # positions-in-period that are attn
                                           # (ssm/hybrid only; dense = all)
    global_attn_positions: tuple[int, ...] = ()  # gemma-style local:global
    sliding_window: int = 0
    moe_positions: tuple[int, ...] = ()    # positions-in-period with MoE FFN
    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    moe_k: int = 0
    moe_d_ff: int = 0
    moe_hierarchical: tuple[int, int] | None = None   # (groups, per-group)
    dense_residual: bool = False           # arctic: MoE + parallel dense FFN
    # The one routing configuration path (docs/routing.md): a RouterSpec
    # carrying policy/k/capacity/noise/balance weights.  None resolves the
    # deprecated fields below (gating_mode/capacity_factor/...) into one;
    # the spec's k inherits moe_k.
    router: RouterSpec | None = None
    # Deprecated routing spellings (router.resolve_spec shim).  The
    # capacity default is unified in RouterSpec (this used to say 1.25
    # while MoEArgs said 2.0 — two disagreeing defaults for one knob).
    capacity_factor: float = DEFAULT_CAPACITY_FACTOR
    w_importance: float = 0.1              # paper §C.1 defaults
    w_load: float = 0.1
    gating_mode: str = "noisy_topk"
    moe_wide_dispatch: bool = True         # §3.1 combined-batch resharding
    # --- MoA (Mixture-of-Attention-Heads; core/moa.py, docs/moa.md) ---------
    # Positions-in-period whose *mixer* is a routed head-group layer:
    # n_experts groups of moa_heads_per_expert query heads, k per token,
    # shared K/V (n_kv_heads, MQA-style — the KV cache is a plain
    # attention cache).  Routing defaults to the FFN RouterSpec path;
    # moa_router overrides it independently of the FFN router.
    moa_positions: tuple[int, ...] = ()
    moa_experts: int = 0
    moa_k: int = 0
    moa_heads_per_expert: int = 0
    moa_router: RouterSpec | None = None
    # --- attention ----------------------------------------------------------
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # pad query heads (with zero-weight heads, sliced off before the output
    # projection) up to this count so they divide the model axis — the
    # §Perf fix for 56-head arctic on a 16-wide TP axis (1.14x padded
    # FLOPs instead of 16x replication).
    pad_attn_heads: int = 0
    # --- ssm ----------------------------------------------------------------
    ssm_d_state: int = 0
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    # --- modality frontend stub ----------------------------------------------
    frontend: str = "none"      # none | vision | audio
    n_prefix: int = 0           # prefix embedding slots fed by the stub
    # --- misc ----------------------------------------------------------------
    activation: str = "swiglu"
    norm_eps: float = 1e-6
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    scan_layers: bool = True       # False: unroll (XLA cost validation)
    # attention blocking (perf knobs; see EXPERIMENTS.md §Perf)
    q_block: int = 512
    kv_block: int = 512
    expert_impl: str = "einsum"            # legacy spelling of kernel_backend
    dispatch_impl: str = "sort"
    # Kernel backend for the MoE hot path ("ref" | "pallas"); None derives
    # from expert_impl.  See src/repro/kernels/backend.py and docs/kernels.md.
    kernel_backend: str | None = None
    # VMEM budget (bytes) for the fused dispatch/combine kernels; None =
    # kernels.dispatch.DEFAULT_VMEM_LIMIT.  Past it the pallas backend
    # E-blocks the buffer ([e_block, C, d] slabs) instead of bailing to
    # the ref scatter; see docs/kernels.md §E-blocked dispatch.
    dispatch_vmem_limit: int | None = None
    # Force a fused dispatch/combine slab size; None auto-selects against
    # the VMEM budget.
    dispatch_e_block: int | None = None
    # Consult the measured GMM tiling table (make tune-kernels); False
    # pins the static 128-tile defaults.
    gmm_autotune: bool = True
    # Serve-time fused decode step (docs/kernels.md §Fused decode step):
    # decode-shaped MoE/MoA calls run routing + dispatch + expert FFN +
    # combine as ONE kernel launch per layer.  Inference-only — train and
    # prefill paths ignore it; greedy outputs are bit-identical on/off.
    fused_decode: bool = False

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token contexts? (ssm/hybrid/sliding-win)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return bool(self.sliding_window and self.global_attn_positions !=
                    tuple(range(self.period)))


def layer_kinds(cfg: ModelConfig) -> list[LayerKind]:
    """One LayerKind per position-in-period."""
    kinds = []
    for p in range(cfg.period):
        if cfg.family == "ssm":
            mixer = "mamba"
        elif cfg.family == "hybrid":
            mixer = "attn" if p in cfg.attn_positions else "mamba"
        elif cfg.sliding_window and cfg.global_attn_positions:
            mixer = "attn" if p in cfg.global_attn_positions else "attn_local"
        else:
            mixer = "attn"
        if p in cfg.moa_positions:
            # Loud fallback for unsupported combos (docs/moa.md): MoA is
            # an attention mixer — it cannot replace an ssm state scan,
            # and it has no sliding-window variant.
            if mixer == "mamba":
                raise ValueError(
                    f"moa_positions={cfg.moa_positions}: position {p} is "
                    f"an ssm mixer in family {cfg.family!r}; MoA routes "
                    "attention head groups and cannot replace a state-"
                    "space scan (put MoA on an attn position)")
            if mixer == "attn_local":
                raise ValueError(
                    f"moa_positions={cfg.moa_positions}: position {p} is "
                    "a sliding-window local-attention layer; MoA has no "
                    "windowed variant (use a global_attn_positions slot)")
            if cfg.moa_experts < 2 or cfg.moa_k < 1 \
                    or cfg.moa_heads_per_expert < 1:
                raise ValueError(
                    "moa_positions set but moa_experts/moa_k/"
                    "moa_heads_per_expert are not configured "
                    f"(got {cfg.moa_experts}/{cfg.moa_k}/"
                    f"{cfg.moa_heads_per_expert})")
            mixer = "moa"
        if cfg.family == "ssm":
            ffn = "none"                     # pure mamba blocks have no FFN
        elif p in cfg.moe_positions:
            ffn = "moe+dense" if cfg.dense_residual else "moe"
        elif cfg.d_ff > 0:
            ffn = "dense"
        else:
            ffn = "none"
        kinds.append(LayerKind(mixer=mixer, ffn=ffn))
    return kinds


def n_periods(cfg: ModelConfig) -> tuple[int, int]:
    """(full scanned periods, remainder/unrolled layers)."""
    if not cfg.scan_layers:
        return 0, cfg.n_layers
    return divmod(cfg.n_layers, cfg.period)[0], cfg.n_layers % cfg.period


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str, **overrides) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates the registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]()
    return cfg.replace(**overrides) if overrides else cfg


def list_configs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Parameter accounting (Table 1/7-style reporting + MODEL_FLOPS)
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig) -> dict:
    """Analytic parameter counts (total / active per token)."""
    d = cfg.d_model
    kinds = layer_kinds(cfg)
    full, rem = n_periods(cfg)
    total = emb = 2 * cfg.vocab_size * d
    active = emb
    gated = cfg.activation in ("swiglu", "geglu")
    per_pos_counts = []
    for kind in kinds:
        c_total = c_active = 0
        if kind.mixer in ("attn", "attn_local"):
            c = d * cfg.head_dim * (2 * cfg.n_heads + 2 * cfg.n_kv_heads)
            c_total += c
            c_active += c
        elif kind.mixer == "moa":
            hg = cfg.moa_heads_per_expert * cfg.head_dim
            per_e = 2 * d * hg                      # wq + wo per head group
            shared = 2 * d * max(cfg.n_kv_heads, 1) * cfg.head_dim + \
                d * cfg.moa_experts                 # wk/wv + gate
            c_total += cfg.moa_experts * per_e + shared
            c_active += cfg.moa_k * per_e + shared
        elif kind.mixer == "mamba":
            d_in = cfg.ssm_expand * d
            r = -(-d // 16)
            c = (d * 2 * d_in + cfg.ssm_d_conv * d_in
                 + d_in * (r + 2 * cfg.ssm_d_state) + r * d_in
                 + d_in * cfg.ssm_d_state + d_in * d)
            c_total += c
            c_active += c
        if kind.ffn in ("dense",):
            c = d * cfg.d_ff * (3 if gated else 2)
            c_total += c
            c_active += c
        if kind.ffn in ("moe", "moe+dense"):
            per_e = d * cfg.moe_d_ff * (3 if gated else 2)
            c_total += cfg.n_experts * per_e
            c_active += cfg.moe_k * per_e
            if kind.ffn == "moe+dense":
                c = d * cfg.d_ff * (3 if gated else 2)
                c_total += c
                c_active += c
        per_pos_counts.append((c_total, c_active))
    for i, (ct, ca) in enumerate(per_pos_counts):
        reps = full + (1 if i < rem else 0)
        total += reps * ct
        active += reps * ca
    return {"total": total, "active": active,
            "total_excl_embed": total - emb,
            "active_excl_embed": active - emb}
