"""jamba-v0.1-52b [hybrid]: Mamba + attention 1:7 interleave, MoE every 2nd.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
[arXiv:2403.19887; hf]

Period-8 pattern: position 4 is attention, the other seven are Mamba
(1 attn : 7 mamba); MoE replaces the dense FFN on odd positions (every
other layer).  Hybrid ⇒ runs the sub-quadratic ``long_500k`` shape.
"""
from repro.configs.base import ModelConfig, register


@register("jamba-v0.1-52b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=65536,
        period=8, attn_positions=(4,), moe_positions=(1, 3, 5, 7),
        n_experts=16, moe_k=2, moe_d_ff=14336,
        ssm_d_state=16, ssm_d_conv=4, ssm_expand=2,
        activation="swiglu",
    )
