"""gemma3-27b [dense]: 5:1 local:global sliding-window attention, 128k ctx.

62L d_model=5376 32H (GQA kv=16, head_dim=128) d_ff=21504 vocab=262144.
[hf:google/gemma-3-1b-pt; unverified]

Period-6 pattern: 5 sliding-window (1024) layers then 1 global layer.
62 = 6·10 + 2, so ten stacked periods plus a 2-layer local tail.  The
window bounds the KV cache for 52 of 62 layers, making ``long_500k``
feasible (global layers' caches shard their sequence axis over the data
mesh axis under the ``decode_long`` plan).
"""
from repro.configs.base import ModelConfig, register


@register("gemma3-27b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
        d_ff=21504, vocab_size=262144,
        period=6, global_attn_positions=(5,), sliding_window=1024,
        qk_norm=True, rope_theta=1e6, activation="geglu",
    )
