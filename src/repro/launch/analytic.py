"""Analytic per-device FLOPs / HBM bytes / collective wire bytes.

Why analytic: XLA's ``cost_analysis()`` on the partitioned module counts
every ``while`` body **once**, but our production graphs deliberately live
inside loops (scan-over-layers, chunked xent, blockwise-attention kv scans,
mamba chunk scans) precisely to keep HLO small — so the XLA numbers
undercount by the trip counts.  The dry-run records both; the §Roofline
terms use these analytic numbers, which are validated against
``cost_analysis`` on *unrolled, single-trip* configurations in
``tests/test_roofline_validation.py`` (agreement within a few percent).

Counting conventions
--------------------
* 1 MAC = 2 FLOPs; matmul [m,k]x[k,n] = 2mkn.
* Backward = 2x forward matmul FLOPs; full-remat recompute adds 1x
  => train multiplier 4 on rematerialized segments (all block internals and
  the chunked xent), 3 elsewhere.  This makes the MODEL_FLOPS/HLO ratio
  honestly show the remat overhead (6ND useful vs ~8ND executed).
* Sharding: each op's FLOPs divide by the mesh axes that actually shard it.
  Resolution goes through ``partition.resolve_spec`` — identical divisibility
  fallbacks as the real lowering, so a 9-head model that cannot shard over
  model=16 is correctly charged replicated attention FLOPs.
* MoE expert FLOPs are charged on *capacity slots* (E x C), not on routed
  tokens: the padding waste of capacity-factor dispatch is real work and
  the useful-ratio shows it.
"""
from __future__ import annotations

import dataclasses
import math

import jax

from repro.configs.base import ModelConfig, layer_kinds, n_periods
from repro.configs.shapes import ShapeSpec
from repro.core.dispatch import capacity_for
from repro.sharding import partition


def cfg_microbatches(cfg: ModelConfig, shape: ShapeSpec,
                     batch_shards: int = 16) -> int:
    """Gradient-accumulation depth for train cells: cap the per-device
    microbatch at ~4k tokens (keeps layer-scan carries + dispatch buffers
    inside HBM for d_model~7k models; see EXPERIMENTS.md §Dry-run).
    Each microbatch's global batch must stay divisible by the batch
    sharding, so mb is the largest power of two dividing B/batch_shards
    under the token cap."""
    if shape.kind != "train":
        return 1
    seqs_per_shard = max(shape.global_batch // batch_shards, 1)
    tokens_loc = seqs_per_shard * shape.seq_len
    mb = 1
    while (mb * 2 <= seqs_per_shard and seqs_per_shard % (mb * 2) == 0
           and tokens_loc // mb > 4096):
        mb *= 2
    return mb


@dataclasses.dataclass
class Analytic:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    wire_bytes_per_dev: float
    detail: dict


def _shards(rules, mesh, shape, axes) -> int:
    """Number of devices the given tensor is split across."""
    spec = partition.resolve_spec(rules, mesh, shape, axes)
    n = 1
    for entry in spec:
        if entry is None:
            continue
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            n *= mesh.shape[ax]
    return n


def _axis(mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def analyze_cell(cfg: ModelConfig, shape: ShapeSpec,
                 mesh: jax.sharding.Mesh, plan: str) -> Analytic:
    rules = partition.PLANS[plan]
    kind = shape.kind
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    n_dev = mesh.size
    P, D, M = _axis(mesh, "pod"), _axis(mesh, "data"), _axis(mesh, "model")

    # --- token/batch sharding ------------------------------------------
    batch_shards = _shards(rules, mesh, (B,), ("batch",))
    tokens_global = B * S if kind != "decode" else B
    tokens_loc = tokens_global / batch_shards
    # decode processes 1 position; "S" is the cache/history length.
    seq_for_attn = S

    gated = cfg.activation in ("swiglu", "geglu")
    n_mat = 3 if gated else 2
    mult = {"train": 4.0, "prefill": 1.0, "decode": 1.0}[kind]
    bytes_p = 2  # bf16 params/activations

    flops = 0.0
    hbm = 0.0
    wire = 0.0
    detail: dict = {}

    # --- per-layer-position costs ---------------------------------------
    kinds = layer_kinds(cfg)
    full, rem = n_periods(cfg)
    reps = [full + (1 if i < rem else 0) for i in range(cfg.period)]

    def msh(shape_, axes_):   # shard count helper
        return _shards(rules, mesh, shape_, axes_)

    layer_flops = layer_wire = layer_hbm = 0.0
    params_local_bytes = 0.0       # all params, local shard
    fsdp_local_bytes = 0.0         # subset whose d_model dim is FSDP-sharded
    act_bytes = 0.0
    mbs = max(cfg_microbatches(cfg, shape, batch_shards), 1) \
        if kind == "train" else 1
    fsdp_on = "data" in rules.lookup("embed_fsdp") and D > 1

    for pos, lk in enumerate(kinds):
        r = reps[pos]
        if r == 0:
            continue
        f = w = h = 0.0   # per-step totals for this position (all reps)
        p_loc = 0.0       # local param bytes for this position (all reps)
        p_fsdp = 0.0      # portion that FSDP must gather per microbatch

        if lk.mixer in ("attn", "attn_local"):
            H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            if cfg.pad_attn_heads > H:
                H = KV * (-(-cfg.pad_attn_heads // KV))
            # compute shards = activation (head) sharding; FSDP shards only
            # weight *storage*, the gathered weight computes everywhere.
            m_h = max(msh((B, S, H, hd), ("batch", None, "heads", None))
                      / batch_shards, 1)
            m_kvh = max(msh((B, S, KV, hd),
                            ("batch", None, "kv_heads", None))
                        / batch_shards, 1)
            proj = (2 * d * hd * (2 * H) / m_h
                    + 2 * d * hd * (2 * KV) / m_kvh)
            f += mult * proj * tokens_loc * r
            # score/pv flops: per (token, kv position, head) 4*hd FLOPs.
            # (flash bwd recomputes s twice: dq pass + dkv pass => train
            # multiplier 5 instead of 4 on score flops.)
            if lk.mixer == "attn_local" and cfg.sliding_window:
                kv_eff = min(cfg.sliding_window + cfg.kv_block, seq_for_attn)
            elif kind == "decode":
                kv_eff = seq_for_attn
            else:
                kv_eff = seq_for_attn / 2 + cfg.kv_block
            score_mult = mult + 1 if kind == "train" else mult
            f += score_mult * 4 * hd * H * kv_eff / m_h * tokens_loc * r
            # params
            p = d * hd * (2 * H + 2 * KV) * bytes_p
            p_here = r * (p / msh((d, H, hd),
                                  ("embed_fsdp", "heads", "head_dim")))
            p_loc += p_here
            p_fsdp += p_here if fsdp_on else 0.0
            # TP all-reduce of attn output (fwd+bwd)
            ar = tokens_loc * d * bytes_p * 2 * (M - 1) / M
            w += (2 * ar if kind == "train" else ar) * r
            # decode: read the KV cache once per step
            if kind == "decode":
                if lk.mixer == "attn_local" and cfg.sliding_window:
                    cache_len = min(cfg.sliding_window, S)
                else:
                    cache_len = S
                cache = B * cache_len * KV * hd * bytes_p * 2
                h += r * cache / msh((B, cache_len, KV, hd),
                                     ("batch", "kv_seq", "kv_heads",
                                      "head_dim"))
            act = tokens_loc * (2 * d + (H + 2 * KV) * hd / max(m_h, 1)) \
                * bytes_p
            act_bytes += r * act if kind != "decode" else 0.0

        elif lk.mixer == "mamba":
            d_in = cfg.ssm_expand * d
            rr = -(-d // 16)
            N = cfg.ssm_d_state
            m_i = max(msh((B, S, d_in), ("batch", None, "ssm_inner"))
                      / batch_shards, 1)
            per_tok = (2 * d * 2 * d_in + 2 * cfg.ssm_d_conv * d_in
                       + 2 * d_in * (rr + 2 * N) + 2 * rr * d_in
                       + 10 * d_in * N + 2 * d_in * N + 2 * d_in * d)
            f += mult * per_tok / m_i * tokens_loc * r
            p = (d * 2 * d_in + cfg.ssm_d_conv * d_in
                 + d_in * (rr + 2 * N) + rr * d_in + d_in * N + d_in * d) \
                * bytes_p
            p_here = r * p / msh((d, 2 * d_in), ("embed_fsdp", "ssm_inner"))
            p_loc += p_here
            p_fsdp += p_here if fsdp_on else 0.0
            ar = tokens_loc * d * bytes_p * 2 * (M - 1) / M
            w += (2 * ar if kind == "train" else ar) * r
            if kind == "decode":
                st = B * d_in * N * 4 * 2
                h += r * st / msh((B, d_in, N),
                                  ("batch", "ssm_inner", "ssm_state"))
            m_act = msh((tokens_global, d_in), (None, "ssm_inner"))
            act_bytes += r * tokens_loc * (2 * d + 6 * d_in / m_act) \
                * bytes_p

        if lk.ffn in ("dense", "moe+dense"):
            m_f = max(msh((B, S, cfg.d_ff), ("batch", None, "mlp"))
                      / batch_shards, 1)
            f += mult * 2 * d * cfg.d_ff * n_mat / m_f * tokens_loc * r
            p = d * cfg.d_ff * n_mat * bytes_p
            p_here = r * p / msh((d, cfg.d_ff), ("embed_fsdp", "mlp"))
            p_loc += p_here
            p_fsdp += p_here if fsdp_on else 0.0
            ar = tokens_loc * d * bytes_p * 2 * (M - 1) / M
            w += (2 * ar if kind == "train" else ar) * r
            act_bytes += r * tokens_loc * (d + cfg.d_ff / m_f) * bytes_p

        if lk.ffn in ("moe", "moe+dense"):
            E, k, ff = cfg.n_experts, cfg.moe_k, cfg.moe_d_ff
            toks_for_cap = int(tokens_global) // mbs
            cap = capacity_for(toks_for_cap, E, k, cfg.capacity_factor)
            slots = E * cap
            m_e = msh((E, d, ff), ("experts", "expert_embed", "expert_mlp"))
            cap_shards = msh((E, cap, ff),
                             ("experts", "expert_capacity", "expert_mlp"))
            f += mult * 2 * d * ff * n_mat * slots * mbs / cap_shards * r
            # gating
            f += mult * 2 * d * E * tokens_loc * r
            p = E * d * ff * n_mat * bytes_p
            p_here = r * p / m_e
            p_loc += p_here
            p_fsdp += p_here if (fsdp_on and "data" in
                                 rules.lookup("expert_embed")) else 0.0
            # expert-TP over data: partial-sum reduce of the expert output
            # buffer per microbatch (replaces weight gathers entirely).
            if "data" in rules.lookup("expert_mlp") and D > 1:
                buf_dev = slots / max(msh((E, cap, d),
                                          ("experts", "expert_capacity",
                                           None)), 1) * d * bytes_p
                rs = buf_dev * (D - 1) / D * mbs
                w += (3 * rs if kind == "train" else rs) * r
            # dispatch+combine traffic.  Wide dispatch (§3.1): tokens first
            # reshard over (data x model) — a2a shrinks by M — and the
            # combine output all-gathers back over model once per layer.
            if cfg.moe_wide_dispatch:
                tok_moe = tokens_loc / M
                ag_back = tokens_loc * d * bytes_p * (M - 1) / M
            else:
                tok_moe = tokens_loc
                ag_back = 0.0
            a2a = k * tok_moe * d * bytes_p * cfg.capacity_factor \
                * (M - 1) / M
            per_dir = 2 * a2a + ag_back
            w += (2 * per_dir if kind == "train" else per_dir) * r
            act_bytes += r * (slots * mbs / cap_shards) * (2 * d + ff) \
                * bytes_p

        layer_flops += f
        layer_wire += w
        layer_hbm += h
        params_local_bytes += p_loc
        fsdp_local_bytes += p_fsdp

    # --- embedding / unembedding ----------------------------------------
    m_v_store = msh((d, cfg.vocab_size), ("embed_fsdp", "vocab"))
    m_v = max(msh((B, S, cfg.vocab_size), ("batch", None, "vocab"))
              / batch_shards, 1)
    emb_p = 2 * cfg.vocab_size * d * bytes_p
    params_local_bytes += emb_p / m_v_store
    if kind == "train":
        flops += 4.0 * 2 * d * cfg.vocab_size / m_v * tokens_loc
    else:
        # prefill computes last-position logits only; decode all positions.
        flops += 2 * d * cfg.vocab_size / m_v * (B / batch_shards)
    flops += layer_flops
    wire += layer_wire
    hbm += layer_hbm

    # --- FSDP weight gathers + grad reduce-scatter (train) ---------------
    if kind == "train":
        emb_fsdp = (emb_p / m_v_store) if fsdp_on else 0.0
        fsdp_bytes = fsdp_local_bytes + emb_fsdp
        if fsdp_on:
            # Per microbatch: all-gather fwd + remat-recompute gather +
            # reduce-scatter grads (grads at param dtype, EF/accum local).
            gathered = fsdp_bytes * (D - 1)
            wire += mbs * 3 * gathered
        if P > 1:
            wire += 2 * (P - 1) / P * params_local_bytes * 2  # pod grad AR
        # HBM: params r/w + f32 grads + factored opt (negligible) + acts.
        hbm += 6 * params_local_bytes + act_bytes * 2.5
    elif kind == "prefill":
        hbm += params_local_bytes + act_bytes
    else:
        hbm += params_local_bytes  # decode: stream every weight once

    # --- resident HBM estimate (the TPU fits-proof) -----------------------
    # The CPU build host emulates bf16 dots with hoisted f32 weight copies,
    # inflating measured temp; this resident model is the TPU-side number
    # (validated against memory_analysis modulo that artifact).
    cache_local = 0.0
    if kind in ("prefill", "decode"):
        for pos, lk in enumerate(kinds):
            r = reps[pos]
            if lk.mixer in ("attn", "attn_local"):
                L = min(cfg.sliding_window, S) \
                    if (lk.mixer == "attn_local" and cfg.sliding_window) \
                    else S
                sh = _shards(rules, mesh, (B, L, cfg.n_kv_heads,
                                           cfg.head_dim),
                             ("batch", "kv_seq", "kv_heads", "head_dim"))
                cache_local += r * 2 * B * L * cfg.n_kv_heads \
                    * cfg.head_dim * bytes_p / sh
            elif lk.mixer == "mamba":
                d_in = cfg.ssm_expand * d
                sh = _shards(rules, mesh, (B, d_in, cfg.ssm_d_state),
                             ("batch", "ssm_inner", "ssm_state"))
                cache_local += r * (B * d_in * cfg.ssm_d_state * 4
                                    + B * (cfg.ssm_d_conv - 1) * d_in
                                    * bytes_p) / sh
    if kind == "train":
        # params + f32 grads + factored opt (~1% of grads) + layer carries
        # of ONE microbatch (grad accumulation over cfg_microbatches).
        carries = cfg.n_layers * (tokens_loc / mbs) * d * bytes_p
        resident = params_local_bytes * (1 + 2 + 0.05) + carries * 2
    elif kind == "prefill":
        # no backward: XLA reuses activation buffers, working set ~ a few
        # layers' activations, not the whole stack's.
        per_layer = act_bytes / max(cfg.n_layers, 1)
        resident = params_local_bytes + cache_local + 4 * per_layer
    else:
        resident = params_local_bytes + cache_local  # donated in-place

    return Analytic(
        flops_per_dev=flops, hbm_bytes_per_dev=hbm,
        wire_bytes_per_dev=wire,
        detail={
            "tokens_local": tokens_loc,
            "params_local_bytes": params_local_bytes,
            "activation_bytes": act_bytes,
            "cache_local_bytes": cache_local,
            "resident_bytes_per_dev": resident,
            "batch_shards": batch_shards,
        })
