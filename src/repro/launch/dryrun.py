import os
import sys
if "jax" in sys.modules:
    # The XLA_FLAGS write below is a silent no-op once jax has initialized
    # its backends — the dry-run would then "succeed" against however many
    # devices the caller happened to have instead of the 512-device pod.
    raise RuntimeError(
        "repro.launch.dryrun must be imported before jax: it forces "
        "--xla_force_host_platform_device_count=512 via XLA_FLAGS at "
        "import time, which jax only reads at first backend init. "
        "Run it as a fresh process: python -m repro.launch.dryrun ...")
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above run before any other import — jax locks the device
count at first backend init, and this driver (and ONLY this driver) needs
512 placeholder CPU devices to build the production meshes.

Per cell this:
  1. builds abstract params / optimizer state / caches (ShapeDtypeStruct —
     a 1T-param model is described, never allocated),
  2. ``jit(step, in_shardings, out_shardings).lower().compile()``,
  3. records ``memory_analysis()`` (fits-per-device proof),
     ``cost_analysis()`` (FLOPs/bytes for §Roofline), and the collective
     schedule parsed from the partitioned HLO,
  4. appends a JSON line to the output file (resumable: existing cells are
     skipped unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl
"""
import argparse
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             plan: str | None = None, overrides: dict | None = None) -> dict:
    import jax
    from repro.configs import shapes as shp
    from repro.configs.base import get_config, count_params
    from repro.launch import analytic as an
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell
    from repro.sharding import partition

    cfg = get_config(arch, **(overrides or {}))
    shape = shp.SHAPES[shape_name]
    ok, why = shp.cell_supported(cfg, shape_name)
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "plan": plan, "kind": shape.kind,
              "global_batch": shape.global_batch, "seq_len": shape.seq_len}
    if not ok:
        record.update(status="skipped", reason=why)
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_dev = mesh.size
    t0 = time.time()
    lowered, spec = lower_cell(cfg, shape, mesh, plan=plan)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    from repro.sharding import context as ctx_lib
    ma = compiled.memory_analysis()
    cost = ctx_lib.compiled_cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = rl.parse_collectives(hlo, n_dev)
    params = count_params(cfg)
    plan_name = plan or partition.plan_for(shape_name)
    ana = an.analyze_cell(cfg, shape, mesh, plan_name)
    record.update(
        status="ok",
        n_devices=n_dev,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory={
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        },
        cost={k: v for k, v in cost.items()
              if k in ("flops", "bytes accessed", "transcendentals")},
        cost_caveat="XLA counts while-loop bodies once; use 'analytic'",
        collectives=coll,
        analytic={"flops_per_dev": ana.flops_per_dev,
                  "hbm_bytes_per_dev": ana.hbm_bytes_per_dev,
                  "wire_bytes_per_dev": ana.wire_bytes_per_dev,
                  **ana.detail},
        params=params,
        sharding_fallbacks=[f"{s} axis={a} mesh_axis={x} dim={d}"
                            for (s, a, x, d) in spec.fallbacks][:20],
    )
    roof = rl.analyze(record, cfg)
    record["roofline"] = {
        "compute_s": roof.compute_s, "memory_s": roof.memory_s,
        "collective_s": roof.collective_s, "dominant": roof.dominant,
        "model_flops": roof.model_flops,
        "useful_ratio": round(roof.useful_ratio, 4),
        "roofline_fraction": round(rl.roofline_fraction(roof, n_dev), 4),
    }
    return record


ALL_ARCHS = (
    "pixtral-12b", "jamba-v0.1-52b", "kimi-k2-1t-a32b", "arctic-480b",
    "qwen3-1.7b", "gemma3-27b", "smollm-135m", "llama3-8b",
    "musicgen-large", "falcon-mamba-7b",
)
ALL_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--plan", default=None)
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) for --mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"],
                              r.get("plan")))
                except json.JSONDecodeError:
                    pass

    if args.all:
        meshes = ["pod", "multipod"] if args.both_meshes else [args.mesh]
        cells = [(a, s, m) for m in meshes for a in ALL_ARCHS
                 for s in ALL_SHAPES]
    else:
        cells = [(args.arch, args.shape, args.mesh)]

    for arch, shape, mesh_kind in cells:
        key = (arch, shape, mesh_kind, args.plan)
        if key in done:
            print(f"[dryrun] skip (done): {key}")
            continue
        print(f"[dryrun] {arch} x {shape} x {mesh_kind} "
              f"plan={args.plan or 'auto'} ...", flush=True)
        try:
            rec = run_cell(arch, shape, mesh_kind, plan=args.plan)
        except Exception as e:  # record failures; they are bugs to fix
            rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                   "plan": args.plan, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"[dryrun] ERROR: {rec['error']}", flush=True)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        if rec.get("status") == "ok":
            m = rec["analytic"]["resident_bytes_per_dev"] / 2**30
            r = rec["roofline"]
            print(f"[dryrun]   ok: {m:.2f} GiB/dev resident, "
                  f"compute {r['compute_s']*1e3:.1f} ms, "
                  f"memory {r['memory_s']*1e3:.1f} ms, "
                  f"collective {r['collective_s']*1e3:.1f} ms "
                  f"-> {r['dominant']}-bound "
                  f"(compile {rec['compile_s']:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
