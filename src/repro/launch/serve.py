"""Serving launcher: load (or init) a model and serve a request trace
through the continuous-batching engine.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduce \
      --requests 8 --new-tokens 16
  # staggered mixed-length trace, static-batch baseline for comparison:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduce \
      --requests 16 --slots 4 --stagger 2 --policy static
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.common import param as pm
from repro.configs.base import get_config
from repro.core import router as router_lib
from repro.launch.mesh import make_host_mesh
from repro.launch.train import reduced
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine
from repro.sharding import context as ctx_lib
from repro.train.checkpoint import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir to restore params from")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--slots", type=int, default=None,
                    help="slot-pool size (default: min(requests, 8))")
    ap.add_argument("--stagger", type=int, default=0,
                    help="admit one request every N engine steps")
    ap.add_argument("--policy", choices=("continuous", "static"),
                    default="continuous",
                    help="static = batch-drain baseline")
    ap.add_argument("--router-policy", default=None,
                    help="routing policy override (docs/routing.md)")
    ap.add_argument("--capacity-factor", type=float, default=None,
                    help="capacity-factor override (RouterSpec)")
    ap.add_argument("--moa-k", type=int, default=None,
                    help="MoA head-groups-per-token override (archs with "
                         "moa_positions; docs/moa.md)")
    ap.add_argument("--no-dead-slot-mask", action="store_true",
                    help="let dead slots route through the MoE (pre-"
                         "router behavior; more capacity overflow)")
    ap.add_argument("--no-prefill-buckets", action="store_true",
                    help="exact-length prefill (one jit per distinct "
                         "prompt length)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: ingest prompts longer than "
                         "this many tokens as a sequence of chunk "
                         "work-items interleaved with decode steps "
                         "(0 = whole-prompt prefill)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="max prompt tokens of prefill per engine step "
                         "(0 = unlimited)")
    ap.add_argument("--admission", choices=("fcfs", "aware"),
                    default="fcfs",
                    help="aware = prompt-length-aware: skip queued "
                         "requests whose next chunk does not fit the "
                         "step's remaining prefill budget")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix radix KV cache: retired pages "
                         "seed a prefix trie and later requests prefill "
                         "only their uncached tail (requires "
                         "--prefill-chunk; docs/serving.md)")
    ap.add_argument("--prefix-cache-bytes", type=int, default=1 << 30,
                    help="LRU byte budget for cached prefix pages "
                         "(<= 0 = unlimited)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="give every request the same N-token prompt "
                         "prefix (exercises --prefix-cache)")
    ap.add_argument("--fused-decode", action="store_true",
                    help="one fused kernel launch per MoE/MoA layer at "
                         "decode (routing + dispatch + expert FFN + "
                         "combine; bit-identical greedy outputs — "
                         "docs/kernels.md §Fused decode step)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a chrome-trace JSON of the run here "
                         "(Perfetto-loadable; docs/observability.md)")
    ap.add_argument("--trace-sync", action="store_true",
                    help="calibration tracing: block on device results "
                         "inside prefill/decode spans so durations are "
                         "real op walls (costs ~2%% lost overlap; what "
                         "the cost-model fit wants)")
    ap.add_argument("--log-decisions", action="store_true",
                    help="record per-step scheduler StepDecision entries "
                         "(the replay simulator's fidelity contract)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    if args.router_policy is not None or args.capacity_factor is not None:
        spec = router_lib.resolve_spec(cfg)
        if args.router_policy is not None:
            spec = spec.replace(policy=args.router_policy)
        if args.capacity_factor is not None:
            spec = spec.replace(capacity_factor=args.capacity_factor)
        router_lib.get_policy(spec.policy)
        cfg = cfg.replace(router=spec)
        print(f"[serve] router: {spec}")
    if args.moa_k is not None:
        if not cfg.moa_positions:
            raise SystemExit(
                f"--moa-k: arch {cfg.name!r} has no MoA layers "
                "(moa_positions is empty)")
        cfg = cfg.replace(moa_k=args.moa_k)
        print(f"[serve] moa_k: {cfg.moa_k}/{cfg.moa_experts} head groups")
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    if args.ckpt:
        mgr = CheckpointManager(args.ckpt)
        step = mgr.latest_step()
        state_like = {"params": params}
        restored, _, _ = mgr.restore(step, state_like)
        params = restored["params"]
        print(f"[serve] restored checkpoint step {step}")

    if len(jax.devices()) > 1:
        ctx = ctx_lib.MeshContext.for_mesh(make_host_mesh(), "decode_std")
    else:
        ctx = ctx_lib.MeshContext.null(plan="decode_std")
    n_slots = args.slots or min(args.requests, 8)
    max_len = args.prompt_len + args.new_tokens + 1
    if args.prefill_chunk > 0:
        # chunk writes land in [start, start + chunk) windows: size the
        # page to a chunk multiple so the final padded window fits.
        max_len = -(-max_len // args.prefill_chunk) * args.prefill_chunk
    engine = ServeEngine(params, cfg, ServeConfig(
        max_len=max_len,
        temperature=args.temperature, n_slots=n_slots,
        policy=args.policy,
        mask_dead_slots=not args.no_dead_slot_mask,
        prefill_buckets=not args.no_prefill_buckets,
        prefill_chunk=args.prefill_chunk,
        prefill_budget=args.prefill_budget,
        admission=args.admission,
        prefix_cache=args.prefix_cache,
        prefix_cache_bytes=args.prefix_cache_bytes,
        fused_decode=args.fused_decode,
        trace_path=args.trace,
        trace_sync=args.trace_sync,
        log_decisions=args.log_decisions), ctx=ctx)
    rng = np.random.RandomState(0)
    shared = rng.randint(1, cfg.vocab_size,
                         (min(args.shared_prefix, args.prompt_len),))
    reqs = [engine.submit(
                np.concatenate([shared, rng.randint(
                    1, cfg.vocab_size,
                    (args.prompt_len - shared.shape[0],))]),
                args.new_tokens, arrival=i * args.stagger)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0
    total = engine.stats["generated_tokens"]
    print(f"[serve] {args.requests} requests x {args.new_tokens} tokens in "
          f"{dt:.2f}s ({total/dt:.1f} tok/s on this host, "
          f"policy={args.policy}, slots={n_slots}, "
          f"steps={engine.stats['decode_steps']}, "
          f"util={engine.slot_utilization:.2f})")
    print(f"[serve] prefill compiles: {len(engine.prefill_lengths)} "
          f"({sorted(engine.prefill_lengths)}; "
          f"buckets={'on' if engine._can_bucket else 'off'}, "
          f"dead-slot mask="
          f"{'on' if engine.sc.mask_dead_slots else 'off'})")
    if engine._chunk:
        print(f"[serve] chunked prefill: chunk={engine._chunk}, "
              f"budget={engine.sc.prefill_budget or 'unlimited'}, "
              f"admission={engine.sc.admission}, "
              f"chunks={engine.stats['prefill_chunks']} in "
              f"{engine.stats['prefill_calls']} calls, "
              f"offsets={sorted(engine.chunk_offsets)}")
    if engine.prefix is not None:
        ps = engine.prefix.stats
        print(f"[serve] prefix cache: {ps['hits']} hits / "
              f"{ps['hits'] + ps['misses']} lookups, "
              f"{ps['hit_tokens']} prompt tokens reused, "
              f"{engine.prefix.n_pages} pages "
              f"({engine.prefix.bytes / 1e6:.1f} MB, "
              f"{ps['evictions']} evictions)")
    if engine.telemetry:
        if any("expert_load" in t for t in engine.telemetry):
            load = np.sum([t["expert_load"] for t in engine.telemetry
                           if "expert_load" in t], axis=0)
            over = engine.stats["overflow_total"]
            print(f"[serve] expert load (decode): "
                  f"{load.astype(int).tolist()} "
                  f"(capacity overflow: {over:.0f})")
        if any("moa_load" in t for t in engine.telemetry):
            load = np.sum([t["moa_load"] for t in engine.telemetry
                           if "moa_load" in t], axis=0)
            over = engine.stats["moa_overflow_total"]
            print(f"[serve] MoA head-group load (decode): "
                  f"{load.astype(int).tolist()} "
                  f"(capacity overflow: {over:.0f})")
    if args.trace:
        print(f"[serve] trace written: {args.trace} "
              f"({len(engine.tracer.events)} events; load in Perfetto)")
    if args.log_decisions:
        print(f"[serve] decision log: {len(engine.sched.decision_log)} "
              "scheduling steps recorded")
    print(f"[serve] sample: {reqs[0].tokens[:10]}")


if __name__ == "__main__":
    main()
