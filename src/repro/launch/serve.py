"""Serving launcher: load (or init) a model and serve batched requests.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduce \
      --requests 8 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.common import param as pm
from repro.configs.base import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.train import reduced
from repro.models import lm
from repro.serve.engine import ServeConfig, ServeEngine
from repro.sharding import context as ctx_lib
from repro.train.checkpoint import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir to restore params from")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    if args.ckpt:
        mgr = CheckpointManager(args.ckpt)
        step = mgr.latest_step()
        state_like = {"params": params}
        restored, _, _ = mgr.restore(step, state_like)
        params = restored["params"]
        print(f"[serve] restored checkpoint step {step}")

    if len(jax.devices()) > 1:
        ctx = ctx_lib.MeshContext.for_mesh(make_host_mesh(), "decode_std")
    else:
        ctx = ctx_lib.MeshContext.null(plan="decode_std")
    engine = ServeEngine(params, cfg, ServeConfig(
        max_len=args.prompt_len + args.new_tokens + 1,
        temperature=args.temperature), ctx=ctx)
    prompts = np.random.RandomState(0).randint(
        1, cfg.vocab_size, (args.requests, args.prompt_len))
    t0 = time.perf_counter()
    out = engine.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    total = out.size
    print(f"[serve] {args.requests} requests x {out.shape[1]} tokens in "
          f"{dt:.2f}s ({total/dt:.1f} tok/s on this host)")
    print(f"[serve] sample: {out[0][:10].tolist()}")


if __name__ == "__main__":
    main()
