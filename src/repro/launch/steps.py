"""The three lowerable step functions (train / prefill / decode) and their
abstract input+sharding assembly for the dry-run and launchers.

Everything here works on ShapeDtypeStructs — a kimi-k2 train cell describes
~2 TB of parameters without allocating a byte.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.common import param as pm
from repro.configs import shapes as shp
from repro.configs.base import ModelConfig
from repro.models import lm, transformer
from repro.optim import optimizers as opt_lib
from repro.sharding import context as ctx_lib
from repro.sharding import partition


@dataclasses.dataclass
class LoweringSpec:
    """Everything jit().lower() needs for one (arch × shape × mesh) cell."""
    fn: object                   # the step callable
    args: tuple                  # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: object        # None => infer
    kind: str
    fallbacks: list


def make_train_step_fn(cfg: ModelConfig, oc: opt_lib.OptConfig,
                       ctx: ctx_lib.MeshContext,
                       microbatches: int = 1):
    def loss_fn(params, batch, rng):
        return lm.lm_loss(params, batch, cfg, rng=rng, train=True, ctx=ctx)

    def grads_of(params, batch, rng):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch, rng)

    def train_step(state, batch, seed):
        rng = jax.random.PRNGKey(seed)
        params = state["params"]
        if microbatches > 1:
            def reshape(x):
                b = x.shape[0]
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])
            mbs = jax.tree_util.tree_map(reshape, batch)
            rngs = jax.random.split(rng, microbatches)

            def body(carry, xs):
                acc, met = carry
                mb, r = xs
                (_, metrics), grads = grads_of(params, mb, r)
                acc = jax.tree_util.tree_map(jnp.add, acc, grads)
                met = jax.tree_util.tree_map(jnp.add, met, metrics)
                return (acc, met), None

            zeros_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mb0 = jax.tree_util.tree_map(lambda x: x[0], mbs)
            (_, m0), _ = jax.eval_shape(grads_of, params, mb0, rngs[0])
            zeros_m = jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), m0)
            (grads, metrics), _ = jax.lax.scan(body, (zeros_g, zeros_m),
                                               (mbs, rngs))
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
            metrics = jax.tree_util.tree_map(
                lambda m: m / microbatches, metrics)
        else:
            (_, metrics), grads = grads_of(params, batch, rng)
        new_params, new_opt, info = opt_lib.apply_updates(
            params, grads, state["opt"], oc)
        return {"params": new_params, "opt": new_opt}, \
            dict(metrics, **info)

    return train_step


def make_prefill_step_fn(cfg: ModelConfig, ctx: ctx_lib.MeshContext):
    def prefill_step(params, batch, cache):
        return lm.lm_prefill(params, batch, cache, cfg, ctx=ctx)
    return prefill_step


def make_decode_step_fn(cfg: ModelConfig, ctx: ctx_lib.MeshContext):
    def serve_step(params, tokens, cache, cur_index):
        return lm.lm_decode(params, tokens, cache, cur_index, cfg, ctx=ctx)
    return serve_step


def build_lowering(cfg: ModelConfig, shape: shp.ShapeSpec,
                   mesh: jax.sharding.Mesh,
                   oc: opt_lib.OptConfig | None = None,
                   plan: str | None = None) -> LoweringSpec:
    plan = plan or partition.plan_for(shape.name)
    ctx = ctx_lib.MeshContext.for_mesh(mesh, plan)
    fallbacks: list = []
    oc = oc or opt_lib.OptConfig(kind="factored")

    param_defs = lm.lm_defs(cfg)
    params_abs = pm.abstract(param_defs)
    params_shd = ctx.tree_shardings(param_defs, fallbacks)

    batch_abs = shp.batch_inputs(cfg, shape)
    batch_axes = shp.logical_batch_axes(cfg, shape)
    batch_shd = {
        k: ctx.shd(batch_abs[k].shape, batch_axes[k], fallbacks)
        for k in batch_abs}

    def repl(x=()):
        return jax.sharding.NamedSharding(mesh,
                                          jax.sharding.PartitionSpec())

    if shape.kind == "train":
        from repro.launch.analytic import cfg_microbatches
        opt_defs = opt_lib.state_defs(param_defs, oc)
        state_abs = {"params": params_abs, "opt": pm.abstract(opt_defs)}
        state_shd = {"params": params_shd,
                     "opt": ctx.tree_shardings(opt_defs, fallbacks)}
        bsh = ctx.resolve((shape.global_batch,), ("batch",))
        n_bsh = 1
        for e in bsh:
            if e is None:
                continue
            for ax in (e if isinstance(e, tuple) else (e,)):
                n_bsh *= mesh.shape[ax]
        fn = make_train_step_fn(
            cfg, oc, ctx,
            microbatches=cfg_microbatches(cfg, shape, n_bsh))
        seed_abs = jax.ShapeDtypeStruct((), jnp.int32)
        return LoweringSpec(
            fn=fn, args=(state_abs, batch_abs, seed_abs),
            in_shardings=(state_shd, batch_shd, repl()),
            out_shardings=(state_shd, None), kind="train",
            fallbacks=fallbacks)

    cache_abs, cache_defs = shp.cache_specs(cfg, shape)
    cache_shd = ctx.tree_shardings(cache_defs, fallbacks)
    if shape.kind == "prefill":
        fn = make_prefill_step_fn(cfg, ctx)
        return LoweringSpec(
            fn=fn, args=(params_abs, batch_abs, cache_abs),
            in_shardings=(params_shd, batch_shd, cache_shd),
            out_shardings=(None, cache_shd), kind="prefill",
            fallbacks=fallbacks)

    fn = make_decode_step_fn(cfg, ctx)
    idx_abs = jax.ShapeDtypeStruct((), jnp.int32)
    return LoweringSpec(
        fn=fn, args=(params_abs, batch_abs["tokens"], cache_abs, idx_abs),
        in_shardings=(params_shd, batch_shd["tokens"], cache_shd, repl()),
        out_shardings=(None, cache_shd), kind="decode",
        fallbacks=fallbacks)


_DONATE = {"train": (0,), "prefill": (2,), "decode": (2,)}


def lower_cell(cfg: ModelConfig, shape: shp.ShapeSpec,
               mesh: jax.sharding.Mesh, **kw):
    spec = build_lowering(cfg, shape, mesh, **kw)
    with ctx_lib.use_mesh(mesh):
        jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                         out_shardings=spec.out_shardings,
                         donate_argnums=_DONATE[spec.kind])
        lowered = jitted.lower(*spec.args)
    return lowered, spec
