"""Training launcher: ``--arch <id>`` selects any zoo architecture.

On a real TPU slice this runs under ``jax.distributed.initialize()`` with
the production mesh; on a dev host it uses whatever devices exist and a
reduced config unless ``--full`` is passed.  Fault tolerance is on by
default: atomic checkpoints every ``--checkpoint-every`` steps, auto-resume
from the newest one, straggler events logged to the heartbeat file.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 50 --reduce --workdir /tmp/run1
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.common import param as pm
from repro.configs.base import get_config
from repro.core import router as router_lib
from repro.data.pipeline import DataConfig, DataIterator
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim.optimizers import OptConfig
from repro.sharding import context as ctx_lib
from repro.train.trainer import Trainer, TrainLoopConfig


def reduced(cfg):
    kw = dict(n_layers=(2 * cfg.period) if cfg.period > 1 else 2,
              d_model=64, vocab_size=512, param_dtype=jnp.float32,
              compute_dtype=jnp.float32, q_block=32, kv_block=32)
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=2, head_dim=16)
    if cfg.d_ff:
        kw.update(d_ff=128)
    if cfg.n_experts:
        kw.update(n_experts=8, moe_k=2, moe_d_ff=64)
    if cfg.moa_experts:
        kw.update(moa_experts=4, moa_k=2, moa_heads_per_expert=2)
    if cfg.ssm_d_state:
        kw.update(ssm_d_state=4)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    if cfg.n_prefix:
        kw.update(n_prefix=0, frontend="none")
    return cfg.replace(**kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--optimizer", default="factored",
                    choices=["factored", "adam"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--reduce", action="store_true",
                    help="shrink the config for a dev host")
    ap.add_argument("--kernel-backend", default=None,
                    choices=["ref", "pallas"],
                    help="MoE kernel backend override (docs/kernels.md); "
                         "default: the arch config's choice")
    ap.add_argument("--dispatch-vmem-limit", type=int, default=None,
                    help="VMEM budget (bytes) for the fused dispatch/"
                         "combine kernels; past it the pallas backend "
                         "E-blocks the [E, C, d] buffer")
    ap.add_argument("--dispatch-e-block", type=int, default=None,
                    help="force the fused dispatch/combine expert-slab "
                         "size; default: auto-select against the budget")
    ap.add_argument("--no-gmm-autotune", action="store_true",
                    help="ignore the measured GMM tiling table "
                         "(make tune-kernels) and pin static 128 tiles")
    ap.add_argument("--router-policy", default=None,
                    help="routing policy override (docs/routing.md): "
                         "noisy_topk | batchwise | threshold | "
                         "expert_choice | any registered policy")
    ap.add_argument("--capacity-factor", type=float, default=None,
                    help="train capacity-factor override (RouterSpec)")
    ap.add_argument("--eval-capacity-factor", type=float, default=None,
                    help="eval capacity-factor override (RouterSpec)")
    ap.add_argument("--moa-k", type=int, default=None,
                    help="MoA head-groups-per-token override (archs with "
                         "moa_positions; docs/moa.md)")
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a chrome-trace JSON of the run here "
                         "(train.step spans; docs/observability.md)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg)
    if args.kernel_backend is not None:
        cfg = cfg.replace(kernel_backend=args.kernel_backend)
    if args.dispatch_vmem_limit is not None:
        cfg = cfg.replace(dispatch_vmem_limit=args.dispatch_vmem_limit)
    if args.dispatch_e_block is not None:
        cfg = cfg.replace(dispatch_e_block=args.dispatch_e_block)
    if args.no_gmm_autotune:
        cfg = cfg.replace(gmm_autotune=False)
    # Router flags configure the spec at ONE resolution point: whatever
    # the arch config carries (explicit spec or legacy fields) resolves to
    # a RouterSpec here, the overrides land on it, and the spec rides
    # cfg.router through every MoE layer (docs/routing.md).
    if (args.router_policy is not None or args.capacity_factor is not None
            or args.eval_capacity_factor is not None):
        spec = router_lib.resolve_spec(cfg)
        if args.router_policy is not None:
            spec = spec.replace(policy=args.router_policy)
        if args.capacity_factor is not None:
            spec = spec.replace(capacity_factor=args.capacity_factor)
        if args.eval_capacity_factor is not None:
            spec = spec.replace(eval_capacity_factor=
                                args.eval_capacity_factor)
        router_lib.get_policy(spec.policy)   # unknown policy fails here
        cfg = cfg.replace(router=spec)
        print(f"[train] router: {spec}")
    if args.moa_k is not None:
        if not cfg.moa_positions:
            raise SystemExit(
                f"--moa-k: arch {cfg.name!r} has no MoA layers "
                "(moa_positions is empty)")
        cfg = cfg.replace(moa_k=args.moa_k)
        print(f"[train] moa_k: {cfg.moa_k}/{cfg.moa_experts} head groups")
    params = pm.materialize(lm.lm_defs(cfg), jax.random.PRNGKey(0))
    print(f"[train] {cfg.name}: {pm.param_count(params)/1e6:.1f}M params "
          f"on {len(jax.devices())} device(s)")

    # Explicit sharding context: a host mesh when more than one device is
    # visible, else the null (identity-constraint) context.
    if len(jax.devices()) > 1:
        ctx = ctx_lib.MeshContext.for_mesh(make_host_mesh(), "dp_tp_ep")
    else:
        ctx = ctx_lib.MeshContext.null()

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    batch_size=args.batch, n_clusters=64)
    trainer = Trainer(
        loss_fn=lambda p, b, r: lm.lm_loss(p, b, cfg, rng=r, ctx=ctx),
        params=params,
        oc=OptConfig(kind=args.optimizer, learning_rate=args.lr,
                     warmup_steps=max(args.steps // 10, 10)),
        loop=TrainLoopConfig(total_steps=args.steps,
                             microbatches=args.microbatches,
                             checkpoint_every=args.checkpoint_every,
                             log_every=10),
        data_iter=DataIterator(dc), workdir=args.workdir,
        kernel_backend=cfg.kernel_backend, router=cfg.router,
        trace_path=args.trace)
    final = trainer.run()
    if args.trace:
        print(f"[train] trace written: {args.trace} "
              f"({len(trainer.tracer.events)} events; load in Perfetto)")
    print(f"[train] done: {final}")


if __name__ == "__main__":
    main()
