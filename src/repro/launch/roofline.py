"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch × shape × mesh) cell, from the SPMD-partitioned executable
(everything below is **per device**, which is what XLA reports post-
partitioning):

    compute term    = HLO_FLOPs / peak_FLOP/s                 (197 TF bf16)
    memory term     = HLO_bytes_accessed / HBM_bw             (819 GB/s)
    collective term = wire_bytes(collectives) / link_bw       (50 GB/s)

``cost_analysis`` has no collective traffic, so wire bytes are parsed from
``compiled.as_text()``: every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute line contributes its result shape scaled by
the standard ring-algorithm factor for its replica-group size g:

    all-reduce       2·(g-1)/g · result          (result == operand)
    all-gather       (g-1)/g   · result          (result == full)
    reduce-scatter   (g-1)     · result          (result == one shard)
    all-to-all       (g-1)/g   · result
    collective-perm  1         · result

Also computes MODEL_FLOPS (6·N_active·tokens for training, 2·N_active·tokens
for inference) and the MODEL_FLOPS / HLO_FLOPs ratio — the "useful compute"
fraction that exposes remat recompute and padding waste.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

from repro.configs.base import ModelConfig, count_params
from repro.launch.mesh import CHIP

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}\s/]+\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


_WIRE_FACTOR = {
    "all-reduce": lambda g: 2.0 * (g - 1) / g,
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: float(g - 1),
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


def parse_collectives(hlo_text: str, n_devices: int) -> dict:
    """Per-device wire bytes by collective kind (skips -done halves)."""
    out = {k: 0.0 for k in _WIRE_FACTOR}
    counts = {k: 0 for k in _WIRE_FACTOR}
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done." in line:
            continue  # paired with the -start that carries the shape
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2).lower()
        nbytes = _shape_bytes(m.group(1))
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        out[kind] += nbytes * _WIRE_FACTOR[kind](g)
        counts[kind] += 1
    out_total = sum(out.values())
    return {"wire_bytes_by_kind": out, "op_counts": counts,
            "wire_bytes_total": out_total}


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_per_dev: float
    useful_ratio: float
    bound_step_s: float


def model_flops(cfg: ModelConfig, kind: str, global_batch: int,
                seq_len: int) -> float:
    """6·N_active·D (train) / 2·N_active·D (prefill) / 2·N_active·B (decode),
    N_active excluding embeddings (the paper's ops/timestep convention)."""
    n_active = count_params(cfg)["active_excl_embed"]
    if kind == "train":
        return 6.0 * n_active * global_batch * seq_len
    if kind == "prefill":
        return 2.0 * n_active * global_batch * seq_len
    return 2.0 * n_active * global_batch           # decode: one token


def analyze(record: dict, cfg: ModelConfig) -> Roofline:
    """record: one dry-run JSONL entry (see launch/dryrun.py).

    Prefers the analytic per-device numbers (correct across `while` loops)
    when present; the raw XLA numbers stay in the record for reference.
    """
    n_dev = record["n_devices"]
    if "analytic" in record:
        flops_dev = record["analytic"]["flops_per_dev"]
        bytes_dev = record["analytic"]["hbm_bytes_per_dev"]
        wire = record["analytic"]["wire_bytes_per_dev"]
    else:
        flops_dev = record["cost"]["flops"]
        bytes_dev = record["cost"].get("bytes accessed", 0.0)
        wire = record["collectives"]["wire_bytes_total"]
    compute_s = flops_dev / CHIP["peak_bf16_flops"]
    memory_s = bytes_dev / CHIP["hbm_bandwidth"]
    coll_s = wire / CHIP["ici_link_bandwidth"]
    mf = model_flops(cfg, record["kind"], record["global_batch"],
                     record["seq_len"])
    useful = mf / max(flops_dev * n_dev, 1.0)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    return Roofline(compute_s=compute_s, memory_s=memory_s,
                    collective_s=coll_s, dominant=dominant,
                    model_flops=mf, hlo_flops_per_dev=flops_dev,
                    useful_ratio=useful,
                    bound_step_s=max(terms.values()))


def roofline_fraction(r: Roofline, n_devices: int) -> float:
    """Achievable MFU under the bounding term: the fraction of peak compute
    the *useful* model flops would sustain if the step ran exactly at the
    dominant roofline term."""
    ideal_s = r.model_flops / (n_devices * CHIP["peak_bf16_flops"])
    return ideal_s / max(r.bound_step_s, 1e-30)
