"""Production mesh construction.

A function, not a module-level constant: importing this module never touches
jax device state (jax locks the device count on first backend init, and the
smoke tests must see 1 CPU device while the dry-run sees 512 placeholders).

Topology: TPU v5e pods of 256 chips arranged (data=16, model=16); the
multi-pod mesh prepends a ``pod`` axis over the (slower, DCN-connected)
cross-pod dimension.  Axis usage under the default ``dp_tp_ep`` plan:

* ``pod``   — pure data parallelism (gradient sync only; candidate for the
              int8 error-feedback compression in train/compression.py)
* ``data``  — data parallelism + FSDP of parameter d_model dims
* ``model`` — tensor parallelism (heads / d_ff / vocab) and *expert
              parallelism* (the paper's §3.1 model-parallel expert shards)
"""
from __future__ import annotations

import jax

from repro.sharding import context as ctx_lib


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return ctx_lib.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Single-host mesh over however many (possibly fake) devices exist."""
    n = len(jax.devices())
    data = n // model
    return ctx_lib.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis.
CHIP = {
    "name": "tpu-v5e",
    "peak_bf16_flops": 197e12,      # per chip
    "hbm_bandwidth": 819e9,         # bytes/s per chip
    "ici_link_bandwidth": 50e9,     # bytes/s per link
}
