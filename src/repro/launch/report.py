"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from dryrun.jsonl.

Usage: PYTHONPATH=src python -m repro.launch.report results/dryrun.jsonl
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def fmt_ms(s):
    return f"{s*1e3:.1f}"


def load(path):
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


def dryrun_table(recs, mesh):
    rows = ["| arch | shape | status | resident GiB/dev | XLA peak GiB/dev "
            "| collective ops (AR/AG/RS/A2A/CP) | compile s | fallbacks |",
            "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skipped "
                        f"(sub-quadratic gate) | — | — | — | — | — |")
            continue
        if r["status"] == "error":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | — | — | — "
                        f"| — | {r['error'][:40]} |")
            continue
        c = r["collectives"]["op_counts"]
        ops = (f"{c['all-reduce']}/{c['all-gather']}/"
               f"{c['reduce-scatter']}/{c['all-to-all']}/"
               f"{c['collective-permute']}")
        nfb = len(r.get("sharding_fallbacks", []))
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {fmt_bytes(r['analytic']['resident_bytes_per_dev'])} "
            f"| {fmt_bytes(r['memory']['peak_bytes_per_device'])} "
            f"| {ops} | {r['compile_s']:.0f} | {nfb} |")
    return "\n".join(rows)


def roofline_table(recs, mesh="pod"):
    rows = ["| arch | shape | compute ms | memory ms | collective ms "
            "| dominant | MODEL_FLOPS | useful ratio | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {fmt_ms(ro['compute_s'])} | {fmt_ms(ro['memory_s'])} "
            f"| {fmt_ms(ro['collective_s'])} | **{ro['dominant']}** "
            f"| {ro['model_flops']:.2e} | {ro['useful_ratio']:.2f} "
            f"| {ro['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def summary(recs):
    ok = [r for r in recs if r["status"] == "ok"]
    sk = [r for r in recs if r["status"] == "skipped"]
    er = [r for r in recs if r["status"] == "error"]
    by_dom = {}
    for r in ok:
        if r["mesh"] == "pod":
            d = r["roofline"]["dominant"]
            by_dom[d] = by_dom.get(d, 0) + 1
    return (f"{len(ok)} compiled ok, {len(sk)} skipped (sub-quadratic "
            f"gate), {len(er)} errors; single-pod dominant terms: {by_dom}")


def main():
    paths = sys.argv[1:] or ["results/dryrun.jsonl"]
    recs = []
    for p in paths:
        recs.extend(load(p))
    # keep last record per cell (later files / re-runs win)
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r["mesh"], r.get("plan"))] = r
    recs = list(seen.values())
    print("## Summary\n")
    print(summary(recs))
    print("\n## Dry-run — single pod (16x16 = 256 chips)\n")
    print(dryrun_table(recs, "pod"))
    print("\n## Dry-run — multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(recs, "multipod"))
    print("\n## Roofline — single pod\n")
    print(roofline_table(recs, "pod"))


if __name__ == "__main__":
    main()
