import os
import sys
if "jax" in sys.modules:
    # The XLA_FLAGS write below is a silent no-op once jax has initialized
    # its backends — the dry-run would then "succeed" against however many
    # devices the caller happened to have instead of the 512-device pod.
    raise RuntimeError(
        "repro.launch.dryrun_pp must be imported before jax: it forces "
        "--xla_force_host_platform_device_count=512 via XLA_FLAGS at "
        "import time, which jax only reads at first backend init. "
        "Run it as a fresh process: python -m repro.launch.dryrun_pp ...")
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Pipeline-parallel dry-run for the kimi-k2 hillclimb (§Perf iteration).

Lowers the GPipe train step on the production pod mesh with the data axis
repurposed as 16 pipeline stages (model axis stays EP/TP inside stages),
records memory/cost/collectives, and emits the analytic roofline terms for
the PP schedule.

Usage: PYTHONPATH=src python -m repro.launch.dryrun_pp \
           [--arch kimi-k2-1t-a32b] [--micro 64] [--out results/pp.jsonl]
"""
import argparse
import json
import time


def pp_analytic(cfg, shape, mesh, n_stages, n_micro):
    """Roofline terms for the GPipe schedule (per device)."""
    from repro.configs.base import count_params
    from repro.core.dispatch import capacity_for
    from repro.launch.mesh import CHIP

    M = mesh.shape["model"]
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    tokens = B * S
    tokens_mb = tokens // n_micro
    per, total = -(-cfg.n_layers // n_stages), 0
    total = per * n_stages
    pad_ratio = total / cfg.n_layers
    ticks = n_micro + n_stages - 1
    bubble = ticks / n_micro

    n_act = count_params(cfg)["active_excl_embed"]
    # per-device FLOPs: global useful x remat(4/3) x capacity waste x
    # padding x bubble, spread over all devices (each tick all devices run).
    cap = capacity_for(tokens_mb, cfg.n_experts, cfg.moe_k,
                       cfg.capacity_factor)
    cap_waste = (cfg.n_experts * cap) / (cfg.moe_k * tokens_mb) \
        if cfg.n_experts else 1.0
    flops_dev = (6 * n_act * tokens) * (4 / 3) * cap_waste * pad_ratio \
        * bubble / mesh.size
    # xent + embed remat
    flops_dev += 4 * 2 * d * cfg.vocab_size / M * tokens / (
        mesh.shape.get("data", 1))

    # collectives per device:
    bytes_p = 2
    boundary = tokens_mb * d * bytes_p / M          # ppermute per tick
    wire = 2 * boundary * ticks                      # fwd + bwd shifts
    if cfg.n_experts:
        t_loc = tokens_mb / M
        a2a = (2 * cfg.moe_k * t_loc * d * bytes_p * cfg.capacity_factor
               * (M - 1) / M)
        ag_back = tokens_mb / M * d * bytes_p * (M - 1)
        layers_here = per                            # per device
        wire += (2 * (a2a + ag_back)) * layers_here * n_micro
    # in-stage attention TP all-reduce
    ar = tokens_mb * d * bytes_p * 2 * (M - 1) / M
    wire += 2 * ar * per * n_micro
    # grads: none across stages (weights resident); opt local.

    params_loc = count_params(cfg)["total"] * bytes_p / mesh.size
    hbm = 6 * params_loc + ticks * boundary * 4
    return {
        "flops_per_dev": flops_dev,
        "hbm_bytes_per_dev": hbm,
        "wire_bytes_per_dev": wire,
        "compute_s": flops_dev / CHIP["peak_bf16_flops"],
        "memory_s": hbm / CHIP["hbm_bandwidth"],
        "collective_s": wire / CHIP["ici_link_bandwidth"],
        "bubble_overhead": bubble,
        "pad_ratio": pad_ratio,
        "resident_bytes_per_dev": params_loc * 3.05
        + ticks * tokens_mb * d * bytes_p / M,
    }


def main():
    import jax
    import jax.numpy as jnp
    from repro.common import param as pm
    from repro.configs import shapes as shp
    from repro.configs.base import get_config
    from repro.launch import roofline as rl
    from repro.launch.mesh import CHIP, make_production_mesh
    from repro.optim import optimizers as opt_lib
    from repro.train import pipeline as pp

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="kimi-k2-1t-a32b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--micro", type=int, default=64)
    ap.add_argument("--out", default="results/pp.jsonl")
    args = ap.parse_args()

    import jax.numpy as _jnp
    # CPU-host workaround: XLA's CPU bf16-dot emulation inserts copy ops
    # that CHECK-fail the SPMD partitioner inside the manual-axis shard_map
    # (hlo_instruction.cc:1558 "Invalid binary instruction opcode copy").
    # Lower in f32 here — on TPU bf16 dots are native and no such copies
    # exist.  Recorded in the output (dtype_note); memory figures below are
    # f32 (2x the bf16 target).
    cfg = get_config(args.arch, param_dtype=_jnp.float32,
                     compute_dtype=_jnp.float32)
    shape = shp.SHAPES[args.shape]
    mesh = make_production_mesh()          # (data=16 -> stages, model=16)
    n_stages = mesh.shape["data"]
    oc = opt_lib.OptConfig(kind="factored")

    defs = pp.pipeline_param_defs(cfg, n_stages)
    params_abs = pm.abstract(defs)
    opt_abs = pm.abstract(opt_lib.state_defs(defs, oc))
    from repro.sharding import context as ctx_lib
    from repro.sharding import partition
    rules = partition.PLANS["dp_tp_ep"]
    # stage axis sharding for the stacked blocks; model-axis sharding for
    # everything via the usual rules (stage dim resolves from "stage"...)
    stage_rules = partition.ShardingRules(
        table={**rules.table, "stage": ("data",), "layers": (),
               "embed_fsdp": ()}, name="pp")
    ctx = ctx_lib.MeshContext(mesh=mesh, rules=stage_rules)
    params_shd = ctx.tree_shardings(defs)
    opt_shd = ctx.tree_shardings(opt_lib.state_defs(defs, oc))

    batch_abs = shp.batch_inputs(cfg, shape)
    batch_shd = {k: ctx.shd(v.shape,
                            ("batch", "seq") if v.ndim == 2 else
                            ("batch", None, "embed"))
                 for k, v in batch_abs.items()}

    step = pp.make_pipeline_train_step(cfg, oc, mesh=mesh,
                                       n_stages=n_stages,
                                       n_micro=args.micro, ctx=ctx)
    state_abs = {"params": params_abs, "opt": opt_abs}
    state_shd = {"params": params_shd, "opt": opt_shd}
    seed = jax.ShapeDtypeStruct((), jnp.int32)
    print(f"[pp] lowering {args.arch} x {args.shape}: {n_stages} stages x "
          f"{args.micro} microbatches ...", flush=True)
    t0 = time.time()
    with ctx_lib.use_mesh(mesh):
        lowered = jax.jit(
            step, in_shardings=(state_shd, batch_shd,
                                jax.sharding.NamedSharding(
                                    mesh, jax.sharding.PartitionSpec())),
            donate_argnums=(0,)).lower(state_abs, batch_abs, seed)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    coll = rl.parse_collectives(compiled.as_text(), mesh.size)
    ana = pp_analytic(cfg, shape, mesh, n_stages, args.micro)
    rec = {
        "arch": args.arch, "shape": args.shape, "mesh": "pod",
        "plan": f"pipeline_s{n_stages}_m{args.micro}", "status": "ok",
        "kind": "train", "n_devices": mesh.size,
        "global_batch": shape.global_batch, "seq_len": shape.seq_len,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "dtype_note": "lowered f32 (CPU bf16-emulation partitioner bug); memory figures are 2x the bf16 target",
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes)},
        "collectives": coll,
        "analytic": ana,
        "cost": ctx_lib.compiled_cost_analysis(compiled),
    }
    rec["cost"] = {k: v for k, v in rec["cost"].items()
                   if k in ("flops", "bytes accessed")}
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        f.write(json.dumps(rec) + "\n")
    print(f"[pp] ok: compute {ana['compute_s']*1e3:.0f} ms, "
          f"collective {ana['collective_s']*1e3:.0f} ms, "
          f"memory {ana['memory_s']*1e3:.0f} ms, "
          f"bubble x{ana['bubble_overhead']:.2f}, "
          f"resident {ana['resident_bytes_per_dev']/2**30:.1f} GiB/dev, "
          f"XLA peak {rec['memory']['peak_bytes_per_device']/2**30:.1f} "
          f"GiB/dev (compile {t_compile:.0f}s)")


if __name__ == "__main__":
    main()
