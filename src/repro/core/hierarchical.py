"""Two-level hierarchical Mixture-of-Experts (Appendix B).

A primary gating network selects among ``a`` groups; each group is itself a
secondary MoE over ``b`` experts.  Output (Eq. 12):

    y_H = sum_i sum_j G_primary(x)_i * G_i(x)_j * E_{i,j}(x)

Utilization metrics follow Eqs. (13)-(14):

    Importance_H(X)_{i,j} = sum_x Gp(x)_i * G_i(x)_j
    Load_H(X)_{i,j}       = Load_primary(X)_i * Load_i(X^(i))_j / |X^(i)|

The paper used the hierarchy so 16 GPUs could host 4096+ experts with a
small branching factor; here the primary branch maps onto the *model* mesh
axis (one group of secondary experts per model-shard), the exact analogue of
"each secondary MoE resides on one device" (§3.1).

Routing at both levels goes through the Router API (``HMoEArgs.router``
holds one :class:`repro.core.router.RouterSpec`; per-level k comes from
``k_primary``/``k_secondary``): the primary level capacity-dispatches
tokens into [a, Cp, d] buffers, then the secondary routers run vmapped
over groups with the dispatch-padding slots passed as the router's
token-validity ``mask`` — padded (zero) tokens influence neither gates
nor load statistics.  ``noisy_topk`` and ``expert_choice`` policies are
supported; the Appendix-F batchwise/threshold policies need per-level
threshold parameters the hierarchy does not declare and raise RouterError.

Both levels route their hot-path ops (dispatch/combine scatter, expert
FFN) through the kernel backend registry (``repro.kernels.backend``) —
``kernel_backend="pallas"`` runs the fused kernels (vmapped over groups at
the secondary level), ``"ref"`` the jnp path; resolution is explicit and
raises on an unknown/broken backend, same as the flat MoE layer.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef
from repro.core import dispatch as dsp
from repro.core import gating, losses
from repro.core import router as router_lib
from repro.kernels import backend as backend_lib
from repro.sharding import context as ctx_lib


@dataclasses.dataclass(frozen=True)
class HMoEArgs:
    n_groups: int                 # a — primary branching factor
    n_experts_per_group: int      # b — secondary branching factor
    k_primary: int                # paper: k=2 at each level for the big LMs
    k_secondary: int
    d_model: int
    d_ff: int
    activation: str = "relu"
    # --- routing (docs/routing.md) ------------------------------------------
    # One spec for both levels; k is overridden per level.  None resolves
    # the deprecated fields below via router.resolve_spec.
    router: "router_lib.RouterSpec | None" = None
    capacity_factor: float | None = None   # deprecated; None = spec default
    w_importance: float = 0.1
    w_load: float = 0.1
    dispatch_impl: str = "sort"         # deprecated; ref backend: sort|einsum
    # --- kernels ------------------------------------------------------------
    # Kernel backend (repro/kernels/backend.py): "ref" | "pallas"; None
    # resolves to "ref" (explicit resolution — unknown/broken raises).
    kernel_backend: str | None = None
    dispatch_vmem_limit: int | None = None
    dispatch_e_block: int | None = None    # fused-kernel slab size; None=auto
    gmm_autotune: bool = True              # measured GMM tilings (kernels.md)
    dtype: Any = jnp.bfloat16

    @property
    def n_experts(self) -> int:
        return self.n_groups * self.n_experts_per_group


_HMOE_POLICIES = ("noisy_topk", "expert_choice")


def _level_specs(a: HMoEArgs):
    """(primary, secondary) RouterSpecs from the carrier's single spec."""
    spec = router_lib.resolve_spec(a)
    if spec.policy not in _HMOE_POLICIES:
        raise router_lib.RouterError(
            f"hierarchical MoE supports policies {_HMOE_POLICIES}, got "
            f"{spec.policy!r} (Appendix-F modes need per-level threshold "
            "parameters the hierarchy does not declare)")
    return spec.replace(k=a.k_primary), spec.replace(k=a.k_secondary)


def hmoe_defs(a: HMoEArgs) -> dict:
    gated = a.activation == "swiglu"
    _level_specs(a)                 # validate the policy early
    defs = {
        "gate_primary": gating.gating_defs(a.d_model, a.n_groups),
        # Secondary gates stacked over groups: [a, d_model, b].
        "gate_secondary": {
            "wg": ParamDef((a.n_groups, a.d_model, a.n_experts_per_group),
                           ("expert_groups", "embed", "experts"),
                           init="zeros", dtype=jnp.float32),
            "wnoise": ParamDef((a.n_groups, a.d_model,
                                a.n_experts_per_group),
                               ("expert_groups", "embed", "experts"),
                               init="zeros", dtype=jnp.float32),
        },
        "w1": ParamDef((a.n_groups, a.n_experts_per_group, a.d_model, a.d_ff),
                       ("expert_groups", "experts", "expert_embed",
                        "expert_mlp"),
                       dtype=a.dtype, fan_in=a.d_model),
        "w2": ParamDef((a.n_groups, a.n_experts_per_group, a.d_ff, a.d_model),
                       ("expert_groups", "experts", "expert_mlp",
                        "expert_embed"),
                       dtype=a.dtype, fan_in=a.d_ff),
    }
    if gated:
        defs["w3"] = ParamDef(
            (a.n_groups, a.n_experts_per_group, a.d_model, a.d_ff),
            ("expert_groups", "experts", "expert_embed", "expert_mlp"),
            dtype=a.dtype, fan_in=a.d_model)
    return defs


def _secondary_one_group(gate_params, w1, w2, w3, x_grp, valid, a: HMoEArgs,
                         spec_s: "router_lib.RouterSpec", train: bool, rng):
    """Run one group's secondary MoE on its [Cp, d] buffer.

    ``valid`` masks the padding slots left by primary capacity dispatch —
    it is passed as the router's token-validity mask, so padded tokens
    neither route nor consume secondary capacity.  Returns (y [Cp, d],
    importance_j [b], load_j [b], n_valid scalar, telemetry dict of [b]
    counters).  Dispatch/combine and the expert FFN go through the kernel
    backend registry (vmapped over groups).
    """
    bk = backend_lib.resolve(a)
    router_s = router_lib.Router(spec_s, a.n_experts_per_group)
    cap = spec_s.capacity(x_grp.shape[0], a.n_experts_per_group,
                          train=train)
    dec = router_s.route({"gate": gate_params}, x_grp, train=train,
                         rng=rng, mask=valid, capacity=cap)
    buf = bk.dispatch(x_grp, dec, a)
    params = {"w1": w1, "w2": w2}
    if a.activation == "swiglu":
        params["w3"] = w3
    out = bk.expert_ffn(params, buf, a)
    y = bk.combine(out, dec, a, dtype=x_grp.dtype)
    importance_j = losses.importance(dec.gates)                 # [b]
    load_j = dec.load                                           # [b], masked
    n_valid = jnp.sum(valid)
    return y, importance_j, load_j, n_valid, dec.telemetry


def hmoe_apply(params, x: jax.Array, a: HMoEArgs, *, train: bool = True,
               rng: jax.Array | None = None,
               ctx: ctx_lib.MeshContext | None = None,
               mask: jax.Array | None = None
               ) -> tuple[jax.Array, dict]:
    """x: [T, d_model] -> (y [T, d_model], aux).  ``mask`` ([T] in {0,1})
    marks valid tokens (dead serving slots route nowhere)."""
    t, d = x.shape
    rng_p, rng_s = (jax.random.split(rng) if rng is not None
                    else (None, None))
    bk = backend_lib.resolve(a)     # explicit: raises on unknown/broken
    spec_p, spec_s = _level_specs(a)
    router_p = router_lib.Router(spec_p, a.n_groups,
                                 topk_impl=bk.topk_impl)
    dec_p = router_p.route({"gate": params["gate_primary"]}, x,
                           train=train, rng=rng_p, mask=mask)
    buf = bk.dispatch(x, dec_p, a, ctx=ctx)            # [a, Cp, d]
    valid = dsp.dispatch(jnp.ones((t, 1), x.dtype), dec_p.plan)[..., 0]
    valid = (valid > 0).astype(jnp.float32)            # [a, Cp]
    buf = ctx_lib.with_constraint(buf, ("expert_groups", None, "embed"),
                                  ctx)

    w3 = params.get("w3", jnp.zeros_like(params["w1"]))
    rngs = (jax.random.split(rng_s, a.n_groups) if rng_s is not None
            else None)
    sec = jax.vmap(
        lambda gp, gn, w1, w2, w3g, xg, vg, rg: _secondary_one_group(
            {"wg": gp, "wnoise": gn}, w1, w2, w3g, xg, vg, a, spec_s,
            train, rg))
    y_grp, imp_sec, load_sec, n_valid, telem_sec = sec(
        params["gate_secondary"]["wg"], params["gate_secondary"]["wnoise"],
        params["w1"], params["w2"], w3, buf, valid,
        rngs if rngs is not None else jnp.zeros((a.n_groups, 2), jnp.uint32))

    y = bk.combine(y_grp, dec_p, a, dtype=x.dtype, ctx=ctx)    # primary

    # Eq. (13): Importance_H = Gp_i * G_i_j summed over tokens.  The
    # secondary importance was computed on dispatched tokens whose combine
    # weights already include only the secondary gates, so scale by the mean
    # primary gate mass per group.
    imp_primary = losses.importance(dec_p.gates)                    # [a]
    imp_h = (imp_sec * (imp_primary /
                        jnp.maximum(n_valid, 1.0))[:, None])        # [a, b]
    # Eq. (14): Load_H = Load_p_i * Load_i / |X^(i)|.
    load_h = (dec_p.load[:, None] * load_sec /
              jnp.maximum(n_valid, 1.0)[:, None])                   # [a, b]

    aux_loss = (spec_p.w_importance * losses.cv_squared(imp_h.reshape(-1))
                + spec_p.w_load * losses.cv_squared(load_h.reshape(-1)))
    metrics = {
        "cv_importance": jnp.sqrt(losses.cv_squared(imp_h.reshape(-1))),
        "cv_load": jnp.sqrt(losses.cv_squared(load_h.reshape(-1))),
        "max_over_mean_load": jnp.max(load_h) / jnp.maximum(
            jnp.mean(load_h), 1e-9),
        "fraction_dropped": dec_p.plan.fraction_dropped,
    }
    # Serving telemetry over the flattened (group, expert) grid; primary-
    # level drops are visible via metrics["fraction_dropped"].
    telemetry = {"expert_load": telem_sec["expert_load"].reshape(-1),
                 "overflow": telem_sec["overflow"].reshape(-1)}
    return y, {"aux_loss": aux_loss, "metrics": metrics,
               "telemetry": telemetry}
