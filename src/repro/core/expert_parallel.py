"""Explicit expert-parallel MoE with the paper's §3.1 communication schedule.

The paper: "We distribute the standard layers ... according to conventional
data-parallel schemes, but keep only one shared copy of each expert.  Each
expert receives a combined batch consisting of the relevant examples from all
of the data-parallel input batches."

TPU mapping (shard_map, explicit collectives):

* tokens shard over the dp axes; gating runs locally (data-parallel, tiny
  replicated gate weights — "the number of gating parameters is small", §3.2);
* each shard dispatches its local tokens into per-expert buffers, then an
  ``all_to_all`` over the *ep* axis exchanges expert-major buffers so every
  shard holds the combined batch for its local experts — the d× expert batch
  improvement of §3.1;
* expert weights shard over the ep axis (expert parallelism) and their
  d_model dim over the dp axis (FSDP: all-gathered on use, reduce-scattered
  in backward) — so exactly **one** copy of every expert exists cluster-wide,
  as in the paper;
* a second ``all_to_all`` returns expert outputs, combined locally.

This is the schedule the GSPMD path must be compared against in §Perf: a2a
moves ``2 * k * tokens * d_model`` bytes per layer, independent of E.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import losses
from repro.core import router as router_lib
from repro.core.moe import MoEArgs
from repro.kernels import backend as backend_lib
from repro.sharding import context as ctx_lib


def _local_moe(params, x_local, mask_local, a: MoEArgs, *, train, rng,
               ep_axis: str, fsdp_axis: str | None, ep: int,
               bk: backend_lib.KernelBackend,
               router: router_lib.Router,
               body_ctx: ctx_lib.MeshContext | None):
    """Body executed per shard under shard_map.

    ``ep`` is the ep-axis size, passed from the mesh at the shard_map
    boundary (0.4.x jax cannot query a mapped axis's size by name).
    ``bk`` is the resolved kernel backend; ``router`` the resolved Router
    (routing runs locally on each shard's tokens — data-parallel gating,
    §3.2); ``body_ctx`` the Manual-mode context the backend ops use to
    derive per-shard block specs."""
    ep_rank = jax.lax.axis_index(ep_axis)
    t_local, d = x_local.shape
    if a.n_experts % ep != 0:
        raise ValueError(
            f"n_experts={a.n_experts} must divide over ep={ep} shards")
    e_local = a.n_experts // ep

    # Per-shard rng so noise differs across shards.
    if rng is not None:
        rng = jax.random.fold_in(rng, ep_rank)
        if fsdp_axis is not None:
            rng = jax.random.fold_in(rng, jax.lax.axis_index(fsdp_axis))

    dec = router.route(params, x_local, train=train, rng=rng,
                       mask=mask_local)
    info, p = dec, dec.plan
    capacity = p.capacity
    # Local tokens scatter into a *global*-E buffer before the exchange —
    # the shape where the pallas backend's VMEM planning matters most: past
    # the budget it runs the E-blocked kernels ([e_block, C, d] slabs,
    # a.dispatch_e_block / a.dispatch_vmem_limit) rather than bailing to
    # the ref scatter.
    buf = bk.dispatch(x_local, p, a)                   # [E, C, d] local

    # all_to_all #1: expert-major exchange.  [E, C, d] -> [E/ep, ep*C, d]
    buf = buf.reshape(ep, e_local, capacity, d)
    buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                             tiled=False)              # [ep, e_local, C, d]
    buf = jnp.moveaxis(buf, 0, 1).reshape(e_local, ep * capacity, d)

    # FSDP: all-gather the d_model-sharded expert weights on use.
    def gather_w(w, dim):
        if fsdp_axis is None:
            return w
        return jax.lax.all_gather(w, fsdp_axis, axis=dim, tiled=True)

    w_local = {"w1": gather_w(params["w1"], 1),        # [e_local, d, f]
               "w2": gather_w(params["w2"], 2)}        # [e_local, f, d]
    if a.activation == "swiglu":
        w_local["w3"] = gather_w(params["w3"], 1)
    # The combined batch for the local experts, through the kernel backend:
    # the ops see the per-shard [e_local, ep*C, d] view and derive their
    # block specs from it via body_ctx.
    out = bk.expert_ffn(w_local, buf, a, ctx=body_ctx)

    # all_to_all #2: return to token-major shards.
    out = out.reshape(e_local, ep, capacity, d)
    out = jnp.moveaxis(out, 1, 0)                      # [ep, e_local, C, d]
    out = jax.lax.all_to_all(out, ep_axis, split_axis=0, concat_axis=0,
                             tiled=False)
    out = out.reshape(a.n_experts, capacity, d)

    y = bk.combine(out, p, a, dtype=x_local.dtype)
    # Balance statistics are over the *global* batch: psum the raw vectors.
    axes = (ep_axis,) if fsdp_axis is None else (ep_axis, fsdp_axis)
    imp = jax.lax.psum(losses.importance(info.gates), axes)
    load = jax.lax.psum(info.load, axes)
    # Combined-batch balancing losses (paper §3.1/§4: every expert serves
    # one combined batch, so Importance(X)/Load(X) in Eqs. (6)/(11) sum
    # over *all* data-parallel shards).  The router computed shard-local
    # losses; re-derive CV² from the psum'd global vectors and keep only
    # the policy's extra term (e.g. Appendix-F threshold alignment) from
    # the local value — pmean of per-shard CVs is NOT the global CV (each
    # shard routing all its tokens to a different single expert is
    # maximally skewed locally yet perfectly balanced globally; for
    # expert_choice the shard-local load is capacity-uniform by
    # construction, so only the global view can see imbalance at all).
    spec = router.spec
    local_balance = (losses.importance_loss(info.gates, spec.w_importance)
                     + losses.load_loss(info.load, spec.w_load))
    extra = dec.aux_loss - local_balance      # exact: same fp recompute
    aux_loss = (spec.w_importance * losses.cv_squared(imp)
                + spec.w_load * losses.cv_squared(load)
                + jax.lax.pmean(extra, axes))
    metrics = {
        "cv_importance": jnp.sqrt(losses.cv_squared(imp)),
        "cv_load": jnp.sqrt(losses.cv_squared(load)),
        "max_over_mean_load": jnp.max(load) / jnp.maximum(jnp.mean(load),
                                                          1e-9),
        "fraction_dropped": jax.lax.pmean(p.fraction_dropped, axes),
    }
    return y, {"aux_loss": aux_loss, "metrics": metrics}


def moe_apply_ep(params, x, a: MoEArgs, mesh: Mesh | None = None, *,
                 train: bool = True, rng: jax.Array | None = None,
                 ep_axis: str = "model",
                 dp_axes: tuple[str, ...] = ("data",),
                 mask: jax.Array | None = None,
                 ctx: ctx_lib.MeshContext | None = None):
    """Expert-parallel MoE over a flat token batch x: [T, d_model].

    Tokens shard over (dp_axes..., ep_axis); expert weights shard as
    [experts -> ep_axis, d_model -> dp_axes[-1] (FSDP)]; gates replicated.
    ``mask`` ([T] in {0,1}, sharded like the tokens) is the router's
    token-validity mask: masked tokens (dead serving slots, padding)
    route nowhere, consume no capacity, and drop out of the globally
    psum'd importance/load balance statistics.
    The mesh comes from ``ctx`` when given (explicit-first), else the
    positional ``mesh`` argument.  NOTE: only ``ctx.mesh`` is consumed —
    this schedule's sharding is fixed by ``ep_axis``/``dp_axes``, not by
    ``ctx.rules``, and it must own the whole mesh (no enclosing Manual
    axes).
    """
    if ctx is not None and ctx.mesh is not None:
        if ctx.manual_axes:
            raise RuntimeError(
                "moe_apply_ep opens its own shard_map; it cannot run "
                "inside a Manual-mode context")
        mesh = ctx.mesh
    if mesh is None:
        raise RuntimeError(
            "moe_apply_ep needs a mesh (ctx or positional)")
    bk = backend_lib.resolve(a)     # explicit: raises on unknown/broken
    router = router_lib.build(a, topk_impl=bk.topk_impl)
    # Context for the shard_map body: every mesh axis is Manual on 0.4.x,
    # so backend ops derive per-shard [E/ep, C, d] block specs from it.
    # Only meaningful when the plan's expert axis is the ep axis we use.
    body_ctx = (ctx or ctx_lib.MeshContext.for_mesh(mesh)).manual(
        *mesh.axis_names)
    if ep_axis not in body_ctx.rules.lookup("experts"):
        body_ctx = None
    fsdp_axis = dp_axes[-1] if dp_axes else None
    token_spec = P(tuple(dp_axes) + (ep_axis,), None)
    w_specs = {
        "gate": jax.tree_util.tree_map(lambda _: P(None, None),
                                       params["gate"]),
        "w1": P(ep_axis, fsdp_axis, None),
        "w2": P(ep_axis, None, fsdp_axis),
    }
    if "w3" in params:
        w_specs["w3"] = P(ep_axis, fsdp_axis, None)
    if "thresholds" in params:      # Appendix-F policy params: replicated
        w_specs["thresholds"] = jax.tree_util.tree_map(
            lambda _: P(None), params["thresholds"])
    aux_spec = {"aux_loss": P(), "metrics": {
        "cv_importance": P(), "cv_load": P(), "max_over_mean_load": P(),
        "fraction_dropped": P()}}
    fn = functools.partial(_local_moe, a=a, train=train, rng=rng,
                           ep_axis=ep_axis, fsdp_axis=fsdp_axis,
                           ep=mesh.shape[ep_axis], bk=bk, router=router,
                           body_ctx=body_ctx)
    if mask is None:
        return ctx_lib.shard_map(
            lambda p, t: fn(p, t, None), mesh, (w_specs, token_spec),
            (token_spec, aux_spec))(params, x)
    mask_spec = P(tuple(dp_axes) + (ep_axis,))
    return ctx_lib.shard_map(fn, mesh,
                             (w_specs, token_spec, mask_spec),
                             (token_spec, aux_spec))(params, x, mask)
