"""First-class Router API: RouterSpec + policy registry + RouteDecision.

The paper's core contribution is the *trainable gating network* (§2, §4,
Appendix A), so routing deserves the same first-class treatment the kernel
hot path got from ``repro.kernels.backend``: one typed spec, one registry,
one resolution point — instead of ``gating_mode`` / ``dispatch_impl`` /
``capacity_factor`` strings and floats spread (with disagreeing defaults)
across ``MoEArgs``, ``HMoEArgs`` and ``ModelConfig``.

* :class:`RouterSpec` — a frozen value object holding *everything* that
  configures a routing decision: policy name, k, train/eval capacity
  factors, noise, balance-loss weights, and the dispatch scatter flavour.
  ``ModelConfig.router`` / ``MoEArgs.router`` / ``HMoEArgs.router`` carry
  one; the legacy string fields are a deprecated shim that
  :func:`resolve_spec` folds into a spec (with a ``DeprecationWarning``
  for the old spellings).
* the **policy registry** — ``register_policy`` / ``get_policy``, exactly
  analogous to the kernel-backend registry: resolution is explicit and an
  unknown policy raises :class:`RouterError` (never a silent default).
  Built-ins: ``noisy_topk`` (Eqs. 3-5 + Appendix-A load), ``batchwise``
  and ``threshold`` (Appendix F), and ``expert_choice`` (experts pick
  tokens — capacity-bound by construction, Zhou et al. 2022), the proof
  that new routing scenarios land as one registered function instead of
  edits to moe.py/hierarchical.py/configs in lockstep.
* :class:`Router` / :class:`RouteDecision` — ``router.route(params, x,
  train=..., mask=...)`` returns the full typed routing decision: combine
  weights, expert indices, the capacity-dispatch plan, balancing losses,
  balance metrics and serving telemetry.  ``moe_apply`` / ``hmoe_apply``
  and the expert-parallel schedule consume it; the kernel backends accept
  a decision wherever they accept a plan.

Token-validity masking: ``route(..., mask=valid)`` (``[T]`` in {0,1})
zeroes masked tokens out of gates, load, telemetry *and* capacity — a
masked token's assignments sort behind every real token and take no
buffer slot.  The serving engine uses this to stop dead slots from
consuming expert capacity, and bucketed prefill uses it to keep padded
prompt tails out of routing (docs/routing.md).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dispatch as dsp
from repro.core import gating, losses

# The single capacity-factor default.  ModelConfig used to say 1.25 while
# MoEArgs said 2.0; the paper-LM config (§C.1) trains at 2.0 and that is
# the value every carrier now inherits unless it sets one explicitly
# (tests/test_router.py pins the resolved value for the paper config).
DEFAULT_CAPACITY_FACTOR = 2.0


class RouterError(ValueError):
    """Unknown routing policy or invalid router configuration."""


# ---------------------------------------------------------------------------
# the spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RouterSpec:
    """Everything that configures one routing decision.

    ``k`` may be ``None`` to inherit the carrier's value (``MoEArgs.k`` /
    ``ModelConfig.moe_k`` / the per-level k of ``HMoEArgs``), since k also
    sizes parameter definitions and analytic accounting there.
    ``eval_capacity_factor=None`` means "same as training".
    """
    policy: str = "noisy_topk"
    k: int | None = None
    capacity_factor: float = DEFAULT_CAPACITY_FACTOR
    eval_capacity_factor: float | None = None
    noise: bool = True              # Eq. (3) tunable Gaussian noise (train)
    w_importance: float = 0.1       # §C.1 defaults for Eqs. (7)/(11)
    w_load: float = 0.1
    dispatch: str = "sort"          # ref-backend scatter: sort | einsum
    priority_dispatch: bool = False  # over-capacity slots by weight, not order
    capacity_multiple: int = 8      # TPU tiling round-up for capacity

    def replace(self, **kw) -> "RouterSpec":
        return dataclasses.replace(self, **kw)

    @property
    def eval_cf(self) -> float:
        return (self.capacity_factor if self.eval_capacity_factor is None
                else self.eval_capacity_factor)

    def capacity(self, n_tokens: int, n_experts: int, *,
                 train: bool) -> int:
        """Slots per expert for a batch of ``n_tokens`` (ceil + tiling)."""
        cf = self.capacity_factor if train else self.eval_cf
        return dsp.capacity_for(n_tokens, n_experts, self.k or 1, cf,
                                multiple=self.capacity_multiple)


# ---------------------------------------------------------------------------
# the decision
# ---------------------------------------------------------------------------

class RouteDecision(NamedTuple):
    """The full typed result of one routing decision."""
    combine_weights: jax.Array   # [T, k] f32 gate values of the winners
    expert_index: jax.Array      # [T, k] int32 winning experts
    gates: jax.Array             # [T, E] f32 sparse gate matrix G(x)
    load: jax.Array              # [E] f32 (smooth) load estimator
    plan: dsp.DispatchPlan       # capacity dispatch plan (post-truncation)
    aux_loss: jax.Array          # §4 balancing losses, already weighted
    metrics: dict                # Table-6 diagnostics + fraction_dropped
    telemetry: dict              # serving counters: expert_load / overflow


def route_telemetry(info: gating.GatingInfo, p: dsp.DispatchPlan) -> dict:
    """Per-expert serving counters from one gating/dispatch decision.

    ``expert_load``: hard assignment counts (tokens routed per expert),
    ``overflow``: assignments dropped by capacity truncation per expert.
    Masked (zero-weight) tokens count toward neither.
    """
    assigned = (info.combine_weights > 0.0).reshape(-1)
    kept = (p.position < p.capacity).reshape(-1)
    flat_e = info.expert_index.reshape(-1)
    zero = jnp.zeros((p.n_experts,), jnp.float32)
    return {
        "expert_load": zero.at[flat_e].add(assigned.astype(jnp.float32)),
        "overflow": zero.at[flat_e].add(
            (assigned & ~kept).astype(jnp.float32)),
    }


# ---------------------------------------------------------------------------
# policy registry
# ---------------------------------------------------------------------------

class PolicyOutput(NamedTuple):
    """What a policy hands back to the Router.

    ``capacity``/``plan`` are overrides: ``None`` lets the Router derive
    the capacity from the spec and build the standard dispatch plan.
    ``extra_loss`` joins the importance/load losses (e.g. the Appendix-F
    threshold-alignment loss, Eq. 20).
    """
    info: gating.GatingInfo
    capacity: int | None = None
    plan: dsp.DispatchPlan | None = None
    extra_loss: jax.Array | float = 0.0


@dataclasses.dataclass(frozen=True)
class RouterPolicy:
    """One registered routing policy.

    ``route(params, x, spec, n_experts, *, train, rng, mask, capacity,
    topk_impl) -> PolicyOutput``; ``defs(spec, d_model, n_experts)``
    returns the policy's parameter definitions (merged into the MoE
    layer's defs — e.g. ``{"gate": ...}`` plus Appendix-F thresholds).
    """
    name: str
    route: Callable
    defs: Callable


_POLICIES: dict[str, RouterPolicy] = {}


def register_policy(policy: RouterPolicy) -> None:
    _POLICIES[policy.name] = policy


def available_policies() -> list[str]:
    return sorted(_POLICIES)


def get_policy(name: str) -> RouterPolicy:
    entry = _POLICIES.get(name)
    if entry is None:
        raise RouterError(
            f"unknown router policy {name!r}; registered: "
            f"{sorted(_POLICIES)}")
    return entry


# ---------------------------------------------------------------------------
# legacy-string resolution (the deprecation shim)
# ---------------------------------------------------------------------------

_LEGACY_STRINGS = ("gating_mode", "dispatch_impl", "expert_impl")
_LEGACY_DEFAULTS = {"gating_mode": "noisy_topk", "dispatch_impl": "sort",
                    "expert_impl": "einsum"}


def _warn_legacy(a) -> None:
    used = [f for f in _LEGACY_STRINGS
            if getattr(a, f, _LEGACY_DEFAULTS[f]) != _LEGACY_DEFAULTS[f]]
    if used:
        warnings.warn(
            f"{type(a).__name__} fields {used} are deprecated string "
            "spellings; pass a repro.core.router.RouterSpec (router=...) "
            "instead (docs/routing.md)", DeprecationWarning, stacklevel=3)


def resolve_spec(a) -> RouterSpec:
    """The single resolution point: carrier (MoEArgs / HMoEArgs /
    ModelConfig / PaperLMConfig) -> a validated RouterSpec.

    An explicit ``a.router`` wins; otherwise the legacy fields resolve
    into a spec (``DeprecationWarning`` for non-default string
    spellings).  ``k=None`` inherits the carrier's k.  The policy name is
    validated against the registry — unknown policies raise RouterError.
    """
    spec = getattr(a, "router", None)
    if spec is None:
        _warn_legacy(a)
        cf = getattr(a, "capacity_factor", None)
        spec = RouterSpec(
            policy=getattr(a, "gating_mode", "noisy_topk"),
            capacity_factor=DEFAULT_CAPACITY_FACTOR if cf is None else cf,
            eval_capacity_factor=getattr(a, "eval_capacity_factor", None),
            w_importance=getattr(a, "w_importance", 0.1),
            w_load=getattr(a, "w_load", 0.1),
            dispatch=getattr(a, "dispatch_impl", "sort"),
            priority_dispatch=getattr(a, "priority_dispatch", False))
    if spec.k is None:
        k = getattr(a, "k", None)
        if k is None:
            k = getattr(a, "moe_k", None)
        if k:
            spec = spec.replace(k=int(k))
    get_policy(spec.policy)     # explicit: unknown policy raises here
    return spec


# ---------------------------------------------------------------------------
# the Router
# ---------------------------------------------------------------------------

class Router:
    """A resolved (spec, n_experts) pair with a callable ``route``.

    ``topk_impl`` is the kernel backend's fused KeepTopK+softmax (or
    ``None`` for the lax.top_k path) — the only coupling between routing
    and the kernel registry, passed in so this module imports neither.
    """

    def __init__(self, spec: RouterSpec, n_experts: int, *,
                 topk_impl: Callable | None = None):
        if spec.k is None:
            raise RouterError(f"RouterSpec.k unresolved for {spec}")
        self.spec = spec
        self.n_experts = n_experts
        self.policy = get_policy(spec.policy)
        self.topk_impl = topk_impl

    def gate_defs(self, d_model: int) -> dict:
        """Parameter definitions this policy needs (merged into moe_defs)."""
        return self.policy.defs(self.spec, d_model, self.n_experts)

    def capacity(self, n_tokens: int, *, train: bool) -> int:
        return self.spec.capacity(n_tokens, self.n_experts, train=train)

    def route(self, params, x: jax.Array, *, train: bool,
              rng: jax.Array | None = None,
              mask: jax.Array | None = None,
              capacity: int | None = None) -> RouteDecision:
        """One routing decision over a flat token batch x: [T, d].

        ``mask`` ([T] in {0,1}) marks valid tokens: masked tokens get
        zero gate weight, zero load, zero telemetry, and consume no
        expert capacity.  ``capacity`` overrides the spec-derived
        slots-per-expert (the hierarchical secondary level does this).
        """
        spec = self.spec
        if mask is not None:
            mask = jnp.asarray(mask, jnp.float32).reshape(-1)
        if capacity is None:
            capacity = self.capacity(x.shape[0], train=train)
        out = self.policy.route(params, x, spec, self.n_experts,
                                train=train, rng=rng, mask=mask,
                                capacity=capacity,
                                topk_impl=self.topk_impl)
        info = out.info
        plan = out.plan
        if plan is None:
            cap = capacity if out.capacity is None else out.capacity
            plan = dsp.plan(info.expert_index, info.combine_weights,
                            self.n_experts, cap,
                            priority=spec.priority_dispatch)
        aux_loss = (losses.importance_loss(info.gates, spec.w_importance)
                    + losses.load_loss(info.load, spec.w_load)
                    + out.extra_loss)
        metrics = losses.balance_metrics(info.gates, info.load)
        metrics["fraction_dropped"] = plan.fraction_dropped
        return RouteDecision(
            combine_weights=info.combine_weights,
            expert_index=info.expert_index, gates=info.gates,
            load=info.load, plan=plan, aux_loss=aux_loss,
            metrics=metrics, telemetry=route_telemetry(info, plan))


def build(a, *, topk_impl: Callable | None = None) -> Router:
    """Carrier args -> Router (resolve_spec + n_experts), the one-liner
    ``moe_apply``/``hmoe_apply``/the EP schedule use."""
    return Router(resolve_spec(a), a.n_experts, topk_impl=topk_impl)


# ---------------------------------------------------------------------------
# built-in policies
# ---------------------------------------------------------------------------

def _gate_only_defs(spec: RouterSpec, d_model: int, n_experts: int) -> dict:
    return {"gate": gating.gating_defs(d_model, n_experts, noisy=False)}


def _noisy_topk_defs(spec: RouterSpec, d_model: int, n_experts: int) -> dict:
    return {"gate": gating.gating_defs(d_model, n_experts,
                                       noisy=spec.noise)}


def _noisy_topk_route(params, x, spec, n_experts, *, train, rng, mask,
                      capacity, topk_impl) -> PolicyOutput:
    """Eqs. (3)-(5) + the Appendix-A load estimator."""
    info = gating.noisy_topk_gating(
        params["gate"], x, spec.k, train=train and spec.noise,
        rng=rng if spec.noise else None, valid=mask, topk_impl=topk_impl)
    return PolicyOutput(info=info)


def _appendix_f_capacity(spec: RouterSpec, n_tokens: int,
                         n_experts: int) -> int:
    """Appendix F: exactly m = k·T/E slots per expert; nothing dropped."""
    cap = max((spec.k * n_tokens) // n_experts, 1)
    m = spec.capacity_multiple
    return int(-(-cap // m) * m)


def _batchwise_route(params, x, spec, n_experts, *, train, rng, mask,
                     capacity, topk_impl) -> PolicyOutput:
    info = gating.batchwise_gating(params["gate"], x, spec.k, valid=mask)
    cap = (_appendix_f_capacity(spec, x.shape[0], n_experts) if train
           else None)
    return PolicyOutput(info=info, capacity=cap)


def _threshold_defs(spec: RouterSpec, d_model: int, n_experts: int) -> dict:
    return {"gate": gating.gating_defs(d_model, n_experts, noisy=False),
            "thresholds": gating.threshold_defs(n_experts)}


def _threshold_route(params, x, spec, n_experts, *, train, rng, mask,
                     capacity, topk_impl) -> PolicyOutput:
    if train:   # train with the batchwise mask, infer with thresholds
        info = gating.batchwise_gating(params["gate"], x, spec.k,
                                       valid=mask)
        extra = gating.batchwise_threshold_loss(
            params["gate"], params["thresholds"], x, spec.k)
        cap = _appendix_f_capacity(spec, x.shape[0], n_experts)
        return PolicyOutput(info=info, capacity=cap, extra_loss=extra)
    info = gating.threshold_gating(params["gate"], params["thresholds"],
                                   x, spec.k, valid=mask)
    return PolicyOutput(info=info)


def _expert_choice_route(params, x, spec, n_experts, *, train, rng, mask,
                         capacity, topk_impl) -> PolicyOutput:
    """Expert-choice routing (Zhou et al. 2022): experts pick tokens.

    Each expert selects its top-``capacity`` tokens by gate affinity, so
    the dispatch buffers are full-by-construction and *nothing ever
    overflows* — the positions assigned here are column ranks < capacity.
    A token keeps at most ``spec.k`` of the experts that picked it (the
    token-major [T, k] interface the dispatch plan and kernels share);
    picks beyond that per-token width are reported as
    ``fraction_dropped``.  Masked tokens are never picked.
    """
    t = x.shape[0]
    xf = jnp.asarray(x, jnp.float32)
    logits = xf @ jnp.asarray(params["gate"]["wg"], jnp.float32)   # [T, E]
    g_dense = jax.nn.softmax(logits, axis=-1)
    g_pickable = g_dense if mask is None else g_dense * mask[:, None]

    cap = min(capacity, t)
    # Per-expert top-C tokens over the batch (columns of g).
    col_vals, col_idx = jax.lax.top_k(g_pickable.T, cap)           # [E, C]
    # Rank (= buffer position) of each picked token within its expert.
    e_rows = jnp.broadcast_to(jnp.arange(n_experts)[:, None],
                              (n_experts, cap))
    picked = jnp.zeros((t, n_experts), bool).at[
        col_idx, e_rows].set(col_vals > 0.0)                       # [T, E]
    pos_matrix = jnp.full((t, n_experts), capacity, jnp.int32).at[
        col_idx, e_rows].set(
        jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32)[None, :],
                         (n_experts, cap)))                        # [T, E]

    # Token-major view: each token keeps its k best picking experts.
    kk = min(spec.k, n_experts)
    g_kept = jnp.where(picked, g_dense, 0.0)
    combine, topk_idx = jax.lax.top_k(g_kept, kk)                  # [T, k]
    topk_idx = topk_idx.astype(jnp.int32)
    position = jnp.take_along_axis(pos_matrix, topk_idx, axis=1)
    position = jnp.where(combine > 0.0, position, capacity)

    gates = jnp.zeros_like(g_dense).at[
        jnp.arange(t)[:, None], topk_idx].set(combine)
    load = jnp.sum(picked.astype(jnp.float32), axis=0)             # [E]

    n_picks = jnp.maximum(jnp.sum(picked.astype(jnp.float32)), 1.0)
    kept = jnp.sum((combine > 0.0).astype(jnp.float32))
    plan = dsp.DispatchPlan(
        expert_index=topk_idx, position=position,
        weight=combine.astype(jnp.float32), n_experts=n_experts,
        capacity=capacity,
        fraction_dropped=(n_picks - kept) / n_picks)
    info = gating.GatingInfo(
        combine_weights=combine, expert_index=topk_idx, gates=gates,
        load=load, raw_logits=logits)
    return PolicyOutput(info=info, plan=plan)


register_policy(RouterPolicy(name="noisy_topk", route=_noisy_topk_route,
                             defs=_noisy_topk_defs))
register_policy(RouterPolicy(name="batchwise", route=_batchwise_route,
                             defs=_gate_only_defs))
register_policy(RouterPolicy(name="threshold", route=_threshold_route,
                             defs=_threshold_defs))
register_policy(RouterPolicy(name="expert_choice",
                             route=_expert_choice_route,
                             defs=_gate_only_defs))
