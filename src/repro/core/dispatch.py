"""Token dispatch / combine for sparse expert computation.

XLA requires static shapes, so the paper's dynamic "send each token to its
winning experts" becomes *capacity-based* dispatch: every expert owns a fixed
buffer of ``capacity`` token slots.  Assignments beyond capacity are dropped
(their gate weight is zeroed, so the token simply passes through the residual
connection).  With the paper's Appendix-F batchwise gating the buffers are
exactly full and nothing is dropped — that gating mode *is* this dispatch.

Two implementations with identical semantics:

* ``sort``   — O(T·k) scatter via a stable sort on expert id.  Scales to
               hundreds of experts (kimi-k2's 384, arctic's 128).
* ``einsum`` — GShard-style one-hot [T, E, C] masks.  O(T·E·C) memory but
               pure MXU work; used as the reference oracle and for small E.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DispatchPlan(NamedTuple):
    expert_index: jax.Array      # [T, k] int32
    position: jax.Array          # [T, k] int32 slot within the expert buffer
    weight: jax.Array            # [T, k] f32 combine weight (0 if dropped)
    n_experts: int
    capacity: int
    fraction_dropped: jax.Array  # scalar f32


def capacity_for(n_tokens: int, n_experts: int, k: int,
                 capacity_factor: float, *, multiple: int = 8) -> int:
    """Slots per expert: ceil(k*T/E * factor), rounded up for TPU tiling."""
    raw = (k * n_tokens * capacity_factor) / max(n_experts, 1)
    cap = int(-(-raw // 1))
    cap = max(cap, 1)
    return int(-(-cap // multiple) * multiple)


def plan(expert_index: jax.Array, weight: jax.Array, n_experts: int,
         capacity: int, *, priority: bool = False) -> DispatchPlan:
    """Assign a buffer slot to every (token, k) pair.

    ``priority=True`` gives over-capacity slots to the highest-weight
    assignments instead of earliest-in-batch (beyond-paper option; the
    paper's infrastructure used batch order).
    """
    t, k = expert_index.shape
    flat_e = expert_index.reshape(-1)                       # [T*k]
    flat_w = jnp.asarray(weight, jnp.float32).reshape(-1)
    # Sort by expert id; zero-weight assignments (batchwise-gating padding)
    # go last within their group so they never displace real tokens.
    if priority:
        order = jnp.lexsort((-flat_w, flat_e))
    else:
        order = jnp.argsort(flat_e * 2 + (flat_w <= 0), stable=True)
    sorted_e = flat_e[order]
    sorted_w = flat_w[order]
    counts_all = jnp.zeros((n_experts,), jnp.int32).at[flat_e].add(1)
    # Position within expert group = sorted rank - group start.
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts_all)[:-1].astype(jnp.int32)])
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    pos_sorted = jnp.where(sorted_w > 0, rank, capacity)    # pad ⇒ dropped
    position = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted)
    position = position.reshape(t, k)
    kept = position < capacity
    w = jnp.where(kept, weight, 0.0)
    denom = jnp.maximum(jnp.sum((jnp.asarray(weight) > 0), dtype=jnp.float32),
                        1.0)
    frac_dropped = jnp.sum(
        ((jnp.asarray(weight) > 0) & ~kept).astype(jnp.float32)) / denom
    return DispatchPlan(expert_index=expert_index, position=position,
                        weight=w, n_experts=n_experts, capacity=capacity,
                        fraction_dropped=frac_dropped)


# ---------------------------------------------------------------------------
# sort/scatter implementation
# ---------------------------------------------------------------------------

def dispatch(x: jax.Array, p: DispatchPlan) -> jax.Array:
    """[T, d] -> [E, C, d].  Out-of-capacity scatters are dropped (OOB)."""
    t, d = x.shape
    k = p.expert_index.shape[1]
    buf = jnp.zeros((p.n_experts, p.capacity, d), x.dtype)
    flat_e = p.expert_index.reshape(-1)
    flat_pos = p.position.reshape(-1)            # >= capacity ⇒ dropped by .at
    xk = jnp.broadcast_to(x[:, None, :], (t, k, d)).reshape(t * k, d)
    return buf.at[flat_e, flat_pos].set(xk, mode="drop")


def combine(expert_out: jax.Array, p: DispatchPlan, dtype=None) -> jax.Array:
    """[E, C, d] -> [T, d]: weighted gather, y = sum_k w_k * E_{e_k}(x)."""
    t, k = p.expert_index.shape
    gathered = expert_out[p.expert_index, jnp.clip(p.position, 0,
                                                   p.capacity - 1)]  # [T,k,d]
    w = p.weight.astype(jnp.float32)[..., None]
    y = jnp.sum(gathered.astype(jnp.float32) * w, axis=1)
    return y.astype(dtype or expert_out.dtype)


# ---------------------------------------------------------------------------
# einsum (GShard-style) reference implementation
# ---------------------------------------------------------------------------

def masks_einsum(p: DispatchPlan):
    """Build dense dispatch/combine one-hot tensors [T, E, C]."""
    e_oh = jax.nn.one_hot(p.expert_index, p.n_experts, dtype=jnp.float32)
    pos_clipped = jnp.where(p.position < p.capacity, p.position, p.capacity)
    c_oh = jax.nn.one_hot(pos_clipped, p.capacity, dtype=jnp.float32)
    disp = jnp.einsum("tke,tkc->tec", e_oh, c_oh)
    comb = jnp.einsum("tke,tkc,tk->tec", e_oh, c_oh,
                      p.weight.astype(jnp.float32))
    return disp, comb


def dispatch_einsum(x: jax.Array, p: DispatchPlan) -> jax.Array:
    disp, _ = masks_einsum(p)
    return jnp.einsum("tec,td->ecd", disp,
                      x.astype(jnp.float32)).astype(x.dtype)


def combine_einsum(expert_out: jax.Array, p: DispatchPlan,
                   dtype=None) -> jax.Array:
    _, comb = masks_einsum(p)
    y = jnp.einsum("tec,ecd->td", comb, expert_out.astype(jnp.float32))
    return y.astype(dtype or expert_out.dtype)
