"""Auxiliary balancing losses (§4 and Appendix A).

* ``importance_loss`` — Eq. (6)+(7): CV(sum_x G(x))^2 * w_importance.
* ``load_loss``       — Eq. (11):     CV(Load(X))^2   * w_load.
* ``cv_squared``      — the shared squared coefficient of variation.

Both losses are computed in float32; with zero-initialized gates every expert
starts with identical importance/load so both losses start at ~0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cv_squared(x: jax.Array, eps: float = 1e-10) -> jax.Array:
    """Squared coefficient of variation: Var(x) / Mean(x)^2.

    Returns 0 for vectors of length <= 1 (a single expert cannot be
    imbalanced), matching the reference implementation.
    """
    x = jnp.asarray(x, jnp.float32)
    if x.shape[-1] <= 1:
        return jnp.zeros((), jnp.float32)
    mean = jnp.mean(x, axis=-1)
    var = jnp.var(x, axis=-1)
    return var / (mean * mean + eps)


def importance(gates: jax.Array) -> jax.Array:
    """Eq. (6): Importance(X)_i = sum_x G(x)_i.  gates: [T, E] -> [E]."""
    return jnp.sum(jnp.asarray(gates, jnp.float32), axis=0)


def importance_loss(gates: jax.Array, w_importance: float) -> jax.Array:
    """Eq. (7)."""
    return w_importance * cv_squared(importance(gates))


def load_loss(load: jax.Array, w_load: float) -> jax.Array:
    """Eq. (11); `load` is the smooth estimator from the gating network."""
    return w_load * cv_squared(load)


def balance_metrics(gates: jax.Array, load: jax.Array) -> dict:
    """The Table-6 diagnostics: CV(Importance), CV(Load), max/mean load."""
    imp = importance(gates)
    loadf = jnp.asarray(load, jnp.float32)
    return {
        "cv_importance": jnp.sqrt(cv_squared(imp)),
        "cv_load": jnp.sqrt(cv_squared(loadf)),
        "max_over_mean_load": jnp.max(loadf) / jnp.maximum(
            jnp.mean(loadf), 1e-9),
        "fraction_dropped": jnp.zeros((), jnp.float32),  # filled by dispatch
    }
