"""Mixture-of-Attention-Heads: per-token routed attention head groups.

MoA (Zhang et al. 2022, PAPERS.md) applies the paper's conditional-
computation thesis to the *mixer*: the experts are groups of attention
heads, and each token runs only its top-k head groups instead of all of
them.  This module is the first non-FFN consumer of the Router API
(``core/router.py``) — proof that RouteDecision/DispatchPlan are
expert-agnostic (ROADMAP open item 4):

* **Shared K/V** (MoA keeps one K/V projection for every head group, the
  MQA-style factorization that makes the sparsity pay): the KV cache has
  the exact shape of a plain attention layer
  (``attention.init_cache_defs``), so ``SlotKVCache`` pages, chunked
  prefill, and the shared-prefix radix cache work unchanged.
* **Routed Q/O**: each expert owns a ``[d, Hg·hd]`` query projection and
  a ``[Hg·hd, d]`` output projection.  A token's winning experts are
  chosen by any registered routing policy (noisy_topk, expert_choice,
  batchwise, threshold) and the projections run as grouped matmuls over
  capacity buffers — the same ``dispatch → gmm → combine`` hot path the
  MoE FFN uses, through the same kernel backend registry
  (``repro.kernels.backend``, custom VJPs intact on ``ref`` and
  ``pallas``).

Layer math (one token t, selected experts e with gates w_e):

    y_t = sum_e  w_e · Attn(x_t W_q^e, K, V) W_o^e

where K/V are shared across experts.  Because W_o is linear and the
combine is linear, the gate weighting is applied once, at the final
combine.  The attention itself runs in token-major layout over the k·Hg
*selected* virtual heads — k/E of the dense-all-heads FLOPs for the
score/value contractions and the Q/O projections.

Data movement uses the backend kernels twice per direction:

1. Q: ``dispatch(x)`` → ``gmm(wq)`` → assignment-major ``combine``
   (unit weights) gathers each token's k projected head groups back to
   token-major for attention;
2. O: assignment-major ``dispatch`` scatters the per-assignment
   attention outputs to the buffers → ``gmm(wo)`` → weighted ``combine``.

The assignment-major view reshapes the token-major [T, k] plan to
[T·k, 1] — every (token, slot) assignment is its own row, which is
exactly what the fused kernels already support (k is just an array dim).

Capacity/overflow semantics match the MoE layer: an assignment beyond
expert capacity is dropped — its gate weight is zeroed, so that head
group contributes nothing and the token leans on its other winners (or
the residual, if all k drop).  Masked tokens (dead serving slots,
bucketed-prefill padding) route nowhere and consume no capacity.

See docs/moa.md for the serving invariants and the bench methodology.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef
from repro.core import dispatch as dsp
from repro.core import router as router_lib
from repro.kernels import backend as backend_lib
from repro.models import attention as attn_lib
from repro.models import layers
from repro.sharding import context as ctx_lib


@dataclasses.dataclass(frozen=True)
class MoAArgs:
    """Configuration of one Mixture-of-Attention-Heads layer.

    ``n_experts`` head groups of ``n_heads_per_expert`` query heads each;
    ``k`` groups run per token.  ``n_kv_heads`` shared K/V heads must
    divide ``n_heads_per_expert`` (each group spreads its heads uniformly
    over the shared K/V heads; MoA's paper setting is 1 — pure MQA).
    Routing/kernel knobs mirror ``MoEArgs`` so ``router.build`` and
    ``backend.resolve`` treat both carriers identically.
    """
    n_experts: int
    k: int
    d_model: int
    n_heads_per_expert: int
    head_dim: int
    n_kv_heads: int = 1
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # --- routing (docs/routing.md; resolve_spec inherits k) ---------------
    router: "router_lib.RouterSpec | None" = None
    capacity_factor: float | None = None
    eval_capacity_factor: float | None = None
    w_importance: float = 0.1
    w_load: float = 0.1
    priority_dispatch: bool = False
    # --- kernels (docs/kernels.md) ----------------------------------------
    kernel_backend: str | None = None
    dispatch_impl: str = "sort"
    dispatch_vmem_limit: int | None = None
    dispatch_e_block: int | None = None
    gmm_autotune: bool = True
    # Serve-time fused decode (docs/kernels.md §Fused decode step): each
    # routed Q/O projection runs dispatch -> grouped matmul -> combine as
    # one kernel launch (``decode_proj`` on the backend).  Inference-only;
    # set by the model layer for decode-shaped calls only.
    fused_decode: bool = False
    # --- attention blocking -----------------------------------------------
    q_block: int = 512
    kv_block: int = 512
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        if self.n_experts < 2:
            raise ValueError(
                f"MoA needs >= 2 head-group experts, got {self.n_experts}")
        if not 1 <= self.k <= self.n_experts:
            raise ValueError(
                f"MoA k={self.k} out of range for E={self.n_experts}")
        if self.n_kv_heads < 1 \
                or self.n_heads_per_expert % self.n_kv_heads:
            raise ValueError(
                f"n_heads_per_expert={self.n_heads_per_expert} must be a "
                f"positive multiple of n_kv_heads={self.n_kv_heads} (each "
                "head group spreads uniformly over the shared K/V heads)")

    @property
    def d_head_group(self) -> int:
        return self.n_heads_per_expert * self.head_dim


def moa_defs(a: MoAArgs) -> dict:
    """Parameter definitions: router gate + per-expert wq/wo (sharded like
    MoE expert weights: experts over the model axis, d_model FSDP) +
    shared wk/wv (plain attention axes — one K/V projection, MQA-style)."""
    spec = router_lib.resolve_spec(a)
    defs = dict(router_lib.Router(spec, a.n_experts).gate_defs(a.d_model))
    hh = a.d_head_group
    defs.update({
        "wq": ParamDef((a.n_experts, a.d_model, hh),
                       ("experts", "expert_embed", "expert_mlp"),
                       dtype=a.dtype, fan_in=a.d_model),
        "wo": ParamDef((a.n_experts, hh, a.d_model),
                       ("experts", "expert_mlp", "expert_embed"),
                       dtype=a.dtype, fan_in=hh),
        "wk": ParamDef((a.d_model, a.n_kv_heads, a.head_dim),
                       ("embed_fsdp", "kv_heads", "head_dim"),
                       dtype=a.dtype, fan_in=a.d_model),
        "wv": ParamDef((a.d_model, a.n_kv_heads, a.head_dim),
                       ("embed_fsdp", "kv_heads", "head_dim"),
                       dtype=a.dtype, fan_in=a.d_model),
    })
    if a.qk_norm:
        defs["q_norm"] = layers.rmsnorm_defs(a.head_dim)
        defs["k_norm"] = layers.rmsnorm_defs(a.head_dim)
    return defs


def init_cache_defs(batch: int, max_len: int, a: MoAArgs, *, dtype=None):
    """KV-cache defs — identical to a plain attention layer's (the shared
    K/V projection is the whole point: pages/prefix-cache reuse)."""
    return attn_lib.init_cache_defs(batch, max_len, a.n_kv_heads,
                                    a.head_dim, window=0,
                                    dtype=dtype or a.dtype)


# ---------------------------------------------------------------------------
# plan views + projection pipeline
# ---------------------------------------------------------------------------

def assignment_plan(p: dsp.DispatchPlan) -> dsp.DispatchPlan:
    """Token-major [T, k] plan -> assignment-major [T·k, 1] view.

    Every (token, slot) assignment becomes its own row with unit weight
    (zero where the assignment was dropped or masked), so the backend's
    ``combine`` acts as a pure gather of per-assignment rows and its
    ``dispatch`` as a pure per-assignment scatter — the fused kernels run
    unchanged (k is just an array dimension to them)."""
    tk = p.expert_index.size
    unit = (p.weight > 0.0).astype(jnp.float32)
    return dsp.DispatchPlan(
        expert_index=p.expert_index.reshape(tk, 1),
        position=p.position.reshape(tk, 1),
        weight=unit.reshape(tk, 1),
        n_experts=p.n_experts, capacity=p.capacity,
        fraction_dropped=p.fraction_dropped)


def _routed_q(params, flat, dec, a: MoAArgs, bk, ctx):
    """[T, d] tokens -> [T·k, Hg·hd] selected head-group queries."""
    buf = bk.dispatch(flat, dec, a, ctx=ctx)               # [E, C, d]
    buf = ctx_lib.with_constraint(
        buf, ("experts", "expert_capacity", "embed"), ctx)
    qbuf = bk.gmm(buf, params["wq"], a, ctx=ctx)           # [E, C, Hg·hd]
    ap = assignment_plan(dec.plan)
    return bk.combine(qbuf, ap, a, dtype=flat.dtype, ctx=ctx)


def _routed_o(params, o_sel, dec, a: MoAArgs, bk, ctx, dtype):
    """[T·k, Hg·hd] attention outputs -> [T, d] gate-weighted output."""
    ap = assignment_plan(dec.plan)
    obuf = bk.dispatch(o_sel, ap, a, ctx=ctx)              # [E, C, Hg·hd]
    out = bk.gmm(obuf, params["wo"], a, ctx=ctx)           # [E, C, d]
    out = ctx_lib.with_constraint(
        out, ("experts", "expert_capacity", "embed"), ctx)
    return bk.combine(out, dec, a, dtype=dtype, ctx=ctx)


def _norm_rope_q(params, q_sel, positions, a: MoAArgs):
    """q_sel: [B, S, kk·Hg, hd] — per-head qk-norm then RoPE."""
    if a.qk_norm:
        q_sel = layers.rmsnorm(params["q_norm"], q_sel)
    return layers.rope(q_sel, positions, a.rope_theta)


def _shared_kv(params, x, positions, a: MoAArgs):
    """Shared K/V projection (+norm +rope): [B, S, d] -> 2x [B, S, KV, hd]."""
    dt = x.dtype
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    if a.qk_norm:
        k = layers.rmsnorm(params["k_norm"], k)
    k = layers.rope(k, positions, a.rope_theta)
    return k, v


def _to_virtual(q_sel, n_kv: int):
    """[B, S, kk, Hg, hd] -> KV-major [B, S, kk·Hg, hd] virtual heads.

    ``blockwise/flash_attention`` map query head h to KV head h // g, so
    the virtual head axis must be KV-major: within each KV head sit the
    kk·(Hg/KV) selected per-group heads."""
    b, s, kk, hg, hd = q_sel.shape
    ge = hg // n_kv
    q = q_sel.reshape(b, s, kk, n_kv, ge, hd).transpose(0, 1, 3, 2, 4, 5)
    return q.reshape(b, s, n_kv * kk * ge, hd)


def _from_virtual(o, n_kv: int, kk: int, hg: int):
    """Inverse of :func:`_to_virtual`: [B, S, kk·Hg, hd] -> [B,S,kk,Hg,hd]."""
    b, s, h, hd = o.shape
    ge = hg // n_kv
    o = o.reshape(b, s, n_kv, kk, ge, hd).transpose(0, 1, 3, 2, 4, 5)
    return o.reshape(b, s, kk, hg, hd)


def _block(pref: int, n: int) -> int:
    """Largest usable block size: ``flash_attention`` silently truncates
    sequences that don't divide the block, so fall back to the full
    length (one block) when ``pref`` doesn't divide ``n``."""
    b = min(pref, n)
    return b if n % b == 0 else n


def _route(params, flat, a: MoAArgs, bk, *, train, rng, mask):
    router = router_lib.build(a, topk_impl=bk.topk_impl)
    return router.route(params, flat, train=train, rng=rng, mask=mask)


def _aux(dec: router_lib.RouteDecision) -> dict:
    return {"aux_loss": dec.aux_loss, "metrics": dec.metrics,
            "telemetry": dec.telemetry}


# ---------------------------------------------------------------------------
# train/prefill/decode entry points
# ---------------------------------------------------------------------------

def moa_apply(params, x, a: MoAArgs, *, positions, train: bool = True,
              rng: jax.Array | None = None,
              ctx: ctx_lib.MeshContext | None = None,
              mask: jax.Array | None = None) -> tuple[jax.Array, dict]:
    """Full-sequence MoA (train / whole-prompt forward).

    x: [B, S, d]; positions: [B, S].  ``mask`` ([B·S] or broadcastable)
    marks valid tokens for routing.  Returns (y [B, S, d], aux) with the
    same aux contract as ``moe_apply`` (aux_loss / metrics / telemetry).
    """
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    bk = backend_lib.resolve(a)
    dec = _route(params, flat, a, bk, train=train, rng=rng, mask=mask)
    kk = dec.plan.expert_index.shape[1]

    q_sel = _routed_q(params, flat, dec, a, bk, ctx)       # [T·k, Hg·hd]
    q_sel = q_sel.reshape(b, s, kk * a.n_heads_per_expert, a.head_dim)
    q = _norm_rope_q(params, q_sel, positions, a)
    q = _to_virtual(q.reshape(b, s, kk, a.n_heads_per_expert, a.head_dim),
                    a.n_kv_heads)
    k, v = _shared_kv(params, x, positions, a)

    q_block = _block(a.q_block, s)
    kv_block = _block(a.kv_block, s)
    kv_heads = a.n_kv_heads
    g = q.shape[2] // kv_heads
    qr = jnp.moveaxis(q.reshape(b, s, kv_heads, g, a.head_dim), 1, 3)
    kr = jnp.moveaxis(k, 1, 3)
    vr = jnp.moveaxis(v, 1, 2)
    o = attn_lib.flash_attention(qr, kr, vr, True, 0, q_block, kv_block)
    o = o.reshape(b, kv_heads * g, s, a.head_dim).transpose(0, 2, 1, 3)
    o = _from_virtual(o, a.n_kv_heads, kk, a.n_heads_per_expert)

    o_sel = o.reshape(b * s * kk, a.d_head_group)
    y = _routed_o(params, o_sel, dec, a, bk, ctx, x.dtype)
    return y.reshape(b, s, d), _aux(dec)


def moa_prefill(params, x, positions, a: MoAArgs, *, cache: dict,
                ctx: ctx_lib.MeshContext | None = None,
                mask: jax.Array | None = None,
                start_pos: int | None = None):
    """Prefill: routed attention that also fills the shared KV cache.

    Mirrors ``attention.prefill_attention`` — full caches take K/V at
    positions [0, S); ``start_pos`` (static int) is chunked-prefill mode:
    K/V land at [start_pos, start_pos + S) and attention resumes against
    the cached prefix.  ``mask`` ([B·S]) keeps bucketed/chunk padding out
    of routing.  Returns (y, new_cache) — prefill drops the aux like the
    FFN path does."""
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    bk = backend_lib.resolve(a)
    dec = _route(params, flat, a, bk, train=False, rng=None, mask=mask)
    kk = dec.plan.expert_index.shape[1]

    q_sel = _routed_q(params, flat, dec, a, bk, ctx)
    q_sel = q_sel.reshape(b, s, kk * a.n_heads_per_expert, a.head_dim)
    q = _norm_rope_q(params, q_sel, positions, a)
    q = _to_virtual(q.reshape(b, s, kk, a.n_heads_per_expert, a.head_dim),
                    a.n_kv_heads)
    k, v = _shared_kv(params, x, positions, a)

    kc = k.astype(cache["k"].dtype)
    vc = v.astype(cache["v"].dtype)
    off = 0 if start_pos is None else int(start_pos)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], kc, off, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], vc, off, axis=1)
    if off:
        k = jnp.concatenate(
            [jax.lax.slice_in_dim(cache["k"], 0, off, axis=1)
             .astype(k.dtype), k], axis=1)
        v = jnp.concatenate(
            [jax.lax.slice_in_dim(cache["v"], 0, off, axis=1)
             .astype(v.dtype), v], axis=1)
    o = attn_lib.blockwise_attention(
        q, k, v, causal=True, window=0, q_block=_block(a.q_block, s),
        kv_block=_block(a.kv_block, k.shape[1]), q_offset=off)
    o = _from_virtual(o, a.n_kv_heads, kk, a.n_heads_per_expert)

    o_sel = o.reshape(b * s * kk, a.d_head_group)
    y = _routed_o(params, o_sel, dec, a, bk, ctx, x.dtype)
    return y.reshape(b, s, d), {"k": new_k, "v": new_v}


def moa_decode(params, x, cache: dict, cur_index, a: MoAArgs, *,
               ctx: ctx_lib.MeshContext | None = None,
               mask: jax.Array | None = None):
    """One-token routed decode. x: [B, 1, d]; cur_index: scalar or [B]
    per-slot positions.  ``mask`` ([B]) is slot occupancy — dead slots
    route nowhere and consume no head-group capacity.  Returns
    (y [B, 1, d], new_cache, aux) with per-step routing telemetry."""
    b = x.shape[0]
    cur = jnp.broadcast_to(
        jnp.asarray(cur_index, jnp.int32).reshape(-1), (b,))
    positions = cur[:, None]                                # [B, 1]
    bk = backend_lib.resolve(a)
    flat = x.reshape(b, x.shape[-1])
    dec = _route(params, flat, a, bk, train=False, rng=None, mask=mask)
    kk = dec.plan.expert_index.shape[1]

    # Fused decode: each routed projection (dispatch -> gmm -> combine)
    # collapses to one kernel launch via the backend's ``decode_proj``;
    # MoA's assignment-major [T·k, 1] plan view runs through the same op
    # (docs/kernels.md §Fused decode step).  Bit-identical to the
    # _routed_q/_routed_o pipeline.
    fused = a.fused_decode and bk.decode_proj is not None
    ap = assignment_plan(dec.plan) if fused else None
    if fused:
        q_sel = bk.decode_proj(flat, params["wq"], dec.plan, ap, a,
                               dtype=flat.dtype, ctx=ctx)
    else:
        q_sel = _routed_q(params, flat, dec, a, bk, ctx)    # [B·k, Hg·hd]
    q_sel = q_sel.reshape(b, 1, kk * a.n_heads_per_expert, a.head_dim)
    q = _norm_rope_q(params, q_sel, positions, a)
    q = _to_virtual(q.reshape(b, 1, kk, a.n_heads_per_expert, a.head_dim),
                    a.n_kv_heads)
    k_new, v_new = _shared_kv(params, x, positions, a)

    length = cache["k"].shape[1]
    # One-hot blend cache write (same rationale as decode_attention: no
    # dynamic_update_slice on the sharded sequence axis).
    hit = (jnp.arange(length)[None, :] == cur[:, None])[..., None, None]
    k = jnp.where(hit, k_new.astype(cache["k"].dtype), cache["k"])
    v = jnp.where(hit, v_new.astype(cache["v"].dtype), cache["v"])

    kv_heads = a.n_kv_heads
    hd = a.head_dim
    g = q.shape[2] // kv_heads
    qr = q.reshape(b, 1, kv_heads, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qr, k,
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    valid_kv = jnp.arange(length)[None, :] <= cur[:, None]  # [B, S]
    s = jnp.where(valid_kv[:, None, None, None, :], s, attn_lib.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, kv_heads * g, hd).astype(x.dtype)
    o = _from_virtual(o, a.n_kv_heads, kk, a.n_heads_per_expert)

    o_sel = o.reshape(b * kk, a.d_head_group)
    if fused:
        y = bk.decode_proj(o_sel, params["wo"], ap, dec.plan, a,
                           dtype=x.dtype, ctx=ctx)
    else:
        y = _routed_o(params, o_sel, dec, a, bk, ctx, x.dtype)
    return y.reshape(b, 1, x.shape[-1]), {"k": k, "v": v}, _aux(dec)
