"""The Sparsely-Gated Mixture-of-Experts layer (§2) as a composable module.

``moe_defs`` declares the parameters; ``moe_apply`` runs routing → dispatch →
expert FFN → combine and returns (output, aux) where aux carries the §4
balancing losses and the Table-6 diagnostics.

Expert networks are the paper's one-hidden-layer ReLU FFNs by default;
``activation="swiglu"`` upgrades them to gated-SiLU experts (w1/w3/w2) for
the modern architectures in the zoo (kimi-k2, arctic, jamba).

Routing is configured by a single :class:`repro.core.router.RouterSpec`
(``MoEArgs.router``, docs/routing.md): policy, k, train/eval capacity
factors, noise, balance-loss weights.  ``router.route(params, x,
mask=...)`` returns a typed :class:`~repro.core.router.RouteDecision`; the
legacy ``gating_mode``/``dispatch_impl``/``expert_impl`` strings (and the
old per-carrier ``capacity_factor`` floats) are a deprecated shim that
``router.resolve_spec`` folds into a spec.  ``mask`` marks valid tokens —
the serving engine passes slot occupancy so dead slots neither route nor
consume expert capacity.

The hot-path ops (top-k gating, dispatch/combine, expert FFN) route
through the kernel backend registry (``repro.kernels.backend``,
docs/kernels.md): ``kernel_backend="ref"`` is the jnp/XLA path,
``"pallas"`` the fused trainable kernels.  Resolution is explicit — an
unknown or broken backend raises instead of degrading silently.

Distribution: logical axes are annotated so that under the ``dp_tp_ep`` plan
experts shard over the *model* mesh axis (expert parallelism, §3.1) while
their d_model dimension shards over *data* (FSDP — exactly one copy of every
expert across the cluster, as the paper specifies).  The explicit all-to-all
schedule lives in ``expert_parallel.py``; this module uses GSPMD constraints.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef
from repro.core import dispatch as dsp
from repro.core import gating
from repro.core import router as router_lib
from repro.kernels import backend as backend_lib
from repro.sharding import context as ctx_lib


@dataclasses.dataclass(frozen=True)
class MoEArgs:
    n_experts: int
    k: int
    d_model: int
    d_ff: int
    activation: str = "relu"            # relu (paper) | swiglu
    # --- routing ------------------------------------------------------------
    # The one configuration path for gating/dispatch/capacity (docs/
    # routing.md).  None resolves the deprecated string/float fields below
    # into a spec; the spec's k inherits from ``k`` above.
    router: "router_lib.RouterSpec | None" = None
    # Deprecated spellings (router.resolve_spec shim; DeprecationWarning):
    gating_mode: str = "noisy_topk"     # noisy_topk | batchwise | threshold
    capacity_factor: float | None = None   # None = RouterSpec default (2.0)
    # None = same as training.  NOTE: this used to default to 2.0
    # *independently* of capacity_factor, so a legacy caller that set only
    # capacity_factor evaluated at 2.0; it now evaluates at the training
    # factor (set eval_capacity_factor explicitly to pin the old value).
    eval_capacity_factor: float | None = None
    w_importance: float = 0.1           # paper §C.1
    w_load: float = 0.1
    dispatch_impl: str = "sort"         # sort | einsum (ref backend only)
    expert_impl: str = "einsum"         # legacy spelling of kernel_backend
    priority_dispatch: bool = False
    # --- kernels ------------------------------------------------------------
    # Kernel backend for the hot path (see repro/kernels/backend.py):
    # "ref" | "pallas"; None derives from the legacy expert_impl field.
    # Resolution is explicit — an unknown or broken backend raises
    # KernelBackendError instead of silently degrading to the slow path.
    kernel_backend: str | None = None
    # VMEM budget (bytes) for the fused dispatch/combine kernels; None
    # uses kernels.dispatch.DEFAULT_VMEM_LIMIT.  Past the limit the pallas
    # backend E-blocks the buffer (only an [e_block, C, d] slab resident
    # per grid step); only a shape whose one-expert slab still exceeds the
    # budget falls back to the ref scatter.
    dispatch_vmem_limit: int | None = None
    # Expert-block size for the fused dispatch/combine kernels: None
    # auto-selects against the VMEM budget (whole buffer resident when it
    # fits, else the largest fitting power-of-two slab); an explicit int
    # forces that slab size for both forward and backward.
    dispatch_e_block: int | None = None
    # Consult the measured GMM tiling table (docs/kernels.md §Tiling
    # autotune, seeded by `make tune-kernels`) when planning expert-FFN
    # blocks; False pins the static 128-tile defaults.
    gmm_autotune: bool = True
    # Serve-time fused decode: run routing + dispatch + expert FFN +
    # combine as ONE kernel launch (docs/kernels.md §Fused decode step).
    # Inference-only — ignored under train=True; the backend falls back
    # (RuntimeWarning) to the unfused pipeline past the VMEM slab budget.
    # Set by the model layer for decode-shaped calls only.
    fused_decode: bool = False
    sigmoid_output: bool = False        # paper's LM passes MoE out thru sigmoid
    wide_dispatch: bool = True          # §3.1 combined-batch token resharding
    dtype: Any = jnp.bfloat16


def moe_defs(a: MoEArgs) -> dict:
    spec = router_lib.resolve_spec(a)
    gated = a.activation == "swiglu"
    defs = dict(router_lib.Router(spec, a.n_experts).gate_defs(a.d_model))
    defs.update({
        "w1": ParamDef((a.n_experts, a.d_model, a.d_ff),
                       ("experts", "expert_embed", "expert_mlp"),
                       dtype=a.dtype, fan_in=a.d_model),
        "w2": ParamDef((a.n_experts, a.d_ff, a.d_model),
                       ("experts", "expert_mlp", "expert_embed"),
                       dtype=a.dtype, fan_in=a.d_ff),
    })
    if gated:
        defs["w3"] = ParamDef((a.n_experts, a.d_model, a.d_ff),
                              ("experts", "expert_embed", "expert_mlp"),
                              dtype=a.dtype, fan_in=a.d_model)
    return defs


def expert_ffn(params, x: jax.Array, a: MoEArgs,
               ctx: ctx_lib.MeshContext | None = None) -> jax.Array:
    """Apply every expert to its [E, C, d] buffer of dispatched tokens.

    Routed through the kernel backend registry — resolution is explicit
    and raises on an unknown/broken backend (no silent degradation)."""
    return backend_lib.resolve(a).expert_ffn(params, x, a, ctx=ctx)


def run_gating(params, x: jax.Array, a: MoEArgs, *, train: bool,
               rng: jax.Array | None,
               topk_impl=None) -> gating.GatingInfo:
    """Deprecated: use ``router.build(a).route(...)`` (docs/routing.md).

    ``raw_logits`` is reconstructed as log-gates (the batchwise/threshold
    convention) — RouteDecision does not carry the pre-noise logits."""
    warnings.warn("run_gating is deprecated; use repro.core.router "
                  "(build(a).route(...))", DeprecationWarning, stacklevel=2)
    dec = router_lib.build(a, topk_impl=topk_impl).route(
        params, x, train=train, rng=rng)
    return gating.GatingInfo(
        combine_weights=dec.combine_weights,
        expert_index=dec.expert_index, gates=dec.gates, load=dec.load,
        raw_logits=jnp.log(jnp.maximum(dec.gates, 1e-20)))


def moe_apply(params, x: jax.Array, a: MoEArgs, *, train: bool = True,
              rng: jax.Array | None = None,
              ctx: ctx_lib.MeshContext | None = None,
              mask: jax.Array | None = None
              ) -> tuple[jax.Array, dict]:
    """x: [T, d_model] (tokens already flattened — the paper's 'convolutional'
    application over all positions of a batch, §3.1).

    ``ctx`` is the explicit sharding context; ``None`` resolves the
    contextvar (identity constraints off-mesh).  ``mask`` ([T] in {0,1})
    marks valid tokens: masked tokens (dead serving slots, bucketed-
    prefill padding) get zero gate weight, zero load/telemetry, and
    consume no expert capacity."""
    t, d = x.shape
    bk = backend_lib.resolve(a)     # explicit: raises on unknown/broken
    if not train and a.fused_decode and bk.decode_step is not None:
        # One-launch decode step: the backend fuses routing -> scatter ->
        # expert FFN -> combine (bit-identical to the pipeline below) and
        # emits the same load/overflow telemetry families.  Decode
        # consumers discard losses/metrics, so aux carries zeros.
        token_axis = "tokens" if a.wide_dispatch else "batch"
        y, telemetry = bk.decode_step(params, x, a, mask=mask, ctx=ctx)
        y = ctx_lib.with_constraint(y, (token_axis, "embed"), ctx)
        if a.sigmoid_output:
            y = jax.nn.sigmoid(y.astype(jnp.float32)).astype(x.dtype)
        zero = jnp.zeros((), jnp.float32)
        return y, {"aux_loss": zero,
                   "metrics": {k: zero for k in
                               ("cv_importance", "cv_load",
                                "max_over_mean_load", "fraction_dropped")},
                   "telemetry": telemetry}
    router = router_lib.build(a, topk_impl=bk.topk_impl)
    dec = router.route(params, x, train=train, rng=rng, mask=mask)

    token_axis = "tokens" if a.wide_dispatch else "batch"
    x = ctx_lib.with_constraint(x, (token_axis, "embed"), ctx)
    buf = bk.dispatch(x, dec, a, ctx=ctx)
    buf = ctx_lib.with_constraint(
        buf, ("experts", "expert_capacity", "embed"), ctx)
    out = bk.expert_ffn(params, buf, a, ctx=ctx)
    out = ctx_lib.with_constraint(
        out, ("experts", "expert_capacity", "embed"), ctx)
    y = bk.combine(out, dec, a, dtype=x.dtype, ctx=ctx)
    y = ctx_lib.with_constraint(y, (token_axis, "embed"), ctx)
    if a.sigmoid_output:
        y = jax.nn.sigmoid(y.astype(jnp.float32)).astype(x.dtype)

    return y, {"aux_loss": dec.aux_loss, "metrics": dec.metrics,
               "telemetry": dec.telemetry}


def gating_telemetry(info: gating.GatingInfo, p: dsp.DispatchPlan) -> dict:
    """Back-compat alias for :func:`repro.core.router.route_telemetry`."""
    return router_lib.route_telemetry(info, p)
