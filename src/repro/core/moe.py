"""The Sparsely-Gated Mixture-of-Experts layer (§2) as a composable module.

``moe_defs`` declares the parameters; ``moe_apply`` runs gating → dispatch →
expert FFN → combine and returns (output, aux) where aux carries the §4
balancing losses and the Table-6 diagnostics.

Expert networks are the paper's one-hidden-layer ReLU FFNs by default;
``activation="swiglu"`` upgrades them to gated-SiLU experts (w1/w3/w2) for
the modern architectures in the zoo (kimi-k2, arctic, jamba).

The hot-path ops (top-k gating, dispatch/combine, expert FFN) route
through the kernel backend registry (``repro.kernels.backend``,
docs/kernels.md): ``kernel_backend="ref"`` is the jnp/XLA path,
``"pallas"`` the fused trainable kernels.  Resolution is explicit — an
unknown or broken backend raises instead of degrading silently.

Distribution: logical axes are annotated so that under the ``dp_tp_ep`` plan
experts shard over the *model* mesh axis (expert parallelism, §3.1) while
their d_model dimension shards over *data* (FSDP — exactly one copy of every
expert across the cluster, as the paper specifies).  The explicit all-to-all
schedule lives in ``expert_parallel.py``; this module uses GSPMD constraints.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef
from repro.core import dispatch as dsp
from repro.core import gating, losses
from repro.kernels import backend as backend_lib
from repro.sharding import context as ctx_lib


@dataclasses.dataclass(frozen=True)
class MoEArgs:
    n_experts: int
    k: int
    d_model: int
    d_ff: int
    activation: str = "relu"            # relu (paper) | swiglu
    gating_mode: str = "noisy_topk"     # noisy_topk | batchwise | threshold
    capacity_factor: float = 2.0
    eval_capacity_factor: float = 2.0
    w_importance: float = 0.1           # paper §C.1
    w_load: float = 0.1
    dispatch_impl: str = "sort"         # sort | einsum (ref backend only)
    expert_impl: str = "einsum"         # legacy spelling of kernel_backend
    # Kernel backend for the hot path (see repro/kernels/backend.py):
    # "ref" | "pallas"; None derives from the legacy expert_impl field.
    # Resolution is explicit — an unknown or broken backend raises
    # KernelBackendError instead of silently degrading to the slow path.
    kernel_backend: str | None = None
    # VMEM budget (bytes) for the fused dispatch/combine kernel's resident
    # [E, C, d] buffer; None uses kernels.dispatch.DEFAULT_VMEM_LIMIT.
    # Past the limit the pallas backend falls back to the ref scatter
    # instead of silently OOMing (the E-blocked variant is future work).
    dispatch_vmem_limit: int | None = None
    priority_dispatch: bool = False
    sigmoid_output: bool = False        # paper's LM passes MoE out thru sigmoid
    wide_dispatch: bool = True          # §3.1 combined-batch token resharding
    dtype: Any = jnp.bfloat16


def moe_defs(a: MoEArgs) -> dict:
    gated = a.activation == "swiglu"
    defs = {
        "gate": gating.gating_defs(a.d_model, a.n_experts,
                                   noisy=a.gating_mode == "noisy_topk"),
        "w1": ParamDef((a.n_experts, a.d_model, a.d_ff),
                       ("experts", "expert_embed", "expert_mlp"),
                       dtype=a.dtype, fan_in=a.d_model),
        "w2": ParamDef((a.n_experts, a.d_ff, a.d_model),
                       ("experts", "expert_mlp", "expert_embed"),
                       dtype=a.dtype, fan_in=a.d_ff),
    }
    if gated:
        defs["w3"] = ParamDef((a.n_experts, a.d_model, a.d_ff),
                              ("experts", "expert_embed", "expert_mlp"),
                              dtype=a.dtype, fan_in=a.d_model)
    if a.gating_mode == "threshold":
        defs["thresholds"] = gating.threshold_defs(a.n_experts)
    return defs


def expert_ffn(params, x: jax.Array, a: MoEArgs,
               ctx: ctx_lib.MeshContext | None = None) -> jax.Array:
    """Apply every expert to its [E, C, d] buffer of dispatched tokens.

    Routed through the kernel backend registry — resolution is explicit
    and raises on an unknown/broken backend (no silent degradation)."""
    return backend_lib.resolve(a).expert_ffn(params, x, a, ctx=ctx)


def run_gating(params, x: jax.Array, a: MoEArgs, *, train: bool,
               rng: jax.Array | None,
               topk_impl=None) -> gating.GatingInfo:
    if a.gating_mode == "noisy_topk":
        return gating.noisy_topk_gating(params["gate"], x, a.k,
                                        train=train, rng=rng,
                                        topk_impl=topk_impl)
    if a.gating_mode == "batchwise":
        return gating.batchwise_gating(params["gate"], x, a.k)
    if a.gating_mode == "threshold":
        if train:  # train with the batchwise mask, infer with thresholds
            return gating.batchwise_gating(params["gate"], x, a.k)
        return gating.threshold_gating(params["gate"], params["thresholds"],
                                       x, a.k)
    raise ValueError(f"unknown gating mode {a.gating_mode!r}")


def moe_apply(params, x: jax.Array, a: MoEArgs, *, train: bool = True,
              rng: jax.Array | None = None,
              ctx: ctx_lib.MeshContext | None = None
              ) -> tuple[jax.Array, dict]:
    """x: [T, d_model] (tokens already flattened — the paper's 'convolutional'
    application over all positions of a batch, §3.1).

    ``ctx`` is the explicit sharding context; ``None`` resolves the
    contextvar (identity constraints off-mesh)."""
    t, d = x.shape
    bk = backend_lib.resolve(a)     # explicit: raises on unknown/broken
    info = run_gating(params, x, a, train=train, rng=rng,
                      topk_impl=bk.topk_impl)

    cf = a.capacity_factor if train else a.eval_capacity_factor
    if a.gating_mode in ("batchwise", "threshold") and train:
        # Appendix F: exactly m = k·T/E slots per expert; nothing dropped.
        capacity = max((a.k * t) // a.n_experts, 1)
        capacity = int(-(-capacity // 8) * 8)
    else:
        capacity = dsp.capacity_for(t, a.n_experts, a.k, cf)
    p = dsp.plan(info.expert_index, info.combine_weights, a.n_experts,
                 capacity, priority=a.priority_dispatch)

    token_axis = "tokens" if a.wide_dispatch else "batch"
    x = ctx_lib.with_constraint(x, (token_axis, "embed"), ctx)
    buf = bk.dispatch(x, p, a, ctx=ctx)
    buf = ctx_lib.with_constraint(
        buf, ("experts", "expert_capacity", "embed"), ctx)
    out = bk.expert_ffn(params, buf, a, ctx=ctx)
    out = ctx_lib.with_constraint(
        out, ("experts", "expert_capacity", "embed"), ctx)
    y = bk.combine(out, p, a, dtype=x.dtype, ctx=ctx)
    y = ctx_lib.with_constraint(y, (token_axis, "embed"), ctx)
    if a.sigmoid_output:
        y = jax.nn.sigmoid(y.astype(jnp.float32)).astype(x.dtype)

    aux_loss = (losses.importance_loss(info.gates, a.w_importance)
                + losses.load_loss(info.load, a.w_load))
    if a.gating_mode == "threshold" and train:
        aux_loss = aux_loss + gating.batchwise_threshold_loss(
            params["gate"], params["thresholds"], x, a.k)
    metrics = losses.balance_metrics(info.gates, info.load)
    metrics["fraction_dropped"] = p.fraction_dropped
    return y, {"aux_loss": aux_loss, "metrics": metrics,
               "telemetry": gating_telemetry(info, p)}


def gating_telemetry(info: gating.GatingInfo, p: dsp.DispatchPlan) -> dict:
    """Per-expert serving counters from one gating/dispatch decision.

    ``expert_load``: hard assignment counts (tokens routed per expert),
    ``overflow``: assignments dropped by capacity truncation per expert.
    Consumed by the serving telemetry path (stack_decode accumulates these
    across MoE layers); the train path drops them in ``_add_aux``.
    """
    assigned = (info.combine_weights > 0.0).reshape(-1)
    kept = (p.position < p.capacity).reshape(-1)
    flat_e = info.expert_index.reshape(-1)
    zero = jnp.zeros((p.n_experts,), jnp.float32)
    return {
        "expert_load": zero.at[flat_e].add(assigned.astype(jnp.float32)),
        "overflow": zero.at[flat_e].add(
            (assigned & ~kept).astype(jnp.float32)),
    }
