"""Gating networks for the Sparsely-Gated Mixture-of-Experts layer.

Faithful implementations of the paper's gating functions:

* ``softmax_gating``      — Eq. (2),  G_sigma(x) = Softmax(x @ Wg)
* ``noisy_topk_gating``   — Eqs. (3)-(5): tunable Gaussian noise, KeepTopK,
  softmax over the kept entries.  Also returns the smooth load estimator
  P(x, i) of Appendix A (Eqs. 8-10) used by L_load.
* ``batchwise_gating``    — Appendix F "strictly balanced" gating: top-m per
  expert across the batch (Eq. 18).  On TPU this is the *native* mode — every
  expert receives exactly the same number of tokens, i.e. static shapes.
* ``threshold_gating``    — Appendix F inference mask (Eq. 19) with the
  learned per-expert thresholds T, trained by L_batchwise (Eq. 20).

All gating math runs in float32 regardless of the activation dtype.

Initialization: Wg and Wnoise are zero-initialized, which "yields no signal
and some noise" (Appendix A) — the network starts perfectly load-balanced.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef

NOISE_EPSILON = 1e-2  # floor on the noise std-dev, as in the reference impl.


class GatingInfo(NamedTuple):
    """Everything downstream consumers need from a gating decision."""
    combine_weights: jax.Array   # [T, k]  float32, the non-zero G(x) values
    expert_index: jax.Array      # [T, k]  int32, which expert each weight is for
    gates: jax.Array             # [T, E]  float32 sparse gate matrix (G(x))
    load: jax.Array              # [E]     float32 smooth load estimator Load(X)
    raw_logits: jax.Array        # [T, E]  clean logits x @ Wg (pre-noise)


def gating_defs(d_model: int, n_experts: int, *, noisy: bool = True,
                dtype=jnp.float32) -> dict:
    """Zero-initialized Wg / Wnoise (Appendix A: balanced initial load)."""
    defs = {
        "wg": ParamDef((d_model, n_experts), ("embed", "experts"),
                       init="zeros", dtype=dtype),
    }
    if noisy:
        defs["wnoise"] = ParamDef((d_model, n_experts), ("embed", "experts"),
                                  init="zeros", dtype=dtype)
    return defs


def threshold_defs(n_experts: int, dtype=jnp.float32) -> dict:
    """Per-expert thresholds T for Appendix-F inference (Eq. 19)."""
    return {"t": ParamDef((n_experts,), ("experts",), init="zeros",
                          dtype=dtype)}


def softmax_gating(params, x: jax.Array) -> jax.Array:
    """Eq. (2): dense softmax gates [T, E] in float32."""
    logits = jnp.asarray(x, jnp.float32) @ jnp.asarray(params["wg"], jnp.float32)
    return jax.nn.softmax(logits, axis=-1)


def _top_k(v: jax.Array, k: int):
    vals, idx = jax.lax.top_k(v, k)
    return vals, idx.astype(jnp.int32)


def _normal_cdf(z):
    return 0.5 * (1.0 + jax.lax.erf(z / jnp.sqrt(2.0).astype(z.dtype)))


def noisy_topk_gating(
    params,
    x: jax.Array,
    k: int,
    *,
    train: bool,
    rng: jax.Array | None = None,
    valid: jax.Array | None = None,
    topk_impl=None,
) -> GatingInfo:
    """Eqs. (3)-(5) + the Appendix-A load estimator.

    H(x)_i = (x Wg)_i + StandardNormal() * Softplus((x Wnoise)_i)
    G(x)   = Softmax(KeepTopK(H(x), k))          (softmax over the k survivors)

    The load probability (Eq. 9) uses the *clean* logit in the numerator and
    the k-th-excluding-self threshold of the *noisy* logits, computed from the
    top-(k+1) values: for a winner the threshold is the (k+1)-th noisy value,
    for a loser it is the k-th.

    ``valid`` ([T] in {0,1}) masks padding rows (hierarchical-MoE buffers):
    masked rows contribute nothing to gates, combine weights, or load.

    ``topk_impl`` swaps the KeepTopK+softmax for a fused kernel (the
    backend registry's ``topk_impl``): ``(noisy, k, kk) -> (combine [T,k],
    idx [T,k], raw top values [T,kk])`` — semantics identical to the
    ``lax.top_k`` path (lowest-index tie-break, softmax over survivors).
    """
    xf = jnp.asarray(x, jnp.float32)
    clean = xf @ jnp.asarray(params["wg"], jnp.float32)            # [T, E]
    n_experts = clean.shape[-1]
    k = min(k, n_experts)

    if train and "wnoise" in params and rng is not None:
        raw_noise = xf @ jnp.asarray(params["wnoise"], jnp.float32)
        noise_std = jax.nn.softplus(raw_noise) + NOISE_EPSILON      # [T, E]
        noisy = clean + jax.random.normal(rng, clean.shape) * noise_std
    else:
        noise_std = None
        noisy = clean

    # KeepTopK + softmax over survivors (renormalized over k).
    kk = min(k + 1, n_experts)
    if topk_impl is not None:
        combine, topk_idx, top_vals = topk_impl(noisy, k, kk)       # fused
    else:
        top_vals, top_idx = _top_k(noisy, kk)                       # [T, k+1]
        topk_idx = top_idx[..., :k]
        combine = jax.nn.softmax(top_vals[..., :k], axis=-1)        # [T, k]
    if valid is not None:
        combine = combine * valid[:, None]

    # Scatter back to a sparse [T, E] gate matrix (zeros off the top-k).
    gates = jnp.zeros_like(clean).at[
        jnp.arange(clean.shape[0])[:, None], topk_idx].set(combine)

    # Smooth load estimator (Appendix A).  Only meaningful with noise.
    if noise_std is not None and kk > k:
        in_topk = gates > 0.0                                       # [T, E]
        thresh_if_in = top_vals[..., k][:, None]      # (k+1)-th noisy value
        thresh_if_out = top_vals[..., k - 1][:, None]  # k-th noisy value
        threshold = jnp.where(in_topk, thresh_if_in, thresh_if_out)
        p = _normal_cdf((clean - threshold) / noise_std)            # Eq. (9)
        if valid is not None:
            p = p * valid[:, None]
        load = jnp.sum(p, axis=0)                                   # Eq. (10)
    else:
        # Deterministic fall-back: load == hard assignment counts.
        hard = (gates > 0.0).astype(jnp.float32)
        if valid is not None:
            hard = hard * valid[:, None]
        load = jnp.sum(hard, axis=0)

    return GatingInfo(combine_weights=combine, expert_index=topk_idx,
                      gates=gates, load=load, raw_logits=clean)


def batchwise_gating(params, x: jax.Array, k: int,
                     valid: jax.Array | None = None) -> GatingInfo:
    """Appendix F, Eq. (16)+(18): keep the top m = k*T/E tokens *per expert*.

    Every expert receives exactly m tokens — perfectly static shapes, which is
    why the paper used it "if every expert received exactly the same batch
    size", and why it is the TPU-native gating mode here.

    ``valid`` ([T] in {0,1}) masks padding / dead-slot rows: masked rows
    are never selected and contribute nothing to gates or load.
    """
    g_sigma = softmax_gating(params, x)                             # [T, E]
    if valid is not None:
        g_sigma = g_sigma * jnp.asarray(valid, jnp.float32)[:, None]
    t, e = g_sigma.shape
    m = max((k * t) // e, 1)
    # top-m per expert over the batch axis.
    col_vals, col_idx = jax.lax.top_k(g_sigma.T, m)                 # [E, m]
    mask = jnp.zeros((e, t), jnp.float32).at[
        jnp.arange(e)[:, None], col_idx].set(1.0).T                 # [T, E]
    if valid is not None:
        # masked rows may be "picked" as zero-valued filler when an expert
        # has fewer than m valid tokens; keep them out of load and gates.
        mask = mask * jnp.asarray(valid, jnp.float32)[:, None]
    masked = g_sigma * mask
    denom = jnp.sum(masked, axis=-1, keepdims=True)
    gates = masked / jnp.maximum(denom, 1e-9)                       # Eq. (16)

    # Per-token top-k of the masked gates for the dispatch interface.  A token
    # may win fewer than k experts (or none); zero weights are simply unused
    # capacity downstream.
    kk = min(k, e)
    combine, topk_idx = _top_k(gates, kk)
    load = jnp.sum(mask, axis=0)
    return GatingInfo(combine_weights=combine, expert_index=topk_idx,
                      gates=gates, load=load,
                      raw_logits=jnp.log(jnp.maximum(g_sigma, 1e-20)))


def threshold_gating(params, thresholds, x: jax.Array, k: int,
                     valid: jax.Array | None = None) -> GatingInfo:
    """Appendix F inference path, Eq. (19): M_i = 1 if g_i > T_i."""
    g_sigma = softmax_gating(params, x)
    mask = (g_sigma > jnp.asarray(thresholds["t"], jnp.float32)[None, :])
    if valid is not None:
        mask = mask * (jnp.asarray(valid, jnp.float32)[:, None] > 0)
    masked = g_sigma * mask
    denom = jnp.sum(masked, axis=-1, keepdims=True)
    gates = masked / jnp.maximum(denom, 1e-9)
    kk = min(k, g_sigma.shape[-1])
    combine, topk_idx = _top_k(gates, kk)
    load = jnp.sum(mask.astype(jnp.float32), axis=0)
    return GatingInfo(combine_weights=combine, expert_index=topk_idx,
                      gates=gates, load=load,
                      raw_logits=jnp.log(jnp.maximum(g_sigma, 1e-20)))


def batchwise_threshold_loss(params, thresholds, x: jax.Array, k: int
                             ) -> jax.Array:
    """Eq. (20): aligns the threshold mask with the batchwise mask."""
    g_sigma = softmax_gating(params, x)                             # [T, E]
    t, e = g_sigma.shape
    m = max((k * t) // e, 1)
    col_vals, col_idx = jax.lax.top_k(g_sigma.T, m)
    m_batch = jnp.zeros((e, t), jnp.float32).at[
        jnp.arange(e)[:, None], col_idx].set(1.0).T                 # [T, E]
    tvec = jnp.asarray(thresholds["t"], jnp.float32)[None, :]
    m_thresh = (g_sigma > tvec).astype(jnp.float32)
    # Straight-through on the indicator; gradient flows via (X - T).
    return jnp.sum((m_thresh - m_batch) * (g_sigma - tvec)) / t
