"""Decoder blocks + the period-stacked layer scan.

Layers repeat with a per-arch *period* (gemma3: 5 local + 1 global = 6;
jamba: 7 mamba + 1 attn with MoE on odd positions = 8; homogeneous archs:
1).  Parameters for each position-in-period are stacked across periods and
the stack runs under one ``lax.scan`` — keeping HLO size O(period) instead
of O(n_layers), which is what makes 61-64-layer models compile fast and
lets one remat policy wrap the whole scan body (the paper's Appendix-D
"recompute expert activations on the backward pass" falls out of this).
Remainder layers (gemma3's 62 = 6·10 + 2) run unrolled as a tail.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import param as pm
from repro.common.param import ParamDef
from repro.configs.base import LayerKind, ModelConfig, layer_kinds, n_periods
from repro.core import hierarchical as hmoe
from repro.core import moa as moa_lib
from repro.core import moe as moe_lib
from repro.models import attention, layers, ssm
from repro.sharding import context as ctx_lib


def _moe_args(cfg: ModelConfig, *, decode: bool = False) -> moe_lib.MoEArgs:
    # ``decode`` marks a decode-shaped call: only those opt in to the
    # fused single-launch decode step (train/prefill stay unfused).
    return moe_lib.MoEArgs(
        n_experts=cfg.n_experts, k=cfg.moe_k, d_model=cfg.d_model,
        d_ff=cfg.moe_d_ff, activation=cfg.activation,
        router=cfg.router,
        gating_mode=cfg.gating_mode, capacity_factor=cfg.capacity_factor,
        w_importance=cfg.w_importance, w_load=cfg.w_load,
        dispatch_impl=cfg.dispatch_impl, expert_impl=cfg.expert_impl,
        kernel_backend=cfg.kernel_backend,
        dispatch_vmem_limit=cfg.dispatch_vmem_limit,
        dispatch_e_block=cfg.dispatch_e_block,
        gmm_autotune=cfg.gmm_autotune,
        fused_decode=cfg.fused_decode and decode,
        wide_dispatch=cfg.moe_wide_dispatch, dtype=cfg.param_dtype)


def _hmoe_args(cfg: ModelConfig) -> hmoe.HMoEArgs:
    a, b = cfg.moe_hierarchical
    return hmoe.HMoEArgs(
        n_groups=a, n_experts_per_group=b, k_primary=cfg.moe_k,
        k_secondary=cfg.moe_k, d_model=cfg.d_model, d_ff=cfg.moe_d_ff,
        activation=cfg.activation, router=cfg.router,
        capacity_factor=cfg.capacity_factor,
        w_importance=cfg.w_importance, w_load=cfg.w_load,
        kernel_backend=cfg.kernel_backend, dispatch_impl=cfg.dispatch_impl,
        dispatch_vmem_limit=cfg.dispatch_vmem_limit,
        dispatch_e_block=cfg.dispatch_e_block,
        gmm_autotune=cfg.gmm_autotune, dtype=cfg.param_dtype)


def _moa_args(cfg: ModelConfig, *, decode: bool = False) -> moa_lib.MoAArgs:
    # The FFN RouterSpec is reused for MoA policy/capacity knobs unless
    # moa_router overrides it — but its k is the FFN's k, so strip it and
    # let resolve_spec re-inherit from MoAArgs.k (= cfg.moa_k).
    router = cfg.moa_router
    if router is None and cfg.router is not None:
        router = cfg.router.replace(k=None)
    return moa_lib.MoAArgs(
        n_experts=cfg.moa_experts, k=cfg.moa_k, d_model=cfg.d_model,
        n_heads_per_expert=cfg.moa_heads_per_expert, head_dim=cfg.head_dim,
        n_kv_heads=max(cfg.n_kv_heads, 1), qk_norm=cfg.qk_norm,
        rope_theta=cfg.rope_theta,
        router=router,
        capacity_factor=cfg.capacity_factor,
        w_importance=cfg.w_importance, w_load=cfg.w_load,
        kernel_backend=cfg.kernel_backend, dispatch_impl=cfg.dispatch_impl,
        dispatch_vmem_limit=cfg.dispatch_vmem_limit,
        dispatch_e_block=cfg.dispatch_e_block,
        gmm_autotune=cfg.gmm_autotune,
        fused_decode=cfg.fused_decode and decode,
        q_block=cfg.q_block, kv_block=cfg.kv_block, dtype=cfg.param_dtype)


def block_defs(cfg: ModelConfig, kind: LayerKind) -> dict:
    defs: dict = {"ln1": layers.rmsnorm_defs(cfg.d_model)}
    if kind.mixer in ("attn", "attn_local"):
        defs["attn"] = attention.attention_defs(
            cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            qk_norm=cfg.qk_norm, dtype=cfg.param_dtype)
    elif kind.mixer == "moa":
        defs["moa"] = moa_lib.moa_defs(_moa_args(cfg))
    else:
        defs["mamba"] = ssm.mamba_defs(
            cfg.d_model, d_state=cfg.ssm_d_state, d_conv=cfg.ssm_d_conv,
            expand=cfg.ssm_expand, dtype=cfg.param_dtype)
    if kind.ffn != "none":
        defs["ln2"] = layers.rmsnorm_defs(cfg.d_model)
    if kind.ffn in ("moe", "moe+dense"):
        if cfg.moe_hierarchical:
            defs["moe"] = hmoe.hmoe_defs(_hmoe_args(cfg))
        else:
            defs["moe"] = moe_lib.moe_defs(_moe_args(cfg))
    if kind.ffn in ("dense", "moe+dense"):
        defs["mlp"] = layers.mlp_defs(cfg.d_model, cfg.d_ff, cfg.activation,
                                      cfg.param_dtype)
    return defs


_ZERO_METRICS = ("cv_importance", "cv_load", "max_over_mean_load",
                 "fraction_dropped")


def _zero_aux():
    return {"aux_loss": jnp.zeros((), jnp.float32),
            "metrics": {k: jnp.zeros((), jnp.float32)
                        for k in _ZERO_METRICS},
            "n_moe": jnp.zeros((), jnp.float32)}


def _add_aux(acc, aux):
    # aux["n"] is the number of routed sublayers the entry sums over — a
    # block with an MoA mixer *and* an MoE FFN contributes 2 (metrics are
    # averaged over n_moe in lm_loss, so the count must match the sums).
    return {"aux_loss": acc["aux_loss"] + aux["aux_loss"],
            "metrics": {k: acc["metrics"][k] + aux["metrics"][k]
                        for k in _ZERO_METRICS},
            "n_moe": acc["n_moe"] + aux.get("n", 1.0)}


def _merge_aux(a, b):
    """Merge the mixer's and the FFN's per-layer aux (either may be None).
    Telemetry dicts merge by key — MoA entries use moa_load/moa_overflow,
    MoE entries expert_load/overflow, so both survive side by side."""
    if a is None:
        return b
    if b is None:
        return a
    out = {"aux_loss": a["aux_loss"] + b["aux_loss"],
           "metrics": {k: a["metrics"][k] + b["metrics"][k]
                       for k in _ZERO_METRICS},
           "n": a.get("n", 1.0) + b.get("n", 1.0)}
    ta = a.get("telemetry") or {}
    tb = b.get("telemetry") or {}
    if ta or tb:
        out["telemetry"] = {**ta, **tb}
    return out


def _moa_aux(aux):
    """Adapt an MoA layer's router aux: rename the telemetry counters so
    head-group load is never summed into FFN-expert load (the vectors can
    even differ in length)."""
    t = aux.get("telemetry")
    out = {"aux_loss": aux["aux_loss"], "metrics": aux["metrics"],
           "n": 1.0}
    if t is not None:
        out["telemetry"] = {"moa_load": t["expert_load"],
                            "moa_overflow": t["overflow"]}
    return out


def _flat_mask(valid, b, s):
    """[B] or [B, S] validity -> flat [B·S] float routing mask (None
    passes through)."""
    if valid is None:
        return None
    return jnp.broadcast_to(
        jnp.asarray(valid, jnp.float32).reshape(
            (b, -1) if jnp.ndim(valid) > 1 else (b, 1)),
        (b, s)).reshape(b * s)


# ---------------------------------------------------------------------------
# Serving telemetry: per-expert load / overflow counters summed over the
# MoE layers of one decode (or prefill) step.  The train path drops the
# per-layer "telemetry" entry in _add_aux; the decode stack accumulates it
# so serving skew is observable per step.
# ---------------------------------------------------------------------------

def telemetry_width(cfg: ModelConfig) -> int:
    """Length of the per-expert telemetry vectors (0 = model has no MoE)."""
    if not any(k.ffn in ("moe", "moe+dense") for k in layer_kinds(cfg)):
        return 0
    if cfg.moe_hierarchical:
        a, b = cfg.moe_hierarchical
        return a * b
    return cfg.n_experts


def moa_telemetry_width(cfg: ModelConfig) -> int:
    """Length of the per-head-group telemetry vectors (0 = no MoA mixer)."""
    if not any(k.mixer == "moa" for k in layer_kinds(cfg)):
        return 0
    return cfg.moa_experts


def _telemetry_zero(cfg: ModelConfig):
    t = {}
    n = telemetry_width(cfg)
    if n:
        t.update(expert_load=jnp.zeros((n,), jnp.float32),
                 overflow=jnp.zeros((n,), jnp.float32),
                 n_moe=jnp.zeros((), jnp.float32))
    m = moa_telemetry_width(cfg)
    if m:
        t.update(moa_load=jnp.zeros((m,), jnp.float32),
                 moa_overflow=jnp.zeros((m,), jnp.float32),
                 n_moa=jnp.zeros((), jnp.float32))
    return t or None


def _add_telemetry(acc, aux):
    if acc is None or aux is None:
        return acc
    t = aux.get("telemetry")
    if t is None:
        return acc
    out = dict(acc)
    if "expert_load" in t and "expert_load" in acc:
        out["expert_load"] = acc["expert_load"] + t["expert_load"]
        out["overflow"] = acc["overflow"] + t["overflow"]
        out["n_moe"] = acc["n_moe"] + 1.0
    if "moa_load" in t and "moa_load" in acc:
        out["moa_load"] = acc["moa_load"] + t["moa_load"]
        out["moa_overflow"] = acc["moa_overflow"] + t["moa_overflow"]
        out["n_moa"] = acc["n_moa"] + 1.0
    return out


def _apply_ffn(params, x, kind: LayerKind, cfg: ModelConfig, *, train, rng,
               ctx: ctx_lib.MeshContext | None = None, valid=None,
               decode: bool = False):
    """Post-mixer FFN with residual. x: [B, S, d].

    ``valid`` ([B] or [B, S] in {0,1}) is the router's token-validity
    mask: masked tokens (dead serving slots, bucketed-prefill padding)
    neither route nor consume MoE expert capacity."""
    if kind.ffn == "none":
        return x, None
    h = layers.rmsnorm(params["ln2"], x, cfg.norm_eps)
    out = x
    aux = None
    if kind.ffn in ("moe", "moe+dense"):
        b, s, d = h.shape
        flat = h.reshape(b * s, d)
        mask = _flat_mask(valid, b, s)
        if cfg.moe_hierarchical:
            y, aux = hmoe.hmoe_apply(params["moe"], flat, _hmoe_args(cfg),
                                     train=train, rng=rng, ctx=ctx,
                                     mask=mask)
        else:
            y, aux = moe_lib.moe_apply(params["moe"], flat,
                                       _moe_args(cfg, decode=decode),
                                       train=train, rng=rng, ctx=ctx,
                                       mask=mask)
        out = out + y.reshape(b, s, d)
    if kind.ffn in ("dense", "moe+dense"):
        out = out + layers.mlp(params["mlp"], h, cfg.activation, ctx=ctx)
    return out, aux


def block_apply(params, x, kind: LayerKind, cfg: ModelConfig, *,
                positions, rng, train: bool,
                ctx: ctx_lib.MeshContext | None = None):
    """Train/prefill block. Returns (x, aux)."""
    h = layers.rmsnorm(params["ln1"], x, cfg.norm_eps)
    aux_mix = None
    if kind.mixer in ("attn", "attn_local"):
        window = cfg.sliding_window if kind.mixer == "attn_local" else 0
        y = attention.attention(params["attn"], h, positions,
                                rope_theta=cfg.rope_theta,
                                qk_norm=cfg.qk_norm, window=window,
                                q_block=cfg.q_block, kv_block=cfg.kv_block,
                                pad_heads=cfg.pad_attn_heads, ctx=ctx)
    elif kind.mixer == "moa":
        # Fold the rng so head-group routing noise decorrelates from the
        # FFN router's noise in the same block.
        sub = jax.random.fold_in(rng, 1) if rng is not None else None
        y, a_moa = moa_lib.moa_apply(params["moa"], h, _moa_args(cfg),
                                     positions=positions, train=train,
                                     rng=sub, ctx=ctx)
        aux_mix = _moa_aux(a_moa)
    else:
        y = ssm.mamba(params["mamba"], h, d_state=cfg.ssm_d_state, ctx=ctx)
    x = x + y
    x, aux = _apply_ffn(params, x, kind, cfg, train=train, rng=rng, ctx=ctx)
    return x, _merge_aux(aux_mix, aux)


def block_prefill(params, x, kind: LayerKind, cfg: ModelConfig, cache,
                  positions,
                  ctx: ctx_lib.MeshContext | None = None, valid=None,
                  start_pos: int | None = None):
    """Prefill block: causal attention + cache fill. Returns (x, cache).
    ``valid`` ([B, S]) keeps bucketed-prefill padding out of MoE routing.
    ``start_pos`` (static int) runs the block in chunked-prefill mode:
    K/V land at cache positions [start_pos, start_pos + S) and attention
    resumes against the cached prefix (attention mixers only — ssm state
    scans cannot resume from a cache page)."""
    h = layers.rmsnorm(params["ln1"], x, cfg.norm_eps)
    if kind.mixer in ("attn", "attn_local"):
        window = cfg.sliding_window if kind.mixer == "attn_local" else 0
        y, new_cache = attention.prefill_attention(
            params["attn"], h, positions, rope_theta=cfg.rope_theta,
            qk_norm=cfg.qk_norm, cache=cache, window=window,
            q_block=cfg.q_block, kv_block=cfg.kv_block, offset=start_pos)
    elif kind.mixer == "moa":
        b, s, _ = h.shape
        y, new_cache = moa_lib.moa_prefill(
            params["moa"], h, positions, _moa_args(cfg), cache=cache,
            ctx=ctx, mask=_flat_mask(valid, b, s), start_pos=start_pos)
    else:
        if start_pos is not None:
            raise ValueError(
                "chunked prefill requires attention mixers (ssm/hybrid "
                "state scans cannot resume mid-prompt from a cache page)")
        y, new_cache = ssm.mamba(params["mamba"], h, d_state=cfg.ssm_d_state,
                                 return_state=True, ctx=ctx)
    x = x + y
    x, _ = _apply_ffn(params, x, kind, cfg, train=False, rng=None, ctx=ctx,
                      valid=valid)
    return x, new_cache


def block_decode(params, x, kind: LayerKind, cfg: ModelConfig, cache,
                 cur_index,
                 ctx: ctx_lib.MeshContext | None = None, valid=None):
    """One-token decode block. ``cur_index`` is a scalar or a [B] vector of
    per-sequence positions (mixed-age serving slots).  ``valid`` ([B]) is
    slot occupancy — dead slots route nowhere and consume no capacity.
    Returns (x, new_cache, aux)."""
    h = layers.rmsnorm(params["ln1"], x, cfg.norm_eps)
    aux_mix = None
    if kind.mixer in ("attn", "attn_local"):
        window = cfg.sliding_window if kind.mixer == "attn_local" else 0
        y, new_cache = attention.decode_attention(
            params["attn"], h, cache, cur_index,
            rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm, window=window)
    elif kind.mixer == "moa":
        mask = (None if valid is None
                else jnp.asarray(valid, jnp.float32).reshape(-1))
        y, new_cache, a_moa = moa_lib.moa_decode(
            params["moa"], h, cache, cur_index,
            _moa_args(cfg, decode=True), ctx=ctx, mask=mask)
        aux_mix = _moa_aux(a_moa)
    else:
        y, new_cache = ssm.mamba_decode(params["mamba"], h, cache,
                                        d_state=cfg.ssm_d_state)
    x = x + y
    x, aux = _apply_ffn(params, x, kind, cfg, train=False, rng=None, ctx=ctx,
                        valid=valid, decode=True)
    return x, new_cache, _merge_aux(aux_mix, aux)


# ---------------------------------------------------------------------------
# Period-stacked layer stack
# ---------------------------------------------------------------------------

def _stack_tree(tree, n: int):
    """Prepend a stacked 'layers' axis of size n to every ParamDef."""
    def one(d: ParamDef):
        return ParamDef((n,) + d.shape, ("layers",) + d.axes,
                        init=d.init, dtype=d.dtype, fan_in=d.fan_in)
    return jax.tree_util.tree_map(one, tree, is_leaf=pm.is_def)


def stack_defs(cfg: ModelConfig) -> dict:
    kinds = layer_kinds(cfg)
    full, rem = n_periods(cfg)
    defs: dict = {}
    if full:
        defs["periods"] = {
            f"pos{p}": _stack_tree(block_defs(cfg, kinds[p]), full)
            for p in range(cfg.period)}
    if rem:
        defs["tail"] = {f"pos{p}": block_defs(cfg, kinds[p % cfg.period])
                        for p in range(rem)}
    return defs


def stack_apply(params, x, cfg: ModelConfig, *, positions, rng,
                train: bool, ctx: ctx_lib.MeshContext | None = None):
    """Run all layers. Returns (x, summed aux)."""
    kinds = layer_kinds(cfg)
    full, rem = n_periods(cfg)
    aux0 = _zero_aux()

    def period_body(carry, xs):
        x, aux = carry
        period_params, idx = xs
        for p in range(cfg.period):
            sub = (jax.random.fold_in(rng, idx * cfg.period + p)
                   if rng is not None else None)
            x, a = block_apply(period_params[f"pos{p}"], x, kinds[p], cfg,
                               positions=positions, rng=sub, train=train,
                               ctx=ctx)
            if a is not None:
                aux = _add_aux(aux, a)
        return (x, aux), None

    body = jax.checkpoint(period_body) if cfg.remat else period_body
    if full:
        (x, aux0), _ = jax.lax.scan(
            body, (x, aux0),
            (params["periods"], jnp.arange(full)))
    for p in range(rem):
        sub = (jax.random.fold_in(rng, full * cfg.period + p)
               if rng is not None else None)
        x, a = block_apply(params["tail"][f"pos{p}"], x,
                           kinds[p % cfg.period], cfg,
                           positions=positions, rng=sub, train=train,
                           ctx=ctx)
        if a is not None:
            aux0 = _add_aux(aux0, a)
    return x, aux0


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Decode-cache ParamDefs matching the stacked parameter structure."""
    kinds = layer_kinds(cfg)
    full, rem = n_periods(cfg)

    def one(kind: LayerKind):
        if kind.mixer in ("attn", "attn_local"):
            window = cfg.sliding_window if kind.mixer == "attn_local" else 0
            return attention.init_cache_defs(
                batch, max_len, cfg.n_kv_heads, cfg.head_dim, window=window,
                dtype=cfg.param_dtype)
        if kind.mixer == "moa":
            # Shared-K/V invariant: an MoA layer's cache is a plain
            # attention cache (pages/prefix reuse work unchanged).
            return moa_lib.init_cache_defs(batch, max_len, _moa_args(cfg),
                                           dtype=cfg.param_dtype)
        return ssm.init_state_defs(batch, cfg.d_model,
                                   d_state=cfg.ssm_d_state,
                                   d_conv=cfg.ssm_d_conv,
                                   expand=cfg.ssm_expand,
                                   dtype=cfg.param_dtype)

    defs: dict = {}
    if full:
        defs["periods"] = {f"pos{p}": _stack_tree(one(kinds[p]), full)
                           for p in range(cfg.period)}
    if rem:
        defs["tail"] = {f"pos{p}": one(kinds[p % cfg.period])
                        for p in range(rem)}
    return defs


def stack_prefill(params, x, cfg: ModelConfig, cache, positions,
                  ctx: ctx_lib.MeshContext | None = None, valid=None,
                  start_pos: int | None = None):
    """Prefill all layers, filling the cache. Returns (x, new_cache).
    ``valid`` ([B, S]) masks padded prompt positions out of MoE routing
    (bucketed prefill).  ``start_pos`` (static int) is the chunked-prefill
    offset: this call ingests prompt positions [start_pos, start_pos + S)
    against a cache already holding [0, start_pos)."""
    kinds = layer_kinds(cfg)
    full, rem = n_periods(cfg)
    new_cache: dict = {}

    def period_body(x, xs):
        period_params, period_cache = xs
        out_cache = {}
        for p in range(cfg.period):
            x, out_cache[f"pos{p}"] = block_prefill(
                period_params[f"pos{p}"], x, kinds[p], cfg,
                period_cache[f"pos{p}"], positions, ctx=ctx, valid=valid,
                start_pos=start_pos)
        return x, out_cache

    body = jax.checkpoint(period_body) if cfg.remat else period_body
    if full:
        x, new_cache["periods"] = jax.lax.scan(
            body, x, (params["periods"], cache["periods"]))
    if rem:
        new_cache["tail"] = {}
        for p in range(rem):
            x, new_cache["tail"][f"pos{p}"] = block_prefill(
                params["tail"][f"pos{p}"], x, kinds[p % cfg.period], cfg,
                cache["tail"][f"pos{p}"], positions, ctx=ctx, valid=valid,
                start_pos=start_pos)
    return x, new_cache


def stack_decode(params, x, cfg: ModelConfig, cache, cur_index,
                 ctx: ctx_lib.MeshContext | None = None, valid=None):
    """One-token decode through all layers.  ``cur_index`` is a scalar or a
    [B] vector of per-sequence positions; ``valid`` ([B]) is slot
    occupancy (dead slots are masked out of MoE routing).  Returns
    (x, new_cache, telemetry) where telemetry is the summed per-expert
    load/overflow counters over MoE layers (None if the model has none)."""
    kinds = layer_kinds(cfg)
    full, rem = n_periods(cfg)
    new_cache: dict = {}
    telem = _telemetry_zero(cfg)

    def period_body(carry, xs):
        x, telem = carry
        period_params, period_cache = xs
        out_cache = {}
        for p in range(cfg.period):
            x, out_cache[f"pos{p}"], aux = block_decode(
                period_params[f"pos{p}"], x, kinds[p], cfg,
                period_cache[f"pos{p}"], cur_index, ctx=ctx, valid=valid)
            telem = _add_telemetry(telem, aux)
        return (x, telem), out_cache

    if full:
        (x, telem), new_cache["periods"] = jax.lax.scan(
            period_body, (x, telem), (params["periods"], cache["periods"]))
    if rem:
        new_cache["tail"] = {}
        for p in range(rem):
            x, new_cache["tail"][f"pos{p}"], aux = block_decode(
                params["tail"][f"pos{p}"], x, kinds[p % cfg.period], cfg,
                cache["tail"][f"pos{p}"], cur_index, ctx=ctx, valid=valid)
            telem = _add_telemetry(telem, aux)
    return x, new_cache, telem
