"""The paper's language model (§C.1) and its computationally-matched baselines.

Five layers: word embedding → LSTM → MoE (applied "convolutionally" over all
timesteps, §3.1) → LSTM → softmax.  Residual connections around each
non-softmax layer with dropout on the layer output; the MoE output passes
through a sigmoid before dropout (§C.1).

Variants (Appendix C baselines, Table 7):

* ``moe``        — MoE-n with noisy-top-k gating (flat or hierarchical)
* ``moe_1_wide`` — a single expert with one 4096-unit hidden layer
* ``moe_1_deep`` — a single expert with four 1024-unit hidden layers
* ``lstm_4x``    — MoE layer replaced by two more 512-unit LSTMs
* ``lstm_2048_512`` — one 2048-unit LSTM with a 512-d output projection
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common import param as pm
from repro.common.param import ParamDef
from repro.core import hierarchical as hmoe_lib
from repro.core import moe as moe_lib
from repro.models import layers, lstm as lstm_lib
from repro.sharding import context as ctx_lib


@dataclasses.dataclass(frozen=True)
class PaperLMConfig:
    vocab_size: int
    variant: str = "moe"            # moe | moe_1_wide | moe_1_deep |
                                    # lstm_4x | lstm_2048_512
    d_model: int = 512
    n_experts: int = 4
    k: int = 4                      # paper: k=4 flat, k=2 per level (hier.)
    expert_hidden: int = 1024
    hierarchical: tuple[int, int] | None = None
    # One routing configuration path (docs/routing.md); None resolves the
    # deprecated fields below into a RouterSpec (k inherited from ``k``).
    router: Any = None              # RouterSpec | None
    gating_mode: str = "noisy_topk"
    capacity_factor: float = 2.0    # §C.1 paper value == RouterSpec default
    w_importance: float = 0.1       # §C.1
    w_load: float = 0.1
    dropout: float = 0.1
    # MoE kernel backend ("ref" | "pallas"); None = ref.  See docs/kernels.md.
    kernel_backend: str | None = None
    dtype: Any = jnp.float32


def _moe_args(cfg: PaperLMConfig) -> moe_lib.MoEArgs:
    return moe_lib.MoEArgs(
        n_experts=cfg.n_experts, k=cfg.k, d_model=cfg.d_model,
        d_ff=cfg.expert_hidden, activation="relu",
        router=cfg.router,
        gating_mode=cfg.gating_mode, capacity_factor=cfg.capacity_factor,
        w_importance=cfg.w_importance, w_load=cfg.w_load,
        sigmoid_output=True, kernel_backend=cfg.kernel_backend,
        dtype=cfg.dtype)


def _hmoe_args(cfg: PaperLMConfig) -> hmoe_lib.HMoEArgs:
    a, b = cfg.hierarchical
    return hmoe_lib.HMoEArgs(
        n_groups=a, n_experts_per_group=b, k_primary=2, k_secondary=2,
        d_model=cfg.d_model, d_ff=cfg.expert_hidden, activation="relu",
        router=cfg.router, capacity_factor=cfg.capacity_factor,
        w_importance=cfg.w_importance, w_load=cfg.w_load,
        kernel_backend=cfg.kernel_backend, dtype=cfg.dtype)


def paper_lm_defs(cfg: PaperLMConfig) -> dict:
    d = cfg.d_model
    defs: dict = {
        "embed": layers.embed_defs(cfg.vocab_size, d, cfg.dtype),
        "lstm1": lstm_lib.lstm_defs(d, d, dtype=cfg.dtype),
        "lstm2": lstm_lib.lstm_defs(d, d, dtype=cfg.dtype),
        "softmax": {"w": ParamDef((d, cfg.vocab_size),
                                  ("embed_fsdp", "vocab"), dtype=cfg.dtype,
                                  fan_in=d)},
    }
    if cfg.variant == "moe":
        if cfg.hierarchical:
            defs["moe"] = hmoe_lib.hmoe_defs(_hmoe_args(cfg))
        else:
            defs["moe"] = moe_lib.moe_defs(_moe_args(cfg))
    elif cfg.variant == "moe_1_wide":
        defs["mid"] = {
            "w1": ParamDef((d, 4096), ("embed_fsdp", "mlp"), dtype=cfg.dtype),
            "w2": ParamDef((4096, d), ("mlp", "embed_fsdp"), dtype=cfg.dtype),
        }
    elif cfg.variant == "moe_1_deep":
        defs["mid"] = {"w0": ParamDef((d, 1024), ("embed_fsdp", "mlp"),
                                      dtype=cfg.dtype)}
        for i in range(3):
            defs["mid"][f"w{i+1}"] = ParamDef(
                (1024, 1024), ("mlp", "mlp2"), dtype=cfg.dtype)
        defs["mid"]["w4"] = ParamDef((1024, d), ("mlp", "embed_fsdp"),
                                     dtype=cfg.dtype)
    elif cfg.variant == "lstm_4x":
        defs["mid"] = {"lstm3": lstm_lib.lstm_defs(d, d, dtype=cfg.dtype),
                       "lstm4": lstm_lib.lstm_defs(d, d, dtype=cfg.dtype)}
    elif cfg.variant == "lstm_2048_512":
        # Replaces lstm1/MoE/lstm2 stack semantics: one big projected LSTM.
        defs["mid"] = {"big": lstm_lib.lstm_defs(d, 2048, d_proj=d,
                                                 dtype=cfg.dtype)}
    else:
        raise ValueError(cfg.variant)
    return defs


def _mid_layer(params, x2d, cfg: PaperLMConfig, *, train, rng,
               ctx: ctx_lib.MeshContext | None = None):
    """The capacity layer between the LSTMs. x2d: [T, d]."""
    zero_aux = {"aux_loss": jnp.zeros((), jnp.float32), "metrics": {}}
    if cfg.variant == "moe":
        if cfg.hierarchical:
            return hmoe_lib.hmoe_apply(params["moe"], x2d, _hmoe_args(cfg),
                                       train=train, rng=rng, ctx=ctx)
        return moe_lib.moe_apply(params["moe"], x2d, _moe_args(cfg),
                                 train=train, rng=rng, ctx=ctx)
    if cfg.variant == "moe_1_wide":
        h = jax.nn.relu(x2d @ params["mid"]["w1"])
        return jax.nn.sigmoid(h @ params["mid"]["w2"]), zero_aux
    if cfg.variant == "moe_1_deep":
        h = x2d
        for i in range(5):
            h = h @ params["mid"][f"w{i}"]
            if i < 4:
                h = jax.nn.relu(h)
        return jax.nn.sigmoid(h), zero_aux
    raise ValueError(cfg.variant)


def paper_lm_loss(params, batch, cfg: PaperLMConfig, *, rng=None,
                  train: bool = True,
                  ctx: ctx_lib.MeshContext | None = None):
    """batch: tokens/labels [B, S]. Returns (loss, metrics)."""
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    rngs = (jax.random.split(rng, 4) if rng is not None else [None] * 4)
    x = layers.embed(params["embed"], tokens, cfg.dtype)
    x = layers.dropout(x, cfg.dropout, rngs[0], train)

    aux = {"aux_loss": jnp.zeros((), jnp.float32), "metrics": {}}
    if cfg.variant == "lstm_2048_512":
        h, _ = lstm_lib.lstm(params["mid"]["big"], x)
        x = x + layers.dropout(h, cfg.dropout, rngs[1], train)
    else:
        h, _ = lstm_lib.lstm(params["lstm1"], x)
        x = x + layers.dropout(h, cfg.dropout, rngs[1], train)
        if cfg.variant == "lstm_4x":
            h, _ = lstm_lib.lstm(params["mid"]["lstm3"], x)
            x = x + layers.dropout(h, cfg.dropout, rngs[2], train)
            h, _ = lstm_lib.lstm(params["mid"]["lstm4"], x)
            x = x + layers.dropout(h, cfg.dropout, rngs[2], train)
        else:
            # The MoE is applied convolutionally: all B*S positions as one
            # big batch (§3.1 "Taking Advantage of Convolutionality").
            y2d, aux = _mid_layer(params, x.reshape(b * s, -1), cfg,
                                  train=train, rng=rngs[2], ctx=ctx)
            x = x + layers.dropout(y2d.reshape(b, s, -1), cfg.dropout,
                                   rngs[2], train)
        h, _ = lstm_lib.lstm(params["lstm2"], x)
        x = x + layers.dropout(h, cfg.dropout, rngs[3], train)

    logits = (x @ params["softmax"]["w"]).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    xent = jnp.mean(lse - gold)
    loss = xent + aux["aux_loss"]
    metrics = {"xent": xent, "perplexity": jnp.exp(xent),
               "aux_loss": aux["aux_loss"], "loss": loss,
               **aux.get("metrics", {})}
    return loss, metrics
