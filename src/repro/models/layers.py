"""Shared neural-net layers (functional, ParamDef-declared)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef
from repro.sharding import context as ctx_lib


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_defs(d: int) -> dict:
    return {"scale": ParamDef((d,), ("embed",), init="ones",
                              dtype=jnp.float32)}


def rmsnorm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = jnp.asarray(x, jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(x.dtype)


def layernorm_defs(d: int) -> dict:
    return {"scale": ParamDef((d,), ("embed",), init="ones",
                              dtype=jnp.float32),
            "bias": ParamDef((d,), ("embed",), init="zeros",
                             dtype=jnp.float32)}


def layernorm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = jnp.asarray(x, jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_defs(vocab: int, d: int, dtype) -> dict:
    return {"table": ParamDef((vocab, d), ("vocab", "embed_fsdp"),
                              init="embed", dtype=dtype)}


def embed(params, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0).astype(dtype)


# ---------------------------------------------------------------------------
# Dense MLP (relu / gelu two-matrix, or gated swiglu / geglu)
# ---------------------------------------------------------------------------

GATED = ("swiglu", "geglu")


def mlp_defs(d: int, d_ff: int, activation: str, dtype) -> dict:
    defs = {
        "w1": ParamDef((d, d_ff), ("embed_fsdp", "mlp"), dtype=dtype),
        "w2": ParamDef((d_ff, d), ("mlp", "embed_fsdp"), dtype=dtype),
    }
    if activation in GATED:
        defs["w3"] = ParamDef((d, d_ff), ("embed_fsdp", "mlp"), dtype=dtype)
    return defs


def mlp(params, x: jax.Array, activation: str,
        ctx: ctx_lib.MeshContext | None = None) -> jax.Array:
    dt = x.dtype
    h = jnp.einsum("...d,df->...f", x, params["w1"].astype(dt),
                   preferred_element_type=jnp.float32)
    h = ctx_lib.with_constraint(h, (None,) * (h.ndim - 1) + ("mlp",), ctx)
    if activation == "relu":
        h = jax.nn.relu(h)
    elif activation == "gelu":
        h = jax.nn.gelu(h)
    elif activation in GATED:
        g = jnp.einsum("...d,df->...f", x, params["w3"].astype(dt),
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(h) if activation == "swiglu"
             else jax.nn.gelu(h)) * g
    else:
        raise ValueError(activation)
    return jnp.einsum("...f,fd->...d", h.astype(dt), params["w2"].astype(dt),
                      preferred_element_type=jnp.float32).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (llama-style rotate-half)
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, n_heads, head_dim]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]                          # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = jnp.asarray(x1, jnp.float32), jnp.asarray(x2, jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
        axis=-1).astype(x.dtype)


def dropout(x: jax.Array, rate: float, rng: jax.Array | None,
            train: bool) -> jax.Array:
    if not train or rate <= 0.0 or rng is None:
        return x
    keep = jax.random.bernoulli(rng, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0).astype(x.dtype)
