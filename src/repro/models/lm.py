"""Top-level language model: embedding → layer stack → norm → logits/loss.

Entry points (all pure functions of (params, batch)):

* ``lm_loss``      — training forward: mean token cross-entropy + the §4
                     balancing losses summed over MoE layers.
* ``lm_prefill``   — prompt ingestion: last-position logits + filled cache.
* ``lm_decode``    — one-token decode step against the cache.

Cross-entropy is *chunked over the sequence*: logits for a [B, chunk, V]
slice are produced, reduced and discarded inside a remat'd scan, so the full
[B, S, V] logits tensor (43 GB for kimi-k2 at 4k×16-per-device) never
exists.  The unembedding is vocab-sharded over the model axis, so the chunk
reduction is a cheap sharded logsumexp.

Modality frontends ([vlm]/[audio]) are stubs per the assignment: the stub
supplies precomputed prefix embeddings which overwrite the first
``n_prefix`` token-embedding positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import param as pm
from repro.configs.base import ModelConfig
from repro.models import layers, transformer
from repro.sharding import context as ctx_lib


def lm_defs(cfg: ModelConfig) -> dict:
    return {
        "embed": layers.embed_defs(cfg.vocab_size, cfg.d_model,
                                   cfg.param_dtype),
        "blocks": transformer.stack_defs(cfg),
        "ln_f": layers.rmsnorm_defs(cfg.d_model),
        "unembed": {"w": pm.ParamDef((cfg.d_model, cfg.vocab_size),
                                     ("embed_fsdp", "vocab"),
                                     dtype=cfg.param_dtype,
                                     fan_in=cfg.d_model)},
    }


def _embed_with_prefix(params, tokens, cfg: ModelConfig,
                       prefix_embeds=None):
    x = layers.embed(params["embed"], tokens, cfg.compute_dtype)
    if cfg.n_prefix and prefix_embeds is not None:
        # Stub modality frontend: precomputed patch/frame embeddings occupy
        # the first n_prefix positions.
        pe = prefix_embeds.astype(cfg.compute_dtype)
        x = jax.lax.dynamic_update_slice_in_dim(x, pe, 0, axis=1)
    return x


def logits_fn(params, x, cfg: ModelConfig,
              ctx: ctx_lib.MeshContext | None = None):
    dt = x.dtype
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"]["w"].astype(dt),
                        preferred_element_type=jnp.float32)
    return ctx_lib.with_constraint(logits, ("batch", None, "vocab"), ctx)


def chunked_xent(params, x, labels, cfg: ModelConfig,
                 chunk: int = 512,
                 ctx: ctx_lib.MeshContext | None = None) -> jax.Array:
    """Mean cross-entropy without materializing [B, S, V]."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    if s % chunk != 0:
        raise ValueError(
            f"sequence length {s} not divisible by loss chunk {chunk}")
    n = s // chunk
    xc = x.reshape(b, n, chunk, d).swapaxes(0, 1)          # [n, B, c, d]
    lc = labels.reshape(b, n, chunk).swapaxes(0, 1)

    def body(total, xs):
        xi, li = xs
        logits = logits_fn(params, xi, cfg, ctx)           # [B, c, V] f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(li, cfg.vocab_size, dtype=logits.dtype)
        onehot = ctx_lib.with_constraint(onehot, ("batch", None, "vocab"),
                                         ctx)
        gold = jnp.einsum("bcv,bcv->bc", logits, onehot)
        return total + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(body),
                            jnp.zeros((), jnp.float32), (xc, lc))
    return total / (b * s)


def lm_loss(params, batch: dict, cfg: ModelConfig, *, rng=None,
            train: bool = True,
            ctx: ctx_lib.MeshContext | None = None):
    """batch: tokens [B,S] int32, labels [B,S] int32,
    (+ prefix_embeds [B,n_prefix,d] for vlm/audio stubs).
    Returns (loss, metrics).  ``ctx`` is the explicit sharding context,
    threaded through the whole layer stack."""
    tokens = ctx_lib.with_constraint(batch["tokens"], ("batch", "seq"), ctx)
    x = _embed_with_prefix(params, tokens, cfg, batch.get("prefix_embeds"))
    x = ctx_lib.with_constraint(x, ("batch", "seq", "embed"), ctx)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :],
                                 x.shape[:2])
    x, aux = transformer.stack_apply(params["blocks"], x, cfg,
                                     positions=positions, rng=rng,
                                     train=train, ctx=ctx)
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    xent = chunked_xent(params, x, batch["labels"], cfg, ctx=ctx)
    loss = xent + aux["aux_loss"]
    n_moe = jnp.maximum(aux["n_moe"], 1.0)
    metrics = {"xent": xent, "aux_loss": aux["aux_loss"],
               "loss": loss,
               **{k: v / n_moe for k, v in aux["metrics"].items()}}
    return loss, metrics


def lm_prefill(params, batch: dict, cache, cfg: ModelConfig,
               ctx: ctx_lib.MeshContext | None = None, *,
               last_index=None, valid=None, start_pos: int | None = None):
    """Prompt ingestion. batch: tokens [B,S]. Returns (last_logits, cache).

    Bucketed prefill (docs/serving.md): ``last_index`` (scalar, or a [B]
    vector when rows end at different positions — cross-slot batched
    chunk groups) selects the logits position — the true final prompt
    token when the prompt was right-padded to a length bucket — and
    ``valid`` ([B, S]) masks the padded tail out of MoE routing so
    padding can never displace real tokens from expert capacity.
    Defaults reproduce the exact-length path (last position, everything
    valid).

    Chunked prefill: ``start_pos`` (a *static* int) ingests the prompt
    slice at absolute positions [start_pos, start_pos + S) against a
    cache already holding positions [0, start_pos) — chunk N resumes
    where chunk N-1 ended (RoPE, KV writes, and the causal mask all use
    the absolute positions).  ``last_index`` stays chunk-local."""
    x = _embed_with_prefix(params, batch["tokens"], cfg,
                           batch.get("prefix_embeds"))
    positions = jnp.broadcast_to(
        (start_pos or 0) + jnp.arange(x.shape[1])[None, :], x.shape[:2])
    x, new_cache = transformer.stack_prefill(params["blocks"], x, cfg,
                                             cache, positions, ctx=ctx,
                                             valid=valid,
                                             start_pos=start_pos)
    if last_index is None:
        x = x[:, -1:, :]
    else:
        li = jnp.asarray(last_index, jnp.int32)
        if li.ndim == 0:
            x = jax.lax.dynamic_slice_in_dim(x, li, 1, axis=1)
        else:
            # Per-row final positions: a pure gather (vmapped slice), so a
            # [1]-vector is bitwise-identical to the scalar path.
            x = jax.vmap(lambda xi, lii: jax.lax.dynamic_slice_in_dim(
                xi, lii, 1, axis=0))(x, li)
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = logits_fn(params, x, cfg, ctx)[:, 0, :]
    return logits, new_cache


def lm_decode(params, tokens, cache, cur_index, cfg: ModelConfig,
              ctx: ctx_lib.MeshContext | None = None, *,
              valid=None, return_telemetry: bool = False):
    """One decode step. tokens: [B] int32; cur_index: scalar int32 position
    of the *new* token, or a [B] vector of per-sequence positions (serving
    slots of mixed age).  ``valid`` ([B] in {0,1}) is slot occupancy: dead
    slots are masked out of MoE routing and consume no expert capacity.
    Returns (logits [B, V], new_cache), plus — with ``return_telemetry`` —
    the per-expert MoE load/overflow counters summed over layers (None for
    models without MoE)."""
    x = layers.embed(params["embed"], tokens[:, None], cfg.compute_dtype)
    x, new_cache, telem = transformer.stack_decode(params["blocks"], x, cfg,
                                                   cache, cur_index, ctx=ctx,
                                                   valid=valid)
    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = logits_fn(params, x, cfg, ctx)[:, 0, :]
    if return_telemetry:
        return logits, new_cache, telem
    return logits, new_cache
