"""LSTM layers (the paper's recurrent backbone, §C.1) via ``lax.scan``.

Supports the projected variant of Sak et al. (2014) used by LSTM-2048-512:
hidden size H with an output projection to P, where the recurrent input is
the projected output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef
from repro.sharding import context as ctx_lib


def lstm_defs(d_in: int, d_hidden: int, d_proj: int | None = None,
              dtype=jnp.float32) -> dict:
    rec = d_proj or d_hidden
    defs = {
        "wx": ParamDef((d_in, 4 * d_hidden), ("embed_fsdp", "mlp"),
                       dtype=dtype, fan_in=d_in),
        "wh": ParamDef((rec, 4 * d_hidden), ("embed_fsdp", "mlp"),
                       dtype=dtype, fan_in=rec),
        "b": ParamDef((4 * d_hidden,), ("mlp",), init="zeros", dtype=dtype),
    }
    if d_proj:
        defs["proj"] = ParamDef((d_hidden, d_proj), ("mlp", "embed_fsdp"),
                                dtype=dtype, fan_in=d_hidden)
    return defs


def _cell(params, carry, x_t):
    h, c = carry
    d_hidden = c.shape[-1]
    gates = (x_t @ params["wx"].astype(x_t.dtype)
             + h @ params["wh"].astype(x_t.dtype)
             + params["b"].astype(x_t.dtype))
    i, f, g, o = jnp.split(gates.astype(jnp.float32), 4, axis=-1)
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_full = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    if "proj" in params:
        h_new = (h_full.astype(x_t.dtype)
                 @ params["proj"].astype(x_t.dtype)).astype(jnp.float32)
    else:
        h_new = h_full
    return (h_new.astype(x_t.dtype), c_new), h_new.astype(x_t.dtype)


def lstm(params, x: jax.Array, state: tuple | None = None,
         ctx: ctx_lib.MeshContext | None = None
         ) -> tuple[jax.Array, tuple]:
    """x: [B, S, d_in] -> ([B, S, d_out], final_state)."""
    x = ctx_lib.with_constraint(x, ("batch", "seq", None), ctx)
    b = x.shape[0]
    d_hidden = params["b"].shape[0] // 4
    rec = params["wh"].shape[0]
    if state is None:
        state = (jnp.zeros((b, rec), x.dtype),
                 jnp.zeros((b, d_hidden), jnp.float32))
    step = lambda carry, x_t: _cell(params, carry, x_t)
    final, ys = jax.lax.scan(step, state, x.swapaxes(0, 1))
    return ys.swapaxes(0, 1), final
