"""Mamba-1 selective state-space block (falcon-mamba / jamba mixers).

TPU adaptation of the CUDA selective-scan kernel: the GPU implementation is a
fused SRAM-resident recurrence; the TPU-native formulation here is a
*chunked* scan — an outer ``lax.scan`` carries the [B, d_inner, d_state] SSM
state across sequence chunks while an inner ``associative_scan`` (log-depth,
MXU/VPU friendly) handles each chunk.  Memory per chunk is
O(B · chunk · d_inner · d_state) instead of O(B · S · d_inner · d_state),
which is what makes 500k-token sequences feasible (see DESIGN.md
§Hardware-adaptation).

Decode is the exact single-step recurrence with a (d_conv-1)-entry
convolution state — O(1) per token, which is why the SSM archs run the
``long_500k`` shape that pure-attention archs skip.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef
from repro.sharding import context as ctx_lib


def _dt_rank(d_model: int) -> int:
    return -(-d_model // 16)   # ceil(d/16), mamba-1 default


def mamba_defs(d_model: int, *, d_state: int, d_conv: int, expand: int,
               dtype) -> dict:
    d_in = expand * d_model
    r = _dt_rank(d_model)
    return {
        "in_proj": ParamDef((d_model, 2 * d_in), ("embed_fsdp", "ssm_inner"),
                            dtype=dtype, fan_in=d_model),
        "conv_w": ParamDef((d_conv, d_in), ("conv", "ssm_inner"),
                           dtype=dtype, fan_in=d_conv),
        "conv_b": ParamDef((d_in,), ("ssm_inner",), init="zeros",
                           dtype=dtype),
        "x_proj": ParamDef((d_in, r + 2 * d_state), ("ssm_inner", None),
                           dtype=dtype, fan_in=d_in),
        "dt_proj": ParamDef((r, d_in), (None, "ssm_inner"), dtype=dtype,
                            fan_in=r),
        "dt_bias": ParamDef((d_in,), ("ssm_inner",), init="zeros",
                            dtype=jnp.float32),
        "a_log": ParamDef((d_in, d_state), ("ssm_inner", "ssm_state"),
                          init="ones", dtype=jnp.float32),
        "d_skip": ParamDef((d_in,), ("ssm_inner",), init="ones",
                           dtype=jnp.float32),
        "out_proj": ParamDef((d_in, d_model), ("ssm_inner", "embed_fsdp"),
                             dtype=dtype, fan_in=d_in),
    }


def _ssm_inputs(params, u: jax.Array, d_state: int):
    """Shared pre-scan computation. u: [B, L, d_in] (post conv+silu)."""
    r = params["dt_proj"].shape[0]
    dt_bc = jnp.einsum("bld,dr->blr", u,
                       params["x_proj"].astype(u.dtype),
                       preferred_element_type=jnp.float32)
    dt, b_mat, c_mat = jnp.split(dt_bc, [r, r + d_state], axis=-1)
    dt = jnp.einsum("blr,rd->bld", dt.astype(u.dtype),
                    params["dt_proj"].astype(u.dtype),
                    preferred_element_type=jnp.float32)
    dt = jax.nn.softplus(dt + params["dt_bias"])          # [B, L, d_in] f32
    a = -jnp.exp(params["a_log"])                          # [d_in, N] f32
    da = jnp.exp(dt[..., None] * a)                        # [B, L, d_in, N]
    dbx = (dt * u.astype(jnp.float32))[..., None] * \
        b_mat.astype(jnp.float32)[:, :, None, :]           # [B, L, d_in, N]
    return da, dbx, c_mat.astype(jnp.float32)


def _conv1d(params, x: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv (kernel d_conv). x: [B, L, d_in]."""
    d_conv = params["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], d_conv - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    w = params["conv_w"].astype(x.dtype)
    out = sum(xp[:, i:i + x.shape[1], :] *
              w[i][None, None, :] for i in range(d_conv))
    new_state = xp[:, -(d_conv - 1):, :] if d_conv > 1 else pad
    return out + params["conv_b"].astype(x.dtype), new_state


def mamba(params, x: jax.Array, *, d_state: int, chunk: int = 128,
          return_state: bool = False,
          ctx: ctx_lib.MeshContext | None = None):
    """Training/prefill forward. x: [B, S, d_model] -> [B, S, d_model].

    With ``return_state`` also returns {"ssm", "conv"} for decode handoff."""
    dt = x.dtype
    b, s, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt),
                    preferred_element_type=jnp.float32).astype(dt)
    u, z = jnp.split(xz, 2, axis=-1)                      # [B, S, d_in] x2
    u_raw = u
    u, _ = _conv1d(params, u)
    u = jax.nn.silu(u)
    u = ctx_lib.with_constraint(u, ("batch", None, "ssm_inner"), ctx)
    d_in = u.shape[-1]

    chunk = min(chunk, s)
    if s % chunk != 0:
        raise ValueError(
            f"sequence length {s} not divisible by ssm scan chunk {chunk}")
    n_chunks = s // chunk
    uc = u.reshape(b, n_chunks, chunk, d_in)

    def chunk_step(h, u_chunk):
        # h: [B, d_in, N] f32 carried state.
        da, dbx, c_mat = _ssm_inputs(params, u_chunk, d_state)
        # Inclusive associative scan within the chunk:
        #   (a2, b2) ∘ (a1, b1) = (a1 a2, a2 b1 + b2)
        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a1 * a2, a2 * b1 + b2
        a_sc, b_sc = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h_all = a_sc * h[:, None] + b_sc                  # [B, L, d_in, N]
        y = jnp.einsum("blds,bls->bld", h_all, c_mat)
        h_new = h_all[:, -1]
        return h_new, y

    h0 = jnp.zeros((b, d_in, d_state), jnp.float32)
    h_final, yc = jax.lax.scan(jax.checkpoint(chunk_step), h0,
                               uc.swapaxes(0, 1))
    y = yc.swapaxes(0, 1).reshape(b, s, d_in)
    y = y + params["d_skip"] * u.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(dt)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt),
                     preferred_element_type=jnp.float32).astype(dt)
    if return_state:
        d_conv = params["conv_w"].shape[0]
        conv_state = u_raw[:, -(d_conv - 1):, :]
        return out, {"ssm": h_final, "conv": conv_state}
    return out


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_state_defs(batch: int, d_model: int, *, d_state: int, d_conv: int,
                    expand: int, dtype=jnp.float32) -> dict:
    d_in = expand * d_model
    return {
        "ssm": ParamDef((batch, d_in, d_state),
                        ("batch", "ssm_inner", "ssm_state"),
                        init="zeros", dtype=jnp.float32),
        "conv": ParamDef((batch, d_conv - 1, d_in),
                         ("batch", "conv", "ssm_inner"),
                         init="zeros", dtype=dtype),
    }


def mamba_decode(params, x: jax.Array, state: dict, *, d_state: int
                 ) -> tuple[jax.Array, dict]:
    """Single-token step. x: [B, 1, d_model] -> (y, new_state)."""
    dt = x.dtype
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt),
                    preferred_element_type=jnp.float32).astype(dt)
    u, z = jnp.split(xz, 2, axis=-1)
    u, conv_state = _conv1d(params, u, state["conv"])
    u = jax.nn.silu(u)
    da, dbx, c_mat = _ssm_inputs(params, u, d_state)     # L == 1
    h = state["ssm"] * da[:, 0] + dbx[:, 0]              # [B, d_in, N]
    y = jnp.einsum("bds,bs->bd", h, c_mat[:, 0])[:, None, :]
    y = y + params["d_skip"] * u.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(dt)
    y = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    return y, {"ssm": h, "conv": conv_state}
