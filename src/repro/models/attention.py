"""Grouped-query attention with memory-bounded blockwise (flash-style) softmax.

Training/prefill never materializes the [S, S] score matrix: an outer scan
over query blocks and an inner ``fori_loop`` over key/value blocks maintain
online-softmax statistics.  The inner loop's trip count is *dynamic* — for
causal masks only blocks at or below the diagonal run, and for sliding-window
layers only blocks inside the window run — so the HLO does no wasted
quadratic work (this matters for the §Roofline MODEL_FLOPS ratio).

Decode attends a single query against the KV cache; sliding-window layers
use a ring-buffer cache of size ``window`` so a 500k-context gemma-style
model stores only O(window) per local layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef
from repro.models import layers
from repro.sharding import context as ctx_lib

NEG_INF = -1e30


def attention_defs(d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, qk_norm: bool, dtype) -> dict:
    defs = {
        "wq": ParamDef((d_model, n_heads, head_dim),
                       ("embed_fsdp", "heads", "head_dim"), dtype=dtype,
                       fan_in=d_model),
        "wk": ParamDef((d_model, n_kv_heads, head_dim),
                       ("embed_fsdp", "kv_heads", "head_dim"), dtype=dtype,
                       fan_in=d_model),
        "wv": ParamDef((d_model, n_kv_heads, head_dim),
                       ("embed_fsdp", "kv_heads", "head_dim"), dtype=dtype,
                       fan_in=d_model),
        "wo": ParamDef((n_heads, head_dim, d_model),
                       ("heads", "head_dim", "embed_fsdp"), dtype=dtype,
                       fan_in=n_heads * head_dim),
    }
    if qk_norm:
        defs["q_norm"] = layers.rmsnorm_defs(head_dim)
        defs["k_norm"] = layers.rmsnorm_defs(head_dim)
    return defs


def _qkv(params, x, positions, *, rope_theta, qk_norm, eps=1e-6):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    if qk_norm:
        q = layers.rmsnorm(params["q_norm"], q, eps)
        k = layers.rmsnorm(params["k_norm"], k, eps)
    q = layers.rope(q, positions, rope_theta)
    k = layers.rope(k, positions, rope_theta)
    return q, k, v


def _kv_range(i: int, nkv: int, q_block: int, kv_block: int, causal: bool,
              window: int) -> tuple[int, int]:
    """Static kv-block range visible to query block i."""
    if causal:
        hi = min(nkv, (i * q_block + q_block + kv_block - 1) // kv_block)
    else:
        hi = nkv
    lo = max(0, (i * q_block + 1 - window) // kv_block) if window > 0 else 0
    return lo, hi


def _q_range(j: int, nq: int, q_block: int, kv_block: int, causal: bool,
             window: int) -> tuple[int, int]:
    """Static q-block range that can see kv block j (inverse of _kv_range)."""
    lo = (j * kv_block) // q_block if causal else 0
    if window > 0:
        hi = min(nq, (j * kv_block + kv_block - 1 + window) // q_block + 1)
    else:
        hi = nq
    return lo, hi


def _mask(pos_q, pos_k, causal, window):
    m = jnp.ones((pos_q.shape[0], pos_k.shape[0]), bool)
    if causal:
        m &= pos_k[None, :] <= pos_q[:, None]
    if window > 0:
        m &= pos_k[None, :] > pos_q[:, None] - window
    return m


def blockwise_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        q_block: int = 512, kv_block: int = 512,
                        kv_len: jax.Array | None = None,
                        q_offset: int = 0):
    """q: [B,Sq,H,hd]; k,v: [B,Skv,KV,hd] -> [B,Sq,H,hd].

    Assumes q position i attends kv positions <= i (+ window lower bound).
    ``kv_len`` optionally masks a padded cache tail.  ``q_offset`` (a
    *static* int) places the queries at absolute positions
    ``q_offset + i`` against kv positions ``0..Skv`` — chunked prefill
    resumes a prompt mid-sequence with the cached prefix as kv context
    while the static per-block kv ranges keep pruning above the shifted
    diagonal.
    """
    b, sq, h, hd = q.shape
    skv, kv_heads = k.shape[1], k.shape[2]
    g = h // kv_heads
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    if sq % q_block != 0 or skv % kv_block != 0:
        raise ValueError(
            f"sequence lengths must divide the attention blocks: "
            f"sq={sq} % q_block={q_block}, skv={skv} % kv_block={kv_block}")
    nq = sq // q_block
    nkv = skv // kv_block
    scale = 1.0 / (hd ** 0.5)
    # [B, KV, G, S, hd] layout so GQA is a plain batched matmul.
    qr = jnp.moveaxis(q.reshape(b, sq, kv_heads, g, hd), 1, 3)
    kr = jnp.moveaxis(k, 1, 3)                     # [B, KV, hd, Skv]
    vr = jnp.moveaxis(v, 1, 2)                     # [B, KV, Skv, hd]

    def one_q_block(i: int):
        # i is a *Python* int: the kv range below is static, so only the
        # blocks at/below the diagonal (and inside the window) exist in the
        # HLO at all — no masked-out quadratic work, and the loop stays
        # reverse-mode differentiable.
        q_i = jax.lax.slice_in_dim(qr, i * q_block, (i + 1) * q_block,
                                   axis=3)
        pos_q = q_offset + i * q_block + jnp.arange(q_block)
        if causal:
            hi = min(nkv, (q_offset + i * q_block + q_block + kv_block - 1)
                     // kv_block)
        else:
            hi = nkv
        lo = max(0, (q_offset + i * q_block + 1 - window) // kv_block) \
            if window > 0 else 0

        def kv_step(carry, j):
            acc, m, l = carry
            k_j = jax.lax.dynamic_slice_in_dim(kr, j * kv_block, kv_block,
                                               axis=3)
            v_j = jax.lax.dynamic_slice_in_dim(vr, j * kv_block, kv_block,
                                               axis=2)
            pos_k = j * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bkgqh,bkhs->bkgqs", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= pos_k[None, :] <= pos_q[:, None]
            if window > 0:
                mask &= pos_k[None, :] > pos_q[:, None] - window
            if kv_len is not None:
                mask &= (pos_k < kv_len)[None, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bksh->bkgqh", p.astype(v_j.dtype), v_j,
                            preferred_element_type=jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, kv_heads, g, q_block, hd), jnp.float32)
        m0 = jnp.full((b, kv_heads, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv_heads, g, q_block), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      jnp.arange(lo, hi))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)                 # [B, KV, G, qb, hd]

    blocks = jnp.stack([one_q_block(i) for i in range(nq)], axis=3)
    # blocks: [B, KV, G, nq, qb, hd] -> [B, Sq, H, hd]
    return blocks.reshape(b, kv_heads, g, sq, hd).reshape(
        b, h, sq, hd).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# FlashAttention-2-style memory-bounded attention with a custom VJP.
#
# The naive blockwise backward lets XLA stack one [B,KV,G,qb,kvb] probability
# tensor per kv step as a scan residual — 23 GiB/device of temps for even a
# 135M model at 4k (measured; see EXPERIMENTS.md §Perf iteration 1).  The
# custom VJP saves only (q, k, v, out, logsumexp) and recomputes the
# probabilities blockwise in the backward pass: dq in q-block-major order,
# dk/dv in kv-block-major order, both with static diagonal/window ranges.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(qr, kr, vr, causal, window, q_block, kv_block):
    """qr: [B,KV,G,Sq,hd]; kr: [B,KV,hd,Skv]; vr: [B,KV,Skv,hd]."""
    out, _ = _flash_fwd_impl(qr, kr, vr, causal, window, q_block, kv_block)
    return out


def _flash_fwd_impl(qr, kr, vr, causal, window, q_block, kv_block):
    b, kv_heads, g, sq, hd = qr.shape
    skv = kr.shape[-1]
    nq, nkv = sq // q_block, skv // kv_block
    scale = 1.0 / (hd ** 0.5)
    outs, lses = [], []
    for i in range(nq):
        q_i = jax.lax.slice_in_dim(qr, i * q_block, (i + 1) * q_block,
                                   axis=3)
        pos_q = i * q_block + jnp.arange(q_block)
        lo, hi = _kv_range(i, nkv, q_block, kv_block, causal, window)

        def kv_step(carry, j, q_i=q_i, pos_q=pos_q):
            acc, m, l = carry
            k_j = jax.lax.dynamic_slice_in_dim(kr, j * kv_block, kv_block,
                                               axis=3)
            v_j = jax.lax.dynamic_slice_in_dim(vr, j * kv_block, kv_block,
                                               axis=2)
            pos_k = j * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bkgqh,bkhs->bkgqs", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            s = jnp.where(_mask(pos_q, pos_k, causal, window), s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bksh->bkgqh", p.astype(v_j.dtype), v_j,
                            preferred_element_type=jnp.float32)
            return (acc * alpha[..., None] + pv, m_new, l_new), None

        acc0 = jnp.zeros((b, kv_heads, g, q_block, hd), jnp.float32)
        m0 = jnp.full((b, kv_heads, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv_heads, g, q_block), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      jnp.arange(lo, hi))
        lsafe = jnp.maximum(l, 1e-30)
        outs.append((acc / lsafe[..., None]).astype(qr.dtype))
        lses.append(m + jnp.log(lsafe))
    return jnp.concatenate(outs, axis=3), jnp.concatenate(lses, axis=3)


def _flash_fwd(qr, kr, vr, causal, window, q_block, kv_block):
    out, lse = _flash_fwd_impl(qr, kr, vr, causal, window, q_block,
                               kv_block)
    return out, (qr, kr, vr, out, lse)


def _flash_bwd(causal, window, q_block, kv_block, res, dout):
    qr, kr, vr, out, lse = res
    b, kv_heads, g, sq, hd = qr.shape
    skv = kr.shape[-1]
    nq, nkv = sq // q_block, skv // kv_block
    scale = 1.0 / (hd ** 0.5)
    # delta_i = rowsum(dOut * Out)   [B,KV,G,Sq]
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)

    def p_block(i, j, q_i):
        k_j = jax.lax.dynamic_slice_in_dim(kr, j * kv_block, kv_block,
                                           axis=3)
        pos_q = i * q_block + jnp.arange(q_block)
        pos_k_rel = jnp.arange(kv_block)
        s = jnp.einsum("bkgqh,bkhs->bkgqs", q_i, k_j,
                       preferred_element_type=jnp.float32) * scale
        pos_k = j * kv_block + pos_k_rel
        s = jnp.where(_mask(pos_q, pos_k, causal, window), s, NEG_INF)
        lse_i = jax.lax.slice_in_dim(lse, i * q_block, (i + 1) * q_block,
                                     axis=3)
        return jnp.exp(s - lse_i[..., None]), k_j

    # dq: q-block-major (same ranges as forward).
    dqs = []
    for i in range(nq):
        q_i = jax.lax.slice_in_dim(qr, i * q_block, (i + 1) * q_block,
                                   axis=3)
        do_i = jax.lax.slice_in_dim(dout, i * q_block, (i + 1) * q_block,
                                    axis=3).astype(jnp.float32)
        dl_i = jax.lax.slice_in_dim(delta, i * q_block, (i + 1) * q_block,
                                    axis=3)
        lo, hi = _kv_range(i, nkv, q_block, kv_block, causal, window)

        def dq_step(acc, j, i=i, q_i=q_i, do_i=do_i, dl_i=dl_i):
            p, k_j = p_block(i, j, q_i)
            v_j = jax.lax.dynamic_slice_in_dim(vr, j * kv_block, kv_block,
                                               axis=2)
            dp = jnp.einsum("bkgqh,bksh->bkgqs", do_i,
                            v_j.astype(jnp.float32))
            ds = p * (dp - dl_i[..., None]) * scale
            dq = jnp.einsum("bkgqs,bkhs->bkgqh", ds, k_j)
            return acc + dq, None

        acc0 = jnp.zeros((b, kv_heads, g, q_block, hd), jnp.float32)
        dq_i, _ = jax.lax.scan(jax.checkpoint(dq_step), acc0,
                               jnp.arange(lo, hi))
        dqs.append(dq_i.astype(qr.dtype))
    dq = jnp.concatenate(dqs, axis=3)

    # dk/dv: kv-block-major.
    dks, dvs = [], []
    for j in range(nkv):
        k_j = jax.lax.dynamic_slice_in_dim(kr, j * kv_block, kv_block,
                                           axis=3)
        v_j = jax.lax.dynamic_slice_in_dim(vr, j * kv_block, kv_block,
                                           axis=2)
        lo, hi = _q_range(j, nq, q_block, kv_block, causal, window)

        def dkv_step(carry, i, j=j, k_j=k_j, v_j=v_j):
            dk_acc, dv_acc = carry
            q_i = jax.lax.dynamic_slice_in_dim(qr, i * q_block, q_block,
                                               axis=3)
            do_i = jax.lax.dynamic_slice_in_dim(
                dout, i * q_block, q_block, axis=3).astype(jnp.float32)
            dl_i = jax.lax.dynamic_slice_in_dim(delta, i * q_block, q_block,
                                                axis=3)
            pos_q = i * q_block + jnp.arange(q_block)
            pos_k = j * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bkgqh,bkhs->bkgqs", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            s = jnp.where(_mask(pos_q, pos_k, causal, window), s, NEG_INF)
            lse_i = jax.lax.dynamic_slice_in_dim(lse, i * q_block, q_block,
                                                 axis=3)
            p = jnp.exp(s - lse_i[..., None])
            dv_acc = dv_acc + jnp.einsum("bkgqs,bkgqh->bksh", p, do_i)
            dp = jnp.einsum("bkgqh,bksh->bkgqs", do_i,
                            v_j.astype(jnp.float32))
            ds = p * (dp - dl_i[..., None]) * scale
            dk_acc = dk_acc + jnp.einsum("bkgqs,bkgqh->bkhs", ds,
                                         q_i.astype(jnp.float32))
            return (dk_acc, dv_acc), None

        dk0 = jnp.zeros((b, kv_heads, hd, kv_block), jnp.float32)
        dv0 = jnp.zeros((b, kv_heads, kv_block, hd), jnp.float32)
        (dk_j, dv_j), _ = jax.lax.scan(jax.checkpoint(dkv_step), (dk0, dv0),
                                       jnp.arange(lo, hi))
        dks.append(dk_j.astype(kr.dtype))
        dvs.append(dv_j.astype(vr.dtype))
    dk = jnp.concatenate(dks, axis=3)
    dv = jnp.concatenate(dvs, axis=2)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def attention(params, x, positions, *, rope_theta: float, qk_norm: bool,
              window: int = 0, q_block: int = 512,
              kv_block: int = 512, pad_heads: int = 0,
              ctx: ctx_lib.MeshContext | None = None) -> jax.Array:
    """Causal self-attention for train/prefill. x: [B, S, d].

    ``pad_heads``: pad query heads (and KV heads, preserving group
    structure) with zeros up to this count so the head axis divides the
    model mesh axis — ~(pad/H)x extra FLOPs instead of TP replication for
    head counts like arctic's 56.  Padded outputs are sliced off before
    the output projection, so the function is numerically unchanged."""
    q, k, v = _qkv(params, x, positions, rope_theta=rope_theta,
                   qk_norm=qk_norm)
    b, sq, h, hd = q.shape
    kv_heads = k.shape[2]
    g = g_orig = h // kv_heads
    if pad_heads > h:
        # Pad the per-group query-head dim (g) so KV heads are untouched:
        # 56 heads (g=7, kv=8) -> 64 (g=8).  Zero heads attend uniformly
        # to garbage that is sliced off below.
        g = -(-pad_heads // kv_heads)
        q = q.reshape(b, sq, kv_heads, g_orig, hd)
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, g - g_orig), (0, 0)))
        q = q.reshape(b, sq, kv_heads * g, hd)
        h = kv_heads * g
    q = ctx_lib.with_constraint(q, ("batch", None, "heads", None), ctx)
    k = ctx_lib.with_constraint(k, ("batch", None, "kv_heads", None), ctx)
    v = ctx_lib.with_constraint(v, ("batch", None, "kv_heads", None), ctx)
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sq)
    qr = jnp.moveaxis(q.reshape(b, sq, kv_heads, g, hd), 1, 3)
    kr = jnp.moveaxis(k, 1, 3)
    vr = jnp.moveaxis(v, 1, 2)
    o = flash_attention(qr, kr, vr, True, window, q_block, kv_block)
    o = o[:, :, :g_orig]                      # drop padded heads
    o = o.reshape(b, kv_heads * g_orig, sq, hd).transpose(0, 2, 1, 3)
    dt = x.dtype
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt),
                      preferred_element_type=jnp.float32).astype(dt)


def prefill_attention(params, x, positions, *, rope_theta: float,
                      qk_norm: bool, cache: dict, window: int = 0,
                      q_block: int = 512, kv_block: int = 512,
                      offset: int | None = None):
    """Prefill: causal attention that also fills the KV cache.

    Returns (y, new_cache).  Full caches take K/V at positions [0, S);
    ring-buffer (windowed) caches take the last ``window`` positions at
    their ``pos % window`` slots.

    ``offset`` (a *static* int) switches to chunked-prefill mode: the S
    tokens are the prompt slice at positions [offset, offset + S), their
    K/V is written into the cache at that range, and attention runs
    against the cached prefix [0, offset) concatenated with the chunk —
    so chunk N resumes exactly where chunk N-1's cache write ended.
    Sliding-window layers are unsupported (their ring buffers make the
    prefix slice ambiguous); the engine refuses chunking for them.
    """
    q, k, v = _qkv(params, x, positions, rope_theta=rope_theta,
                   qk_norm=qk_norm)
    s = x.shape[1]
    length = cache["k"].shape[1]
    kc, vc = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
    if offset is not None:
        if window != 0:
            raise ValueError(
                "chunked prefill is unsupported for sliding-window layers")
        off = int(offset)
        new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], kc, off,
                                                    axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], vc, off,
                                                    axis=1)
        # Attend over [cached prefix, this chunk]: the prefix holds the
        # previous chunks' K/V (cast back to compute dtype), the shifted
        # causal mask keeps each row at its absolute position.
        k_ctx = jnp.concatenate(
            [jax.lax.slice_in_dim(cache["k"], 0, off, axis=1)
             .astype(k.dtype), k], axis=1)
        v_ctx = jnp.concatenate(
            [jax.lax.slice_in_dim(cache["v"], 0, off, axis=1)
             .astype(v.dtype), v], axis=1)
        o = blockwise_attention(q, k_ctx, v_ctx, causal=True, window=0,
                                q_block=q_block, kv_block=kv_block,
                                q_offset=off)
        dt = x.dtype
        y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt),
                       preferred_element_type=jnp.float32).astype(dt)
        return y, {"k": new_k, "v": new_v}
    o = blockwise_attention(q, k, v, causal=True, window=window,
                            q_block=q_block, kv_block=kv_block)
    if window > 0 and s >= length:
        tail = jnp.arange(s - length, s)
        slots = tail % length
        new_k = cache["k"].at[:, slots].set(kc[:, tail])
        new_v = cache["v"].at[:, slots].set(vc[:, tail])
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], kc, 0,
                                                    axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], vc, 0,
                                                    axis=1)
    dt = x.dtype
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    return y, {"k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# Decode with KV cache (full or ring-buffer for sliding-window layers)
# ---------------------------------------------------------------------------

def init_cache_defs(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                    *, window: int = 0, dtype=jnp.bfloat16) -> dict:
    """Cache ParamDefs (zeros).  Sliding-window layers get a ring buffer."""
    length = min(window, max_len) if window > 0 else max_len
    shape = (batch, length, n_kv_heads, head_dim)
    axes = ("batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": ParamDef(shape, axes, init="zeros", dtype=dtype),
            "v": ParamDef(shape, axes, init="zeros", dtype=dtype)}


def decode_attention(params, x, cache, cur_index, *, rope_theta: float,
                     qk_norm: bool, window: int = 0) -> tuple[jax.Array, dict]:
    """One-token decode. x: [B, 1, d]; cur_index: scalar position, or a
    [B] vector of *per-sequence* positions (continuous-batching slots of
    mixed age each decode at their own offset).

    Returns (y [B,1,d], updated cache).  For windowed layers the cache is a
    ring buffer written at ``cur_index % window``.
    """
    b = x.shape[0]
    cur = jnp.broadcast_to(
        jnp.asarray(cur_index, jnp.int32).reshape(-1), (b,))       # [B]
    positions = cur[:, None]
    q, k_new, v_new = _qkv(params, x, positions, rope_theta=rope_theta,
                           qk_norm=qk_norm)
    length = cache["k"].shape[1]
    slot = cur % length if window > 0 else cur                     # [B]
    # One-hot blend instead of dynamic_update_slice: a DUS at a traced
    # offset on the sharded cache-sequence axis makes GSPMD all-gather the
    # whole cache per layer; the blend is shard-local (each shard compares
    # its own slot ids) and costs one select over data already streamed.
    hit = (jnp.arange(length)[None, :] == slot[:, None])[..., None, None]
    k = jnp.where(hit, k_new.astype(cache["k"].dtype), cache["k"])
    v = jnp.where(hit, v_new.astype(cache["v"].dtype), cache["v"])

    h, hd = q.shape[2], q.shape[3]
    kv_heads = k.shape[2]
    g = h // kv_heads
    qr = q.reshape(b, 1, kv_heads, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qr, k,
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    slots = jnp.arange(length)[None, :]                            # [1, S]
    if window > 0:
        # Ring buffer: after writing at `slot`, slot s holds absolute
        # position p = cur - slot + s - W*(s > slot), the latest p <= cur
        # with p % W == s.  All such p lie in (cur - W, cur]; a slot is
        # valid iff it has ever been written, i.e. p >= 0.
        abs_pos = (cur[:, None] - slot[:, None] + slots
                   - length * (slots > slot[:, None]))
        valid = abs_pos >= 0                                       # [B, S]
    else:
        valid = slots <= cur[:, None]                              # [B, S]
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, h, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, {"k": k, "v": v}
